"""Quickstart: the paper's running example (§3.2, Figures 4-6).

A 7-node graph split into two blocks; compute all degrees with one
workerCompute superstep; insert edge (4, 1) and maintain degrees with the
master's M2W directive — exactly the MSG1/MSG2 exchange of Figure 5.
Then the same graph goes through the k-core path twice: once via the
kernel backend registry (`repro.kernels.ops` dispatch) and once over the
distributed runtime's worker mesh, checking they agree bit-for-bit.

The `BlockProgram` section shows the framework claim: swapping the
workload is swapping the program object — connected components,
PageRank, and triangle counting all run through the same
`ops.run_block_program` fused superstep loop, on the same graph, with
the same backend dispatch (see ARCHITECTURE.md for the contract).

Finally the serving section (§4, ARCHITECTURE.md layer 5) opens a
`StreamSession` + `QueryServer` on the same graph and answers typed
queries against a versioned epoch snapshot while a stream window is
applied in between — reads interleaved with writes, answers exact.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    BladygEngine, ConnectedComponentsProgram, PageRankProgram,
    TriangleCountProgram, build_blocks, compute_degrees, coreness,
    coreness_via_spmd, insert_edge, maintain_degrees_insert)
from repro.core.degree import DegreeProgram
from repro.kernels import ops

# Figure 4's graph: nodes 1..7 (0-indexed below), two partitions
edges = np.array([
    [0, 1], [0, 2], [1, 2], [2, 3],      # partition 1 side
    [3, 4], [4, 5], [4, 6], [5, 6],      # partition 2 side
])
n = 7
assign = np.array([0, 0, 0, 0, 1, 1, 1])  # nodes 1-4 | 5-7 (paper's split)

g = build_blocks(edges, n, assign, P=2)
orig = np.asarray(g.orig_id)

print("== BLADYG degree example (paper §3.2) ==")
engine = BladygEngine(g)
deg, _ = engine.run(DegreeProgram(), None, None)
deg = jnp.where(g.node_mask, deg, 0)
for i in range(g.N):
    if orig[i] >= 0:
        print(f"  node {orig[i] + 1}: degree {int(deg[i])} "
              f"(block {i // g.Cn})")
print(f"  messages: {engine.message_totals()}")

# incremental change: insert edge (4, 1)  [paper's new edge]
u = int(np.flatnonzero(orig == 3)[0])   # node 4
v = int(np.flatnonzero(orig == 0)[0])   # node 1
print(f"\n== insert edge (4, 1) -> M2W to blocks {u // g.Cn} and {v // g.Cn} ==")
g2 = insert_edge(g, jnp.int32(u), jnp.int32(v))
deg2 = maintain_degrees_insert(deg, u, v)

# verify the maintained degrees equal recomputation (paper's Figure 6)
recomputed = compute_degrees(g2)
assert (np.asarray(deg2) == np.asarray(recomputed)).all()
for i in range(g2.N):
    if orig[i] >= 0 and int(deg2[i]) != int(deg[i]):
        print(f"  node {orig[i] + 1}: degree {int(deg[i])} -> {int(deg2[i])}")
print("  maintained degrees == recomputed degrees ✓")

# k-core through the kernel registry (backend="auto" resolves per
# platform/size) and again over the distributed runtime's worker mesh
resolved = ops.resolve_backend("auto", g2.N)
core = coreness(g2, backend="auto")
core_spmd, eng_spmd = coreness_via_spmd(g2)
assert (np.asarray(core) == np.asarray(core_spmd)).all()
print(f"\n== k-core: registry backend '{resolved}' vs runtime mesh "
      f"(W={eng_spmd.ex.wm.W}, fold B={eng_spmd.ex.wm.B}) ==")
for i in range(g2.N):
    if orig[i] >= 0:
        print(f"  node {orig[i] + 1}: coreness {int(core[i])}")
print(f"  executed W2W messages: {eng_spmd.message_totals()}")
print("  registry coreness == mesh coreness ✓")

# the BlockProgram API: one runner, any workload — swapping the workload
# is these five lines (each program also runs unchanged on "ell_spmd")
print("\n== BlockProgram workloads on the same graph/runner ==")
for prog in (ConnectedComponentsProgram(),
             PageRankProgram(tol=1e-8, max_steps=200),
             TriangleCountProgram()):
    state, steps = ops.run_block_program(
        g2, prog, backend="auto", with_steps=True)
    out = state if not isinstance(state, tuple) else state[0]
    print(f"  {type(prog).__name__}: {int(steps)} superstep(s), "
          f"out[:7] = {np.asarray(out)[np.asarray(g2.node_mask)][:7]}")

# sanity: the paper graph + edge (4, 1) is one component with 3 triangles
labels = ops.run_block_program(g2, ConnectedComponentsProgram())
assert int(jnp.sum(jnp.unique(jnp.where(g2.node_mask, labels, -1),
                              size=g2.N, fill_value=-1) >= 0)) == 1
tri, _ = ops.run_block_program(g2, TriangleCountProgram())
assert int(jnp.sum(tri) // 3) == 3, int(jnp.sum(tri) // 3)
print("  1 component, 3 triangles ✓")

# the serving layer (ARCHITECTURE.md layer 5): typed queries answered
# against versioned epoch snapshots, interleaved with stream windows
print("\n== query service: reads interleaved with stream writes ==")
import jax

from repro.core import connected_components
from repro.runtime import StreamSession
from repro.service import (
    QueryServer, ServiceConfig, core_of, same_component, topk_pagerank)

# the stream's apply path donates graph buffers, so the session gets its
# own clone of g2 (everything above keeps reading the original)
g3 = jax.tree.map(lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, g2)
sess = StreamSession(g3, jnp.copy(core), R=2, backend="jnp",
                     cc_labels=connected_components(g2))
srv = QueryServer(sess, config=ServiceConfig(pr_steps=10))

w = int(np.flatnonzero(orig == 6)[0])   # node 7
x = int(np.flatnonzero(orig == 1)[0])   # node 2
r1 = srv.submit(core_of(u))             # admitted now ...
r2 = srv.submit(same_component(v, w))
r3 = srv.submit(topk_pagerank(3))
answered = srv.step([(u, v, -1), (w, x, +1)])   # ... answered after the
# window (delete (4,1), insert (7,2)) lands and the snapshot refreshes
print(f"  window applied, {answered} queries answered at epoch {r1.epoch}")
print(f"  core(4) = {r1.answer}, same_component(1, 7) = {r2.answer}")
top_ids, _ = r3.answer
print(f"  top-3 PageRank nodes: {[int(orig[i]) + 1 for i in top_ids]}")

# exactness: the epoch-1 answers equal recompute on the post-window graph
assert r1.answer == int(coreness(sess.g, backend="jnp")[u])
lab = connected_components(sess.g, backend="jnp")
assert r2.answer == bool(lab[v] == lab[w])
s = srv.metrics.summary()
print(f"  answers == recompute on the post-window graph ✓ "
      f"(p50 {s['p50_ms']:.1f} ms, staleness {s['staleness_max']})")
