"""End-to-end driver: dynamic k-core maintenance on a DS1-shaped graph.

The full BLADYG pipeline of paper §4.1/§5.2.1:
  1. generate a Nearest-Neighbor synthetic graph (DS1 family),
  2. partition into 8 blocks (BFS edge-cut partitioner),
  3. static distributed coreness (min-H supersteps) through the kernel
     backend registry (`--backend jnp|dense|ell|ell_spmd|auto`),
  4. stream 200 mixed inter/intra insertions+deletions through the
     Theorem-1 maintenance path (per-update, or via the streaming router
     `repro.runtime.run_stream` with `--stream`),
  5. verify against recompute-from-scratch and report AIT/ADT + candidate
     statistics.

Run:  PYTHONPATH=src python examples/kcore_dynamic.py [--nodes 10000]
      [--backend ell_spmd --stream]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_blocks, coreness, insert_edge_maintain, delete_edge_maintain)
from repro.core.partition import node_bfs_partition
from repro.core.updates import sample_insertions, sample_deletions
from repro.graphgen import nearest_neighbor_graph
from repro.kernels import ops

ap = argparse.ArgumentParser()
ap.add_argument("--nodes", type=int, default=4000)
ap.add_argument("--updates", type=int, default=200)
ap.add_argument("--blocks", type=int, default=8)
ap.add_argument("--backend", default="auto",
                choices=list(ops.BACKENDS) + ["auto"],
                help="kernel registry backend; ell_spmd = worker mesh")
ap.add_argument("--stream", action="store_true",
                help="ingest updates through runtime.run_stream (block "
                     "routing + coordinator escalation) instead of the "
                     "per-update loop")
args = ap.parse_args()

print(f"== generating DS1-shaped graph ({args.nodes} nodes) ==")
edges = nearest_neighbor_graph(args.nodes, u=0.86, seed=7)
n = int(edges.max()) + 1
print(f"   n={n} m={len(edges)}")

print(f"== partitioning into {args.blocks} blocks (BFS edge-cut) ==")
assign = node_bfs_partition(edges, n, args.blocks, seed=1)
g = build_blocks(edges, n, assign, P=args.blocks, deg_slack=64)
print(f"   edge cut: {int(g.edge_cut())} / {g.m_real}")

print(f"== static distributed k-core decomposition "
      f"(backend={args.backend}) ==")
t0 = time.time()
core = coreness(g, backend=args.backend)
jax.block_until_ready(core)
print(f"   max coreness {int(jnp.max(core))} in {time.time() - t0:.2f}s "
      f"(resolved '{ops.resolve_backend(args.backend, g.N)}')")

print(f"== streaming {args.updates} updates through Theorem-1 maintenance ==")
q = args.updates // 4
ups = (sample_insertions(g, q, "inter", seed=2)
       + sample_insertions(g, q, "intra", seed=3)
       + sample_deletions(g, q, "inter", seed=4)
       + sample_deletions(g, q, "intra", seed=5))

if args.stream:
    from repro.runtime import run_stream

    t0 = time.time()
    res = run_stream(g, core, ups, R=8, backend=args.backend
                     if args.backend != "auto" else "jnp")
    g, core, st = res.g, res.core, res.stats
    jax.block_until_ready(core)
    dt = time.time() - t0
    print(f"   {st.updates} updates in {dt:.2f}s: "
          f"{st.block_local} block-local, {st.escalated} escalated "
          f"(cross={st.escalated_cross_block} spill={st.escalated_spill} "
          f"conflict={st.escalated_conflict}), "
          f"{st.bfs_steps} BFS + {st.recompute_steps} recompute supersteps")
else:
    # the per-update maintenance loop supports the single-device backends
    per_update_backend = ops.resolve_backend(
        args.backend if args.backend != "ell_spmd" else "jnp", g.N)
    lat, cands, blocks_touched = [], [], []
    for u, v, op in ups:
        fn = insert_edge_maintain if op > 0 else delete_edge_maintain
        t0 = time.time()
        g, core, st = fn(g, core, jnp.int32(u), jnp.int32(v),
                         backend=per_update_backend)
        jax.block_until_ready(core)
        lat.append(time.time() - t0)
        cands.append(int(st.candidates))
        blocks_touched.append(int(st.blocks_touched))

    print(f"   mean latency {np.mean(lat[2:]) * 1e3:.1f} ms  "
          f"mean candidates {np.mean(cands):.0f}/{n}  "
          f"mean blocks touched {np.mean(blocks_touched):.1f}/{args.blocks}")

print("== verifying against recompute-from-scratch ==")
ref = coreness(g, backend="jnp")
assert (np.asarray(ref) == np.asarray(core)).all()
print("   maintained coreness == recomputed coreness ✓")
