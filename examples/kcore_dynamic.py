"""End-to-end driver: dynamic k-core maintenance on a DS1-shaped graph.

The full BLADYG pipeline of paper §4.1/§5.2.1:
  1. generate a Nearest-Neighbor synthetic graph (DS1 family),
  2. partition into 8 blocks (BFS edge-cut partitioner),
  3. static distributed coreness (min-H supersteps),
  4. stream 200 mixed inter/intra insertions+deletions through the
     Theorem-1 maintenance path,
  5. verify against recompute-from-scratch and report AIT/ADT + candidate
     statistics.

Run:  PYTHONPATH=src python examples/kcore_dynamic.py [--nodes 10000]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_blocks, coreness, insert_edge_maintain, delete_edge_maintain)
from repro.core.partition import node_bfs_partition
from repro.core.updates import sample_insertions, sample_deletions
from repro.graphgen import nearest_neighbor_graph

ap = argparse.ArgumentParser()
ap.add_argument("--nodes", type=int, default=4000)
ap.add_argument("--updates", type=int, default=200)
ap.add_argument("--blocks", type=int, default=8)
args = ap.parse_args()

print(f"== generating DS1-shaped graph ({args.nodes} nodes) ==")
edges = nearest_neighbor_graph(args.nodes, u=0.86, seed=7)
n = int(edges.max()) + 1
print(f"   n={n} m={len(edges)}")

print(f"== partitioning into {args.blocks} blocks (BFS edge-cut) ==")
assign = node_bfs_partition(edges, n, args.blocks, seed=1)
g = build_blocks(edges, n, assign, P=args.blocks, deg_slack=64)
print(f"   edge cut: {int(g.edge_cut())} / {g.m_real}")

print("== static distributed k-core decomposition ==")
t0 = time.time()
core = coreness(g)
jax.block_until_ready(core)
print(f"   max coreness {int(jnp.max(core))} in {time.time() - t0:.2f}s")

print(f"== streaming {args.updates} updates through Theorem-1 maintenance ==")
q = args.updates // 4
ups = (sample_insertions(g, q, "inter", seed=2)
       + sample_insertions(g, q, "intra", seed=3)
       + sample_deletions(g, q, "inter", seed=4)
       + sample_deletions(g, q, "intra", seed=5))
lat, cands, blocks_touched = [], [], []
for u, v, op in ups:
    fn = insert_edge_maintain if op > 0 else delete_edge_maintain
    t0 = time.time()
    g, core, st = fn(g, core, jnp.int32(u), jnp.int32(v))
    jax.block_until_ready(core)
    lat.append(time.time() - t0)
    cands.append(int(st.candidates))
    blocks_touched.append(int(st.blocks_touched))

print(f"   mean latency {np.mean(lat[2:]) * 1e3:.1f} ms  "
      f"mean candidates {np.mean(cands):.0f}/{n}  "
      f"mean blocks touched {np.mean(blocks_touched):.1f}/{args.blocks}")

print("== verifying against recompute-from-scratch ==")
ref = coreness(g)
assert (np.asarray(ref) == np.asarray(core)).all()
print("   maintained coreness == recomputed coreness ✓")
