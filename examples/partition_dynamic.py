"""Dynamic edge partitioning (paper §4.2 / Tables 3-5 protocol).

Partition 90% of a graph with DFEP, stream the remaining 10% through
UB-UPDATE (IncrementalPart) and compare against NaivePart.

Run:  PYTHONPATH=src python examples/partition_dynamic.py [--method dfep]
"""
import argparse

import numpy as np

from repro.core.partition import edge_balance
from repro.core.partition_dynamic import (
    initial_partition, incremental_part, naive_part, delete_edges)
from repro.graphgen import snap_like

ap = argparse.ArgumentParser()
ap.add_argument("--method", default="dfep",
                choices=["hash", "random", "dfep", "vertex_cut"])
ap.add_argument("--dataset", default="ego-Facebook")
ap.add_argument("--scale", type=float, default=0.4)
args = ap.parse_args()

edges = snap_like(args.dataset, scale=args.scale, seed=0)
n = int(edges.max()) + 1
rng = np.random.default_rng(0)
perm = rng.permutation(len(edges))
cut = int(0.9 * len(edges))
base, delta = edges[perm[:cut]], edges[perm[cut:]]
print(f"== {args.dataset} (scale {args.scale}): n={n} m={len(edges)}, "
      f"method={args.method} ==")

st0, pt = initial_partition(base, n, 8, args.method, seed=0)
print(f"partitioning time (90%):   {pt:.3f}s  "
      f"balance={edge_balance(st0.owner, 8):.2f}")

st_inc, ut_inc = incremental_part(st0, delta)
print(f"IncrementalPart (10%):     {ut_inc:.4f}s  "
      f"balance={edge_balance(st_inc.owner, 8):.2f}")

st_nv, ut_nv = naive_part(st0, delta)
print(f"NaivePart (full redo):     {ut_nv:.4f}s  "
      f"balance={edge_balance(st_nv.owner, 8):.2f}")
print(f"speedup incremental vs naive: {ut_nv / max(ut_inc, 1e-9):.1f}x")

# deletion protocol with repartition threshold
st2, repart, ut_del = delete_edges(st_inc, np.arange(50), threshold=1.5)
print(f"deletion of 50 edges:      {ut_del:.4f}s  repartitioned={repart}")
