"""Serve a small LM with batched requests: block prefill + batched decode.

Demonstrates the serving path the decode_32k/long_500k dry-run cells lower:
a batch of prompts is prefilled into the KV cache in one shot, then decoded
token-by-token (greedy) — prefix-LM and MQA archs included.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch internlm2-1.8b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="internlm2-1.8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))
B, Sp, G = args.batch, args.prompt_len, args.gen
max_seq = Sp + G

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, Sp)), jnp.int32)

print(f"== serving {cfg.name} (reduced): batch={B} prompt={Sp} gen={G} ==")
caches = bundle.cache_init(B, max_seq)

# block prefill into the cache (attention archs; SSM archs decode from 0)
decode = jax.jit(bundle.decode_fn)
t0 = time.time()
if cfg.mixer == "mamba":
    # SSM path: stream the prompt token by token (conv+state carry)
    logits = None
    for t in range(Sp):
        logits, caches = decode(params, prompts[:, t:t + 1], caches,
                                jnp.int32(t))
else:
    logits, caches = bundle.decode_fn(params, prompts, caches, jnp.int32(0))
    logits = logits[:, -1:]
jax.block_until_ready(logits)
print(f"prefill: {time.time() - t0:.2f}s")

tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
out = [tokens]
t0 = time.time()
for t in range(Sp, Sp + G - 1):
    logits, caches = decode(params, tokens, caches, jnp.int32(t))
    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out.append(tokens)
jax.block_until_ready(tokens)
dt = time.time() - t0
gen = jnp.concatenate(out, axis=1)
print(f"decode:  {G - 1} steps x {B} seqs in {dt:.2f}s "
      f"({(G - 1) * B / dt:.1f} tok/s on CPU)")
print("generated token ids (first sequence):", np.asarray(gen[0]))
