"""Train an assigned-architecture LM end to end (reduced scale on CPU).

Uses the production launcher (mesh, sharding, AdamW, checkpointing, fault
handling) — the same path that runs the full configs on pods.

Run (CPU demo, ~1 min):
  PYTHONPATH=src python examples/train_lm.py

Pod-scale equivalent (for reference; requires TPU):
  python -m repro.launch.train --arch gemma3-1b --mesh single \
      --steps 300 --batch 256 --seq 4096 --ckpt-dir gs://... --resume auto
"""
import sys

from repro.launch.train import main

sys.exit(main([
    "--arch", "gemma3-1b",
    "--reduced",
    "--steps", "30",
    "--batch", "8",
    "--seq", "128",
    "--ckpt-dir", "/tmp/repro_train_lm",
    "--ckpt-every", "10",
    "--resume", "auto",
    "--log-every", "5",
]))
