"""Simple reference generators: ER, BA, and grid-like (roadNet-shaped)."""
from __future__ import annotations

import numpy as np


def erdos_renyi(n: int, m: int, seed: int = 0) -> np.ndarray:
    """G(n, m): m distinct uniform edges (no loops).

    Vectorized rejection sampling: draw endpoint batches sized to the
    remaining deficit, key each undirected pair as lo*n+hi, np.unique
    the accumulated keys, and top up until m distinct edges exist; a
    final permutation picks the m returned.  O(m) numpy work replaces
    the old one-edge-at-a-time Python set loop (same fix shape as
    PR 3's build_ell_random).  The edge *instance* for a given seed
    differs from the pre-vectorization generator (PR-3 precedent: all
    in-repo consumers derive oracles from the returned edge list, none
    pin instances).
    """
    if n < 2:
        raise ValueError(f"erdos_renyi needs n >= 2, got {n}")
    if m > n * (n - 1) // 2:
        raise ValueError(f"m={m} exceeds the {n * (n - 1) // 2} distinct "
                         f"pairs on n={n} nodes")
    rng = np.random.default_rng(seed)
    keys = np.empty(0, np.int64)
    while keys.size < m:
        batch = max(2 * (m - keys.size) + 16, 256)
        a = rng.integers(0, n, size=batch)
        b = rng.integers(0, n, size=batch)
        ok = a != b
        lo = np.minimum(a, b)[ok].astype(np.int64)
        hi = np.maximum(a, b)[ok].astype(np.int64)
        keys = np.unique(np.concatenate([keys, lo * n + hi]))
    keys = rng.permutation(keys)[:m]
    return np.stack([keys // n, keys % n], 1)


def barabasi_albert(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Preferential attachment, k edges per new node.

    Vectorized Batagelj-Brandes: the sequential algorithm appends both
    endpoints of every accepted edge to a "repeated nodes" array and
    picks each new target uniformly from it (a node's slot count IS its
    degree — that is preferential attachment).  Here the array is never
    materialized sequentially: lay out the endpoint sequence as
    k seed slots + (source, target) pairs, draw every target's slot
    index r_t uniformly over the prefix [0, k + 2t) up front, then
    resolve targets with iterated gathers — a slot that lands on an
    earlier *target* slot chases that slot's own draw (indices strictly
    decrease, so expected O(log nk) full-vector rounds), while seed and
    source slots resolve to known node ids immediately.  Self-loops and
    duplicate pairs are dropped afterwards, matching the old
    generator's simple-graph contract: hubs at early node ids, max
    degree ~k*sqrt(n), mean just under 2k.  The edge *instance* for a
    given seed differs from the pre-vectorization Python loop (PR-3
    precedent: consumers derive oracles from the returned list, none
    pin instances).
    """
    if not 0 < k < n:
        raise ValueError(f"barabasi_albert needs 0 < k < n, got {k=} {n=}")
    rng = np.random.default_rng(seed)
    M = (n - k) * k  # k attachments per node after the k seed nodes
    t = np.arange(M, dtype=np.int64)
    src = k + t // k
    r = rng.integers(0, k + 2 * t)  # target slot: uniform over the prefix
    p = r.copy()
    while True:
        odd = (p >= k) & ((p - k) % 2 == 1)  # landed on a target slot
        if not odd.any():
            break
        p[odd] = r[(p[odd] - k - 1) // 2]
    tgt = np.where(p < k, p, src[np.maximum(p - k, 0) // 2])
    lo = np.minimum(src, tgt)
    hi = np.maximum(src, tgt)
    e = np.stack([lo, hi], 1)[lo != hi]
    return np.unique(e, axis=0)


def grid_like(n: int, seed: int = 0, diag_frac: float = 0.05) -> np.ndarray:
    """Planar-ish lattice with sparse diagonals — roadNet shape: huge
    diameter, tiny clustering, max degree ~4."""
    side = int(np.ceil(np.sqrt(n)))
    rng = np.random.default_rng(seed)
    xs, ys = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    idx = (xs * side + ys).reshape(-1)
    keep = idx < n
    edges = []
    right = (xs * side + (ys + 1)).reshape(-1)
    ok = (ys + 1 < side).reshape(-1) & keep & (right < n)
    edges.append(np.stack([idx[ok], right[ok]], 1))
    down = ((xs + 1) * side + ys).reshape(-1)
    ok = (xs + 1 < side).reshape(-1) & keep & (down < n)
    edges.append(np.stack([idx[ok], down[ok]], 1))
    e = np.concatenate(edges)
    # sparse random diagonals
    extra = int(diag_frac * len(e))
    if extra:
        a = rng.integers(0, n, size=extra)
        b = np.clip(a + side + 1, 0, n - 1)
        ok = a != b
        e = np.concatenate([e, np.stack([a[ok], b[ok]], 1)])
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    return np.unique(np.stack([lo, hi], 1), axis=0)
