"""Simple reference generators: ER, BA, and grid-like (roadNet-shaped)."""
from __future__ import annotations

import numpy as np


def erdos_renyi(n: int, m: int, seed: int = 0) -> np.ndarray:
    """G(n, m): m distinct uniform edges (no loops)."""
    rng = np.random.default_rng(seed)
    seen = set()
    out = []
    while len(out) < m:
        a, b = rng.integers(0, n, size=2)
        if a == b:
            continue
        key = (min(a, b), max(a, b))
        if key in seen:
            continue
        seen.add(key)
        out.append(key)
    return np.asarray(out, dtype=np.int64)


def barabasi_albert(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Preferential attachment, k edges per new node."""
    rng = np.random.default_rng(seed)
    targets = list(range(k))
    repeated: list[int] = []
    edges = []
    for v in range(k, n):
        chosen = set()
        for t in targets:
            if t not in chosen:
                chosen.add(t)
                edges.append((v, t))
        repeated.extend(chosen)
        repeated.extend([v] * len(chosen))
        # next targets: preferential sample
        targets = [repeated[rng.integers(len(repeated))] for _ in range(k)]
    e = np.asarray(edges, dtype=np.int64)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    return np.unique(np.stack([lo, hi], 1), axis=0)


def grid_like(n: int, seed: int = 0, diag_frac: float = 0.05) -> np.ndarray:
    """Planar-ish lattice with sparse diagonals — roadNet shape: huge
    diameter, tiny clustering, max degree ~4."""
    side = int(np.ceil(np.sqrt(n)))
    rng = np.random.default_rng(seed)
    xs, ys = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    idx = (xs * side + ys).reshape(-1)
    keep = idx < n
    edges = []
    right = (xs * side + (ys + 1)).reshape(-1)
    ok = (ys + 1 < side).reshape(-1) & keep & (right < n)
    edges.append(np.stack([idx[ok], right[ok]], 1))
    down = ((xs + 1) * side + ys).reshape(-1)
    ok = (xs + 1 < side).reshape(-1) & keep & (down < n)
    edges.append(np.stack([idx[ok], down[ok]], 1))
    e = np.concatenate(edges)
    # sparse random diagonals
    extra = int(diag_frac * len(e))
    if extra:
        a = rng.integers(0, n, size=extra)
        b = np.clip(a + side + 1, 0, n - 1)
        ok = a != b
        e = np.concatenate([e, np.stack([a[ok], b[ok]], 1)])
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    return np.unique(np.stack([lo, hi], 1), axis=0)
