"""Nearest-Neighbor synthetic graph model [Sala et al., WWW'10].

Growth process with connection probability u:
  * with prob (1-u): add a new node and connect it to a uniformly random
    existing node;
  * with prob u: pick a random node and connect a random pair of its
    neighbors' *2-hop* endpoints (i.e. connect two random nodes at distance
    2), creating a triangle.

This yields the high clustering / heavy-tail degree shape the paper's DS1 and
DS2 exhibit; u controls density: edges-per-node ≈ 1 / (1 - u).
"""
from __future__ import annotations

import numpy as np


def nearest_neighbor_graph(
    n: int, u: float = 0.86, seed: int = 0
) -> np.ndarray:
    """Grow until `n` nodes; returns (m, 2) unique undirected edge list."""
    rng = np.random.default_rng(seed)
    adj = [set() for _ in range(n)]
    edges = []

    def add_edge(a: int, b: int):
        if a != b and b not in adj[a]:
            adj[a].add(b)
            adj[b].add(a)
            edges.append((a, b))

    # seed triangle
    add_edge(0, 1)
    add_edge(1, 2)
    add_edge(0, 2)
    alive = 3
    while alive < n:
        if rng.random() < u and alive > 3:
            # close a random 2-hop pair
            a = int(rng.integers(alive))
            if adj[a]:
                nb = list(adj[a])
                if len(nb) >= 2:
                    i, j = rng.choice(len(nb), size=2, replace=False)
                    add_edge(nb[i], nb[j])
                    continue
            # fall through when no pair available
        b = int(rng.integers(alive))
        add_edge(alive, b)
        alive += 1
    return np.asarray(edges, dtype=np.int64)
