"""SNAP-shaped stand-in graphs (offline substitutes for Table 1 datasets).

Each entry reproduces the *shape* of the paper's dataset (node count, edge
count, clustering regime) with a deterministic generator; `scale` shrinks
node counts proportionally for CI (the paper-scale graph is `scale=1.0`).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from .nn_model import nearest_neighbor_graph
from .simple import barabasi_albert, grid_like

# name -> (n, m, generator kind) from paper Table 1
DATASETS: Dict[str, Tuple[int, int, str]] = {
    "DS1": (50_000, 365_883, "nn"),
    "DS2": (100_000, 734_416, "nn"),
    "ego-Facebook": (4_039, 88_234, "ba-dense"),
    "roadNet-CA": (1_965_206, 2_766_607, "grid"),
    "com-LiveJournal": (3_997_962, 34_681_189, "nn-dense"),
}


def snap_like(name: str, scale: float = 1.0, seed: int = 0) -> np.ndarray:
    """Generate a stand-in for the named paper dataset at `scale`."""
    n_full, m_full, kind = DATASETS[name]
    n = max(64, int(n_full * scale))
    target_ratio = m_full / n_full  # edges per node
    if kind == "nn":
        u = 1.0 - 1.0 / target_ratio
        return nearest_neighbor_graph(n, u=u, seed=seed)
    if kind == "nn-dense":
        u = 1.0 - 1.0 / target_ratio
        return nearest_neighbor_graph(n, u=min(0.93, u), seed=seed)
    if kind == "ba-dense":
        k = max(2, int(round(target_ratio)))
        return barabasi_albert(n, k, seed=seed)
    if kind == "grid":
        return grid_like(n, seed=seed)
    raise ValueError(kind)
