"""Synthetic graph generators (the paper's data sources, offline).

The paper uses (a) synthetic graphs from the Nearest-Neighbor model
[Sala et al., WWW'10] (DS1/DS2) and (b) SNAP real graphs.  SNAP data is not
redistributable offline, so `snap_like` generates size/degree/clustering
matched stand-ins; the substitution is recorded in EXPERIMENTS.md.
"""
from .nn_model import nearest_neighbor_graph
from .simple import erdos_renyi, barabasi_albert, grid_like
from .snap_like import snap_like, DATASETS

__all__ = [
    "nearest_neighbor_graph", "erdos_renyi", "barabasi_albert",
    "grid_like", "snap_like", "DATASETS",
]
