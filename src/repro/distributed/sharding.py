"""Sharding rules: parameter / optimizer / activation / cache layouts.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Batch (and sequence, for serve shapes) shards over the
data-parallel axes; weights shard over ``model`` (TP/EP); optimizer state is
additionally ZeRO-sharded over ``data``.

Rules are *name-anchored on the trailing dimensions* of each leaf, so the
same rule covers a plain layer and its scan-stacked (L, ...) or
(periods, p, ...) variants.  Every rule degrades to replication when the
dimension is not divisible by the axis size — a config can therefore never
fail to shard, it only loses parallelism (and the dry-run roofline makes
that visible).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


# (suffix, trailing-ndim, trailing spec) — first match wins.
# 'M' = model axis, None = replicated.
_RULES: Tuple[Tuple[str, int, Tuple], ...] = (
    ("embed/w", 2, ("M", None)),
    ("lm_head/w", 2, (None, "M")),
    ("prefix_proj/w", 2, (None, "M")),
    ("router/w", 2, (None, None)),
    ("w_gate/w", 3, ("M", None, None)),     # experts on EP axis
    ("w_up/w", 3, ("M", None, None)),
    ("w_down/w", 3, ("M", None, None)),
    ("gate/w", 2, (None, "M")),
    ("up/w", 2, (None, "M")),
    ("down/w", 2, ("M", None)),
    ("wq_a/w", 2, (None, "M")),
    ("wq_b/w", 2, (None, "M")),
    ("wkv_a/w", 2, (None, None)),           # small latent proj, replicated
    ("wkv_b/w", 2, (None, "M")),
    ("wq/w", 2, (None, "M")),
    ("wk/w", 2, (None, "M")),
    ("wv/w", 2, (None, "M")),
    ("wo/w", 2, ("M", None)),
    ("in_proj/w", 2, (None, "M")),
    ("out_proj/w", 2, ("M", None)),
    ("conv_w", 2, (None, "M")),
    ("conv_b", 1, ("M",)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _resolve(spec: Sequence, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Map 'M' -> 'model' with divisibility check; pad leading dims."""
    tp = _axis_size(mesh, "model")
    trailing = []
    for dim, s in zip(shape[len(shape) - len(spec):], spec):
        if s == "M" and tp > 1 and dim % tp == 0:
            trailing.append("model")
        else:
            trailing.append(None)
    lead = [None] * (len(shape) - len(spec))
    return P(*(lead + trailing))


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    for suffix, nd, spec in _RULES:
        if path.endswith(suffix) and len(shape) >= nd:
            return _resolve(spec, shape, mesh)
    return P()  # norms, scalars, biases: replicated


def param_shardings(params_shapes: Params, mesh: Mesh) -> Params:
    """Pytree of NamedSharding for a pytree of ShapeDtypeStruct/arrays."""
    def f(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def zero_spec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO: additionally shard the first replicated dim over 'data'."""
    dp = _axis_size(mesh, "data")
    if dp <= 1:
        return pspec
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % dp == 0 and dim >= dp:
            spec[i] = "data"
            return P(*spec)
    return P(*spec)


def opt_shardings(opt_shapes, params_shapes, mesh: Mesh):
    """AdamWState shardings: master/m/v get param spec + ZeRO over data."""
    pshard = {}

    def record(path, leaf):
        ps = param_spec(_path_str(path), leaf.shape, mesh)
        return NamedSharding(mesh, zero_spec(ps, leaf.shape, mesh))

    def for_tree(tree):
        return jax.tree_util.tree_map_with_path(record, tree)

    import repro.optim.adamw as adamw
    return adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        master=for_tree(opt_shapes.master),
        m=for_tree(opt_shapes.m),
        v=for_tree(opt_shapes.v),
    )


# ---------------------------------------------------------------------------
# activations / batch / caches
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in dp_axes(mesh)]))


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Shard leading batch dim over the dp axes if divisible."""
    if batch % dp_size(mesh) == 0:
        return P(dp_axes(mesh), *([None] * extra_dims))
    return P(*([None] * (1 + extra_dims)))


def cache_sharding(mesh: Mesh, shape: Tuple[int, ...], kind: str) -> NamedSharding:
    """KV / state cache layout.

    kind 'kv':      (L, B, S, Hkv, hd)  — B over dp; else S over model(+dp)
    kind 'mla':     (L, B, S, r)        — B over dp; else S over model(+dp)
    kind 'ssm':     (L, B, H, P, N)     — B over dp; H over model
    kind 'conv':    (L, B, W, C)        — B over dp; C over model
    Leading extra dims (period stacking) are replicated.
    """
    dp = dp_size(mesh)
    tp = _axis_size(mesh, "model")
    nd = len(shape)
    spec = [None] * nd

    def core_dims(n):  # index of the trailing n dims
        return list(range(nd - n, nd))

    if kind in ("kv", "mla"):
        n = 5 if kind == "kv" else 4
        li, bi, si = core_dims(n)[0:3]
        if shape[bi] % dp == 0 and shape[bi] >= dp:
            spec[bi] = dp_axes(mesh)
            if kind == "kv" and shape[nd - 2] % tp == 0 and shape[nd - 2] >= tp:
                spec[nd - 2] = "model"  # kv heads over model when divisible
        else:
            axes = dp_axes(mesh) + ("model",)
            total = dp * tp
            if shape[si] % total == 0:
                spec[si] = axes
            elif shape[si] % tp == 0:
                spec[si] = "model"
    elif kind == "ssm":
        li, bi, hi, pi, ni = core_dims(5)
        if shape[bi] % dp == 0 and shape[bi] >= dp:
            spec[bi] = dp_axes(mesh)
        if shape[hi] % tp == 0 and shape[hi] >= tp:
            spec[hi] = "model"
    elif kind == "conv":
        li, bi, wi, ci = core_dims(4)
        if shape[bi] % dp == 0 and shape[bi] >= dp:
            spec[bi] = dp_axes(mesh)
        if shape[ci] % tp == 0:
            spec[ci] = "model"
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cache_shapes, mesh: Mesh):
    """Walk a cache pytree, classify each leaf by its key name."""
    def f(path, leaf):
        name = _path_str(path)
        last = name.rsplit("/", 1)[-1]
        if last in ("k", "v"):
            return cache_sharding(mesh, leaf.shape, "kv")
        if last in ("ckv", "krope"):
            return cache_sharding(mesh, leaf.shape, "mla")
        if last == "state":
            return cache_sharding(mesh, leaf.shape, "ssm")
        if last == "conv":
            return cache_sharding(mesh, leaf.shape, "conv")
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(f, cache_shapes)
