"""Distributed substrate: sharding specs (live) + fault harness (seed).

``sharding`` is reachable from the product surface (the checkpoint and
elastic layers name it), so the `dead-seed` audit never flags it.

seed_fixtures: ``fault`` is quarantined seed substrate — the
fault-injection harness for the LLM training loop, never imported by
the BLADYG product packages.  The `dead-seed` audit
(`python -m repro.analysis`) accepts this marker.

Marker-only package ``__init__``: importing it must stay side-effect
free (no submodule imports).
"""
