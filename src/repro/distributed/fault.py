"""Fault detection & straggler mitigation — the run-controller side.

On real pods, failure manifests as (a) a NCCL/ICI collective timeout,
(b) a missed heartbeat from a host, or (c) a SIGTERM from the platform.
This module gives the training loop a small, testable state machine around
those events; the CPU test-suite simulates failures by raising
`SimulatedFailure` from a step callback.

Policy implemented (see DESIGN.md §Fault tolerance):

  * heartbeat file per host, bumped every step; the controller marks a host
    dead after `timeout_steps` without progress;
  * on failure: abort the step, flush the last async checkpoint, exit with
    code 42 — the launcher interprets 42 as "restart me" and re-execs with
    ``--resume auto`` (possibly on a smaller mesh -> checkpoint/elastic.py);
  * stragglers: per-step wall-time EWMA; a step slower than
    `straggler_factor` × EWMA raises a `StragglerWarning` so the controller
    can pre-emptively drain the slow host (on TPU pods the usual cause is a
    degraded ICI link or a thermally-throttled chip).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Optional

RESTART_EXIT_CODE = 42


class SimulatedFailure(RuntimeError):
    """Raised by tests/chaos hooks to simulate a node loss mid-run."""


class StragglerWarning(RuntimeWarning):
    pass


@dataclasses.dataclass
class Heartbeat:
    path: str
    host: int = 0

    def beat(self, step: int):
        p = Path(self.path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps({"host": self.host, "step": step,
                                 "t": time.time()}))

    def last(self) -> Optional[dict]:
        p = Path(self.path)
        if not p.exists():
            return None
        return json.loads(p.read_text())


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    alpha: float = 0.1
    _ewma: float = 0.0
    _n: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        if self._n >= 5 and step_seconds > self.factor * self._ewma:
            return True
        self._ewma = (step_seconds if self._n == 0
                      else (1 - self.alpha) * self._ewma + self.alpha * step_seconds)
        self._n += 1
        return False
