"""Mixture-of-Experts layer: top-k router + capacity-bounded dispatch.

Two execution paths with identical semantics (cross-validated in tests):

  * `dense`    — every expert processes every token, gates mask the output.
    O(T·E·d·f); exact; used by tiny smoke configs and as the oracle.
  * `capacity` — sort-based dispatch: tokens sorted by expert, each expert
    processes a static-capacity tile (E, C, d) via batched matmul; overflow
    tokens are dropped (standard capacity-factor semantics).  This is the
    sharded production path: expert weights are laid out (E, ...) so the EP
    mesh axis shards dim 0, and XLA turns the gather/scatter into
    all-to-alls on the `model` axis.

BLADYG connection (DESIGN §4): experts = blocks, token→expert assignments =
edges; the capacity path is the "incremental" assignment (only overflow
tokens are re-routed/dropped), vs. re-dispatching everything.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, swiglu_init, swiglu

Params = Dict[str, Any]


def init_moe(key, cfg, dtype, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.moe_d_ff
    E = cfg.n_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = 1.0 / jnp.sqrt(d)
    p: Params = {
        "router": init_linear(k1, d, E, jnp.float32),  # router in f32
        "w_gate": {"w": (jax.random.normal(k2, (E, d, f), jnp.float32) * std).astype(dtype)},
        "w_up": {"w": (jax.random.normal(k3, (E, d, f), jnp.float32) * std).astype(dtype)},
        "w_down": {"w": (jax.random.normal(k4, (E, f, d), jnp.float32) / jnp.sqrt(f)).astype(dtype)},
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(k5, d, f * cfg.n_shared_experts, dtype)
    return p


def _router(p: Params, cfg, x2d: jax.Array):
    """Returns (top-k weights (T,k), top-k expert ids (T,k), aux losses)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)  # renormalize
    # load-balance aux (Switch-style) + router z-loss
    T, E = probs.shape
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=1), axis=0
    )
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return topv, topi, lb + 1e-3 * z


def _expert_ffn(p: Params, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d) via per-expert SwiGLU (batched matmul)."""
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]["w"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"]["w"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"]["w"])


def moe_dense(p: Params, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Oracle path: all experts on all tokens."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    topv, topi, aux = _router(p, cfg, x2)
    E = cfg.n_experts
    xe = jnp.broadcast_to(x2[None], (E, x2.shape[0], d))
    ye = _expert_ffn(p, xe)                          # (E, T, d)
    gates = jnp.zeros((x2.shape[0], E), x.dtype)
    gates = gates.at[jnp.arange(x2.shape[0])[:, None], topi].set(topv.astype(x.dtype))
    y = jnp.einsum("te,etd->td", gates, ye)
    if "shared" in p:
        y = y + swiglu(p["shared"], x2)
    return y.reshape(B, S, d), aux


def moe_capacity(
    p: Params, cfg, x: jax.Array, capacity: Optional[int] = None
) -> Tuple[jax.Array, jax.Array]:
    """Production path: sort-based capacity dispatch."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    C = capacity or max(1, int(cfg.capacity_factor * T * k / E))

    topv, topi, aux = _router(p, cfg, x2)

    flat_e = topi.reshape(-1)                         # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e)                       # stable in jax
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position within expert group
    seg_start = jnp.searchsorted(se, jnp.arange(E))   # (E,)
    pos_in_e = jnp.arange(T * k) - seg_start[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)  # overflow -> scratch slot

    buf_t = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(st.astype(jnp.int32))
    buf_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sw)
    buf_t, buf_w = buf_t[:-1], buf_w[:-1]

    x_pad = jnp.concatenate([x2, jnp.zeros((1, d), x2.dtype)], axis=0)
    xe = x_pad[buf_t].reshape(E, C, d)
    ye = _expert_ffn(p, xe).reshape(E * C, d)

    y = jnp.zeros((T + 1, d), jnp.float32)
    y = y.at[buf_t].add(ye.astype(jnp.float32) * buf_w[:, None])
    y = y[:T].astype(x.dtype)
    if "shared" in p:
        y = y + swiglu(p["shared"], x2)
    return y.reshape(B, S, d), aux
