"""Multi-head Latent Attention (DeepSeek-V3) — latent-compressed KV.

Two decode paths:
  * naive    — expand K/V from the cached latent every step (baseline).
  * absorbed — fold W^UK into the query and W^UV into the output projection
    so attention runs directly in latent space; the per-step expansion cost
    S·r·H·(d_nope+d_v) drops to r·H·(d_nope+d_v) (+S·r per head) — the
    technique-representative hillclimb in EXPERIMENTS §Perf.

Cache stores only (c_kv: (B,S,r), k_rope: (B,S,d_rope)) — the MLA memory win.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, init_rmsnorm, rmsnorm, apply_rope

Params = Dict[str, Any]


def init_mla(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_linear(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": init_rmsnorm(cfg.q_lora_rank, dtype),
        "wq_b": init_linear(ks[1], cfg.q_lora_rank, H * (dn + dr), dtype),
        "wkv_a": init_linear(ks[2], d, cfg.kv_lora_rank + dr, dtype),
        "kv_norm": init_rmsnorm(cfg.kv_lora_rank, dtype),
        "wkv_b": init_linear(ks[3], cfg.kv_lora_rank, H * (dn + dv), dtype),
        "wo": init_linear(ks[4], H * dv, d, dtype),
    }


def _project_q(p, cfg, x, rope):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = linear(p["wq_b"], rmsnorm(p["q_norm"], linear(p["wq_a"], x)))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _latent_kv(p, cfg, x, rope):
    """Returns (c_kv normalized (B,S,r), k_rope roped (B,S,dr))."""
    dr = cfg.qk_rope_head_dim
    kv_a = linear(p["wkv_a"], x)
    c_kv, k_rope = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank :]
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    cos, sin = rope
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(
    p: Params,
    cfg,
    x: jax.Array,
    rope: Tuple[jax.Array, jax.Array],
    cache: Optional[Dict[str, jax.Array]] = None,  # {'ckv','krope'}
    pos: Optional[jax.Array] = None,
    absorbed: bool = False,
):
    """Returns (out (B,S,D), new_cache)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope = _project_q(p, cfg, x, rope)
    c_new, kr_new = _latent_kv(p, cfg, x, rope)

    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c_new.astype(cache["ckv"].dtype), (0, pos, 0)
        )
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], kr_new.astype(cache["krope"].dtype), (0, pos, 0)
        )
        new_cache = {"ckv": ckv, "krope": krope}
        kv_len = pos + S
        q_offset = pos
    else:
        ckv, krope = c_new, kr_new
        new_cache = None
        kv_len = None
        q_offset = 0

    Sk = ckv.shape[1]
    wkv_b = p["wkv_b"]["w"].reshape(cfg.kv_lora_rank, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]

    if absorbed:
        # latent-space attention: scores = (q_nope W_uk^T) · c + q_rope · k_rope
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
        logits = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv)
            + jnp.einsum("bqhd,bkd->bhqk", q_rope, krope)
        ).astype(jnp.float32) * scale
    else:
        kv = jnp.einsum("bkr,rhd->bkhd", ckv, wkv_b)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        logits = (
            jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
            + jnp.einsum("bqhd,bkd->bhqk", q_rope, krope)
        ).astype(jnp.float32) * scale

    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(Sk)
    mask = qpos[:, None] >= kpos[None, :]
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)

    if absorbed:
        out_lat = jnp.einsum("bhqk,bkr->bqhr", probs, ckv)
        out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv)
    else:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    return linear(p["wo"], out.reshape(B, S, H * dv)), new_cache


def init_mla_cache(cfg, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    return {
        "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }
