"""Shared neural layers (pure JAX, params as nested dict pytrees).

No flax/optax in this environment — parameters are plain dicts, every layer
is an (init, apply) pair.  Compute dtype follows the param dtype; norms and
softmax accumulate in float32.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dtype(cfg_dtype: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg_dtype]


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype) -> Params:
    std = 1.0 / math.sqrt(d_in)
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}


def linear(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"]


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"w": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, ids: jax.Array) -> jax.Array:
    return p["w"][ids]


def swiglu_init(key, d: int, ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d, ff, dtype),
        "up": init_linear(k2, d, ff, dtype),
        "down": init_linear(k3, ff, d, dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_tables(seq: int, dim: int, theta: float, offset: Any = 0):
    """(cos, sin) of shape (seq, dim/2), float32.  `offset` may be traced."""
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    inv = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# scaled-dot-product attention core (GQA, windows, prefix-LM, cross)
# --------------------------------------------------------------------------

def sdpa(
    q: jax.Array,             # (B, Sq, H, D)
    k: jax.Array,             # (B, Sk, Hkv, D)
    v: jax.Array,             # (B, Sk, Hkv, Dv)
    causal: bool,
    window: int = 0,          # >0: sliding window over keys
    q_offset: Any = 0,        # absolute position of q[0] (int or traced)
    prefix_len: int = 0,      # prefix-LM: first `prefix_len` positions dense
    kv_len: Optional[jax.Array] = None,  # decode: #valid cache entries
    softmax_scale: Optional[float] = None,
    key_positions: Optional[jax.Array] = None,  # ring caches: abs pos per slot
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)

    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale

    qpos = jnp.arange(Sq) + q_offset          # (Sq,)
    kpos = (jnp.arange(Sk) if key_positions is None
            else key_positions)               # (Sk,)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        cm = qpos[:, None] >= kpos[None, :]
        if prefix_len:
            cm = cm | ((qpos[:, None] < prefix_len) & (kpos[None, :] < prefix_len))
        mask = mask & cm
    if window:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    if key_positions is not None:
        mask = mask & (kpos[None, :] >= 0)  # ring slots not yet written
    logits = jnp.where(mask[None, None, None], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def sdpa_banded(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Causal sliding-window attention in banded/blocked form (§Perf).

    The masked-full formulation materializes (S, S) scores — at 32k that is
    a multi-GB intermediate per head and S²·d flops, 97% of it masked away
    for window ≪ S.  Banded form: split the sequence into blocks of W =
    window; a query block attends only to its own block and the previous one
    (2W keys), which covers every key with 0 ≤ qpos − kpos < W exactly.
    Flops drop S/(2W)-fold; the giant intermediate disappears.  W-aligned
    blocks are also the natural MXU tiling.

    Requires S % window == 0 (callers pad/fall back otherwise).
    """
    import math as _math
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    W = window
    nb = S // W
    scale = softmax_scale if softmax_scale is not None else 1.0 / _math.sqrt(D)

    qb = q.reshape(B, nb, W, Hkv, G, D)
    kb = k.reshape(B, nb, W, Hkv, D)
    vb = v.reshape(B, nb, W, Hkv, v.shape[-1])
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    k2 = jnp.concatenate([kprev, kb], axis=2)   # (B, nb, 2W, Hkv, D)
    v2 = jnp.concatenate([vprev, vb], axis=2)

    logits = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb, k2).astype(jnp.float32)
    logits = logits * scale

    qi = jnp.arange(W)[:, None]          # position within block
    kj = jnp.arange(2 * W)[None, :]      # position within [prev | own]
    delta = qi + W - kj                  # qpos - kpos
    mask = (delta >= 0) & (delta < W)    # causal, within window
    first = (jnp.arange(nb) == 0)[:, None, None]
    mask = mask[None, :, :] & (~first | (kj >= W))[...]  # block -1 invalid at i=0
    logits = jnp.where(mask[None, :, None, None], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", probs, v2)
    return out.reshape(B, S, H, v.shape[-1])


def banded_enabled() -> bool:
    import os
    return os.environ.get("REPRO_NO_BANDED", "0") != "1"


# --------------------------------------------------------------------------
# GQA attention layer (self or cross), with decode KV cache
# --------------------------------------------------------------------------

def init_attention(key, cfg, dtype, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, d, cfg.n_heads * hd, dtype),
        "wk": init_linear(k2, d, cfg.n_kv_heads * hd, dtype),
        "wv": init_linear(k3, d, cfg.n_kv_heads * hd, dtype),
        "wo": init_linear(k4, cfg.n_heads * hd, d, dtype),
    }


def attention(
    p: Params,
    cfg,
    x: jax.Array,                      # (B, S, D)
    rope: Optional[Tuple[jax.Array, jax.Array]],
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    memory: Optional[jax.Array] = None,   # cross-attention source
    cache: Optional[Dict[str, jax.Array]] = None,  # {'k','v'} (B, Smax, Hkv, hd)
    pos: Optional[jax.Array] = None,      # decode position
    static_kv: bool = False,              # cache holds primed cross K/V
):
    """Returns (out, new_cache)."""
    B, S, _ = x.shape
    hd = cfg.hd
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)

    new_cache = cache
    kv_len = None
    q_offset = 0
    key_positions = None
    if static_kv:
        # cross-attention against precomputed K/V (decode phase)
        k, v = cache["k"], cache["v"]
        causal = False
    else:
        src = memory if memory is not None else x
        k = linear(p["wk"], src).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
        v = linear(p["wv"], src).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
        if rope is not None and memory is None:
            cos, sin = rope
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        if memory is not None:
            causal = False
        elif cache is not None and window and cache["k"].shape[1] == window:
            # ring-buffer cache for sliding-window layers (optimized serve
            # path): cache holds only the last W positions; slot = pos % W.
            # Single-token decode only (S == 1).
            W = cache["k"].shape[1]
            slot = pos % W
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            new_cache = {"k": kc, "v": vc}
            k, v = kc, vc
            # absolute position held by each slot j: pos - ((pos - j) mod W)
            j = jnp.arange(W)
            key_positions = pos - ((pos - j) % W)
            kv_len = pos + S
            q_offset = pos
        elif cache is not None:
            # decode self-attention: write k/v at `pos`, attend over cache
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            new_cache = {"k": kc, "v": vc}
            k, v = kc, vc
            kv_len = pos + S
            q_offset = pos

    if (window and causal and cache is None and memory is None
            and not static_kv and prefix_len == 0 and S % window == 0
            and S // window >= 2 and banded_enabled()):
        out = sdpa_banded(q, k, v, window)
    else:
        out = sdpa(
            q, k, v,
            causal=causal,
            window=window,
            q_offset=q_offset,
            prefix_len=prefix_len,
            kv_len=kv_len,
            key_positions=key_positions,
        )
    return linear(p["wo"], out.reshape(B, S, cfg.n_heads * hd)), new_cache


def init_attention_cache(cfg, batch: int, max_seq: int, dtype) -> Dict[str, jax.Array]:
    hd = cfg.hd
    shape = (batch, max_seq, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
