"""Scan wrapper with dry-run unrolling.

`lax.scan` keeps the HLO small (one body per block kind) — right for real
runs — but XLA's `cost_analysis` counts a while-loop body ONCE, which would
understate flops/collective-bytes by the trip count in the roofline.  The
dry-run therefore sets REPRO_SCAN_UNROLL=1 to fully unroll layer scans, so
every layer's matmuls and collectives are counted exactly.
"""
from __future__ import annotations

import os

import jax


def unrolling() -> bool:
    return os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"


def scan(f, init, xs, length=None):
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if unrolling() else 1)
