"""Mamba2 (SSD — state-space duality) block, chunked-scan training form +
O(1)-state decode step.  [arXiv:2405.21060]

Shapes: d_inner = expand·d_model; H = d_inner / head_dim(P); state size N;
G groups (G=1 here) share B/C across heads.

Chunked algorithm (SSD paper §6): split the sequence into chunks of length
Q; compute the intra-chunk (quadratic attention-like) term and the
inter-chunk term through a recurrence over per-chunk states — the recurrence
is a `lax.associative_scan`, so prefill parallelizes over the sequence.
The decode step is the plain SSM recurrence on a (B,H,P,N) state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, init_rmsnorm, rmsnorm

Params = Dict[str, Any]


def _dims(cfg):
    d_in = cfg.d_inner
    H = cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_dim = d_in + 2 * G * N
    return d_in, H, P, N, G, conv_dim


def init_mamba(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    in_dim = 2 * d_in + 2 * G * N + H  # z, xBC, dt
    return {
        "in_proj": init_linear(ks[0], d, in_dim, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # a = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus(-2)≈0.13
        "norm": init_rmsnorm(d_in, dtype),
        "out_proj": init_linear(ks[4], d_in, d, dtype),
    }


def _split_proj(cfg, zxbcdt):
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim :]
    return z, xBC, dt


def _causal_conv(p: Params, cfg, xBC: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, conv_dim)."""
    W = cfg.conv_width
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * p["conv_w"][i][None, None, :]
        for i in range(W)
    )
    return jax.nn.silu(out + p["conv_b"][None, None, :])


def mamba_chunked(
    p: Params, cfg, x: jax.Array, chunk: int = 128,
    initial_state: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """Training/prefill form.  x: (B, S, D) -> (B, S, D) [, final state]."""
    B, S, D = x.shape
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    z, xBC, dt = _split_proj(cfg, linear(p["in_proj"], x))
    xBC = _causal_conv(p, cfg, xBC)
    xs = xBC[..., :d_in].reshape(B, S, H, P)
    Bm = xBC[..., d_in : d_in + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_in + G * N :].reshape(B, S, G, N)
    assert G == 1, "G=1 supported"
    Bm, Cm = Bm[..., 0, :], Cm[..., 0, :]          # (B, S, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])  # (B,S,H)
    a = -jnp.exp(p["A_log"])                        # (H,)
    dA = dt * a[None, None]                         # (B,S,H) negative

    # chunked views
    xs_c = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    B_c = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(B, nc, Q, H)
    dA_c = dA.reshape(B, nc, Q, H)
    cum = jnp.cumsum(dA_c, axis=2)                  # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within chunk) ----
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcqn,bckn->bcqk", C_c, B_c)             # (B,nc,Q,K)
    att = CB[..., None] * L * dt_c[:, :, None, :, :]         # (B,nc,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att, xs_c)

    # ---- per-chunk states + inter-chunk recurrence ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    Sc = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", B_c, dt_c * decay_to_end, xs_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    if initial_state is not None:
        s0 = initial_state.astype(jnp.float32)               # (B,H,P,N)
    else:
        s0 = jnp.zeros((B, H, P, N), jnp.float32)

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2[..., None, None] + b2

    decays, states = jax.lax.associative_scan(
        op, (chunk_decay, Sc), axis=1
    )  # states[c] = state at END of chunk c (s0=0 case)
    # inject initial state: state_end[c] += s0 * prod(decay[0..c])
    states = states + s0[:, None] * decays[..., None, None]
    # state BEFORE each chunk
    prev = jnp.concatenate([s0[:, None], states[:, :-1]], axis=1)  # (B,nc,H,P,N)

    y_inter = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", C_c, prev, jnp.exp(cum)
    )

    y = (y_intra + y_inter + p["D"][None, None, None, :, None] * xs_c)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    if return_state:
        return out, states[:, -1].astype(jnp.float32)
    return out


def init_mamba_cache(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba_step(
    p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode: x (B, 1, D) -> (B, 1, D); O(1)-state recurrence."""
    B = x.shape[0]
    d_in, H, P, N, G, conv_dim = _dims(cfg)
    z, xBC, dt = _split_proj(cfg, linear(p["in_proj"], x))  # (B,1,·)
    window = jnp.concatenate([cache["conv"], xBC.astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC1 = jax.nn.silu(conv_out)                            # (B, conv_dim)
    new_conv = window[:, 1:]

    xs = xBC1[:, :d_in].reshape(B, H, P)
    Bm = xBC1[:, d_in : d_in + N]
    Cm = xBC1[:, d_in + N :]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])  # (B,H)
    a = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt1 * a[None])                             # (B,H)

    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bm, xs.astype(jnp.float32), dt1
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm, state) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y), {"conv": new_conv, "state": state}


def mamba_sequential_ref(p: Params, cfg, x: jax.Array) -> jax.Array:
    """Step-by-step oracle (tests): must equal mamba_chunked."""
    B, S, D = x.shape
    cache = init_mamba_cache(cfg, B, x.dtype)

    def body(cache, xt):
        y, cache = mamba_step(p, cfg, xt[:, None, :], cache)
        return cache, y[:, 0]

    _, ys = jax.lax.scan(body, cache, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(ys, 0, 1)
