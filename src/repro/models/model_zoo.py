"""Unified model API: build(config) -> ModelBundle with init/step functions.

All 10 assigned architectures are served by three assemblies:
  * decoder-only (`transformer.py`)   — 8 archs (incl. VLM prefix stub)
  * encoder-decoder (`encdec.py`)     — seamless-m4t
and three step kinds per shape config:
  * train_step   — CE loss (+ MoE aux), grads, optimizer update
  * prefill_step — forward building the decode caches
  * serve_step   — single-token decode against the caches
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import transformer as T
from . import encdec as ED
from .layers import _dtype

Params = Dict[str, Any]


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; logits (B,S,V), labels (B,S) (already shifted)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: Any
    init: Callable[[jax.Array], Params]
    loss_fn: Callable[..., Tuple[jax.Array, jax.Array]]   # (params, batch) -> (loss, aux)
    prefill_fn: Optional[Callable] = None
    decode_fn: Optional[Callable] = None
    cache_init: Optional[Callable] = None


def _decoder_bundle(cfg) -> ModelBundle:
    prefix = cfg.n_prefix_tokens > 0

    def init(key):
        return T.init_lm(key, cfg)

    def loss_fn(params, batch, *, moe_path="capacity", remat=True):
        tokens = batch["tokens"]
        labels = batch["labels"]
        pfx = batch.get("prefix_embeds") if prefix else None
        logits, aux = T.lm_forward(params, cfg, tokens, prefix_embeds=pfx,
                                   moe_path=moe_path, remat=remat)
        if prefix:
            logits = logits[:, cfg.n_prefix_tokens :]
        loss = cross_entropy(logits[:, :-1], labels[:, 1:])
        return loss + 0.01 * aux, aux

    def cache_init(batch, max_seq, ring=False):
        return T.init_lm_cache(cfg, batch, max_seq, ring=ring)

    def prefill_fn(params, batch, last_only=False):
        """Forward over the prompt; returns (logits, aux).  The dry-run
        lowers this for prefill shapes (cache write is decode-side).
        `last_only`: serving semantics — logits for the final position only
        (the §Perf prefill optimization)."""
        pfx = batch.get("prefix_embeds") if prefix else None
        return T.lm_forward(params, cfg, batch["tokens"], prefix_embeds=pfx,
                            moe_path="capacity", remat=False,
                            last_only=last_only)

    def decode_fn(params, token, caches, pos, *, mla_absorbed=False,
                  moe_path="capacity", prefix_embeds=None):
        return T.lm_decode_step(params, cfg, token, caches, pos,
                                mla_absorbed=mla_absorbed, moe_path=moe_path,
                                prefix_embeds=prefix_embeds)

    return ModelBundle(cfg, init, loss_fn, prefill_fn, decode_fn, cache_init)


def _encdec_bundle(cfg) -> ModelBundle:
    def init(key):
        return ED.init_encdec(key, cfg)

    def loss_fn(params, batch, *, moe_path="capacity", remat=True):
        logits, aux = ED.encdec_forward(params, cfg, batch["src_embeds"],
                                        batch["tokens"], remat=remat)
        loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return loss, aux

    def cache_init(batch, max_seq):
        return ED.init_encdec_cache(cfg, batch, max_seq, cfg.mem_len)

    def prefill_fn(params, batch):
        memory = ED.encode(params, cfg, batch["src_embeds"])
        return memory, jnp.float32(0.0)

    def decode_fn(params, token, caches, pos, **_):
        return ED.encdec_decode_step(params, cfg, token, caches, pos)

    return ModelBundle(cfg, init, loss_fn, prefill_fn, decode_fn, cache_init)


def build(cfg) -> ModelBundle:
    return _encdec_bundle(cfg) if cfg.is_encdec else _decoder_bundle(cfg)


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
