"""Encoder-decoder stack (seamless-m4t): bidirectional encoder over stub
frame embeddings + causal decoder with cross-attention.

The audio frontend is a STUB per assignment — `src_embeds` arrives
pre-computed as (B, S_src, d_model) frame embeddings.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import scan_util

from .layers import (
    Params, _dtype, init_linear, linear, init_rmsnorm, rmsnorm,
    init_embedding, embed, swiglu_init, swiglu, rope_tables,
    init_attention, attention, init_attention_cache,
)


def _init_enc_layer(key, cfg, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "self_attn": init_attention(k1, cfg, dtype),
        "ln_x": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": init_attention(k2, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": swiglu_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg) -> Params:
    dtype = _dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": init_embedding(k1, cfg.vocab, cfg.d_model, dtype),
        "lm_head": init_linear(k2, cfg.d_model, cfg.vocab, dtype),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(k3, cfg.enc_layers)),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jax.random.split(k4, cfg.n_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }


def encode(params, cfg, src_embeds, *, remat: bool = False):
    """Bidirectional encoder over (B, S_src, D) stub embeddings."""
    x = src_embeds.astype(_dtype(cfg.dtype))
    rope = rope_tables(x.shape[1], cfg.hd, cfg.rope_theta)

    def body(h, p):
        a, _ = attention(p["attn"], cfg, rmsnorm(p["ln1"], h, cfg.norm_eps),
                         rope, causal=False)
        h = h + a
        h = h + swiglu(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = scan_util.scan(body_fn, x, params["enc"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_layer(p, cfg, x, rope, memory, self_cache=None, cross_cache=None, pos=None):
    a, new_self = attention(p["self_attn"], cfg,
                            rmsnorm(p["ln1"], x, cfg.norm_eps), rope,
                            causal=True, cache=self_cache, pos=pos)
    x = x + a
    a, new_cross = attention(p["cross_attn"], cfg,
                             rmsnorm(p["ln_x"], x, cfg.norm_eps), None,
                             memory=memory, cache=cross_cache,
                             static_kv=memory is None and cross_cache is not None)
    x = x + a
    x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, new_self, new_cross


def encdec_forward(params, cfg, src_embeds, tgt_tokens, *, remat: bool = False):
    """Training forward.  Returns (logits, aux=0)."""
    memory = encode(params, cfg, src_embeds, remat=remat)
    x = embed(params["embed"], tgt_tokens)
    rope = rope_tables(x.shape[1], cfg.hd, cfg.rope_theta)

    def body(h, p):
        h, _, _ = _dec_layer(p, cfg, h, rope, memory)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = scan_util.scan(body_fn, x, params["dec"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return linear(params["lm_head"], x), jnp.float32(0.0)


def init_encdec_cache(cfg, batch: int, max_seq: int, memory_len: int):
    """Self-attn KV (L,B,Smax,..) + cross K/V computed once from memory."""
    dtype = _dtype(cfg.dtype)
    one_self = init_attention_cache(cfg, batch, max_seq, dtype)
    one_cross = init_attention_cache(cfg, batch, memory_len, dtype)
    L = cfg.n_layers
    return {
        "self": jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), one_self),
        "cross": jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), one_cross),
    }


def encdec_prime_cross(params, cfg, memory, caches):
    """Precompute per-layer cross K/V from encoder memory (prefill phase)."""
    B, Sm, _ = memory.shape

    def per_layer(p):
        k = linear(p["cross_attn"]["wk"], memory).reshape(B, Sm, cfg.n_kv_heads, cfg.hd)
        v = linear(p["cross_attn"]["wv"], memory).reshape(B, Sm, cfg.n_kv_heads, cfg.hd)
        return {"k": k, "v": v}

    cross = jax.vmap(per_layer)(params["dec"])
    return {"self": caches["self"], "cross": cross}


def encdec_decode_step(params, cfg, token, caches, pos):
    """One decoder step against primed cross caches."""
    x = embed(params["embed"], token)
    rope = rope_tables(1, cfg.hd, cfg.rope_theta, offset=pos)

    def body(h, xs):
        p, cs, cx = xs
        h, new_self, _ = _dec_layer(p, cfg, h, rope, memory=None,
                                    self_cache=cs, cross_cache=cx, pos=pos)
        return h, new_self

    # memory=None but cross_cache primed -> attention uses cached K/V
    x, new_self = scan_util.scan(body, x, (params["dec"], caches["self"], caches["cross"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return linear(params["lm_head"], x), {"self": new_self, "cross": caches["cross"]}
