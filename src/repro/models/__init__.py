"""Assigned-architecture model substrate (pure JAX, dict pytree params).

seed_fixtures: quarantined seed substrate — exercised by the model
consistency tests and roofline benches, never imported by the BLADYG
product packages (`repro.{core,kernels,runtime,service}`).  The
`dead-seed` audit (`python -m repro.analysis`) accepts this marker;
do not grow graph-side dependencies on anything in here.
"""
from .model_zoo import build, ModelBundle, cross_entropy, param_count
from . import layers, attention, moe, ssm, transformer, encdec

__all__ = [
    "build", "ModelBundle", "cross_entropy", "param_count",
    "layers", "attention", "moe", "ssm", "transformer", "encdec",
]
