"""Assigned-architecture model substrate (pure JAX, dict pytree params)."""
from .model_zoo import build, ModelBundle, cross_entropy, param_count
from . import layers, attention, moe, ssm, transformer, encdec

__all__ = [
    "build", "ModelBundle", "cross_entropy", "param_count",
    "layers", "attention", "moe", "ssm", "transformer", "encdec",
]
