"""Decoder-only transformer assembly for all assigned LM architectures.

Layers are grouped into homogeneous *blocks* that scan (`lax.scan`) over
stacked parameters — one compiled layer body per block kind, which keeps the
HLO small even for 88-layer models and preserves interleaved patterns:

  dense_uniform  — attention (GQA or MLA) + dense SwiGLU      [codeqwen,
                   granite, internlm2, paligemma, deepseek's first 3]
  moe_uniform    — attention + MoE                            [deepseek tail,
                   llama4-scout]
  gemma_period   — (5 sliding-window + 1 global) per period   [gemma3]
  mamba_uniform  — Mamba2 blocks                              [mamba2]
  zamba_period   — (6 Mamba2 + 1 weight-SHARED attn/MLP)      [zamba2]

Each block kind implements (init, apply_train, cache_init, apply_decode).
The same code path serves training (no cache), prefill (cache build) and
decode (single-token step) — selected by `mode`.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import scan_util

from .layers import (
    Params, _dtype, init_linear, linear, init_rmsnorm, rmsnorm,
    init_embedding, embed, swiglu_init, swiglu, rope_tables,
    init_attention, attention, init_attention_cache,
)
from .attention import init_mla, mla_attention, init_mla_cache
from .moe import init_moe, moe_dense, moe_capacity
from .ssm import init_mamba, mamba_chunked, mamba_step, init_mamba_cache


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Block:
    kind: str
    count: int          # scan length (layers, or periods for *_period)
    window: int = 0     # sliding window for dense layers in this block
    d_ff: int = 0       # dense ffn width override (deepseek first-3)
    moe: bool = False


def layer_plan(cfg) -> List[Block]:
    if cfg.mixer == "mamba":
        if cfg.shared_attn_period:
            p = cfg.shared_attn_period
            periods, tail = divmod(cfg.n_layers, p)
            plan = [Block("zamba_period", periods)]
            if tail:
                plan.append(Block("mamba_uniform", tail))
            return plan
        return [Block("mamba_uniform", cfg.n_layers)]
    if cfg.n_experts:
        plan = []
        if cfg.first_k_dense:
            plan.append(Block("dense_uniform", cfg.first_k_dense,
                              d_ff=cfg.dense_d_ff or cfg.d_ff))
        plan.append(Block("moe_uniform", cfg.n_layers - cfg.first_k_dense, moe=True))
        return plan
    if cfg.local_global_period:
        p = cfg.local_global_period
        periods, tail = divmod(cfg.n_layers, p)
        plan = [Block("gemma_period", periods, window=cfg.sliding_window)]
        if tail:
            plan.append(Block("dense_uniform", tail, window=cfg.sliding_window,
                              d_ff=cfg.d_ff))
        return plan
    return [Block("dense_uniform", cfg.n_layers, window=cfg.sliding_window,
                  d_ff=cfg.d_ff)]


# ---------------------------------------------------------------------------
# single-layer bodies
# ---------------------------------------------------------------------------

def _init_attn_layer(key, cfg, dtype, d_ff: int, moe: bool) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model, dtype),
                 "ln2": init_rmsnorm(cfg.d_model, dtype)}
    if cfg.attn_impl == "mla":
        p["attn"] = init_mla(k1, cfg, dtype)
    else:
        p["attn"] = init_attention(k2, cfg, dtype)
    if moe:
        p["moe"] = init_moe(k3, cfg, dtype)
    elif d_ff:
        p["mlp"] = swiglu_init(k4, cfg.d_model, d_ff, dtype)
    return p


def _apply_attn_layer(
    p, cfg, x, rope, *, window: int, moe: bool, moe_path: str,
    prefix_len: int, cache=None, pos=None, mla_absorbed: bool = False,
):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attn_impl == "mla":
        attn_out, new_cache = mla_attention(
            p["attn"], cfg, h, rope, cache=cache, pos=pos, absorbed=mla_absorbed
        )
    else:
        attn_out, new_cache = attention(
            p["attn"], cfg, h, rope, causal=True, window=window,
            prefix_len=prefix_len, cache=cache, pos=pos,
        )
    x = x + attn_out
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.float32(0.0)
    if moe:
        fn = moe_dense if moe_path == "dense" else moe_capacity
        mlp_out, aux = fn(p["moe"], cfg, h)
    elif "mlp" in p:
        mlp_out = swiglu(p["mlp"], h)
    else:
        mlp_out = jnp.zeros_like(h)
    return x + mlp_out, aux, new_cache


def _init_mamba_layer(key, cfg, dtype) -> Params:
    return {"ln": init_rmsnorm(cfg.d_model, dtype),
            "mamba": init_mamba(key, cfg, dtype)}


def _apply_mamba_layer(p, cfg, x, cache=None):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    if cache is None:
        return x + mamba_chunked(p["mamba"], cfg, h), None
    out, new_cache = mamba_step(p["mamba"], cfg, h, cache)
    return x + out, new_cache


def _init_shared_block(key, cfg, dtype) -> Params:
    """zamba2's weight-shared attention + MLP block."""
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _apply_shared_block(p, cfg, x, rope, cache=None, pos=None):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, new_cache = attention(p["attn"], cfg, h, rope, causal=True,
                                    cache=cache, pos=pos)
    x = x + attn_out
    x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, new_cache


# ---------------------------------------------------------------------------
# block builders (init / train-apply / cache / decode-apply)
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key, count: int):
    keys = jax.random.split(key, count)
    return jax.vmap(init_fn)(keys)


def init_block(key, cfg, blk: Block, dtype) -> Params:
    if blk.kind in ("dense_uniform", "moe_uniform"):
        return _stack_init(
            lambda k: _init_attn_layer(k, cfg, dtype, blk.d_ff or cfg.d_ff, blk.moe),
            key, blk.count)
    if blk.kind == "gemma_period":
        k1, k2 = jax.random.split(key)
        pl = cfg.local_global_period - 1
        return {
            "local": _stack_init(
                lambda k: _stack_init(
                    lambda kk: _init_attn_layer(kk, cfg, dtype, cfg.d_ff, False),
                    k, pl),
                k1, blk.count),
            "global": _stack_init(
                lambda k: _init_attn_layer(k, cfg, dtype, cfg.d_ff, False),
                k2, blk.count),
        }
    if blk.kind == "mamba_uniform":
        return _stack_init(lambda k: _init_mamba_layer(k, cfg, dtype), key, blk.count)
    if blk.kind == "zamba_period":
        k1, _ = jax.random.split(key)
        p = cfg.shared_attn_period
        return {
            "mamba": _stack_init(
                lambda k: _stack_init(
                    lambda kk: _init_mamba_layer(kk, cfg, dtype), k, p),
                k1, blk.count),
        }
    raise ValueError(blk.kind)


def apply_block_train(
    params, cfg, blk: Block, x, rope, *, moe_path: str, prefix_len: int,
    shared_block: Optional[Params], remat: bool,
):
    """Training / loss forward (no caches).  Returns (x, aux_sum)."""

    if blk.kind in ("dense_uniform", "moe_uniform"):
        def body(carry, p):
            h, aux = carry
            h, a, _ = _apply_attn_layer(
                p, cfg, h, rope, window=blk.window, moe=blk.moe,
                moe_path=moe_path, prefix_len=prefix_len)
            return (h, aux + a), None
        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = scan_util.scan(body_fn, (x, jnp.float32(0.0)), params)
        return x, aux

    if blk.kind == "gemma_period":
        def period(carry, p):
            h, aux = carry

            def local_layer(c, lp):
                hh, au = c
                hh, a, _ = _apply_attn_layer(
                    lp, cfg, hh, rope, window=blk.window, moe=False,
                    moe_path=moe_path, prefix_len=prefix_len)
                return (hh, au + a), None

            (h, aux), _ = scan_util.scan(local_layer, (h, aux), p["local"])
            h, a, _ = _apply_attn_layer(
                p["global"], cfg, h, rope, window=0, moe=False,
                moe_path=moe_path, prefix_len=prefix_len)
            return (h, aux + a), None
        body_fn = jax.checkpoint(period) if remat else period
        (x, aux), _ = scan_util.scan(body_fn, (x, jnp.float32(0.0)), params)
        return x, aux

    if blk.kind == "mamba_uniform":
        def body(carry, p):
            h, _ = _apply_mamba_layer(p, cfg, carry)
            return h, None
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = scan_util.scan(body_fn, x, params)
        return x, jnp.float32(0.0)

    if blk.kind == "zamba_period":
        def period(carry, p):
            h = carry

            def ml(c, lp):
                c2, _ = _apply_mamba_layer(lp, cfg, c)
                return c2, None

            h, _ = scan_util.scan(ml, h, p["mamba"])
            h, _ = _apply_shared_block(shared_block, cfg, h, rope)
            return h, None
        body_fn = jax.checkpoint(period) if remat else period
        x, _ = scan_util.scan(body_fn, x, params)
        return x, jnp.float32(0.0)

    raise ValueError(blk.kind)


def init_block_cache(cfg, blk: Block, batch: int, max_seq: int, dtype,
                     ring: bool = False):
    def _win_seq():
        # ring caches: sliding-window layers only keep the last W slots
        if ring and blk.window:
            return min(max_seq, blk.window)
        return max_seq

    if blk.kind in ("dense_uniform", "moe_uniform"):
        if cfg.attn_impl == "mla":
            one = init_mla_cache(cfg, batch, max_seq, dtype)
        else:
            one = init_attention_cache(cfg, batch, _win_seq(), dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (blk.count,) + a.shape), one)
    if blk.kind == "gemma_period":
        local_one = init_attention_cache(cfg, batch, _win_seq(), dtype)
        one = init_attention_cache(cfg, batch, max_seq, dtype)
        pl = cfg.local_global_period - 1
        return {
            "local": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (blk.count, pl) + a.shape),
                local_one),
            "global": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (blk.count,) + a.shape), one),
        }
    if blk.kind == "mamba_uniform":
        one = init_mamba_cache(cfg, batch, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (blk.count,) + a.shape), one)
    if blk.kind == "zamba_period":
        m = init_mamba_cache(cfg, batch, dtype)
        a = init_attention_cache(cfg, batch, max_seq, dtype)
        p = cfg.shared_attn_period
        return {
            "mamba": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (blk.count, p) + t.shape), m),
            "shared": jax.tree.map(
                lambda t: jnp.broadcast_to(t, (blk.count,) + t.shape), a),
        }
    raise ValueError(blk.kind)


def apply_block_decode(
    params, cfg, blk: Block, x, rope, cache, pos, *,
    shared_block: Optional[Params], mla_absorbed: bool = False,
    prefix_len: int = 0, moe_path: str = "capacity",
):
    """Single-token decode through the block.  Returns (x, new_cache)."""

    if blk.kind in ("dense_uniform", "moe_uniform"):
        def body(h, xs):
            p, c = xs
            h, _, c2 = _apply_attn_layer(
                p, cfg, h, rope, window=blk.window, moe=blk.moe,
                moe_path=moe_path, prefix_len=prefix_len, cache=c, pos=pos,
                mla_absorbed=mla_absorbed)
            return h, c2
        x, new_cache = scan_util.scan(body, x, (params, cache))
        return x, new_cache

    if blk.kind == "gemma_period":
        def period(h, xs):
            p, c = xs

            def local_layer(hh, xs2):
                lp, lc = xs2
                hh, _, lc2 = _apply_attn_layer(
                    lp, cfg, hh, rope, window=blk.window, moe=False,
                    moe_path="capacity", prefix_len=prefix_len,
                    cache=lc, pos=pos)
                return hh, lc2

            h, lc2 = scan_util.scan(local_layer, h, (p["local"], c["local"]))
            h, _, gc2 = _apply_attn_layer(
                p["global"], cfg, h, rope, window=0, moe=False,
                moe_path="capacity", prefix_len=prefix_len,
                cache=c["global"], pos=pos)
            return h, {"local": lc2, "global": gc2}
        x, new_cache = scan_util.scan(period, x, (params, cache))
        return x, new_cache

    if blk.kind == "mamba_uniform":
        def body(h, xs):
            p, c = xs
            h, c2 = _apply_mamba_layer(p, cfg, h, cache=c)
            return h, c2
        x, new_cache = scan_util.scan(body, x, (params, cache))
        return x, new_cache

    if blk.kind == "zamba_period":
        def period(h, xs):
            p, c = xs

            def ml(hh, xs2):
                lp, lc = xs2
                hh, lc2 = _apply_mamba_layer(lp, cfg, hh, cache=lc)
                return hh, lc2

            h, mc2 = scan_util.scan(ml, h, (p["mamba"], c["mamba"]))
            h, sc2 = _apply_shared_block(shared_block, cfg, h, rope,
                                         cache=c["shared"], pos=pos)
            return h, {"mamba": mc2, "shared": sc2}
        x, new_cache = scan_util.scan(period, x, (params, cache))
        return x, new_cache

    raise ValueError(blk.kind)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_lm(key, cfg) -> Params:
    dtype = _dtype(cfg.dtype)
    plan = layer_plan(cfg)
    ks = jax.random.split(key, len(plan) + 4)
    params: Params = {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "blocks": [init_block(ks[2 + i], cfg, blk, dtype)
                   for i, blk in enumerate(plan)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[1], cfg.d_model, cfg.vocab, dtype)
    if cfg.shared_attn_period:
        params["shared_block"] = _init_shared_block(ks[-1], cfg, dtype)
    if cfg.n_prefix_tokens:
        params["prefix_proj"] = init_linear(ks[-2], cfg.prefix_dim, cfg.d_model, dtype)
    return params


def _embed_inputs(params, cfg, tokens, prefix_embeds):
    x = embed(params["embed"], tokens)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)  # gemma-style
    if prefix_embeds is not None:
        pfx = linear(params["prefix_proj"], prefix_embeds.astype(x.dtype))
        x = jnp.concatenate([pfx, x], axis=1)
    return x


def _logits(params, cfg, x):
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ params["embed"]["w"].T
    return linear(params["lm_head"], h)


def lm_forward(
    params, cfg, tokens, prefix_embeds=None, *,
    moe_path: str = "capacity", remat: bool = False, last_only: bool = False,
):
    """Full forward (training / evaluation).  Returns (logits, aux_loss).

    last_only: serving-prefill optimization — compute lm_head logits for the
    final position only (the KV/state build work is identical; the (B,S,V)
    logits matmul + its vocab-axis gather disappear).  See EXPERIMENTS §Perf.
    """
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    S = x.shape[1]
    rope = rope_tables(S, cfg.hd if cfg.attn_impl != "mla" else cfg.qk_rope_head_dim,
                       cfg.rope_theta)
    prefix_len = cfg.n_prefix_tokens
    shared = params.get("shared_block")
    aux = jnp.float32(0.0)
    for blk, bp in zip(layer_plan(cfg), params["blocks"]):
        x, a = apply_block_train(bp, cfg, blk, x, rope, moe_path=moe_path,
                                 prefix_len=prefix_len, shared_block=shared,
                                 remat=remat)
        aux = aux + a
    if last_only:
        x = x[:, -1:]
    return _logits(params, cfg, x), aux


def init_lm_cache(cfg, batch: int, max_seq: int, ring: bool = False):
    dtype = _dtype(cfg.dtype)
    return [init_block_cache(cfg, blk, batch, max_seq, dtype, ring=ring)
            for blk in layer_plan(cfg)]


def lm_decode_step(params, cfg, token, caches, pos, *, mla_absorbed=False,
                   moe_path: str = "capacity", prefix_embeds=None):
    """One decode step (token: (B, 1)) or a block prefill-into-cache
    (token: (B, S), pos = start offset; for attention archs only — mamba
    block prefill goes through `mamba_chunked(return_state=True)`).

    Returns (logits (B, S, V), new_caches).
    """
    x = _embed_inputs(params, cfg, token, prefix_embeds)
    rope_dim = cfg.hd if cfg.attn_impl != "mla" else cfg.qk_rope_head_dim
    rope = rope_tables(x.shape[1], rope_dim, cfg.rope_theta, offset=pos)
    shared = params.get("shared_block")
    new_caches = []
    for blk, bp, c in zip(layer_plan(cfg), params["blocks"], caches):
        x, c2 = apply_block_decode(bp, cfg, blk, x, rope, c, pos,
                                   shared_block=shared,
                                   mla_absorbed=mla_absorbed,
                                   prefix_len=cfg.n_prefix_tokens,
                                   moe_path=moe_path)
        new_caches.append(c2)
    return _logits(params, cfg, x), new_caches
