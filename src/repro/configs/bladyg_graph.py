"""Graph-task configs — the paper's own workloads (Table 1 + §5 protocol).

Selectable via `examples/kcore_dynamic.py` / `benchmarks` the same way LM
archs are selected via --arch: one named config per dataset with the
paper's experimental protocol parameters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class GraphTaskConfig:
    name: str
    dataset: str            # key into repro.graphgen.snap_like DATASETS
    blocks: int = 8         # paper: 8 partitions (+1 master on EC2)
    partitioner: str = "random"   # paper §5.2.1 uses random node partitioning
    updates: int = 1000     # paper: 1000 insertions/deletions per scenario
    scenarios: Tuple[str, ...] = ("inter", "intra")
    deg_slack: int = 64     # ELL headroom for insertions
    scale_ci: float = 0.04  # CI-size fraction of the paper-scale graph


GRAPH_TASKS = {
    c.name: c
    for c in (
        GraphTaskConfig("ds1", "DS1", scale_ci=0.04),
        GraphTaskConfig("ds2", "DS2", scale_ci=0.02),
        GraphTaskConfig("ego-facebook", "ego-Facebook", scale_ci=0.40),
        GraphTaskConfig("roadnet-ca", "roadNet-CA", scale_ci=0.0012),
        GraphTaskConfig("com-livejournal", "com-LiveJournal", scale_ci=0.0005),
    )
}
