"""mamba2-370m — attention-free SSM (state-space duality).

[arXiv:2405.21060; unverified] 48L d_model=1024 d_ff=0 vocab=50280,
ssm_state=128.  No attention, no MLP block (Mamba2 blocks only, d_ff=0);
decode state is O(1) in sequence length -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,            # SSD heads = d_inner / ssm_head_dim = 2048/64
    n_kv_heads=32,
    d_ff=0,                # no MLP block
    vocab=50280,
    mixer="mamba",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    supports_long_context=True,
    source="arXiv:2405.21060; unverified",
    notes="SSD (state-space duality); pure Mamba2 stack",
)
