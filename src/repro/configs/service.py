"""Serving-loop knobs for the graph query service (`repro.service`).

One frozen dataclass, consumed by `service.server.QueryServer` and the
serving benchmark — every knob that shapes the interleave of query
batches with stream windows lives here, so a deployment is one hashable
value instead of a kwargs spray.

The batching-relevant fields are pow2-bucketed downstream (batch sizes
in `service.queries`, top-k widths via `kernels.ops._pow2_bucket`), so
two configs that bucket identically share every compiled kernel.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ServiceConfig:
    """Admission, batching, and refresh policy for one `QueryServer`.

    max_queue      — admission bound: total requests allowed in the
                     server's buckets at once; `submit` beyond it SHEDS
                     (rejects, counted per kind in the metrics) rather
                     than growing latency unboundedly — the classic
                     bounded-queue load-shedding policy.
    max_batch      — per-bucket batch ceiling; a drained bucket is
                     answered in slices of at most this many queries,
                     each padded to the pow2 bucket above its fill.
    refresh_every  — snapshot refresh cadence in stream windows: the
                     epoch snapshot is rebuilt after every
                     `refresh_every`-th window, so queries observe at
                     most that many windows of staleness (tracked as
                     `ServiceMetrics` staleness).
    pr_steps       — fixed PageRank iteration count per refresh (the
                     `fused_analytics(steps=)` budget; also the parity
                     oracle's `max_steps`).
    alpha          — PageRank damping factor.
    """

    max_queue: int = 1024
    max_batch: int = 64
    refresh_every: int = 1
    pr_steps: int = 30
    alpha: float = 0.85

    def __post_init__(self) -> None:
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.refresh_every < 1:
            raise ValueError(
                f"refresh_every must be >= 1, got {self.refresh_every}")
        if self.pr_steps < 1:
            raise ValueError(f"pr_steps must be >= 1, got {self.pr_steps}")
