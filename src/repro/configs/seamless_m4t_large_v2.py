"""seamless-m4t-large-v2 — enc-dec multimodal (speech) transformer.

[arXiv:2308.11596; hf] 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  The speech frontend is a STUB per assignment: input_specs()
provides precomputed frame embeddings (B, S, d_model) for the encoder.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,          # decoder depth
    enc_layers=24,        # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    mem_len=4096,         # encoder memory length for decode cells
    rope_theta=1e4,
    supports_long_context=False,  # full attention; 524k decode skipped
    source="arXiv:2308.11596; hf",
    notes="enc-dec; audio frontend stubbed to precomputed frame embeddings",
)
