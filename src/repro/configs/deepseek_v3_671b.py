"""deepseek-v3-671b — MoE with Multi-head Latent Attention.

[arXiv:2412.19437; hf] 61L d_model=7168 128H d_ff=2048(expert) vocab=129280,
MoE 256 routed top-8 + 1 shared expert; first 3 layers dense (d_ff 18432);
MLA: q_lora 1536, kv_lora 512, rope_head 64, nope_head 128, v_head 128.
MTP (multi-token prediction) is a training objective variant — we train the
main next-token head (MTP depth-0), noted in DESIGN.md.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # MLA: kv heads == heads, latent-compressed cache
    d_ff=2048,             # per-expert hidden (assignment value)
    vocab=129280,
    attn_impl="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    head_dim=192,          # nope + rope
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    dense_d_ff=18432,
    capacity_factor=1.25,
    rope_theta=1e4,
    supports_long_context=False,
    source="arXiv:2412.19437; hf",
    notes="MLA latent KV cache; 1 shared + 256 routed top-8",
)
