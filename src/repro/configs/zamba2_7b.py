"""zamba2-7b — hybrid: Mamba2 backbone + shared attention block.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64.  The backbone is 81 Mamba2 blocks; a single
*weight-shared* attention+MLP block is interleaved every
`shared_attn_period` blocks (Zamba2's shared-block design).  SSM state is
O(1) in sequence -> long_500k runs (only the shared attn block keeps a KV).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,            # MLP of the shared attention block
    vocab=32000,
    mixer="mamba",
    shared_attn_period=6,  # shared block after every 6 mamba blocks
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    supports_long_context=True,
    source="arXiv:2411.15242; unverified",
    notes="Mamba2 x81 + one weight-shared attn/MLP block invoked periodically",
)
