"""Architecture & shape configuration system.

One `ArchConfig` describes any of the 10 assigned architectures (plus the
paper's own graph workloads, which live in `bladyg_graph.py`).  `reduced()`
returns a structurally-identical tiny config for CPU smoke tests; the full
config is exercised only through the dry-run (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    vocab: int
    d_ff: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- layer pattern -----------------------------------------------------
    mixer: str = "attn"            # attn | mamba
    sliding_window: int = 0        # >0: window size for local layers
    local_global_period: int = 0   # gemma3: every p-th layer is global
    shared_attn_period: int = 0    # zamba2: shared attn block every p mamba layers

    # --- attention flavor ----------------------------------------------------
    attn_impl: str = "gqa"         # gqa | mla
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0         # deepseek: leading dense layers
    dense_d_ff: int = 0            # d_ff of those dense layers
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_groups: int = 1

    # --- enc-dec / multimodal ---------------------------------------------------
    enc_layers: int = 0            # >0: encoder-decoder (seamless)
    n_prefix_tokens: int = 0       # vlm: pre-embedded patch tokens
    prefix_dim: int = 0            # raw dim of stub embeddings
    mem_len: int = 4096            # enc-dec decode: encoder memory length

    # --- misc ---------------------------------------------------------------
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    supports_long_context: bool = False  # run long_500k?
    source: str = ""
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self) -> "ArchConfig":
        """Structurally-identical tiny config for CPU smoke tests."""
        period = max(self.local_global_period, self.shared_attn_period)
        layers = max(2, 2 * period) if period else (4 if self.first_k_dense else 2)
        hd = 16
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(self.n_kv_heads, heads))
        return dataclasses.replace(
            self,
            n_layers=layers,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=96 if self.d_ff else 0,
            vocab=512,
            q_lora_rank=24 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            qk_nope_head_dim=8 if self.qk_nope_head_dim else 0,
            v_head_dim=hd if self.v_head_dim else 0,
            n_experts=4 if self.n_experts else 0,
            top_k=min(2, self.top_k) if self.top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            first_k_dense=min(1, self.first_k_dense),
            dense_d_ff=96 if self.dense_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            sliding_window=32 if self.sliding_window else 0,
            enc_layers=2 if self.enc_layers else 0,
            n_prefix_tokens=8 if self.n_prefix_tokens else 0,
            prefix_dim=48 if self.prefix_dim else 0,
            mem_len=16 if self.is_encdec else 4096,
            dtype="float32",
            notes="REDUCED smoke config",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch, shape) runnable?  Returns (ok, reason-if-skip).

    Per assignment: ``long_500k`` only for sub-quadratic-state archs;
    all 10 archs have decoders, so decode shapes apply everywhere.
    """
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: 524k-token decode excluded per "
            "assignment (no sub-quadratic state); see DESIGN.md §Arch-applicability"
        )
    return True, ""
