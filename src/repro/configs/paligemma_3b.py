"""paligemma-3b — VLM: SigLIP frontend (stub) + gemma decoder.

[arXiv:2407.07726; hf] 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216.  The SigLIP tower is a STUB per assignment: input_specs()
provides 256 precomputed patch embeddings (dim 1152) which are linearly
projected and prepended; prefix tokens attend bidirectionally (PaliGemma's
prefix-LM masking), suffix is causal.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    n_prefix_tokens=256,
    prefix_dim=1152,       # SigLIP-So400m width
    rope_theta=1e4,
    tie_embeddings=True,
    supports_long_context=False,
    source="arXiv:2407.07726; hf",
    notes="prefix-LM masking over 256 stub patch embeddings",
)
