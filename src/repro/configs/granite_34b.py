"""granite-34b — deep dense code LM with MQA.

[arXiv:2405.04324; hf] 88L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    rope_theta=1e4,
    supports_long_context=False,
    source="arXiv:2405.04324; hf",
    notes="88-layer MQA code model",
)
