"""gemma3-1b — dense, 5:1 local:global attention, 128k-ready.

[hf:google/gemma-3-1b-pt; unverified] 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144; head_dim 256; sliding window 512 on local layers,
every 6th layer global.  Local layers keep an O(window) KV -> long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    sliding_window=512,
    local_global_period=6,   # 5 local : 1 global
    rope_theta=1e6,
    tie_embeddings=True,
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt; unverified",
    notes="5:1 local:global; tied embeddings; kv=1 (MQA)",
)
