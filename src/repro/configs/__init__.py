"""Config registry: seed LLM fixtures + the graph service's own configs.

Two distinct populations live in this namespace — keep them straight:

  * **Seed fixtures** (`ARCHS`): the 10 LLM architecture configs below
    (codeqwen/deepseek/gemma/llama/...) are NOT part of the BLADYG
    reproduction.  They are frozen seed-repo fixtures that the model-
    plumbing tests (`test_models_consistency`, `test_arch_smoke`,
    `test_sharding_and_specs`), `launch/`, and the roofline benchmarks
    still exercise as a registry of realistic shape/sharding specs — so
    they stay, but nothing in `repro.core`/`repro.runtime`/
    `repro.service` may import them, and no new graph-side code should
    grow dependencies on them.
  * **Service configs** (`service.ServiceConfig`): the graph-side knobs
    — admission control, batching, and snapshot-refresh policy for the
    query-serving layer (`repro.service`).  These are the configs this
    package exists to host going forward.

seed_fixtures: the arch-config population above is quarantined seed
substrate — `python -m repro.analysis` (the `dead-seed` audit) accepts
this package as deliberately unreachable from the product surface.
"""
from .base import ArchConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME, cell_applicable
from .service import ServiceConfig

from .seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from .mamba2_370m import CONFIG as mamba2_370m
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .gemma3_1b import CONFIG as gemma3_1b
from .codeqwen1_5_7b import CONFIG as codeqwen1_5_7b
from .granite_34b import CONFIG as granite_34b
from .internlm2_1_8b import CONFIG as internlm2_1_8b
from .zamba2_7b import CONFIG as zamba2_7b
from .paligemma_3b import CONFIG as paligemma_3b

ARCHS = {
    c.name: c
    for c in (
        seamless_m4t_large_v2,
        mamba2_370m,
        deepseek_v3_671b,
        llama4_scout_17b_a16e,
        gemma3_1b,
        codeqwen1_5_7b,
        granite_34b,
        internlm2_1_8b,
        zamba2_7b,
        paligemma_3b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
    "cell_applicable", "ARCHS", "get_arch", "ServiceConfig",
]
