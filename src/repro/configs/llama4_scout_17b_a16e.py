"""llama4-scout-17b-a16e — MoE (16 experts, top-1) + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1, early fusion.
Text backbone only (early-fusion frontend out of assignment scope);
every layer MoE with one shared expert, per the HF config.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    capacity_factor=1.25,
    rope_theta=5e5,
    supports_long_context=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    notes="MoE top-1 + shared expert every layer; text backbone",
)
