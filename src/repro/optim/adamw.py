"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Pure-JAX (no optax in this environment).  The optimizer state holds the fp32
master copy plus moments; model params stay in the compute dtype (bf16 on
TPU).  State layout is one pytree mirroring the params, which makes ZeRO
sharding a pure sharding-spec concern (see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array        # int32 scalar
    master: Params         # fp32 master weights
    m: Params              # fp32 first moment
    v: Params              # fp32 second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"   # 'bfloat16' halves optimizer memory


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_peak * jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Params, cfg: AdamWConfig) -> AdamWState:
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moments_dtype]
    f32 = lambda p: jax.tree.map(lambda x: x.astype(jnp.float32), p)
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, mdt), p)
    return AdamWState(jnp.int32(0), f32(params), zeros(params), zeros(params))


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def update(
    grads: Params, state: AdamWState, cfg: AdamWConfig, compute_dtype=jnp.bfloat16
) -> Tuple[Params, AdamWState]:
    """Returns (new compute-dtype params, new state)."""
    step = state.step + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step)
        vhat = v2 / (1 - cfg.b2 ** step)
        new_master = master - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        )
        return new_master, m2.astype(m.dtype), v2.astype(v.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_ma = treedef.flatten_up_to(state.master)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, ma, m, v) for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda x: x.astype(compute_dtype), new_master)
    return new_params, AdamWState(step, new_master, new_m, new_v)
