"""Optimizer substrate: AdamW (+ schedule, clipping), gradient compression.

seed_fixtures: quarantined seed substrate — kept for the optimizer
tests, unreachable from the BLADYG product packages (see the
`dead-seed` audit in `python -m repro.analysis`).
"""
from .adamw import AdamWConfig, AdamWState, init, update, cosine_lr, global_norm
from .compress import (
    quantize_int8, dequantize_int8, init_error_feedback, compressed_psum_mean,
)

__all__ = [
    "AdamWConfig", "AdamWState", "init", "update", "cosine_lr", "global_norm",
    "quantize_int8", "dequantize_int8", "init_error_feedback",
    "compressed_psum_mean",
]
