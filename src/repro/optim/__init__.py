"""Optimizer substrate: AdamW (+ schedule, clipping), gradient compression."""
from .adamw import AdamWConfig, AdamWState, init, update, cosine_lr, global_norm
from .compress import (
    quantize_int8, dequantize_int8, init_error_feedback, compressed_psum_mean,
)

__all__ = [
    "AdamWConfig", "AdamWState", "init", "update", "cosine_lr", "global_norm",
    "quantize_int8", "dequantize_int8", "init_error_feedback",
    "compressed_psum_mean",
]
