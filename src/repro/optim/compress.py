"""Gradient compression: int8 quantization with error feedback.

Used for the data-parallel gradient all-reduce at scale (cuts DP collective
bytes 4x vs fp32 / 2x vs bf16).  Error feedback [Karimireddy et al. 2019]
keeps the quantization error in a local buffer and re-injects it next step,
preserving convergence.

The compressed all-reduce runs inside a `shard_map` over the data axis (see
launch/train.py --grad-compression); the quantize/dequantize pair is also
unit-tested standalone against the exact mean.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_mean(
    grads: Params, ef: Params, axis_name: str
) -> Tuple[Params, Params]:
    """int8 all-reduce-mean over `axis_name` with error feedback.

    Must be called inside shard_map/pmap with `axis_name` bound.
    Returns (reduced grads fp32, new error-feedback buffers).
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        new_e = target - deq
        # all-reduce the dequantized value (wire format int8+scale; the
        # lax-level collective carries the dequantized tensor — on real
        # hardware this is the int8 payload + per-tensor scale).
        red = jax.lax.pmean(deq, axis_name)
        return red, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
