"""Data pipelines.

seed_fixtures: quarantined seed substrate — token pipelines for the
model plumbing tests, unreachable from the BLADYG product packages
(see the `dead-seed` audit in `python -m repro.analysis`).
"""
from .pipeline import SyntheticTokens, ByteCorpus
__all__ = ["SyntheticTokens", "ByteCorpus"]
