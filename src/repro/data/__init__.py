"""Data pipelines."""
from .pipeline import SyntheticTokens, ByteCorpus
__all__ = ["SyntheticTokens", "ByteCorpus"]
