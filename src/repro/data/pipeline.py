"""Deterministic, seekable, host-sharded data pipelines.

Two sources:

  * `SyntheticTokens`  — deterministic PRNG tokens keyed by (seed, step,
    host); zero I/O, arbitrary scale.  The default for training runs and the
    dry-run.  Mimics a Zipfian unigram distribution so losses are non-trivial.
  * `ByteCorpus`       — byte-level tokens from a local file (quickstart).

Both are *cursor-addressed*: `batch(step)` is a pure function of the step
index, so checkpoint-restart (and elastic restarts with a different host
count) replays exactly-once without coordination — the BLADYG-era "no
central dispatcher" rule applied to data: no straggling feeder host.

Graph update streams for the BLADYG core live in `repro.core.updates`.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.2

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))
        z = rng.zipf(self.zipf_a, size=(self.local_batch, self.seq_len + 1))
        toks = (z - 1) % self.vocab
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass
class ByteCorpus:
    path: str
    seq_len: int
    global_batch: int
    host_index: int = 0
    host_count: int = 1
    vocab: int = 256

    def __post_init__(self):
        self._data = np.frombuffer(Path(self.path).read_bytes(), dtype=np.uint8)
        assert len(self._data) > self.seq_len + 1, "corpus too small"

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.host_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        n = len(self._data) - self.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([hash(self.path) & 0x7FFFFFFF,
                                    step, self.host_index]))
        starts = rng.integers(0, n, size=self.local_batch)
        rows = np.stack([self._data[s : s + self.seq_len + 1] for s in starts])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }
