"""tracelint engine: findings, pragmas, rule registry, baseline.

The engine is deliberately small: it walks ``*.py`` files under a scan
root, parses each into a :class:`ModuleSource` (AST + per-line pragma
tables + enclosing-function map), runs every registered rule over it,
and filters the results through ``# tracelint:`` pragmas and the
committed baseline.

Pragmas (line comments, honored on the finding's own line):

* ``# tracelint: disable=rule-a,rule-b`` — suppress those rules here;
  bare ``# tracelint: disable`` suppresses every rule on the line.
* ``# tracelint: boundary`` on a ``def`` line — mark the function a
  host boundary (equivalent to a `config.HOST_BOUNDARIES` entry), for
  one-off boundaries that don't warrant a config entry.

Baseline: a JSON list of fingerprints ``(path, rule, snippet)`` — the
snippet is the stripped source line, so findings survive line drift but
NOT edits to the offending line itself.  Matching is count-aware: two
identical findings need two baseline entries.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from collections import Counter
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

PRAGMA_RE = re.compile(
    r"#\s*tracelint:\s*(?P<kind>disable|boundary)"
    r"(?:\s*=\s*(?P<rules>[A-Za-z0-9_,\-\* ]+))?")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint result, addressable and baseline-able."""

    path: str      # scan-root-relative POSIX path (or "<audit>" pseudo-path)
    line: int      # 1-based; 0 for whole-module / audit findings
    rule: str      # rule id, e.g. "host-sync"
    message: str   # human explanation of the violated invariant
    snippet: str   # stripped source line (the baseline fingerprint key)

    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.path, self.rule, self.snippet)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Finding":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}"
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.snippet:
            out += f"\n    {self.snippet}"
        return out


class ModuleSource:
    """A parsed module plus the lookup tables rules need.

    `path` is the scan-root-relative POSIX path rules scope on; `text`
    the full source.  Pragmas are parsed from raw line text (a ``#``
    inside a string literal on the same line can confuse this — an
    accepted limitation for a lint tool).
    """

    def __init__(self, path: str, text: str):
        self.path = path.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)
        #: line -> set of disabled rule ids ("*" disables all)
        self.disables: Dict[int, Set[str]] = {}
        #: lines carrying a `boundary` pragma
        self.boundary_lines: Set[int] = set()
        for i, line in enumerate(self.lines, start=1):
            m = PRAGMA_RE.search(line)
            if not m:
                continue
            if m.group("kind") == "boundary":
                self.boundary_lines.add(i)
            else:
                rules = m.group("rules")
                ids = ({r.strip() for r in rules.split(",") if r.strip()}
                       if rules else {"*"})
                self.disables.setdefault(i, set()).update(ids)
        #: node -> tuple of enclosing FunctionDef/AsyncFunctionDef nodes,
        #: outermost first (decorators get the stack OUTSIDE their def)
        self._func_stack: Dict[int, Tuple[ast.AST, ...]] = {}
        self._assign_stacks(self.tree, ())
        #: module-level integer constants (NAME = <int literal>)
        self.int_constants: Dict[str, int] = {}
        for node in self.tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, int)):
                self.int_constants[node.targets[0].id] = node.value.value

    def _assign_stacks(self, node: ast.AST, stack: Tuple[ast.AST, ...]):
        self._func_stack[id(node)] = stack
        is_func = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        if is_func:
            # decorators/defaults see the OUTER stack; the body sees +self
            for dec in node.decorator_list:
                self._assign_stacks(dec, stack)
            for d in list(node.args.defaults) + [
                    d for d in node.args.kw_defaults if d is not None]:
                self._assign_stacks(d, stack)
            inner = stack + (node,)
            for child in node.body:
                self._assign_stacks(child, inner)
            return
        for child in ast.iter_child_nodes(node):
            self._assign_stacks(child, stack)

    # -- queries rules use -------------------------------------------------

    def enclosing_functions(self, node: ast.AST) -> Tuple[ast.AST, ...]:
        """FunctionDef nodes enclosing `node`, outermost first."""
        return self._func_stack.get(id(node), ())

    def enclosing_names(self, node: ast.AST) -> Tuple[str, ...]:
        return tuple(f.name for f in self.enclosing_functions(node))

    def is_boundary(self, node: ast.AST) -> bool:
        """True if any enclosing function is whitelisted as a host
        boundary (config entry or `# tracelint: boundary` def-line
        pragma), or the whole module is ("*" entry)."""
        from . import config

        allowed = config.boundary_functions(self.path)
        if "*" in allowed:
            return True
        for f in self.enclosing_functions(node):
            if f.name in allowed:
                return True
            # pragma anywhere on the def header (def line .. first body line)
            body_start = f.body[0].lineno if f.body else f.lineno
            if any(f.lineno <= ln <= body_start
                   for ln in self.boundary_lines):
                return True
        return False

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def disabled(self, lineno: int, rule: str) -> bool:
        ids = self.disables.get(lineno, ())
        return "*" in ids or rule in ids

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 0)
        return Finding(path=self.path, line=line, rule=rule,
                       message=message, snippet=self.line_text(line))


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------


class Rule:
    """Base class: subclass, set `id`/`summary`, implement `check`."""

    id: str = ""
    summary: str = ""

    def applies(self, path: str) -> bool:
        return True

    def check(self, mod: ModuleSource) -> Iterable[Finding]:
        raise NotImplementedError


#: rule id -> rule instance (AST rules only; the dead-seed and
#: entry-point audits are separate passes over the tree / the runtime)
RULES: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule to the registry."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    RULES[inst.id] = inst
    return cls


def _selected(rules: Optional[Sequence[str]]) -> List[Rule]:
    # imported for side effect: populates RULES on first use
    from . import rules as _rules  # noqa: F401

    if rules is None:
        return list(RULES.values())
    unknown = [r for r in rules if r not in RULES]
    if unknown:
        raise ValueError(f"unknown rule ids {unknown}; have {sorted(RULES)}")
    return [RULES[r] for r in rules]


# ---------------------------------------------------------------------------
# Scanning
# ---------------------------------------------------------------------------


def iter_py_files(root: Path) -> Iterator[Path]:
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" in p.parts:
            continue
        yield p


def scan_source(text: str, path: str,
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the AST rules over one source string.

    `path` is the virtual scan-root-relative path the snippet pretends
    to live at — rule scoping and boundary whitelists key on it.  This
    is the fixture-test entry point and the doctest surface.
    """
    mod = ModuleSource(path, text)
    findings: List[Finding] = []
    for rule in _selected(rules):
        if not rule.applies(mod.path):
            continue
        for f in rule.check(mod):
            if not mod.disabled(f.line, f.rule):
                findings.append(f)
    return sorted(findings)


def scan_tree(root: Path,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the AST rules over every module under `root` (the directory
    containing the `repro` package); returns sorted findings."""
    root = Path(root)
    findings: List[Finding] = []
    for p in iter_py_files(root):
        rel = p.relative_to(root).as_posix()
        try:
            text = p.read_text()
            findings.extend(scan_source(text, rel, rules=rules))
        except SyntaxError as e:
            findings.append(Finding(
                path=rel, line=e.lineno or 0, rule="parse-error",
                message=f"could not parse: {e.msg}", snippet=""))
    return sorted(findings)


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> Counter:
    """Fingerprint multiset from a baseline file (empty if missing)."""
    path = Path(path)
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    return Counter(tuple(fp) for fp in data.get("fingerprints", []))


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    """Write the grandfathered-findings baseline (sorted, versioned)."""
    fps = sorted(f.fingerprint() for f in findings)
    Path(path).write_text(json.dumps(
        {"version": 1, "count": len(fps), "fingerprints": fps},
        indent=1) + "\n")


def partition_findings(
    findings: Sequence[Finding], baseline: Counter,
) -> Tuple[List[Finding], List[Finding]]:
    """Split into (new, grandfathered) against the baseline multiset."""
    budget = Counter(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in sorted(findings):
        fp = f.fingerprint()
        if budget[fp] > 0:
            budget[fp] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old
