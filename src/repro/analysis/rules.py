"""The tracelint AST rules — one class per enforced invariant.

Rule ids (stable; pragmas, the baseline, and ARCHITECTURE.md key on
them):

* ``host-sync`` — no device→host synchronization inside the protected
  packages outside whitelisted boundary functions.
* ``retrace-hazard`` — shape/data-derived values must pass through the
  pow2 bucket helpers before reaching jit static args or compiled-cache
  keys; no un-memoized jit construction inside function bodies; no
  mutable defaults on jitted/cached functions.
* ``sorted-ell`` — every write to a `nbr` adjacency routes through the
  approved sort/splice helpers.
* ``cache-key`` — compiled-function caches must be registered in
  `config.CACHE_SCHEMAS` and key on their full declared tuple.
* ``pallas-kernel`` — kernel bodies use `lax` loops (not Python loops
  over possibly-traced dims); `pallas_call` specs stay consistent.

All rules are heuristic in the way static analysis must be: they see
names and shapes of expressions, not values.  Each rule's docstring
states exactly what is matched so a reader can predict (and with a
``# tracelint: disable=`` pragma, override) any individual verdict.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

from . import config
from .engine import Finding, ModuleSource, Rule, register


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.device_get' for Attribute chains / Names; None otherwise."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def contains_call(node: ast.AST, names: Iterable[str]) -> bool:
    """True if any descendant Call's dotted name (or its last component)
    is in `names`."""
    names = set(names)
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name and (name in names or name.split(".")[-1] in names):
                return True
    return False


def _mentions_jax(node: ast.AST) -> bool:
    """True if the expression subtree references jax/jnp (so `int(...)`
    of it plausibly blocks on a device value)."""
    for sub in ast.walk(node):
        name = dotted_name(sub) or ""
        if name.startswith(("jnp.", "jax.")) or name in ("jnp", "jax"):
            return True
    return False


#: compound statements own nested statements; yielding them alongside
#: their children would double-count (and over-sanction) every nested site
_COMPOUND_STMTS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef,
                   ast.If, ast.For, ast.AsyncFor, ast.While, ast.With,
                   ast.AsyncWith, ast.Try)


def _statements(tree: ast.AST) -> Iterator[ast.stmt]:
    """Leaf (non-compound) statements of a module."""
    for node in ast.walk(tree):
        if isinstance(node, ast.stmt) and not isinstance(
                node, _COMPOUND_STMTS):
            yield node


def _decorator_names(fn: ast.AST) -> List[str]:
    """Dotted names of a def's decorators; `partial(jax.jit, ...)` and
    `lru_cache(...)` report their callee ('functools.partial' resolves
    to its first argument's name)."""
    out: List[str] = []
    for dec in getattr(fn, "decorator_list", []):
        if isinstance(dec, ast.Call):
            name = dotted_name(dec.func) or ""
            if name.split(".")[-1] == "partial" and dec.args:
                inner = dotted_name(dec.args[0])
                if inner:
                    out.append(inner)
                    continue
            out.append(name)
        else:
            out.append(dotted_name(dec) or "")
    return out


def _is_jit_name(name: str) -> bool:
    return name.split(".")[-1] == "jit"


def _is_cache_decorator(name: str) -> bool:
    return name.split(".")[-1] in ("lru_cache", "cache")


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------


@register
class HostSyncRule(Rule):
    """No device→host pulls in the protected device-loop packages.

    Flags, inside `config.SYNC_SCOPE` files and outside whitelisted
    boundary functions (`config.HOST_BOUNDARIES` or a
    ``# tracelint: boundary`` def-line pragma):

    * ``jax.device_get(...)`` calls,
    * ``.item()`` calls (host scalar extraction),
    * ``np.asarray(...)`` / ``np.array(...)`` — a blocking transfer
      when the argument is a device array, and it bypasses
      `jax.device_get` (so counter-based tests never see it),
    * ``int(x)`` / ``float(x)`` / ``bool(x)`` where `x` mentions
      jax/jnp — a blocking sync on a traced/device value.  Exempt when
      the argument already contains a `device_get` (that call is the
      finding; flagging both would double-count one transfer).

    The zero-steady-state-sync contract these protect is the load-
    bearing performance invariant of the whole runtime: ONE bundled
    transfer per stream window / query batch / fixpoint, everything
    else stays on device (ARCHITECTURE.md "Enforced invariants").
    """

    id = "host-sync"
    summary = "device→host sync outside a whitelisted boundary"

    def applies(self, path: str) -> bool:
        return config.in_sync_scope(path)

    def check(self, mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            kind = self._sync_kind(node)
            if kind is None or mod.is_boundary(node):
                continue
            yield mod.finding(
                self.id, node,
                f"{kind} synchronizes host and device inside a protected "
                "device loop; move it behind a whitelisted boundary "
                "function or keep the value on device")

    @staticmethod
    def _sync_kind(node: ast.Call) -> Optional[str]:
        name = call_name(node)
        if name in ("jax.device_get", "device_get"):
            return "jax.device_get()"
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
                and not node.args and not node.keywords):
            return ".item()"
        if name in ("np.asarray", "numpy.asarray", "np.array",
                    "numpy.array"):
            return f"{name}() on a (possibly device) array"
        if (name in ("int", "float", "bool") and len(node.args) == 1
                and not node.keywords and _mentions_jax(node.args[0])
                and not contains_call(node.args[0], {"device_get"})):
            return f"{name}() on a jax value"
        return None


# ---------------------------------------------------------------------------
# retrace-hazard
# ---------------------------------------------------------------------------


@register
class RetraceHazardRule(Rule):
    """Shape-derived statics must be pow2-bucketed; jit wrappers must be
    memoized; static/cached args must be hashable.

    Three checks inside `config.SYNC_SCOPE`:

    1. **Unbucketed shape-derived scalar**: an ``int(...)`` or
       ``jax.device_get(...)`` whose argument reads ``.shape`` or
       reduces a degree vector (``jnp.max/min(... .deg ...)``) produces
       a value that varies with the data — if it reaches a jit static
       argument or cache key, every distinct value is a fresh compile.
       The statement must route the value through one of the
       `config.BUCKET_HELPERS` (`_pow2_bucket` & co.); the helpers'
       own bodies are exempt.
    2. **Un-memoized nested jit**: calling ``jax.jit`` inside a
       function body builds a NEW compiled callable per call — its
       cache is thrown away every time.  Exempt when an enclosing
       function carries `lru_cache`/`cache` (the `_compiled_*` pattern)
       or is a registered factory (`config.JIT_FACTORIES`).
    3. **Mutable default on a jitted/cached def**: a list/dict/set
       default on a function under `jax.jit` or `lru_cache` is either
       unhashable (TypeError at call time) or a shared mutable key.
    """

    id = "retrace-hazard"
    summary = "shape-derived static / un-memoized jit / unhashable key"

    def applies(self, path: str) -> bool:
        return config.in_sync_scope(path)

    def check(self, mod: ModuleSource) -> Iterator[Finding]:
        yield from self._check_shape_derived(mod)
        yield from self._check_nested_jit(mod)
        yield from self._check_mutable_defaults(mod)

    # -- 1: unbucketed shape-derived host scalars --------------------------

    def _check_shape_derived(self, mod: ModuleSource) -> Iterator[Finding]:
        for stmt in _statements(mod.tree):
            if contains_call(stmt, config.BUCKET_HELPERS):
                continue  # bucketed somewhere in this statement: sanctioned
            for node in ast.walk(stmt):
                if not (isinstance(node, ast.Call)
                        and call_name(node) in (
                            "int", "jax.device_get", "device_get")
                        and node.args):
                    continue
                if not self._shape_derived(node.args[0]):
                    continue
                names = mod.enclosing_names(node)
                if any(n in config.BUCKET_HELPERS for n in names):
                    continue  # inside a bucket helper itself
                yield mod.finding(
                    self.id, node,
                    "shape/degree-derived host scalar never passes a pow2 "
                    "bucket helper (_pow2_bucket/degree_bound/...): as a "
                    "jit static or cache key it compiles once per "
                    "distinct value")
                break  # one finding per statement is enough

    @staticmethod
    def _shape_derived(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr == "shape":
                return True
            if (isinstance(sub, ast.Call)
                    and (call_name(sub) or "").split(".")[-1]
                    in ("max", "min")):
                if any(isinstance(s, ast.Attribute) and s.attr == "deg"
                       for s in ast.walk(sub)):
                    return True
        return False

    # -- 2: nested, un-memoized jit ----------------------------------------

    def _check_nested_jit(self, mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and _is_jit_name(call_name(node) or "")):
                continue
            enclosing = mod.enclosing_functions(node)
            if not enclosing:
                continue  # module-level jit: compiled once, cached forever
            if any(n in config.JIT_FACTORIES
                   for n in mod.enclosing_names(node)):
                continue
            if any(_is_cache_decorator(d)
                   for f in enclosing for d in _decorator_names(f)):
                continue  # the lru_cache'd _compiled_* factory pattern
            yield mod.finding(
                self.id, node,
                "jax.jit(...) built inside a function body without an "
                "enclosing lru_cache: a fresh compiled callable (and a "
                "thrown-away trace cache) per call")

    # -- 3: mutable defaults on jitted/cached defs -------------------------

    def _check_mutable_defaults(self, mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            decs = _decorator_names(node)
            if not any(_is_jit_name(d) or _is_cache_decorator(d)
                       for d in decs):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                if isinstance(d, (ast.List, ast.Dict, ast.Set)):
                    yield mod.finding(
                        self.id, d,
                        f"mutable default on jitted/cached `{node.name}`: "
                        "unhashable as a static/cache key (and shared "
                        "across calls)")


# ---------------------------------------------------------------------------
# sorted-ell
# ---------------------------------------------------------------------------


@register
class SortedEllRule(Rule):
    """Every `nbr` write routes through the approved sort/splice helpers.

    The sorted-ELL invariant (valid slots of every adjacency row
    ascending, PAD=-1 slots packed right) is what the merge-intersection
    triangle kernel and the O(log Cd) row probes rely on; ONE unsorted
    write anywhere silently corrupts their results.

    Flags, in every non-seed `repro` module, writes to a `nbr` target —
    ``nbr[...] = ...`` / ``g.nbr[...] = ...`` subscript stores,
    ``... .nbr.at[...].set/add/max/min(...)`` functional updates, and
    ``nbr=`` keyword arguments to `dataclasses.replace` /
    `GraphBlocks(...)` — unless the written value's expression contains
    a call to an approved helper (`config.SORTED_ELL_HELPERS`: the
    sort + the four splice routines) or the enclosing function is an
    approved raw writer (`config.SORTED_ELL_WRITERS`: the helpers
    themselves and the constructors that end with `sort_nbr_rows`).

    A bare-name value is resolved ONE assignment deep inside the
    enclosing function: ``nbr = g.nbr.at[u].set(_sorted_insert_row(...))``
    followed by ``replace(g, nbr=nbr)`` is approved, because the local's
    defining statement routes through a helper.  Deeper dataflow is out
    of scope — thread the helper call within one assignment of the write.

    Matching is exact on the name ``nbr`` (so `nbr_local`, halo tables
    etc. never trigger).
    """

    id = "sorted-ell"
    summary = "nbr write bypassing the sorted-ELL helpers"

    _AT_SETTERS = ("set", "add", "max", "min", "mul", "apply")

    def applies(self, path: str) -> bool:
        return path.startswith("repro/") and not config.is_seed(path)

    def check(self, mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            for site, value in self._nbr_writes(node):
                if self._approved(mod, site, value):
                    continue
                yield mod.finding(
                    self.id, site,
                    "write to `nbr` bypasses the approved sorted-ELL "
                    "helpers (sort_nbr_rows / _sorted_insert_row / "
                    "_sorted_delete_row / _insert_sorted / "
                    "_delete_sorted): an unsorted row breaks the "
                    "merge-intersection and binary-probe kernels")

    def _approved(self, mod: ModuleSource, site: ast.AST,
                  value: Optional[ast.AST]) -> bool:
        if value is not None and contains_call(
                value, config.SORTED_ELL_HELPERS):
            return True
        if isinstance(value, ast.Name) and self._local_routes_through(
                mod, site, value.id):
            return True
        return any(n in config.SORTED_ELL_WRITERS
                   for n in mod.enclosing_names(site))

    @staticmethod
    def _local_routes_through(mod: ModuleSource, site: ast.AST,
                              name: str) -> bool:
        """One-deep dataflow: does a local assignment `name = ...` in the
        enclosing function route through an approved helper?"""
        fns = mod.enclosing_functions(site)
        if not fns:
            return False
        for stmt in ast.walk(fns[-1]):
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, ast.AnnAssign):
                targets = [stmt.target]
            else:
                continue
            if stmt.value is None:
                continue
            if any(isinstance(t, ast.Name) and t.id == name
                   for t in targets) and contains_call(
                       stmt.value, config.SORTED_ELL_HELPERS):
                return True
        return False

    @classmethod
    def _nbr_writes(
        cls, node: ast.AST,
    ) -> Iterator[Tuple[ast.AST, Optional[ast.AST]]]:
        """(site, written-value) pairs for `nbr` mutations at `node`."""
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                elts = t.elts if isinstance(t, ast.Tuple) else [t]
                for e in elts:
                    if cls._is_nbr_store_target(e):
                        yield e, getattr(node, "value", None)
        elif isinstance(node, ast.Call):
            # <...>.nbr.at[...].set(value)
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr in cls._AT_SETTERS
                    and isinstance(f.value, ast.Subscript)
                    and isinstance(f.value.value, ast.Attribute)
                    and f.value.value.attr == "at"
                    and cls._is_nbr_ref(f.value.value.value)):
                val = node.args[0] if node.args else None
                yield node, val
            # dataclasses.replace(g, nbr=...) / GraphBlocks(..., nbr=...)
            name = (call_name(node) or "").split(".")[-1]
            if name in ("replace", "GraphBlocks"):
                for kw in node.keywords:
                    if kw.arg == "nbr":
                        yield node, kw.value

    @classmethod
    def _is_nbr_store_target(cls, t: ast.AST) -> bool:
        if isinstance(t, ast.Subscript):
            return cls._is_nbr_ref(t.value)
        return isinstance(t, ast.Attribute) and t.attr == "nbr"

    @staticmethod
    def _is_nbr_ref(node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id == "nbr"
        return isinstance(node, ast.Attribute) and node.attr == "nbr"


# ---------------------------------------------------------------------------
# cache-key
# ---------------------------------------------------------------------------


@register
class CacheKeyRule(Rule):
    """Compiled-function caches must register and carry their full key.

    Two cache-site patterns are detected inside `config.SYNC_SCOPE`:

    * ``@functools.lru_cache`` / ``@cache`` defs — the parameter list IS
      the key; it must include every name in the site's registered
      schema (`config.CACHE_SCHEMAS`, keyed ``path::funcname``).
    * dict caches — an (ann)assignment of a dict literal to a name or
      attribute containing ``cache`` (e.g. ``self._step_cache = {}``).
      Every tuple key stored/looked up on that name in the module (via
      ``[...]``, ``.get``, ``.setdefault``, or a `key = (...)` local
      resolved one assignment deep) must mention every schema name —
      element names are the trailing identifier (`ex.wm.mesh` counts
      as ``mesh``); string/number literals are free discriminators.

    A detected site with NO schema entry is itself a finding: new
    caches must declare their key in `config.CACHE_SCHEMAS` so the
    reviewer sees exactly what the compiled artifact varies over —
    under-keyed caches (the (mesh, H) bugs of PRs 2-6) silently serve
    stale compilations when a forgotten axis changes.
    """

    id = "cache-key"
    summary = "unregistered or under-keyed compiled-function cache"

    def applies(self, path: str) -> bool:
        return config.in_sync_scope(path)

    def check(self, mod: ModuleSource) -> Iterator[Finding]:
        yield from self._check_lru_sites(mod)
        yield from self._check_dict_sites(mod)

    # -- lru_cache sites ---------------------------------------------------

    def _check_lru_sites(self, mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(_is_cache_decorator(d)
                       for d in _decorator_names(node)):
                continue
            key = f"{mod.path}::{node.name}"
            schema = config.CACHE_SCHEMAS.get(key)
            if schema is None:
                yield mod.finding(
                    self.id, node,
                    f"lru_cache site `{node.name}` is not registered; add "
                    f'"{key}" with its key names to '
                    "analysis/config.CACHE_SCHEMAS")
                continue
            params = {a.arg for a in (node.args.posonlyargs + node.args.args
                                      + node.args.kwonlyargs)}
            missing = [s for s in schema if s not in params]
            if missing:
                yield mod.finding(
                    self.id, node,
                    f"lru_cache site `{node.name}` is missing registered "
                    f"key fields {missing}: cached compilations would be "
                    "shared across values that must not share them")

    # -- dict cache sites --------------------------------------------------

    def _check_dict_sites(self, mod: ModuleSource) -> Iterator[Finding]:
        sites = {}  # cache attr/name -> defining node
        for node in ast.walk(mod.tree):
            target = value = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                target, value = node.target, node.value
            if target is None or not isinstance(value, ast.Dict):
                continue
            name = (target.id if isinstance(target, ast.Name)
                    else target.attr if isinstance(target, ast.Attribute)
                    else None)
            if name and "cache" in name.lower():
                sites.setdefault(name, node)
        for name, site in sites.items():
            key = f"{mod.path}::{name}"
            schema = config.CACHE_SCHEMAS.get(key)
            if schema is None:
                yield mod.finding(
                    self.id, site,
                    f"dict cache `{name}` is not registered; add "
                    f'"{key}" with its key names to '
                    "analysis/config.CACHE_SCHEMAS")
                continue
            for use, key_expr in self._key_exprs(mod, name):
                tup = self._resolve_tuple(mod, use, key_expr)
                if tup is None:
                    continue  # opaque key expression: nothing to verify
                names = {n for n in map(self._element_name, tup.elts) if n}
                missing = [s for s in schema if s not in names]
                if missing:
                    yield mod.finding(
                        self.id, use,
                        f"cache key for `{name}` is missing registered "
                        f"fields {missing}: a change in those would "
                        "silently reuse a stale compilation")

    @staticmethod
    def _key_exprs(mod: ModuleSource,
                   name: str) -> Iterator[Tuple[ast.AST, ast.AST]]:
        """(usage-node, key-expression) for subscripts / .get / .setdefault
        on the cache called `name`."""
        def is_cache_ref(n: ast.AST) -> bool:
            return ((isinstance(n, ast.Name) and n.id == name)
                    or (isinstance(n, ast.Attribute) and n.attr == name))

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Subscript) and is_cache_ref(node.value):
                yield node, node.slice
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in ("get", "setdefault", "pop")
                  and is_cache_ref(node.func.value) and node.args):
                yield node, node.args[0]

    @staticmethod
    def _resolve_tuple(mod: ModuleSource, use: ast.AST,
                       expr: ast.AST) -> Optional[ast.Tuple]:
        if isinstance(expr, ast.Tuple):
            return expr
        if isinstance(expr, ast.Name):
            # one-assignment-deep local resolution within the same function
            funcs = mod.enclosing_functions(use)
            scope = funcs[-1] if funcs else mod.tree
            found = None
            for node in ast.walk(scope):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == expr.id
                        and isinstance(node.value, ast.Tuple)):
                    found = node.value
            return found
        return None

    @staticmethod
    def _element_name(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Constant):
            return None  # literal discriminators are free
        name = dotted_name(node)
        if name:
            return name.split(".")[-1]
        if isinstance(node, ast.Call):
            inner = call_name(node)
            return inner.split(".")[-1] if inner else None
        return None


# ---------------------------------------------------------------------------
# pallas-kernel
# ---------------------------------------------------------------------------


@register
class PallasKernelRule(Rule):
    """Kernel bodies loop with `lax`, and `pallas_call` specs line up.

    Scope: ``repro/kernels/ell_*.py``.  Inside functions named
    ``*_kernel`` (the functions handed to `pl.pallas_call`):

    * ``while`` statements and ``for ... in range(x)`` where `x` is not
      an integer literal (or a module-level integer constant like
      ``CHUNK``) are flagged — a Python loop over a traced/parameter
      dim unrolls unboundedly at trace time; the idiom is
      `jax.lax.fori_loop` (static unrolls over literal widths and
      `zip`s of refs are fine and not matched).

    For every ``pl.pallas_call(...)``:

    * literal ``out_shape`` and ``out_specs`` lists must have equal
      lengths;
    * a literal ``in_specs`` list must match the positional argument
      count of the immediately-applied call (skipped when the call
      site uses ``*args`` or builds specs programmatically);
    * a literal ``grid`` tuple fixes the arity of every literal
      `BlockSpec` index_map lambda in the specs.
    """

    id = "pallas-kernel"
    summary = "Python loop over traced dim / inconsistent pallas specs"

    def applies(self, path: str) -> bool:
        import fnmatch

        return (fnmatch.fnmatch(path, "repro/kernels/ell_*.py")
                and not config.is_seed(path))

    def check(self, mod: ModuleSource) -> Iterator[Finding]:
        yield from self._check_kernel_loops(mod)
        yield from self._check_pallas_calls(mod)

    # -- loops inside *_kernel bodies --------------------------------------

    def _check_kernel_loops(self, mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, (ast.While, ast.For))):
                continue
            names = mod.enclosing_names(node)
            if not any(n.endswith("_kernel") for n in names):
                continue
            if isinstance(node, ast.While):
                yield mod.finding(
                    self.id, node,
                    "`while` inside a Pallas kernel body: use "
                    "jax.lax.while_loop/fori_loop (a Python loop over a "
                    "traced dim unrolls at trace time)")
                continue
            it = node.iter
            if (isinstance(it, ast.Call)
                    and (call_name(it) or "") == "range"
                    and not all(self._static_int(a, mod) for a in it.args)):
                yield mod.finding(
                    self.id, node,
                    "`for ... in range(<non-literal>)` inside a Pallas "
                    "kernel body: if the bound is a traced or parameter "
                    "dim this unrolls unboundedly; use jax.lax.fori_loop")

    @staticmethod
    def _static_int(node: ast.AST, mod: ModuleSource) -> bool:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return True
        return isinstance(node, ast.Name) and node.id in mod.int_constants

    # -- pallas_call spec consistency --------------------------------------

    def _check_pallas_calls(self, mod: ModuleSource) -> Iterator[Finding]:
        outer_of = {}  # id(pallas_call Call) -> applying Call
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Call)
                    and self._is_pallas_call(node.func)):
                outer_of[id(node.func)] = node
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and self._is_pallas_call(node)):
                continue
            kw = {k.arg: k.value for k in node.keywords if k.arg}
            yield from self._check_out_arity(mod, node, kw)
            yield from self._check_in_arity(mod, node, kw,
                                            outer_of.get(id(node)))
            yield from self._check_index_maps(mod, node, kw)

    @staticmethod
    def _is_pallas_call(node: ast.Call) -> bool:
        return (call_name(node) or "").split(".")[-1] == "pallas_call"

    def _check_out_arity(self, mod, node, kw) -> Iterator[Finding]:
        out_shape, out_specs = kw.get("out_shape"), kw.get("out_specs")
        if (isinstance(out_shape, (ast.List, ast.Tuple))
                and isinstance(out_specs, (ast.List, ast.Tuple))
                and len(out_shape.elts) != len(out_specs.elts)):
            yield mod.finding(
                self.id, node,
                f"pallas_call out_shape has {len(out_shape.elts)} entries "
                f"but out_specs has {len(out_specs.elts)}")

    def _check_in_arity(self, mod, node, kw, outer) -> Iterator[Finding]:
        in_specs = kw.get("in_specs")
        if not isinstance(in_specs, (ast.List, ast.Tuple)):
            return
        if any(not isinstance(e, (ast.Call, ast.Name))
               for e in in_specs.elts):
            return  # comprehension/star pieces: built programmatically
        if outer is None or any(isinstance(a, ast.Starred)
                                for a in outer.args):
            return
        if len(in_specs.elts) != len(outer.args):
            yield mod.finding(
                self.id, node,
                f"pallas_call declares {len(in_specs.elts)} in_specs but "
                f"is applied to {len(outer.args)} positional arrays")

    def _check_index_maps(self, mod, node, kw) -> Iterator[Finding]:
        grid = kw.get("grid")
        if isinstance(grid, ast.Tuple):
            glen = len(grid.elts)
        elif grid is not None and not isinstance(grid, ast.Tuple):
            glen = 1
        else:
            return
        specs: List[ast.AST] = []
        for key in ("in_specs", "out_specs"):
            v = kw.get(key)
            if isinstance(v, (ast.List, ast.Tuple)):
                specs.extend(v.elts)
            elif v is not None:
                specs.append(v)
        for spec in specs:
            if not (isinstance(spec, ast.Call)
                    and (call_name(spec) or "").split(".")[-1]
                    == "BlockSpec"):
                continue
            lam = None
            for cand in list(spec.args) + [k.value for k in spec.keywords]:
                if isinstance(cand, ast.Lambda):
                    lam = cand
            if lam is None:
                continue
            arity = len(lam.args.args)
            if arity != glen:
                yield mod.finding(
                    self.id, spec,
                    f"BlockSpec index_map takes {arity} args but the grid "
                    f"has {glen} dimension(s)")
