"""The entry-point audit: transfer budgets + jaxpr purity, as a manifest.

The per-feature counter tests (`tests/test_device_fixpoints.py`,
`tests/test_service.py`) pin "ONE device_get per window / batch /
fixpoint" for the paths they grew up with.  This module generalizes
that folklore into a declarative manifest: every registered public
entry point states its **transfer budget** (how many `jax.device_get`
calls one execution may make) and, where the entry is a pure jitted
function, a **jaxpr probe** asserting its lowered program contains no
callback/infeed/outfeed primitives — the primitives through which a
host dependency could hide from the transfer counter.

Budgets count `jax.device_get` calls only (parity with the existing
counter tests).  `int()`/`np.asarray()` blocking syncs do NOT route
through `device_get` — those are the host-sync AST rule's job; the two
passes are complementary, not redundant.

Everything runs on a tiny deterministic graph (two blocks, a few path
components), so the audit is cheap enough for CI and for
`tests/test_tracelint.py` to run wholesale.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

from .engine import Finding

RULE_ID = "entrypoint-audit"


# ---------------------------------------------------------------------------
# Transfer counting (the same patch the counter tests use)
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def count_device_gets():
    """Context manager counting `jax.device_get` calls; yields a 1-box."""
    import jax

    box = [0]
    real = jax.device_get

    def counting(x):
        box[0] += 1
        return real(x)

    jax.device_get = counting
    try:
        yield box
    finally:
        jax.device_get = real


# ---------------------------------------------------------------------------
# jaxpr purity scan
# ---------------------------------------------------------------------------

#: primitive-name fragments that smuggle host interaction into a jaxpr
FORBIDDEN_FRAGMENTS = ("callback", "infeed", "outfeed")


def forbidden_primitives(closed_jaxpr) -> List[str]:
    """Names of forbidden primitives anywhere in a jaxpr, recursively
    (through pjit/while/cond/scan sub-jaxprs)."""
    bad: List[str] = []
    seen = set()

    def sub_jaxprs(value):
        if hasattr(value, "jaxpr"):         # ClosedJaxpr
            yield value.jaxpr
        elif hasattr(value, "eqns"):        # raw Jaxpr
            yield value
        elif isinstance(value, (tuple, list)):
            for v in value:
                yield from sub_jaxprs(v)

    def walk(jaxpr):
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if any(frag in name for frag in FORBIDDEN_FRAGMENTS):
                bad.append(name)
            for v in eqn.params.values():
                for sub in sub_jaxprs(v):
                    walk(sub)

    walk(closed_jaxpr.jaxpr
         if hasattr(closed_jaxpr, "jaxpr") else closed_jaxpr)
    return bad


# ---------------------------------------------------------------------------
# The manifest
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One audited public entry point.

    `prepare` builds everything host-side (graphs, executors, sessions —
    uncounted) and returns `(fn, args)`; the audit then runs
    `fn(*args)` under the transfer counter and compares against
    `max_device_gets`.  With `probe=True` the audit additionally traces
    `jax.make_jaxpr(fn)(*args)` and asserts the jaxpr is free of
    callback/infeed/outfeed primitives — only set it on pure jittable
    entries (host-boundary drivers cannot be traced whole).
    """

    name: str
    invariant: str           # the prose contract this budget pins down
    max_device_gets: int
    prepare: Callable[[], Tuple[Callable, tuple]]
    probe: bool = False


# -- tiny deterministic world ------------------------------------------------

_CTX: dict = {}


def _tiny_blocks():
    """Two blocks x 8 rows; four 2-node path components per block.

    Small enough to audit in milliseconds, structured enough that an
    insert between two block-0 components is block-local with disjoint
    candidate sets (the clean-window case the stream budget pins).
    """
    if "g" in _CTX:
        return _CTX["g"]
    import numpy as np

    from ..core.graph import build_blocks

    edges = np.asarray(
        [(0, 1), (2, 3), (4, 5), (6, 7),
         (8, 9), (10, 11), (12, 13), (14, 15)], np.int32)
    assign = np.asarray([0] * 8 + [1] * 8, np.int32)
    g = _CTX["g"] = build_blocks(edges, 16, assign, P=2, deg_slack=6)
    return g


def _padded_of(g, orig: int) -> int:
    import numpy as np

    return int(np.flatnonzero(np.asarray(g.orig_id) == orig)[0])


# -- prepare() builders ------------------------------------------------------


def _prep_route_window():
    import jax.numpy as jnp

    from ..runtime.stream import _route_window

    g = _tiny_blocks()
    R, N = 4, g.N
    cand = jnp.zeros((N, R), bool).at[0, 0].set(True).at[2, 0].set(True)
    us = jnp.asarray([0, 0, 0, 0], jnp.int32)
    vs = jnp.asarray([2, 0, 0, 0], jnp.int32)
    ops_ = jnp.asarray([1, 0, 0, 0], jnp.int32)
    valid = jnp.asarray([True, False, False, False])
    fn = lambda c, u, v, o, w: _route_window(c, u, v, o, w, Cn=g.Cn)  # noqa: E731
    return fn, (cand, us, vs, ops_, valid)


def _prep_block_program_cc():
    from ..core.algorithms import connected_components

    g = _tiny_blocks()
    return partial(connected_components, backend="jnp"), (g,)


def _prep_fused_analytics():
    from ..core.algorithms import fused_analytics

    g = _tiny_blocks()
    return partial(fused_analytics, backend="jnp", steps=4), (g,)


def _prep_coreness(backend: str):
    from ..kernels import ops

    g = _tiny_blocks()
    return partial(ops.coreness_blocks, backend=backend), (g,)


def _prep_spmd_hindex():
    import jax.numpy as jnp

    from ..runtime.spmd import SpmdExecutor

    g = _tiny_blocks()
    if "ex" not in _CTX:
        _CTX["ex"] = SpmdExecutor(g)
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    return _CTX["ex"].hindex, (est,)


def _prep_spmd_coreness():
    from ..runtime.spmd import SpmdExecutor

    g = _tiny_blocks()
    if "ex" not in _CTX:
        _CTX["ex"] = SpmdExecutor(g)
    return _CTX["ex"].coreness, ()


def _copy_graph(g):
    """Deep-copy a GraphBlocks pytree: the stream path CONSUMES its graph
    via jit buffer donation, and the audit's tiny graph is shared."""
    import jax
    import jax.numpy as jnp

    return jax.tree.map(
        lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, g)


def _prep_apply_window_clean():
    from ..core.kcore import coreness
    from ..runtime.stream import StreamSession

    g = _copy_graph(_tiny_blocks())
    core = coreness(g, backend="jnp")
    sess = StreamSession(g, core, R=4, backend="jnp")
    # block-local insert joining two block-0 path components: candidate
    # sets stay inside the block, the window routes clean (no escalation)
    window = [(_padded_of(g, 0), _padded_of(g, 2), 1)]
    return sess.apply_window, (window,)


def _snapshot():
    if "snap" in _CTX:
        return _CTX["snap"]
    from ..core.algorithms import fused_analytics
    from ..service.state import EpochSnapshot

    g = _tiny_blocks()
    core, labels, rank = fused_analytics(g, backend="jnp", steps=4)
    snap = _CTX["snap"] = EpochSnapshot(
        epoch=0, windows=0, core=core, labels=labels, rank=rank,
        deg=g.deg, nbr=g.nbr, node_mask=g.node_mask, orig_id=g.orig_id)
    return snap


def _prep_run_batch_core():
    from ..service import queries as q

    snap = _snapshot()
    batch = [q.core_of(1), q.core_of(2), q.core_of(3)]
    return partial(q.run_batch, snap, "core"), (batch,)


def _prep_run_batch_topk():
    from ..service import queries as q

    snap = _snapshot()
    k = q.topk_bucket(2, int(snap.core.shape[0]))
    batch = [q.topk_pagerank(2)]
    return partial(q.run_batch, snap, "topk_pagerank", k=k), (batch,)


def _prep_batch_gather_probe():
    import jax.numpy as jnp

    from ..service.queries import _batch_gather

    snap = _snapshot()
    ids = jnp.asarray([0, 1, 2, 3], jnp.int32)
    return _batch_gather, (snap.core, ids)


MANIFEST: Tuple[EntryPoint, ...] = (
    EntryPoint(
        name="stream._route_window",
        invariant="window routing is pure device code: the (N, R) "
                  "candidate matrix never reaches the host",
        max_device_gets=0, prepare=_prep_route_window, probe=True),
    EntryPoint(
        name="ops.run_block_program[cc,jnp]",
        invariant="a fused block program makes no per-superstep "
                  "transfers (one host read of n_real at entry, not "
                  "via device_get)",
        max_device_gets=0, prepare=_prep_block_program_cc),
    EntryPoint(
        name="algorithms.fused_analytics[jnp]",
        invariant="the fused multi-field pass stays on device end to "
                  "end",
        max_device_gets=0, prepare=_prep_fused_analytics),
    EntryPoint(
        name="ops.coreness_blocks[jnp]",
        invariant="the jnp fixpoint is one fused while_loop, zero "
                  "transfers",
        max_device_gets=0,
        prepare=partial(_prep_coreness, "jnp")),
    EntryPoint(
        name="ops.coreness_blocks[ell]",
        invariant="the ELL fixpoint makes exactly ONE transfer: the "
                  "pow2-bucketed degree bound",
        max_device_gets=1,
        prepare=partial(_prep_coreness, "ell")),
    EntryPoint(
        name="SpmdExecutor.hindex",
        invariant="a mesh superstep (halo exchange + kernel) is pure "
                  "device code",
        max_device_gets=0, prepare=_prep_spmd_hindex),
    EntryPoint(
        name="SpmdExecutor.coreness",
        invariant="the fused on-mesh coreness loop transfers at most "
                  "once (the fixpoint pull)",
        max_device_gets=1, prepare=_prep_spmd_coreness),
    EntryPoint(
        name="StreamSession.apply_window[clean]",
        invariant="a clean (non-escalating) stream window makes ONE "
                  "bundled transfer: the compact routing verdict",
        max_device_gets=1, prepare=_prep_apply_window_clean),
    EntryPoint(
        name="queries.run_batch[core]",
        invariant="an answered query batch makes ONE transfer: the "
                  "compact answer array",
        max_device_gets=1, prepare=_prep_run_batch_core),
    EntryPoint(
        name="queries.run_batch[topk_pagerank]",
        invariant="a top-k batch makes ONE transfer: the (values, ids) "
                  "pair",
        max_device_gets=1, prepare=_prep_run_batch_topk),
    EntryPoint(
        name="queries._batch_gather",
        invariant="the query kernels are pure gathers",
        max_device_gets=0, prepare=_prep_batch_gather_probe, probe=True),
)


def run_audit(
    entries: Optional[Sequence[EntryPoint]] = None,
) -> List[Finding]:
    """Execute the manifest; one finding per violated budget/probe."""
    import jax

    findings: List[Finding] = []
    for ep in (MANIFEST if entries is None else entries):
        fn, args = ep.prepare()
        try:
            with count_device_gets() as box:
                out = fn(*args)
                jax.block_until_ready(out)
        except Exception as e:  # an entry that cannot run is a finding
            findings.append(Finding(
                path="<audit>", line=0, rule=RULE_ID,
                message=f"{ep.name}: failed to execute: {e!r}",
                snippet=ep.name))
            continue
        if box[0] > ep.max_device_gets:
            findings.append(Finding(
                path="<audit>", line=0, rule=RULE_ID,
                message=(f"{ep.name}: {box[0]} device_get call(s), budget "
                         f"{ep.max_device_gets} — violated invariant: "
                         f"{ep.invariant}"),
                snippet=ep.name))
        if ep.probe:
            jaxpr = jax.make_jaxpr(fn)(*args)
            bad = forbidden_primitives(jaxpr)
            if bad:
                findings.append(Finding(
                    path="<audit>", line=0, rule=RULE_ID,
                    message=(f"{ep.name}: jaxpr contains host-interaction "
                             f"primitives {sorted(set(bad))}"),
                    snippet=ep.name))
    return findings
