"""tracelint: repo-specific static analysis for the device-loop invariants.

Seven PRs of discipline — sorted-ELL on every mutation path,
pow2-bucketed compiled-cache keys, zero steady-state host syncs and
retraces — live here as machine-checked rules instead of prose:

* :mod:`repro.analysis.engine` — AST scan driver, ``# tracelint:``
  pragmas, the committed-baseline mechanism.
* :mod:`repro.analysis.rules` — the five AST rules (``host-sync``,
  ``retrace-hazard``, ``sorted-ell``, ``cache-key``,
  ``pallas-kernel``).
* :mod:`repro.analysis.config` — boundary whitelists, cache-key
  schemas, bucket-helper and seed-package inventories.
* :mod:`repro.analysis.imports` — the ``dead-seed`` import-graph audit.
* :mod:`repro.analysis.entrypoints` — the ``entrypoint-audit``
  transfer-budget + jaxpr-purity manifest.

CLI: ``python -m repro.analysis --check`` (see ``__main__``); docs:
ARCHITECTURE.md "Enforced invariants".
"""
from .engine import (  # noqa: F401
    Finding,
    ModuleSource,
    Rule,
    RULES,
    load_baseline,
    partition_findings,
    scan_source,
    scan_tree,
    write_baseline,
)
from .entrypoints import MANIFEST, count_device_gets, run_audit  # noqa: F401
from .imports import audit_dead_seed, build_import_graph  # noqa: F401

__all__ = [
    "Finding", "ModuleSource", "Rule", "RULES",
    "scan_source", "scan_tree",
    "load_baseline", "write_baseline", "partition_findings",
    "MANIFEST", "run_audit", "count_device_gets",
    "audit_dead_seed", "build_import_graph",
]
