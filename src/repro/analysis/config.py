"""tracelint configuration: scopes, boundary whitelists, and key schemas.

Everything rule-specific but repo-specific lives here, so the rules
themselves stay mechanical and this file reads as the *inventory of
sanctioned exceptions* to the device-loop invariants:

* `HOST_BOUNDARIES` — the functions allowed to synchronize with the
  device (`jax.device_get` / `np.asarray` / `int()` on arrays).  Every
  entry is a documented host boundary: graph construction, stream
  validation, the ONE bundled transfer per window/batch/fixpoint.
* `CACHE_SCHEMAS` — every known compiled-function cache and the names
  its key must contain.  A cache site detected by pattern (an
  `lru_cache` in scope, or a `*cache*` dict) that is not registered
  here is itself a finding — new caches must declare their key.
* `SEED_PREFIXES` — quarantined seed-substrate packages (LLM configs,
  models/optim/data, launch/distributed).  They are kept as fixtures
  (see the `seed_fixtures` notes in their package `__init__`) and are
  excluded from the sync/retrace rules; the dead-seed import audit
  (`repro.analysis.imports`) is what keeps the quarantine honest.

Paths are POSIX-relative to the scan root (the directory containing the
`repro` package), e.g. ``repro/runtime/spmd.py``.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Tuple

#: packages whose device loops the host-sync / retrace rules protect
SYNC_SCOPE: Tuple[str, ...] = (
    "repro/core/",
    "repro/kernels/",
    "repro/runtime/",
    "repro/service/",
)

#: quarantined seed substrate — excluded from every AST rule; the
#: dead-seed audit checks these carry a `seed_fixtures` note instead
SEED_PREFIXES: Tuple[str, ...] = (
    "repro/models/",
    "repro/optim/",
    "repro/data/",
    "repro/launch/",
    "repro/distributed/",
    "repro/configs/",
)

#: reachability roots for the dead-seed import audit: everything in
#: these packages is product surface; modules *outside* them must be
#: imported (transitively) by them or carry the seed_fixtures marker
REACHABILITY_ROOTS: Tuple[str, ...] = (
    "repro.core",
    "repro.kernels",
    "repro.runtime",
    "repro.service",
    "repro.graphgen",
    # repro.checkpoint is no longer a root of its own: the recovery
    # coordinator (repro.runtime.recovery) imports it, so it is regular
    # product surface reached from repro.runtime
)

#: the literal token a quarantined package's `__init__` docstring must
#: contain for the dead-seed audit to accept it
SEED_MARKER = "seed_fixtures"

#: the pow2 bucket helpers — the ONLY sanctioned way a data/shape-derived
#: host scalar may reach a jit static argument or compiled-cache key.
#: Functions named here are also exempt from the shape-derived check on
#: their own bodies (they ARE the helpers).
BUCKET_HELPERS: FrozenSet[str] = frozenset({
    "_pow2_bucket",
    "_pad_to",
    "_tile_dims",
    "degree_bound",
    "batch_bucket",
    "topk_bucket",
})

#: functions allowed to build a fresh jit wrapper inside another
#: function body without an enclosing lru_cache (they memoize by hand
#: or are themselves called only from memoized sites)
JIT_FACTORIES: FrozenSet[str] = frozenset({
    "_smap",
    "_jitted_worker",  # core/engine.py: WeakKeyDictionary memo per program
})

#: host-boundary whitelist for the host-sync rule.
#:
#: Maps file -> set of function names (innermost OR any enclosing def)
#: allowed to synchronize, or "*" for a whole host-side module.  Every
#: entry documents WHY it is a boundary; anything not listed that pulls
#: from device in SYNC_SCOPE is a finding.
HOST_BOUNDARIES: Dict[str, FrozenSet[str]] = {
    # graph construction / host-side accessors (np arrays in, np out);
    # the jitted mutation path (insert_edge/delete_edge/_sorted_*) is
    # deliberately NOT whitelisted.
    "repro/core/graph.py": frozenset({
        "build_blocks", "build_ell_random", "sort_nbr_rows",
        "n_real", "m_real", "halo_slot_counts", "halo_pair_counts",
        "to_networkx_edges", "migrate_vertices", "edge_exists_host",
        "degree_host", "orig_of",
        # capacity escalation: pad-and-rekey relocation is host numpy on
        # the concrete adjacency, like build_blocks / migrate_vertices
        "grow_blocks", "grow", "relocate_rows", "add_vertices_host",
    }),
    # host splice/validation module: the sanctioned numpy twin of the
    # jitted update path
    "repro/core/updates.py": frozenset({"*"}),
    # hub splitting / mirror-plan maintenance: split planning, replica
    # allocation, and per-edit slice splices are host-boundary work on
    # the concrete adjacency (like halo-plan builds) — the per-superstep
    # merge stage lives in kernels/ops.py and runtime/spmd.py, protected
    "repro/core/hub_split.py": frozenset({"*"}),
    # host-side partitioners (numpy throughout)
    "repro/core/partition.py": frozenset({"*"}),
    "repro/core/partition_dynamic.py": frozenset({"*"}),
    # host Bron-Kerbosch / degree summaries (numpy throughout)
    "repro/core/cliques.py": frozenset({"*"}),
    "repro/core/degree.py": frozenset({"*"}),
    # engine host drivers: one transfer per run / per trace flush
    "repro/core/engine.py": frozenset({"run", "run_jit", "_flush_traces"}),
    # coreness host wrappers: documented host-int returns
    "repro/core/kcore.py": frozenset({
        "coreness_with_stats", "max_coreness",
    }),
    # maintenance host drivers: stream validation + the bundled
    # per-chunk verdict pull; the jitted maintain path stays protected
    "repro/core/kcore_dynamic.py": frozenset({
        "maintain_batch", "maintain_batch_host", "_maintain_one",
        "_maintain_one_spmd", "_validate_updates_host",
        "_independent_prefix", "_spmd_executor",
    }),
    # backend resolution (platform query) + the sanctioned ONE-transfer
    # sites: degree_bound (per fixpoint), run_block_program (n_real at
    # entry), coreness_dense/coreness_blocks (bucketed K pull)
    "repro/kernels/ops.py": frozenset({
        "resolve_backend", "degree_bound", "run_block_program",
        "coreness_dense", "coreness_blocks", "dense_adj", "_pad_ell",
        "ell_lanes",
    }),
    # reference oracles are host-side by design
    "repro/kernels/ref.py": frozenset({"*"}),
    # halo plans are BUILT on host from the concrete adjacency (at open /
    # apply_updates time, never per superstep)
    "repro/runtime/halo.py": frozenset({"*"}),
    "repro/runtime/mesh.py": frozenset({"*"}),
    # executor/engine host shell: plan (re)builds + the one fused-run
    # transfer; compiled supersteps live in _compiled_* (protected)
    "repro/runtime/spmd.py": frozenset({
        "__init__", "apply_updates", "rebuild", "run_spmd", "run",
        "_plan_arrays", "_halo_args", "k_reachable_batch",
        "restricted_recompute", "step_build_count",
        # capacity escalation: full plan rebuild at the new (Cn, Cd),
        # same boundary as rebuild
        "grow", "refresh_fields",
    }),
    # stream host driver: window padding (np), the ONE bundled verdict
    # pull per window, and host routing arithmetic; _route_window and
    # the jitted maintain path are NOT whitelisted
    "repro/runtime/stream.py": frozenset({
        "apply_window", "stats", "_owner_blocks", "owner_block",
        "route_updates", "__init__",
        # elasticity + snapshots: grow/add_vertices/migrate mutate the
        # concrete host graph (like migrate_vertices); state_dict /
        # from_state are the checkpoint boundary (one bundled transfer
        # per snapshot); _cur/_compose_perm are host id arithmetic
        "grow", "add_vertices", "migrate", "state_dict", "from_state",
        "_cur", "_compose_perm",
    }),
    # crash-recovery coordinator: evacuation planning, window-log replay
    # and the kill/restore drill are host protocol work by construction
    "repro/runtime/recovery.py": frozenset({"*"}),
    # the ONE device_get per answered batch + host padding
    "repro/service/queries.py": frozenset({"run_batch", "_pad_ids"}),
    # snapshot cut/publish: host boundary between stream and serving
    "repro/service/state.py": frozenset({"refresh", "__init__"}),
    "repro/service/metrics.py": frozenset({"*"}),
}

#: every known compiled-function cache and the names its key carries.
#: lru_cache sites key on their parameter list; dict caches key on the
#: tuple expression stored/looked up.  Adding a cache without
#: registering it here is a cache-key finding.
CACHE_SCHEMAS: Dict[str, Tuple[str, ...]] = {
    "repro/runtime/spmd.py::_compiled_hindex": ("mesh", "H", "overlap"),
    "repro/runtime/spmd.py::_compiled_frontier": ("mesh", "H", "overlap"),
    "repro/runtime/spmd.py::_compiled_coreness": ("mesh", "H", "overlap"),
    "repro/runtime/spmd.py::_compiled_reach": ("mesh", "H", "overlap"),
    "repro/runtime/spmd.py::_compiled_recompute": ("mesh", "H", "overlap"),
    "repro/runtime/spmd.py::_step_cache": (
        "mesh", "H", "B", "Cn", "Cd", "overlap", "program", "mirror"),
}

#: approved sorted-ELL splice/sort helpers: a `nbr` write whose value
#: routes through one of these calls preserves the invariant
SORTED_ELL_HELPERS: FrozenSet[str] = frozenset({
    "sort_nbr_rows",
    "_sorted_insert_row",
    "_sorted_delete_row",
    "_insert_sorted",
    "_delete_sorted",
    # hub-split slice splices (host numpy, in-place on one (Cd,) row
    # slice, shift-based like their jnp row twins above)
    "_sorted_slice_insert",
    "_sorted_slice_delete",
})

#: functions allowed to write `nbr` raw: the helpers themselves plus
#: the constructors that establish the invariant with a terminal
#: `sort_nbr_rows` pass and the host applier that splices via the
#: approved helpers row by row
SORTED_ELL_WRITERS: FrozenSet[str] = SORTED_ELL_HELPERS | frozenset({
    "build_blocks",
    "build_ell_random",
    "apply_updates_host",
    # split_hubs rewires slot-by-slot into fresh replica rows, then
    # re-establishes the invariant with a terminal sort_nbr_rows pass;
    # apply_mirrored_edits splices via the approved slice helpers;
    # run_common_mirror's canonicalized view routes through
    # sort_nbr_rows too (the jnp.asarray dtype wrapper hides the call
    # from the value-flow check)
    "split_hubs",
    "apply_mirrored_edits",
    "run_common_mirror",
    # grow_blocks value-remaps nbr through a MONOTONE rekey (row slots
    # keep their relative order, pads stay right-justified), so the
    # sorted-ELL invariant survives without a re-sort — the property
    # tests/test_growth.py checks against a from-scratch rebuild
    "grow_blocks",
    # snapshot restore re-adopts arrays saved from an invariant-holding
    # graph verbatim (checkpoints are bit-exact copies)
    "from_state",
})


def in_sync_scope(path: str) -> bool:
    """True if `path` (root-relative POSIX) is protected by the
    host-sync / retrace rules."""
    return path.startswith(SYNC_SCOPE) and not is_seed(path)


def is_seed(path: str) -> bool:
    """True if `path` lies in a quarantined seed-substrate package."""
    return path.startswith(SEED_PREFIXES)


def boundary_functions(path: str) -> FrozenSet[str]:
    """Whitelisted host-boundary function names for `path`."""
    return HOST_BOUNDARIES.get(path, frozenset())
