"""``python -m repro.analysis`` — the tracelint CLI.

Exit codes: 0 clean (or informational run), 1 non-baselined findings
under ``--check``, 2 usage errors.

Typical invocations::

    python -m repro.analysis                 # scan + audit, print report
    python -m repro.analysis --check         # CI gate: fail on new findings
    python -m repro.analysis --write-baseline  # grandfather current findings
    python -m repro.analysis --rules host-sync,sorted-ell --no-audit
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from . import config  # noqa: F401  (imported for rule side effects)
from . import engine, entrypoints, imports


def _default_root() -> Path:
    """The directory containing the `repro` package (so scanned paths
    read `repro/...`)."""
    import repro

    # `repro` is a namespace package (no __init__.py): locate via __path__
    return Path(next(iter(repro.__path__))).resolve().parent


def _default_baseline(root: Path) -> Path:
    """`tracelint_baseline.json` at the repo root (one above `src/`),
    falling back next to the scan root."""
    repo = root.parent
    cand = repo / "tracelint_baseline.json"
    return cand if repo.is_dir() else root / "tracelint_baseline.json"


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracelint: enforce the BLADYG device-loop invariants")
    p.add_argument("--root", type=Path, default=None,
                   help="scan root (default: the dir containing `repro`)")
    p.add_argument("--baseline", type=Path, default=None,
                   help="baseline file (default: tracelint_baseline.json "
                        "at the repo root)")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if any non-baselined finding remains")
    p.add_argument("--write-baseline", action="store_true",
                   help="grandfather all current findings into the "
                        "baseline file and exit")
    p.add_argument("--rules", type=str, default=None,
                   help="comma-separated rule ids (default: all AST rules)")
    p.add_argument("--no-audit", action="store_true",
                   help="skip the jaxpr/transfer entry-point audit")
    p.add_argument("--no-imports", action="store_true",
                   help="skip the dead-seed import audit")
    p.add_argument("--report", type=Path, default=None,
                   help="write the full findings report as JSON")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    root = args.root if args.root is not None else _default_root()
    if not (root / "repro").is_dir():
        print(f"error: scan root {root} does not contain a `repro` "
              "package", file=sys.stderr)
        return 2
    baseline_path = (args.baseline if args.baseline is not None
                     else _default_baseline(root))
    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)

    findings = engine.scan_tree(root, rules=rules)
    if not args.no_imports and rules is None:
        findings.extend(imports.audit_dead_seed(root))
    if not args.no_audit and rules is None:
        findings.extend(entrypoints.run_audit())
    findings.sort()

    baseline = engine.load_baseline(baseline_path)
    new, grandfathered = engine.partition_findings(findings, baseline)

    if args.write_baseline:
        engine.write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} fingerprint(s) to {baseline_path}")
        return 0

    if args.report is not None:
        args.report.write_text(json.dumps({
            "root": str(root),
            "total": len(findings),
            "new": [f.to_json() for f in new],
            "grandfathered": [f.to_json() for f in grandfathered],
        }, indent=1) + "\n")

    for f in new:
        print(f)
    summary = (f"tracelint: {len(new)} new finding(s), "
               f"{len(grandfathered)} baselined, "
               f"{len(engine.RULES)} AST rules + dead-seed"
               + ("" if args.no_audit else " + entry-point audit"))
    print(summary)
    if args.check and new:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
