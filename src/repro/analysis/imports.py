"""Dead-seed audit: flag seed modules graph code never imports.

The repo grew out of a seed that shipped an LLM-training substrate
(`models/`, `optim/`, `data/`, `launch/`, `distributed/`, the
architecture files in `configs/`).  The BLADYG product surface is
`repro.{core,kernels,runtime,service,graphgen,checkpoint}`; anything
outside it that those packages never (transitively) import is seed
substrate and must be explicitly quarantined — a documented
``seed_fixtures`` note in its package ``__init__`` — rather than
silently riding along as if it were product code.

The import graph is *static and by-name*: an edge exists when a module
names another in an ``import``/``from`` statement (relative imports
resolved).  Parent-package ``__init__`` side effects are deliberately
NOT modeled — importing ``repro.configs.service`` does execute
``repro.configs.__init__`` at runtime, but the audit asks "does graph
code *name* this module", which is the dependency a refactor must
preserve.  The quarantine marker covers the side-effect-loaded rest.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set

from . import config
from .engine import Finding, iter_py_files

RULE_ID = "dead-seed"


def module_name(rel_posix: str) -> str:
    """'repro/core/graph.py' -> 'repro.core.graph';
    'repro/models/__init__.py' -> 'repro.models'."""
    parts = rel_posix[:-3].split("/")  # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def build_import_graph(root: Path) -> Dict[str, Set[str]]:
    """module -> set of (known, in-tree) modules it names."""
    root = Path(root)
    paths = {module_name(p.relative_to(root).as_posix()): p
             for p in iter_py_files(root)}
    known = set(paths)
    edges: Dict[str, Set[str]] = {m: set() for m in known}

    for mod, path in paths.items():
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError:
            continue
        pkg_parts = mod.split(".")
        for node in ast.walk(tree):
            targets: List[str] = []
            if isinstance(node, ast.Import):
                targets = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    # relative: drop `level` trailing components of the
                    # *package* path (a module's package is its parent)
                    base_parts = pkg_parts[:-1] if path.name != "__init__.py" \
                        else pkg_parts
                    base_parts = base_parts[:len(base_parts) - node.level + 1]
                    base = ".".join(base_parts)
                else:
                    base = ""
                stem = (f"{base}.{node.module}" if base and node.module
                        else (node.module or base))
                if stem:
                    targets.append(stem)
                    targets.extend(f"{stem}.{a.name}" for a in node.names)
            for t in targets:
                # longest known prefix of the dotted target
                parts = t.split(".")
                for i in range(len(parts), 0, -1):
                    cand = ".".join(parts[:i])
                    if cand in known and cand != mod:
                        edges[mod].add(cand)
                        break
    return edges


def reachable_modules(edges: Dict[str, Set[str]]) -> Set[str]:
    """Closure of the product-surface roots over the import graph."""
    roots = [m for m in edges
             if any(m == r or m.startswith(r + ".")
                    for r in config.REACHABILITY_ROOTS)]
    seen = set(roots)
    stack = list(roots)
    while stack:
        for nxt in edges.get(stack.pop(), ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return seen


def _quarantined(root: Path, mod: str) -> bool:
    """True if `mod` or any ancestor package carries the seed marker in
    its `__init__` source."""
    parts = mod.split(".")
    for i in range(len(parts), 0, -1):
        init = root.joinpath(*parts[:i]) / "__init__.py"
        if init.exists() and config.SEED_MARKER in init.read_text():
            return True
    return False


def audit_dead_seed(root: Path) -> List[Finding]:
    """Findings for unreachable modules lacking a seed_fixtures note."""
    root = Path(root)
    relpath = {module_name(p.relative_to(root).as_posix()):
               p.relative_to(root).as_posix()
               for p in iter_py_files(root)}
    edges = build_import_graph(root)
    live = reachable_modules(edges)
    findings: List[Finding] = []
    for mod in sorted(edges):
        if mod in live or mod == "repro":
            continue
        if mod.startswith("repro.analysis"):
            continue  # the linter itself is tooling, not product surface
        if _quarantined(root, mod):
            continue
        findings.append(Finding(
            path=relpath.get(mod, mod.replace(".", "/") + ".py"), line=0,
            rule=RULE_ID,
            message=(f"`{mod}` is unreachable from the product packages "
                     f"({', '.join(config.REACHABILITY_ROOTS)}) and its "
                     "package __init__ carries no `seed_fixtures` note: "
                     "either wire it in or quarantine it explicitly"),
            snippet=mod))
    return findings
