"""Pallas TPU kernel: dense-tile h-index sweep (the k-core hot loop).

GPU/actor formulation (paper): per-node gather of neighbor core estimates +
per-node histogram — irregular, pointer-chasing.

TPU reformulation (DESIGN §2): with thresholds k = 1..K,

    cnt = A @ B,   B[v, k-1] = (est[v] >= k)        -> (T×T)@(T×K) MXU matmuls
    h[u] = max{k : cnt[u, k-1] >= k}                -> VPU reduction

The grid is (node_tiles i, node_tiles j); j is a sequential reduction over
adjacency column tiles accumulating into a VMEM scratch of shape (T, K); the
h-index epilogue fires on the last j step.  A is consumed as 0/1 bf16 tiles
(products are exact; f32 accumulation is exact for counts < 2^24).

Alignment: T and K are multiples of 128 (MXU native), so every matmul is
(128m × 128m) @ (128m × 128k) — no padding waste inside the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _hindex_kernel(est_ref, adj_ref, out_ref, acc_ref, *, K: int, nj: int, T: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # B[v, k-1] = (est[v] >= k) for the j-th column tile of nodes
    est_j = est_ref[...]  # (T, 1) int32
    ks = jax.lax.broadcasted_iota(jnp.int32, (T, K), 1) + 1
    B = (est_j >= ks).astype(adj_ref.dtype)  # (T, K)
    acc_ref[...] += jnp.dot(
        adj_ref[...], B, preferred_element_type=jnp.float32
    )

    @pl.when(j == nj - 1)
    def _epilogue():
        cnt = acc_ref[...]  # (T, K) f32 exact counts
        ks1 = (jax.lax.broadcasted_iota(jnp.int32, (T, K), 1) + 1).astype(
            jnp.float32
        )
        # cnt[:, k] is non-increasing in k, so the indicator is
        # prefix-monotone and its sum equals the h-index.
        h = jnp.sum((cnt >= ks1).astype(jnp.int32), axis=1, keepdims=True)
        out_ref[...] = h


@functools.partial(
    jax.jit, static_argnames=("K", "T", "interpret")
)
def hindex_counts(
    adj: jax.Array,
    est: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """h-index of every node; dense adjacency path.

    adj: (N, N) 0/1 (bf16/f32), est: (N,) int32, K: threshold bound —
    exact iff K >= max(est) + 1 (callers use K = max degree + 1).
    N must be a multiple of T; K a multiple of 128 (pad via ops.py wrapper).
    """
    N = adj.shape[0]
    assert adj.shape == (N, N) and est.shape == (N,)
    assert N % T == 0, (N, T)
    assert K % 128 == 0, K
    ni = nj = N // T

    kernel = functools.partial(_hindex_kernel, K=K, nj=nj, T=T)
    out = pl.pallas_call(
        kernel,
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((T, 1), lambda i, j: (j, 0)),  # est column tile
            pl.BlockSpec((T, T), lambda i, j: (i, j)),  # adjacency tile
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((T, K), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(est[:, None], adj)
    return out[:, 0]
