"""Pallas TPU kernels for the BLADYG hot loops, behind a backend registry.

Two kernel families — dense-tile (O(N^2) adjacency, MXU matmuls) and ELL
block-sparse (O(N*Cd), consumes `GraphBlocks.nbr` tiles directly) — plus the
pure-jnp oracles in `ref.py`.  Core code selects between them only through
`ops` (`backend="auto"|"jnp"|"dense"|"ell"`).

Validated in interpret mode against the oracles; TPU is the compile target
(explicit BlockSpec VMEM tiling, MXU-aligned).
"""
from . import ops, ref
from .kcore_hindex import hindex_counts
from .frontier import frontier_step
from .ell_hindex import hindex_ell
from .ell_frontier import frontier_step_ell
from .ell_cc import neighbor_min_ell
from .ell_pagerank import neighbor_sum_ell
from .ell_triangles import neighbor_common_ell
from .ell_multi import neighbor_multi_ell

__all__ = [
    "ops", "ref", "hindex_counts", "frontier_step",
    "hindex_ell", "frontier_step_ell",
    "neighbor_min_ell", "neighbor_sum_ell", "neighbor_common_ell",
    "neighbor_multi_ell",
]
