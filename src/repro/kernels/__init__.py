"""Pallas TPU kernels for the BLADYG hot loops (dense-tile GraphBLAS style).

Validated in interpret mode against the pure-jnp oracles in `ref.py`;
TPU is the compile target (explicit BlockSpec VMEM tiling, MXU-aligned).
"""
from . import ops, ref
from .kcore_hindex import hindex_counts
from .frontier import frontier_step

__all__ = ["ops", "ref", "hindex_counts", "frontier_step"]
