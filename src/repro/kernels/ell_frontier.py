"""Pallas TPU kernel: ELL block-sparse frontier expansion (batched BFS hop).

Sibling of `frontier.py` (dense A @ F formulation) that consumes the
`GraphBlocks` ELL neighbor lists directly — O(N*Cd) memory, no densification.
One masked hop for R stacked frontiers (R concurrent updates, the batched
maintenance axis of `core.kcore_dynamic.maintain_batch`):

    next[u, r] = (exists j: f[nbr[u, j], r]) & eligible[u, r] & ~visited[u, r]

For undirected ELL storage (every edge stored in both endpoint rows) the
gather formulation above equals the scatter-or over outgoing slots, so one
row tile of `nbr` plus the full frontier matrix in VMEM suffices.  Unlike the
dense kernel, `eligible` here carries a per-frontier column axis — batched
maintenance stacks updates with *different* k values, so each column has its
own k-level eligibility mask.

Grid: row tiles i; per tile a `fori_loop` over chunks of `chunk` neighbor
slots gathers `T*chunk` frontier rows at once (`jnp.take`, see the lowering
note in ell_hindex.py) and ORs the chunk-reduced (T, R) hit mask into a
register accumulator — Cd/chunk gather launches instead of Cd single-slot
gathers, amortizing the per-gather latency.  The sweep **early-exits** at
the highest occupied column of the tile (the sorted-ELL invariant of
`core.graph` keeps pads on the right, so column occupancy is monotone),
and is **double-buffered**: the gather for chunk j+1 is issued before the
reduce of chunk j consumes its rows, so on TPU the next DMA overlaps the
current VPU reduction.  Like the h-index kernel, a max-degree column bound
K < Cd (left-filled rows, see `ops.degree_bound`) restricts the sweep to
the first K slots.  The eligibility/visited epilogue is fused (no HBM
round-trip).  Validated in interpret mode against
`ref.ell_frontier_hop_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams

#: neighbor slots gathered per loop iteration (divides 128, so any padded
#: column count is a multiple of it)
CHUNK = 8


def _ell_frontier_kernel(
    nbr_ref, f_ref, elig_ref, vis_ref, out_ref, *, C: int, T: int, chunk: int
):
    nbr = nbr_ref[...]  # (T, C) int32, -1 padded
    f_full = f_ref[...]  # (N, R) int8
    R = f_full.shape[1]

    def gather(j):  # slot ids + frontier rows of chunk j
        idx = jax.lax.dynamic_slice(nbr, (0, j * chunk), (T, chunk))  # (T, c)
        rows = jnp.take(f_full, jnp.clip(idx, 0).reshape(-1), axis=0)
        return idx, rows.reshape(T, chunk, R)  # (T, c, R)

    def body(j, carry):
        acc, (idx, rows) = carry
        nxt = gather(j + 1)  # prefetch j+1 before reducing j (double buffer)
        hit = jnp.any((rows > 0) & (idx >= 0)[:, :, None], axis=1)  # (T, R)
        return acc | hit, nxt

    # early exit: pad-right rows ⇒ ceil(maxcol/chunk) trips cover all slots
    cols_any = jnp.any(nbr >= 0, axis=0)
    maxcol = jnp.max(jnp.where(cols_any, jnp.arange(C, dtype=jnp.int32) + 1, 0))
    trips = (maxcol + chunk - 1) // chunk

    hit, _ = jax.lax.fori_loop(
        0, trips, body, (jnp.zeros((T, R), jnp.bool_), gather(0)))
    out_ref[...] = (
        hit & (elig_ref[...] > 0) & ~(vis_ref[...] > 0)
    ).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret", "chunk"))
def frontier_step_ell(
    nbr: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
    chunk: int = CHUNK,
) -> jax.Array:
    """One masked BFS hop for R stacked frontiers over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded); f: (N, R) 0/1; eligible: (N, R) 0/1 int8
    (per-column k-level masks); visited: (N, R) 0/1 int8.  K is the column
    bound: exact iff valid slots lie in the first K columns (K >= Cd always
    works; K < Cd needs left-filled rows — the `GraphBlocks` invariant).
    Returns the next frontier (N, R) int8.  N % T == 0, Cd % 128 == 0,
    K % 128 == 0, R % 128 == 0 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    R = f.shape[1]
    assert f.shape == (N, R) and visited.shape == (N, R), (f.shape, visited.shape)
    assert eligible.shape == (N, R), eligible.shape
    assert N % T == 0 and Cd % 128 == 0 and R % 128 == 0, (N, T, Cd, R)
    assert K % 128 == 0, K
    C = min(Cd, K)
    assert C % chunk == 0, (C, chunk)
    ni = N // T

    kernel = functools.partial(_ell_frontier_kernel, C=C, T=T, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-list row tile
            pl.BlockSpec((N, R), lambda i: (0, 0)),   # full frontier matrix
            pl.BlockSpec((T, R), lambda i: (i, 0)),   # eligibility tile
            pl.BlockSpec((T, R), lambda i: (i, 0)),   # visited tile
        ],
        out_specs=pl.BlockSpec((T, R), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, R), jnp.int8),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(
        nbr[:, :C], f.astype(jnp.int8), eligible.astype(jnp.int8),
        visited.astype(jnp.int8),
    )
    return out
