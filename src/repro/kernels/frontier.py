"""Pallas TPU kernel: batched frontier expansion (k-reachability BFS hop).

The Theorem-1 candidate search is a BFS restricted to nodes whose coreness
equals k.  One hop for R stacked frontiers (R concurrent updates — the
batched-maintenance optimization in EXPERIMENTS §Perf):

    next = (A @ F > 0) ∧ eligible ∧ ¬visited

A @ F is a (T×T)@(T×R) MXU matmul per adjacency tile — GraphBLAS-style
SpMV-as-matmul; the masking epilogue is VPU elementwise work fused into the
same kernel (no extra HBM round-trip for `hit`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _frontier_kernel(adj_ref, f_ref, elig_ref, vis_ref, out_ref, acc_ref, *, nj: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        adj_ref[...], f_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(j == nj - 1)
    def _epilogue():
        hit = acc_ref[...] > 0.0
        elig = elig_ref[...] > 0  # (T, 1) broadcasts over R
        vis = vis_ref[...] > 0
        out_ref[...] = (hit & elig & ~vis).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("T", "interpret"))
def frontier_step(
    adj: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    T: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """One masked BFS hop for R stacked frontiers.

    adj: (N, N) 0/1 bf16/f32; f: (N, R) 0/1; eligible: (N,) 0/1 int8;
    visited: (N, R) 0/1 int8.  Returns next frontier (N, R) int8.
    N % T == 0 and R % 128 == 0 (pad via ops.py wrapper).
    """
    N, R = f.shape
    assert adj.shape == (N, N) and eligible.shape == (N,)
    assert visited.shape == (N, R)
    assert N % T == 0 and R % 128 == 0, (N, T, R)
    ni = nj = N // T

    kernel = functools.partial(_frontier_kernel, nj=nj)
    out = pl.pallas_call(
        kernel,
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((T, T), lambda i, j: (i, j)),  # adjacency tile
            pl.BlockSpec((T, R), lambda i, j: (j, 0)),  # frontier rows (j!)
            pl.BlockSpec((T, 1), lambda i, j: (i, 0)),  # eligible
            pl.BlockSpec((T, R), lambda i, j: (i, 0)),  # visited
        ],
        out_specs=pl.BlockSpec((T, R), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, R), jnp.int8),
        scratch_shapes=[pltpu.VMEM((T, R), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(adj, f.astype(adj.dtype), eligible[:, None].astype(jnp.int8), visited)
    return out
