"""Pallas TPU kernel: ELL block-sparse neighbor-min sweep (label propagation).

The connected-components hot loop is the "min" neighbor combine of the
`BlockProgram` contract: each superstep every node pulls its neighbors'
current component labels and keeps the minimum.  Structurally this is the
h-index kernel (`ell_hindex.py`) with the row reduction swapped — gather
through the ELL neighbor lists, reduce each row — so it shares the same
tiling:

    nbr[N, Cd]   int32   padded neighbor ids (-1 = empty slot)
    field[N]     int32   current labels (component = min member id)

Per row tile of T nodes (grid axis i):
  1. gather   vals[t, j] = field[nbr[t, j]]     (PAD slots -> int32 max,
              the min-combine's absorbing fill)
  2. reduce   out[t] = min_j vals[t, j]

Rows with no valid slots reduce to int32 max — `BlockProgram.update`
takes `min(own, red)`, so the fill is harmless by construction.  A
max-degree column bound K < Cd (left-filled rows, `ops.degree_bound`)
restricts the sweep like the sibling kernels.  O(N*Cd) memory; the full
label vector rides in VMEM as a (1, N) int32 row, like the estimate
vector of `ell_hindex.py`.  Validated in interpret mode against
`ref.ell_min_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams

#: absorbing fill for the min combine (what PAD slots read as)
MIN_FILL = jnp.iinfo(jnp.int32).max


def _ell_min_kernel(nbr_ref, field_ref, out_ref, *, T: int):
    nbr = nbr_ref[...]  # (T, C) int32, -1 padded
    vals = jnp.where(
        nbr >= 0,
        jnp.take(field_ref[0], jnp.clip(nbr, 0), axis=0),
        MIN_FILL,
    )
    out_ref[...] = jnp.min(vals, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret"))
def neighbor_min_ell(
    nbr: jax.Array,
    field: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise min of neighbor field values over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded); field: (N,) int32; K: column bound —
    exact iff every row's valid slots lie in the first K columns (always
    true for K >= Cd; K < Cd needs left-filled rows, the `GraphBlocks`
    invariant).  Returns (N,) int32 with int32-max on neighborless rows.
    N % T == 0 and Cd, K multiples of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert field.shape == (N,), (field.shape, N)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    C = min(Cd, K)
    ni = N // T

    out = pl.pallas_call(
        functools.partial(_ell_min_kernel, T=T),
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-list row tile
            pl.BlockSpec((1, N), lambda i: (0, 0)),   # full label vector
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], field.astype(jnp.int32)[None, :])
    return out[:, 0]
