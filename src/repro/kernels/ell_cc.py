"""Pallas TPU kernel: ELL block-sparse neighbor-min sweep (label propagation).

The connected-components hot loop is the "min" neighbor combine of the
`BlockProgram` contract: each superstep every node pulls its neighbors'
current component labels and keeps the minimum.  Structurally this is the
h-index kernel (`ell_hindex.py`) with the row reduction swapped — gather
through the ELL neighbor lists, reduce each row — so it shares the same
tiling:

    nbr[N, Cd]   int32   padded neighbor ids (-1 = empty slot)
    field[N]     int32   current labels (component = min member id)

Per row tile of T nodes (grid axis i), a chunked, double-buffered sweep
over the neighbor slots:
  1. trip bound  the sweep **early-exits** at the highest occupied column
                 of the tile — the sorted-ELL invariant (`core.graph`)
                 keeps pads on the right, so column occupancy is monotone
                 and `ceil(maxcol / chunk)` trips cover every valid slot;
  2. gather      each trip pulls a (T, chunk) slot slice and gathers
                 `field[idx]` (PAD slots -> int32 max, the min-combine's
                 absorbing fill) — the *next* trip's gather is issued
                 before the current trip's reduce consumes its values
                 (software double-buffering: on TPU the DMA for trip j+1
                 overlaps the VPU reduce of trip j);
  3. reduce      out[t] = min over trips and chunk slots.

Rows with no valid slots reduce to int32 max — `BlockProgram.update`
takes `min(own, red)`, so the fill is harmless by construction.  A
max-degree column bound K < Cd (left-filled rows, `ops.degree_bound`)
restricts the sweep like the sibling kernels.  O(N*Cd) memory; the full
label vector rides in VMEM as a (1, N) int32 row, like the estimate
vector of `ell_hindex.py`.  Validated in interpret mode against
`ref.ell_min_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams

#: absorbing fill for the min combine (what PAD slots read as)
MIN_FILL = jnp.iinfo(jnp.int32).max

#: neighbor slots gathered per trip (divides 128, so any padded column
#: count is a multiple of it)
CHUNK = 8


def _ell_min_kernel(nbr_ref, field_ref, out_ref, *, C: int, T: int, chunk: int):
    nbr = nbr_ref[...]  # (T, C) int32, -1 padded
    field = field_ref[0]

    def gather(j):  # values of slot chunk j, PAD -> absorbing fill
        idx = jax.lax.dynamic_slice(nbr, (0, j * chunk), (T, chunk))
        vals = jnp.take(field, jnp.clip(idx, 0).reshape(-1), axis=0)
        return jnp.where(idx >= 0, vals.reshape(T, chunk), MIN_FILL)

    def body(j, carry):
        acc, cur = carry
        nxt = gather(j + 1)  # prefetch j+1 before reducing j (double buffer)
        return jnp.minimum(acc, jnp.min(cur, axis=1)), nxt

    # early exit: pad-right rows ⇒ columns past the highest occupied one
    # are all PAD, so ceil(maxcol/chunk) trips suffice
    cols_any = jnp.any(nbr >= 0, axis=0)
    maxcol = jnp.max(jnp.where(cols_any, jnp.arange(C, dtype=jnp.int32) + 1, 0))
    trips = (maxcol + chunk - 1) // chunk

    acc0 = jnp.full((T,), MIN_FILL, jnp.int32)
    acc, _ = jax.lax.fori_loop(0, trips, body, (acc0, gather(0)))
    out_ref[...] = acc[:, None]


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret", "chunk"))
def neighbor_min_ell(
    nbr: jax.Array,
    field: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
    chunk: int = CHUNK,
) -> jax.Array:
    """Row-wise min of neighbor field values over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded); field: (N,) int32; K: column bound —
    exact iff every row's valid slots lie in the first K columns (always
    true for K >= Cd; K < Cd needs left-filled rows, the `GraphBlocks`
    invariant).  Returns (N,) int32 with int32-max on neighborless rows.
    N % T == 0 and Cd, K multiples of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert field.shape == (N,), (field.shape, N)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    C = min(Cd, K)
    assert C % chunk == 0, (C, chunk)
    ni = N // T

    out = pl.pallas_call(
        functools.partial(_ell_min_kernel, C=C, T=T, chunk=chunk),
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-list row tile
            pl.BlockSpec((1, N), lambda i: (0, 0)),   # full label vector
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], field.astype(jnp.int32)[None, :])
    return out[:, 0]
