"""Pallas TPU kernel: ELL block-sparse h-index sweep (k-core hot loop).

The dense-tile kernel in `kcore_hindex.py` materializes an O(N^2) adjacency —
fine for small blocks, fatal at BLADYG scale (the paper's blocks exist
precisely because no worker can hold a dense view).  This kernel consumes the
`GraphBlocks` ELL representation directly:

    nbr[N, Cd] int32   padded neighbor ids (-1 = empty slot)
    est[N]     int32   current coreness estimates

Per row tile of T nodes (grid axis i), two interchangeable variants:

  "sort" (default) — the O(Cd log Cd) path:
    1. gather   vals[t, j] = est[nbr[t, j]]          (PAD slots -> -1)
    2. sort     each row descending (`jax.lax.sort`, bitonic on TPU)
    3. h-index  h[t] = sum_k (vals_desc[t, k] >= k+1)  (position compare)

  "count" — the original O(Cd * K) threshold-count formulation, kept for
    the kernel-variant benchmark sweep (`benchmarks/bench_kernels.py`):
    a `fori_loop` over the Cd neighbor slots accumulates a (T, K) count
    matrix cnt[t, k] = #{j : vals[t, j] >= k+1}, then
    h[t] = sum_k (cnt[t, k] >= k+1).  With K padded to Cd this is O(Cd^2)
    work per node — the asymptotic gap the sort variant removes.

Threshold/sort bound K: because h(u) <= deg(u) <= Cd, any K >= max degree
is exact *when the rows are left-filled* (valid slots before PAD slots).
The `GraphBlocks` **sorted-ELL invariant** implies left-filling: every
construction/mutation path (`build_blocks`, `insert_edge`'s sorted-position
shift-right, `delete_edge`'s shift-left, `migrate_vertices`' re-sort)
keeps valid slots ascending with pads on the right.  Callers that can
bound the max degree (see `ops.degree_bound`) pass K < Cd and the kernel
reads/sorts only the first K neighbor columns; K = Cd is always safe and
assumes nothing about slot order.

Memory: O(N*K) for the neighbor lists + O(N) for estimates, vs O(N^2) for
the dense path.  The full `est` vector rides along in VMEM ((1, N) int32 —
4 bytes/node, ~200 KB at N=50k); at multi-million-N it would be chunked via
HBM DMA, which is the planned multi-device halo-exchange extension.

Validated in interpret mode against `ref.ell_hindex_ref` (the gather inside
the kernel uses `jnp.take`, which Mosaic lowers only on recent TPU gens —
interpret mode is the portable contract, matching `tests/test_kernels.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams

VARIANTS = ("sort", "count")


def _gather_vals(nbr, est_row):
    """vals[t, j] = est[nbr[t, j]]; empty slots contribute -1 (< every k)."""
    return jnp.where(nbr >= 0, jnp.take(est_row[0], jnp.clip(nbr, 0), axis=0), -1)


def _ell_hindex_sort_kernel(nbr_ref, est_ref, out_ref, *, T: int):
    nbr = nbr_ref[...]  # (T, C) int32, -1 padded
    vals = _gather_vals(nbr, est_ref[...])
    # descending in-tile sort: h = sum_k [vals_desc[k] >= k+1] — the
    # indicator is prefix-monotone, so the sum equals the h-index.
    s = -jnp.sort(-vals, axis=1)
    ranks = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + 1
    out_ref[...] = jnp.sum((s >= ranks).astype(jnp.int32), axis=1, keepdims=True)


def _ell_hindex_count_kernel(nbr_ref, est_ref, out_ref, *, K: int, C: int, T: int):
    nbr = nbr_ref[...]  # (T, C) int32, -1 padded
    vals = _gather_vals(nbr, est_ref[...])
    ks = jax.lax.broadcasted_iota(jnp.int32, (T, K), 1) + 1

    # threshold counts, one neighbor slot per iteration (stays in registers)
    def body(j, cnt):
        col = jax.lax.dynamic_slice(vals, (0, j), (T, 1))  # (T, 1)
        return cnt + (col >= ks).astype(jnp.int32)

    cnt = jax.lax.fori_loop(0, C, body, jnp.zeros((T, K), jnp.int32))
    # cnt[:, k] is non-increasing in k -> prefix-monotone indicator
    out_ref[...] = jnp.sum((cnt >= ks).astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret", "variant"))
def hindex_ell(
    nbr: jax.Array,
    est: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
    variant: str = "sort",
) -> jax.Array:
    """h-index of every node from the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded), est: (N,) int32, K: threshold/sort
    bound — exact iff every row's valid slots lie in the first K columns
    and h <= K (always true for K >= Cd; for max-degree-bounded K < Cd the
    rows must be left-filled, the `GraphBlocks` invariant).  When K < Cd
    only the first K neighbor columns are read.  N must be a multiple of T
    and Cd, K multiples of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert est.shape == (N,), (est.shape, N)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; expected {VARIANTS}")
    C = min(Cd, K)  # columns actually read/sorted
    ni = N // T

    if variant == "sort":
        kernel = functools.partial(_ell_hindex_sort_kernel, T=T)
    else:
        kernel = functools.partial(_ell_hindex_count_kernel, K=K, C=C, T=T)
    out = pl.pallas_call(
        kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-list row tile
            pl.BlockSpec((1, N), lambda i: (0, 0)),   # full estimate vector
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], est[None, :])
    return out[:, 0]
