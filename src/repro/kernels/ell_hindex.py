"""Pallas TPU kernel: ELL block-sparse h-index sweep (k-core hot loop).

The dense-tile kernel in `kcore_hindex.py` materializes an O(N^2) adjacency —
fine for small blocks, fatal at BLADYG scale (the paper's blocks exist
precisely because no worker can hold a dense view).  This kernel consumes the
`GraphBlocks` ELL representation directly:

    nbr[N, Cd] int32   padded neighbor ids (-1 = empty slot)
    est[N]     int32   current coreness estimates

Per row tile of T nodes (grid axis i):

    1. gather   vals[t, j] = est[nbr[t, j]]        (PAD slots -> -1)
    2. count    cnt[t, k]  = #{j : vals[t, j] >= k},  k = 1..K
    3. h-index  h[t] = sum_k (cnt[t, k] >= k)       (prefix-monotone)

Step 2 runs as a `fori_loop` over the Cd neighbor slots with a (T, K)
VPU-shaped compare+accumulate per slot — the "in-register h-index sweep":
the counts never leave the tile.  Because h(u) <= deg(u) <= Cd, thresholds
K = Cd (padded to a lane multiple) are always sufficient, so K is static and
the kernel is jit-safe with no data-dependent bound.

Memory: O(N*Cd) for the neighbor lists + O(N) for estimates, vs O(N^2) for
the dense path.  The full `est` vector rides along in VMEM ((1, N) int32 —
4 bytes/node, ~200 KB at N=50k); at multi-million-N it would be chunked via
HBM DMA, which is the planned multi-device halo-exchange extension.

Validated in interpret mode against `ref.ell_hindex_ref` (the gather inside
the kernel uses `jnp.take`, which Mosaic lowers only on recent TPU gens —
interpret mode is the portable contract, matching `tests/test_kernels.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams


def _ell_hindex_kernel(nbr_ref, est_ref, out_ref, *, K: int, Cd: int, T: int):
    nbr = nbr_ref[...]  # (T, Cd) int32, -1 padded
    est_row = est_ref[...]  # (1, N) int32
    # 1. gather neighbor estimates; empty slots contribute -1 (< every k)
    vals = jnp.where(nbr >= 0, jnp.take(est_row[0], jnp.clip(nbr, 0), axis=0), -1)
    ks = jax.lax.broadcasted_iota(jnp.int32, (T, K), 1) + 1

    # 2. threshold counts, one neighbor slot per iteration (stays in registers)
    def body(j, cnt):
        col = jax.lax.dynamic_slice(vals, (0, j), (T, 1))  # (T, 1)
        return cnt + (col >= ks).astype(jnp.int32)

    cnt = jax.lax.fori_loop(0, Cd, body, jnp.zeros((T, K), jnp.int32))

    # 3. cnt[:, k] is non-increasing in k -> the indicator is prefix-monotone
    #    and its sum equals the h-index.
    out_ref[...] = jnp.sum((cnt >= ks).astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret"))
def hindex_ell(
    nbr: jax.Array,
    est: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """h-index of every node from the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded), est: (N,) int32, K: threshold bound —
    exact iff K >= Cd (h <= deg <= Cd always).  N must be a multiple of T and
    Cd a multiple of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert est.shape == (N,), (est.shape, N)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    ni = N // T

    kernel = functools.partial(_ell_hindex_kernel, K=K, Cd=Cd, T=T)
    out = pl.pallas_call(
        kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, Cd), lambda i: (i, 0)),  # neighbor-list row tile
            pl.BlockSpec((1, N), lambda i: (0, 0)),   # full estimate vector
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr, est[None, :])
    return out[:, 0]
