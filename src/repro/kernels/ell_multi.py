"""Pallas TPU kernel: fused multi-field ELL superstep (one adjacency read).

A `MultiProgram` (`core.engine`) advances several `BlockProgram`s in
lockstep — e.g. coreness + CC labels + PageRank.  Run separately, every
sub-program's superstep re-reads the same (N, Cd) ELL adjacency to gather
its own field: three programs, three sweeps of the largest operand on the
roofline.  This kernel fuses the sweep: ONE pallas launch per tile reads
the neighbor-id tile once, computes the slot validity mask and clipped
gather indices once, and then serves every field's gather + named reduce
off that shared index matrix — k fields cost one adjacency read plus k
cheap (N,)-vector reads instead of k full sweeps.

Supported per-field combines (`MULTI_COMBINES` in ops.py): "min" (CC
label propagation, int32), "sum" (PageRank push, float32), "hindex"
(min-H coreness, int32).  "count_common" is excluded — its field is the
(N, Cd) row matrix, which would defeat the shared-gather point.  Each
reduce reproduces the standalone kernel's formulation operation-for-
operation (same gather, same fill, same reduction axis/order), so fused
results are bit-identical to the dedicated `ell_cc` / `ell_pagerank` /
`ell_hindex` launches.

Tiling is the family standard: row tiles of T nodes on grid axis i, each
field riding in VMEM as a (1, N) row, a max-degree column bound K < Cd
honored on left-filled rows.  Validated in interpret mode against the
`ref.py` oracles per field.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams
from .ell_cc import MIN_FILL

#: combines the fused kernel can serve, with their (dtype, pad fill)
_FIELD_SPEC = {
    "min": (jnp.int32, MIN_FILL),
    "sum": (jnp.float32, 0.0),
    "hindex": (jnp.int32, -1),
}


def _reduce_one(combine: str, vals: jax.Array) -> jax.Array:
    """The standalone kernels' row reductions, shared-gather edition."""
    if combine == "min":
        return jnp.min(vals, axis=1, keepdims=True)
    if combine == "sum":
        return jnp.sum(vals, axis=1, keepdims=True)
    # hindex: descending in-tile sort + prefix-monotone position compare
    s = -jnp.sort(-vals, axis=1)
    ranks = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + 1
    return jnp.sum((s >= ranks).astype(jnp.int32), axis=1, keepdims=True)


def _ell_multi_kernel(nbr_ref, *refs, combines: Tuple[str, ...], T: int):
    n = len(combines)
    field_refs, out_refs = refs[:n], refs[n:]
    nbr = nbr_ref[...]          # (T, C) int32, -1 padded — read ONCE
    valid = nbr >= 0            # shared slot validity
    idx = jnp.clip(nbr, 0)      # shared gather indices
    for combine, f_ref, o_ref in zip(combines, field_refs, out_refs):
        _, fill = _FIELD_SPEC[combine]
        vals = jnp.where(valid, jnp.take(f_ref[0], idx, axis=0), fill)
        o_ref[...] = _reduce_one(combine, vals)


@functools.partial(
    jax.jit, static_argnames=("combines", "K", "T", "interpret"))
def neighbor_multi_ell(
    nbr: jax.Array,
    fields: Sequence[jax.Array],
    combines: Tuple[str, ...],
    K: int,
    T: int = 256,
    interpret: bool = True,
) -> Tuple[jax.Array, ...]:
    """Fused multi-field neighbor reduce over ONE ELL adjacency read.

    nbr: (N, Cd) int32 (-1 padded); fields: one (N,) vector per combine
    (int32 for "min"/"hindex", float32 for "sum"); combines: static tuple
    of names from `_FIELD_SPEC`.  Returns one (N,) reduction per field,
    each bit-identical to its standalone kernel.  N % T == 0 and Cd, K
    multiples of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert len(fields) == len(combines) >= 1, (len(fields), combines)
    for c, f in zip(combines, fields):
        assert c in _FIELD_SPEC, c
        assert f.shape == (N,), (c, f.shape, N)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    C = min(Cd, K)
    ni = N // T

    field_rows = tuple(
        f.astype(_FIELD_SPEC[c][0])[None, :] for c, f in zip(combines, fields))
    outs = pl.pallas_call(
        functools.partial(_ell_multi_kernel, combines=combines, T=T),
        grid=(ni,),
        in_specs=[pl.BlockSpec((T, C), lambda i: (i, 0))]   # nbr row tile
        + [pl.BlockSpec((1, N), lambda i: (0, 0))           # each field row
           for _ in combines],
        out_specs=[pl.BlockSpec((T, 1), lambda i: (i, 0)) for _ in combines],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), _FIELD_SPEC[c][0]) for c in combines],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], *field_rows)
    return tuple(o[:, 0] for o in outs)
