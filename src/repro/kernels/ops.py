"""Kernel backend registry: one dispatch layer for every BLADYG hot loop.

Three interchangeable executions of the two core graph primitives (h-index
of neighbor estimates; masked frontier hop), all exact and bit-identical:

  "jnp"    pure-jnp ELL gather/scatter (`ref.py`) — XLA everywhere, the
           oracle, and the fastest CPU path.
  "dense"  dense-tile Pallas kernels (`kcore_hindex.py`, `frontier.py`) —
           materializes an O(N^2) bf16 adjacency; MXU matmuls; only viable
           for small blocks.
  "ell"    ELL block-sparse Pallas kernels (`ell_hindex.py`,
           `ell_frontier.py`) — consumes `GraphBlocks.nbr` tiles directly,
           O(N*Cd) memory; the scaling path.

A fourth, explicit-only backend executes over the device mesh:

  "ell_spmd"  shard_map over the `workers` mesh axis (`repro.runtime`):
              each device owns a fold of blocks, the neighbor gather is a
              real halo exchange (all-to-all per the precomputed
              `HaloPlan`).  Never chosen by "auto"; host-boundary only —
              the halo plan derives from concrete adjacency, so calls
              under an outer jit trace raise.  Loops should build ONE
              `SpmdExecutor` and thread it through the `executor=`
              parameter of the dispatch entry points; without it each call
              rebuilds the halo plan from scratch.

`backend="auto"` resolves per call: jnp off-TPU (Pallas would run in the
interpreter), dense for blocks small enough to densify profitably
(N <= DENSE_AUTO_MAX), ell beyond.  `core.kcore`, `core.kcore_dynamic`, and
the benchmarks call the primitives *only* through this layer — adding a
backend (the shard_map multi-device path arrived exactly this way) is a
registry entry, not a core-algorithm change.

Fixpoints are device-resident: `coreness_blocks` fuses the whole min-H
iteration into one jitted `lax.while_loop` on every backend (Pallas calls
inside the loop body on dense/ell), so a fixpoint costs ZERO per-superstep
host transfers and returns its superstep count as a device scalar
(`with_steps=True`).  The only host sync is the once-per-fixpoint
`degree_bound` read that buckets the kernels' threshold/sort bound K to a
power of two — the bucketing keeps the per-(shape, K) compiled caches
hitting while the bound tracks the graph instead of the padded Cd.

Beyond the two k-core primitives, the registry carries the named
*neighbor combines* of the `BlockProgram` contract ("min" | "sum" |
"hindex" | "count_common", see `COMBINES`), each with a per-backend
execution — `neighbor_combine_blocks` for one superstep,
`run_block_program` for a whole program fixpoint (CC, PageRank,
triangle counting, coreness: `core.algorithms`).  The program runner is
the generalization of the coreness fixpoint below: one fused
`lax.while_loop` on jnp/dense/ell, the on-mesh `SpmdEngine` fused loop
on ell_spmd, zero per-superstep host transfers either way.

The GraphBlocks-level entry points (`hindex_blocks`, `frontier_blocks`,
`coreness_blocks`, `neighbor_combine_blocks`, `run_block_program`)
duck-type on `.nbr`/`.deg`/`.node_mask`/`.N`/`.Cd` (plus `.n_real` for
the program runner) so this module never imports `repro.core` (no
import cycle; `core.engine` imports the `BlockCtx` contract type from
here).

The raw dense wrappers (`hindex`, `frontier_step`, `coreness_dense`) keep
their historical adjacency-matrix signatures for the kernel sweep tests.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kcore_hindex import hindex_counts as _hindex_pallas
from .frontier import frontier_step as _frontier_pallas
from .ell_hindex import hindex_ell as _hindex_ell_pallas
from .ell_frontier import frontier_step_ell as _frontier_ell_pallas
from .ell_cc import MIN_FILL, neighbor_min_ell as _min_ell_pallas
from .ell_pagerank import neighbor_sum_ell as _sum_ell_pallas
from .ell_triangles import (
    VARIANTS as TRIANGLE_VARIANTS,
    neighbor_common_ell as _common_ell_pallas,
)
from .ell_multi import neighbor_multi_ell as _multi_ell_pallas

BACKENDS = ("jnp", "dense", "ell", "ell_spmd")

#: neighbor combines of the BlockProgram contract, each with a per-backend
#: execution (pure-jnp gather, dense-adjacency form, ELL Pallas kernel, or
#: post-halo `ref.combine_rows` on the mesh)
COMBINES = ("min", "sum", "hindex", "count_common")

#: combines a fused MultiProgram superstep may bundle (ell_multi.py); the
#: meta-combine name "multi" dispatches to the fused shared-gather paths
MULTI_COMBINES = ("min", "sum", "hindex")

#: auto picks the dense MXU path up to this many (padded) nodes; beyond it
#: the O(N^2) adjacency dominates memory and ELL wins (see EXPERIMENTS.md).
DENSE_AUTO_MAX = 4096

#: measured on-TPU crossover for "auto" (see EXPERIMENTS.md §Backends):
#: below JNP_AUTO_MAX padded nodes the plain-XLA superstep beats the Pallas
#: paths — the committed CPU sweep shows the same shape (superstep at
#: N=256: jnp 1437us vs ell 2545us vs dense 6670us), and on TPU the kernel
#: launch + pad overhead dominates tiles this small.  Entries are
#: (inclusive N upper bound, backend); None = no bound.
AUTO_CROSSOVER = ((512, "jnp"), (DENSE_AUTO_MAX, "dense"), (None, "ell"))
JNP_AUTO_MAX = AUTO_CROSSOVER[0][0]


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pow2_bucket(x: int, floor: int = 128) -> int:
    """Smallest power of two >= x, floored at `floor` (a lane multiple)."""
    k = floor
    while k < x:
        k *= 2
    return k


def _tile_dims(N: int, T: int) -> tuple:
    """(Tp, Np): clamp the tile to the 128-lane-padded N, pad N to tiles.

    Single source of truth for the node-axis padding of every kernel
    wrapper — `dense_bytes` relies on it, so the >4 GiB infeasibility
    estimate always matches what the dense wrapper would allocate.
    """
    Tp = min(T, max(128, _pad_to(N, 128)))
    return Tp, _pad_to(N, Tp)


def resolve_backend(backend: Optional[str], N: int) -> str:
    """Resolve "auto" (or None) to a concrete backend name for a graph size.

    Off-TPU, always jnp (Pallas would run interpreted).  On TPU the
    `AUTO_CROSSOVER` table applies: jnp up to JNP_AUTO_MAX padded nodes
    (small tiles lose more to kernel launch + padding than they gain),
    dense while the O(N^2) adjacency stays affordable, ell beyond.
    """
    if backend in (None, "auto"):
        if not _on_tpu():
            return "jnp"  # Pallas would run interpreted — jnp is the fast path
        for bound, b in AUTO_CROSSOVER:
            if bound is None or N <= bound:
                return b
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS + ('auto',)}")
    return backend


def dense_bytes(N: int, T: int = 256) -> int:
    """HBM the dense backend would need for its padded bf16 adjacency."""
    _, Np = _tile_dims(N, T)
    return Np * Np * 2


def degree_bound(g) -> int:
    """pow2-bucketed max-degree threshold bound for the h-index kernels.

    ONE host sync per call (read at the top of a fixpoint, never inside) —
    h(u) <= deg(u), so any bound >= max degree is exact, and the power-of-
    two bucketing means maintenance streams that nudge the max degree keep
    hitting the same compiled kernels.  Under a jit trace (where the
    degrees are abstract) this falls back to the static padded-Cd bound,
    which is always safe and costs no transfer.
    """
    Cdp = max(128, _pad_to(g.Cd, 128))
    if isinstance(g.deg, jax.core.Tracer) or g.N == 0:
        return Cdp
    d = int(jax.device_get(jnp.max(g.deg)))
    return min(Cdp, _pow2_bucket(max(1, d)))


# ---------------------------------------------------------------------------
# Dense-path wrappers (historical adjacency-matrix API, kept for the sweeps).
# ---------------------------------------------------------------------------


def _pad_dense_adj(adj: jax.Array, N: int, Np: int) -> jax.Array:
    """Pad a dense adjacency to the tile-aligned bf16 form the kernels eat."""
    return jnp.zeros((Np, Np), jnp.bfloat16).at[:N, :N].set(
        adj.astype(jnp.bfloat16))


def hindex(
    adj: jax.Array,
    est: jax.Array,
    K: Optional[int] = None,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """h-index per node via the dense-tile kernel (pads N, K as needed).

    K=None uses the static node-count bound (h <= deg < N) — jit-safe and
    free of host syncs; hot loops should pass the graph's degree bound
    (`degree_bound`) for a tighter count matrix.
    """
    N = adj.shape[0]
    if K is None:
        K = max(1, N)  # h <= deg <= N-1: static, no hidden device_get
    Kp = max(128, _pad_to(K, 128))
    Tp, Np = _tile_dims(N, T)
    if interpret is None:
        interpret = not _on_tpu()
    adj_p = _pad_dense_adj(adj, N, Np)
    est_p = jnp.full((Np,), -1, jnp.int32).at[:N].set(est.astype(jnp.int32))
    h = _hindex_pallas(adj_p, est_p, K=Kp, T=Tp, interpret=interpret)
    return h[:N]


def frontier_step(
    adj: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Masked BFS hop; pads N to tile and R to 128 lanes."""
    N, R = f.shape
    Rp = max(128, _pad_to(R, 128))
    Tp, Np = _tile_dims(N, T)
    if interpret is None:
        interpret = not _on_tpu()
    adj_p = _pad_dense_adj(adj, N, Np)
    f_p = jnp.zeros((Np, Rp), jnp.bfloat16).at[:N, :R].set(f.astype(jnp.bfloat16))
    e_p = jnp.zeros((Np,), jnp.int8).at[:N].set(eligible.astype(jnp.int8))
    v_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(visited.astype(jnp.int8))
    nxt = _frontier_pallas(adj_p, f_p, e_p, v_p, T=Tp, interpret=interpret)
    return nxt[:N, :R]


@functools.partial(
    jax.jit,
    static_argnames=("kind", "K", "T", "interpret", "variant", "max_steps"))
def _coreness_fused(mat_p, est0_p, mask_p, kind, K, T, interpret, variant,
                    max_steps):
    """Fused min-H fixpoint: the backend kernel inside ONE while_loop.

    mat_p is the padded bf16 adjacency (kind="dense") or the padded ELL
    neighbor lists (kind="ell") — the only thing the two kernel paths
    disagree on; everything else (clamp, convergence, step counting) is
    shared here so the fixpoint semantics cannot diverge per backend.
    """

    def h_of(est):
        if kind == "dense":
            return _hindex_pallas(mat_p, est, K=K, T=T, interpret=interpret)
        return _hindex_ell_pallas(
            mat_p, est, K=K, T=T, interpret=interpret, variant=variant)

    def cond(c):
        _, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        new = jnp.where(mask_p, jnp.minimum(est, h_of(est)), est)
        return new, jnp.any(new != est), it + 1

    est, _, steps = jax.lax.while_loop(
        cond, body, (est0_p, jnp.bool_(True), jnp.int32(0)))
    return est, steps


def _run_fused_coreness(mat, est0, mask, N, kind, K, T, interpret, variant,
                        max_steps):
    """Pad once (host boundary), run the fused fixpoint: (est[:N], steps)."""
    Tp, Np = _tile_dims(N, T)
    est0_p = jnp.zeros((Np,), jnp.int32).at[:N].set(est0)
    mask_p = jnp.zeros((Np,), bool).at[:N].set(mask)
    if kind == "dense":
        mat_p, Kk = _pad_dense_adj(mat, N, Np), K
    else:
        mat_p, Kk, Tp, Np = _pad_ell(mat, K, T)
    est_p, steps = _coreness_fused(
        mat_p, est0_p, mask_p, kind=kind, K=Kk, T=Tp, interpret=interpret,
        variant=variant, max_steps=max_steps)
    return est_p[:N], steps


def coreness_dense(
    adj: jax.Array,
    T: int = 256,
    max_steps: int = 10_000,
    interpret: Optional[bool] = None,
    with_steps: bool = False,
) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full coreness via the kernelized min-H iteration (dense path).

    Matches `ref.coreness_dense_ref` and `core.kcore.coreness` exactly.
    The whole fixpoint is ONE jitted `lax.while_loop` (zero per-superstep
    host transfers); the only sync is the once-per-call degree-bound read
    for the threshold count K (pow2-bucketed for compile-cache stability).
    `with_steps=True` additionally returns the superstep count as a device
    scalar.
    """
    N = adj.shape[0]
    deg = jnp.sum(adj > 0, axis=1).astype(jnp.int32)
    K = _pow2_bucket(int(jax.device_get(jnp.max(deg))) + 1 if N else 1)
    if interpret is None:
        interpret = not _on_tpu()
    est, steps = _run_fused_coreness(
        adj, deg, jnp.ones((N,), bool), N, "dense", K, T, interpret, "sort",
        max_steps)
    return (est, steps) if with_steps else est


# ---------------------------------------------------------------------------
# ELL-path wrappers (pad N to tile, Cd and R to 128 lanes).
# ---------------------------------------------------------------------------


def _pad_ell(nbr: jax.Array, K: Optional[int], T: int):
    """Pad an ELL adjacency for the kernels: (nbr_p, Ck, Tp, Np).

    K=None keeps the always-safe padded-Cd column bound; a max-degree K
    (left-filled rows, see `degree_bound`) shrinks the columns the kernels
    read and sort to min(Cd, K) — the pow2 bucketing upstream keeps Ck
    stable across maintenance streams.
    """
    N, Cd = nbr.shape
    Cdp = max(128, _pad_to(Cd, 128))
    Ck = Cdp if K is None else min(Cdp, max(128, _pad_to(K, 128)))
    Tp, Np = _tile_dims(N, T)
    Cc = min(Cd, Ck)  # source columns that can hold valid slots
    nbr_p = jnp.full((Np, Ck), -1, jnp.int32).at[:N, :Cc].set(
        nbr[:, :Cc].astype(jnp.int32))
    return nbr_p, Ck, Tp, Np


def hindex_ell(
    nbr: jax.Array,
    est: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
    K: Optional[int] = None,
    variant: str = "sort",
) -> jax.Array:
    """h-index per node via the ELL block-sparse kernel — O(N*Cd) memory.

    `variant` selects the O(Cd log Cd) in-tile sort sweep ("sort", the
    default) or the legacy O(Cd*K) count-matrix kernel ("count", kept for
    the variant benchmark).  K (optional) is the max-degree column bound;
    exactness for K < Cd requires left-filled rows (`GraphBlocks`).
    """
    N, Cd = nbr.shape
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p, Ck, Tp, Np = _pad_ell(nbr, K, T)
    est_p = jnp.full((Np,), -1, jnp.int32).at[:N].set(est.astype(jnp.int32))
    h = _hindex_ell_pallas(
        nbr_p, est_p, K=Ck, T=Tp, interpret=interpret, variant=variant)
    return h[:N]


def frontier_step_ell(
    nbr: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
    K: Optional[int] = None,
) -> jax.Array:
    """Masked BFS hop over the ELL adjacency; eligible is (N, R) per-column.

    K (optional) bounds the neighbor columns swept, like `hindex_ell`.
    """
    N, Cd = nbr.shape
    R = f.shape[1]
    Rp = max(128, _pad_to(R, 128))
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p, Ck, Tp, Np = _pad_ell(nbr, K, T)
    f_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(f.astype(jnp.int8))
    e_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(eligible.astype(jnp.int8))
    v_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(visited.astype(jnp.int8))
    nxt = _frontier_ell_pallas(nbr_p, f_p, e_p, v_p, K=Ck, T=Tp,
                               interpret=interpret)
    return nxt[:N, :R]


def neighbor_min_ell(
    nbr: jax.Array,
    field: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
    K: Optional[int] = None,
) -> jax.Array:
    """Row-wise min of neighbor field values via the ELL kernel.

    nbr: (N, Cd) int32 (-1 padded); field: (N,) int32.  Neighborless rows
    return int32 max (the min combine's absorbing fill).  K optionally
    bounds the swept columns (left-filled rows, see `degree_bound`).
    """
    N, _ = nbr.shape
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p, Ck, Tp, Np = _pad_ell(nbr, K, T)
    field_p = jnp.full((Np,), MIN_FILL, jnp.int32).at[:N].set(
        field.astype(jnp.int32))
    red = _min_ell_pallas(nbr_p, field_p, K=Ck, T=Tp, interpret=interpret)
    return red[:N]


def neighbor_sum_ell(
    nbr: jax.Array,
    field: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
    K: Optional[int] = None,
) -> jax.Array:
    """Row-wise float32 sum of neighbor field values via the ELL kernel.

    nbr: (N, Cd) int32 (-1 padded); field: (N,) float32.  Neighborless
    rows return 0.0.  K optionally bounds the swept columns.
    """
    N, _ = nbr.shape
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p, Ck, Tp, Np = _pad_ell(nbr, K, T)
    field_p = jnp.zeros((Np,), jnp.float32).at[:N].set(
        field.astype(jnp.float32))
    red = _sum_ell_pallas(nbr_p, field_p, K=Ck, T=Tp, interpret=interpret)
    return red[:N]


def neighbor_common_ell(
    nbr: jax.Array,
    rows: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
    K: Optional[int] = None,
    variant: str = "merge",
) -> jax.Array:
    """Directed common-neighbor counts via the ELL intersection kernel.

    nbr, rows: (N, Cd) int32 (-1 padded) — the adjacency swept and the
    per-node row field intersected (identical for whole-graph use).
    Returns (N,) int32: red[u] = sum_j |rows[u] ∩ rows[nbr[u, j]]|.
    K bounds BOTH column axes (left-filled rows required for K < Cd).

    variant="merge" (default) is the O(N*Cd^2*log Cd) sorted binary-probe
    intersection — the kernel canonicalizes the row field on the way in
    (a no-op under the sorted-ELL invariant), so it is exact for ANY slot
    order; "allpairs" keeps the legacy O(N*Cd^3) match for the benchmark
    sweep.  Both are bit-identical to `ref.ell_common_ref`.
    """
    N, _ = nbr.shape
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p, Ck, Tp, Np = _pad_ell(nbr, K, T)
    rows_p, _, _, _ = _pad_ell(rows, K, T)
    red = _common_ell_pallas(nbr_p, rows_p, K=Ck, T=Tp, interpret=interpret,
                             variant=variant)
    return red[:N]


def neighbor_multi_ell(
    nbr: jax.Array,
    fields: Tuple[jax.Array, ...],
    combines: Tuple[str, ...],
    T: int = 256,
    interpret: Optional[bool] = None,
    K: Optional[int] = None,
) -> Tuple[jax.Array, ...]:
    """Fused multi-field neighbor reduce — ONE adjacency read for k fields.

    nbr: (N, Cd) int32 (-1 padded); fields: one (N,) vector per combine;
    combines: static tuple from `MULTI_COMBINES`.  Pads once and serves
    every field's gather + reduce off the shared neighbor-slot indices
    (`ell_multi.py`); each output is bit-identical to its standalone
    kernel.  K optionally bounds the swept columns (left-filled rows).
    """
    from .ell_cc import MIN_FILL as _MF  # local alias; fills per combine
    N, _ = nbr.shape
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p, Ck, Tp, Np = _pad_ell(nbr, K, T)
    fills = {"min": _MF, "sum": 0.0, "hindex": -1}
    dtypes = {"min": jnp.int32, "sum": jnp.float32, "hindex": jnp.int32}
    fields_p = tuple(
        jnp.full((Np,), fills[c], dtypes[c]).at[:N].set(f.astype(dtypes[c]))
        for c, f in zip(combines, fields))
    reds = _multi_ell_pallas(
        nbr_p, fields_p, tuple(combines), K=Ck, T=Tp, interpret=interpret)
    return tuple(r[:N] for r in reds)


# ---------------------------------------------------------------------------
# GraphBlocks-level dispatch — the only entry points core code may use.
# ---------------------------------------------------------------------------


def hindex_blocks(
    g,  # GraphBlocks (duck-typed: .nbr, .N, .Cd)
    est: jax.Array,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    adj: Optional[jax.Array] = None,
    executor=None,
    K: Optional[int] = None,
) -> jax.Array:
    """h-index of neighbor estimates for every node, via the chosen backend.

    g: a GraphBlocks (N = P*Cn padded rows, nbr (N, Cd) int32 with -1
    PAD); est: (N,) int32 current estimates.  Returns (N,) int32 —
    h[u] = h-index of {est[v] : v ~ u}, 0 for neighborless rows.

    All backends are exact and identical (h <= deg <= Cd, so the static
    threshold bound K = Cd keeps the kernel paths jit-safe; fixpoints pass
    the tighter `degree_bound` via K).  Loops that call the dense backend
    repeatedly should densify once and pass `adj` (see `dense_adj`); loops
    on the mesh backend should build one `SpmdExecutor` and pass it via
    `executor=` instead of paying a halo-plan rebuild per call.
    """
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        return ref.ell_hindex_ref(g.nbr, est).astype(jnp.int32)
    if b == "ell":
        return hindex_ell(g.nbr, est, interpret=interpret, K=K)
    if b == "ell_spmd":
        from ..runtime.spmd import hindex_spmd  # lazy: no import cycle

        return hindex_spmd(g, est, executor=executor)
    if adj is None:
        adj = ref.ell_to_dense(g.nbr, g.N)
    return hindex(adj, est, K=g.Cd + 1 if K is None else K,
                  interpret=interpret)


def _eligible_cols(eligible: jax.Array, R: int) -> jax.Array:
    """Broadcast a shared (N,) eligibility mask to the (N, R) column form."""
    if eligible.ndim == 1:
        return jnp.broadcast_to(eligible[:, None], (eligible.shape[0], R))
    return eligible


def dense_adj(g, backend: str) -> Optional[jax.Array]:
    """Densify once for a loop over dense-backend calls; None otherwise."""
    if resolve_backend(backend, g.N) == "dense":
        return ref.ell_to_dense(g.nbr, g.N)
    return None


def frontier_blocks(
    g,  # GraphBlocks (duck-typed)
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    adj: Optional[jax.Array] = None,
    executor=None,
    K: Optional[int] = None,
) -> jax.Array:
    """One masked BFS hop for R stacked frontiers, via the chosen backend.

    f, visited: (N, R) bool; eligible: (N,) shared or (N, R) per-column.
    Returns the next frontier as (N, R) bool.  As with `hindex_blocks`,
    pass a precomputed `adj` when looping over dense-backend hops and a
    long-lived `executor` when looping on the mesh backend.
    """
    R = f.shape[1]
    elig = _eligible_cols(eligible, R)
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        return ref.ell_frontier_hop_ref(g.nbr, f, elig, visited)
    if b == "ell":
        return frontier_step_ell(
            g.nbr, f, elig, visited, interpret=interpret, K=K) > 0
    if b == "ell_spmd":
        from ..runtime.spmd import frontier_spmd  # lazy: no import cycle

        return frontier_spmd(g, f, elig, visited, executor=executor)
    # dense kernel takes a shared (N,) eligibility; fold the per-column mask
    # into `visited` (a node ineligible for column r can never enter it).
    if adj is None:
        adj = ref.ell_to_dense(g.nbr, g.N)
    vis_aug = visited.astype(bool) | ~elig.astype(bool)
    ones = jnp.ones((g.N,), jnp.int8)
    return frontier_step(adj, f, ones, vis_aug, interpret=interpret) > 0


@functools.partial(jax.jit, static_argnames=("max_steps",))
def _coreness_blocks_jnp(g, max_steps: int = 10_000):
    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        h = ref.ell_hindex_ref(g.nbr, est)
        new = jnp.where(g.node_mask, jnp.minimum(est, h), est)
        return new, jnp.any(new != est), it + 1

    est, _, steps = jax.lax.while_loop(
        cond, body, (est0, jnp.bool_(True), jnp.int32(0)))
    return est, steps


def coreness_blocks(
    g,  # GraphBlocks (duck-typed)
    backend: str = "auto",
    max_steps: int = 10_000,
    interpret: Optional[bool] = None,
    executor=None,
    with_steps: bool = False,
    variant: str = "sort",
) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full min-H coreness of every node (0 on padding rows), any backend.

    g: GraphBlocks; returns (N,) int32 coreness (N = P*Cn padded rows),
    plus the superstep count as a device int32 scalar when
    `with_steps=True`.

    Every backend runs the whole fixpoint device-resident — one jitted
    `lax.while_loop` (with the Pallas kernel in the body on dense/ell, or
    the shard_map'd halo-exchange loop on ell_spmd) — so there are ZERO
    per-superstep host transfers; the only sync is the once-per-call
    `degree_bound` read on the kernel paths.  `with_steps=True` returns
    (coreness, supersteps) with the count as a device scalar.
    """
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        est, steps = _coreness_blocks_jnp(g, max_steps)
        return (est, steps) if with_steps else est
    if b == "ell_spmd":
        from ..runtime.spmd import SpmdExecutor  # lazy: no import cycle

        ex = executor if executor is not None else SpmdExecutor(g)
        est, steps = ex.coreness(max_steps=max_steps)
        return (est, steps) if with_steps else est
    if interpret is None:
        interpret = not _on_tpu()
    K = degree_bound(g)  # the single host sync of the whole fixpoint
    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    mat = ref.ell_to_dense(g.nbr, g.N) if b == "dense" else g.nbr
    est, steps = _run_fused_coreness(
        mat, est0, g.node_mask, g.N, b, K, 256, interpret, variant, max_steps)
    return (est, steps) if with_steps else est


# ---------------------------------------------------------------------------
# BlockProgram execution: the generic fused superstep runner.  The program
# contract itself lives in `core.engine.BlockProgram` (which imports the
# context type from here — kernels never import core); the workloads live
# in `core.algorithms`.
# ---------------------------------------------------------------------------


class BlockCtx(NamedTuple):
    """Per-node context handed to `BlockProgram.update`.

    The same program code runs over the whole graph (jnp/dense/ell
    backends: ``n = N = P*Cn`` rows) or over one worker's shard of it
    (ell_spmd: ``n = S = N/W`` rows) — update math must therefore be
    elementwise/broadcast over the leading node axis and may reduce only
    through the values it is handed.

    Attributes
    ----------
    deg:       (n,) int32 — true degree per node (0 on padding rows).
    node_mask: (n,) bool  — True for real nodes.
    n_real:    int        — GLOBAL real-node count (static host int; e.g.
                            the PageRank teleport denominator).
    """

    deg: jax.Array
    node_mask: jax.Array
    n_real: int


def _combine_jnp(nbr: jax.Array, field: jax.Array, combine: str) -> jax.Array:
    """Whole-graph gather + reduce, pure jnp (the oracle execution)."""
    if combine == "min":
        return ref.ell_min_ref(nbr, field)
    if combine == "sum":
        return ref.ell_sum_ref(nbr, field)
    if combine == "hindex":
        return ref.ell_hindex_ref(nbr, field).astype(jnp.int32)
    if combine == "count_common":
        return ref.ell_common_ref(nbr, field)
    raise ValueError(f"unknown combine {combine!r}; expected one of {COMBINES}")


def _combine_ell(nbr: jax.Array, field: jax.Array, combine: str,
                 interpret: Optional[bool], K: Optional[int]) -> jax.Array:
    """Whole-graph gather + reduce via the ELL Pallas kernels."""
    if combine == "min":
        return neighbor_min_ell(nbr, field, interpret=interpret, K=K)
    if combine == "sum":
        return neighbor_sum_ell(nbr, field, interpret=interpret, K=K)
    if combine == "hindex":
        return hindex_ell(nbr, field, interpret=interpret, K=K)
    if combine == "count_common":
        return neighbor_common_ell(nbr, field, interpret=interpret, K=K)
    raise ValueError(f"unknown combine {combine!r}; expected one of {COMBINES}")


def _combine_dense(adj: jax.Array, field: jax.Array, combine: str,
                   Cd: int) -> jax.Array:
    """Dense-adjacency formulations of the combines (adj: (N, N) 0/1).

    min  — masked elementwise min over the adjacency row.
    sum  — the classic SpMV as an MXU matmul: adj @ field.
    hindex — threshold-count matmul (`ref.hindex_counts_ref`, K = Cd + 1:
             exact because h <= deg <= Cd).
    count_common — diag(A^3) as sum(A ∘ A², axis=1): red[u] counts every
             ordered common-neighbor pair at u, identical to the ELL
             intersection.
    """
    if combine == "min":
        fill = jnp.iinfo(jnp.int32).max
        vals = jnp.where(adj > 0, field[None, :].astype(jnp.int32), fill)
        return jnp.min(vals, axis=1)
    if combine == "sum":
        return adj.astype(jnp.float32) @ field.astype(jnp.float32)
    if combine == "hindex":
        return ref.hindex_counts_ref(adj, field, K=Cd + 1)
    if combine == "count_common":
        a = (adj > 0).astype(jnp.float32)
        return jnp.sum(a * (a @ a), axis=1).astype(jnp.int32)
    raise ValueError(f"unknown combine {combine!r}; expected one of {COMBINES}")


# ---------------------------------------------------------------------------
# Fused multi-combine executions (MultiProgram: one adjacency read serves
# every sub-program's gather) + the trace-time gather accounting that
# proves it.
# ---------------------------------------------------------------------------

#: how many adjacency-gather dispatches the program runner has TRACED (not
#: executed): `_block_program_fused` bumps it once per `red_of` trace, so
#: lowering a fused MultiProgram superstep counts 1 where lowering its k
#: sub-programs separately counts k.  Python-side and monotonic; tests
#: snapshot around an explicit `.lower()` (jit cache hits do not retrace,
#: hence do not count).
_GATHER_TRACES = 0


def _count_gather() -> None:
    global _GATHER_TRACES
    _GATHER_TRACES += 1


def gather_trace_count() -> int:
    """Adjacency-gather dispatches traced so far (see `_GATHER_TRACES`)."""
    return _GATHER_TRACES


def _combine_multi_jnp(nbr: jax.Array, fields, combines) -> Tuple:
    """Shared-gather multi reduce, pure jnp: one clip/validity, k takes."""
    valid = nbr >= 0
    idx = jnp.clip(nbr, 0)
    outs = []
    for c, f in zip(combines, fields):
        if c == "min":
            vals = jnp.where(valid, f.astype(jnp.int32)[idx], MIN_FILL)
            outs.append(jnp.min(vals, axis=1))
        elif c == "sum":
            vals = jnp.where(valid, f.astype(jnp.float32)[idx], 0.0)
            outs.append(jnp.sum(vals, axis=1))
        elif c == "hindex":
            vals = jnp.where(valid, f.astype(jnp.int32)[idx], -1)
            outs.append(ref.hindex_rows(vals).astype(jnp.int32))
        else:
            raise ValueError(
                f"combine {c!r} not fusable; expected one of {MULTI_COMBINES}")
    return tuple(outs)


def _combine_multi_ell(nbr: jax.Array, fields, combines,
                       interpret: Optional[bool], K: Optional[int]) -> Tuple:
    """Fused multi reduce via the `ell_multi` Pallas kernel."""
    return neighbor_multi_ell(
        nbr, tuple(fields), tuple(combines), interpret=interpret, K=K)


def _combine_multi_dense(adj: jax.Array, fields, combines, Cd: int) -> Tuple:
    """Dense multi reduce: per-combine dense forms over one resident adj.

    The dense adjacency is already materialized once for the whole
    fixpoint, so "one adjacency read" is the resident (N, N) operand —
    each combine is a separate reduction over it.
    """
    return tuple(
        _combine_dense(adj, f, c, Cd) for c, f in zip(combines, fields))


def neighbor_combine_blocks(
    g,  # GraphBlocks (duck-typed: .nbr, .N, .Cd)
    field: jax.Array,
    combine: str,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    adj: Optional[jax.Array] = None,
    K: Optional[int] = None,
) -> jax.Array:
    """One gather + reduce superstep of a named combine, via any backend.

    field: (N,) values for "min"/"sum"/"hindex", (N, Cd) neighbor rows for
    "count_common".  Loops over the dense backend should densify once and
    pass `adj` (see `dense_adj`).  The ell_spmd backend has no standalone
    combine entry — its reductions only exist downstream of a halo
    exchange; use `run_block_program(backend="ell_spmd")`.
    """
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        return _combine_jnp(g.nbr, field, combine)
    if b == "ell":
        return _combine_ell(g.nbr, field, combine, interpret, K)
    if b == "ell_spmd":
        raise ValueError(
            "neighbor_combine_blocks has no ell_spmd path: mesh combines "
            "only exist inside a halo-exchange superstep — run the whole "
            "program via run_block_program(backend='ell_spmd')."
        )
    if adj is None:
        adj = ref.ell_to_dense(g.nbr, g.N)
    return _combine_dense(adj, field, combine, g.Cd)


def _mirror_merge(red, field, nbr, mirror, combine: str) -> jax.Array:
    """Merge per-slice partial aggregates across each hub replica group.

    The combine-then-broadcast stage of the vertex-cut dataflow
    (`core.hub_split`): entries of `red` at group rows are replaced by
    the LOGICAL aggregate of the full sliced neighborhood; all other
    rows pass through untouched.  Per combine:

      min    — segmented min over the group's partials (exactly
               associative: the slices partition the neighborhood, so
               the merged min sees the identical value multiset).
      sum    — segmented add (bit-exact for ints; float PageRank sums
               re-associate across slices — allclose, not bit-equal).
      hindex — partials do NOT compose through h values; the merge
               recomputes per-slice count histograms (the
               ``variant="count"`` formulation: cnt_t = #{values >= t},
               t = 1..Km) which ADD exactly across slices, then reads
               h = #{t : cnt_t >= t}.  Exact because a merged h-index
               never exceeds the logical degree <= Km.

    Pure device code; the scatter targets of pad entries are pushed out
    of bounds (dropped) so a pad row id can never collide with a real
    group row's write.
    """
    rows, gid, G = mirror.grp_rows, mirror.grp_gid, mirror.Gmax
    live = gid < G
    if combine == "min":
        fill = jnp.iinfo(red.dtype).max
        vals = jnp.where(live, red[rows], fill)
        part = jnp.full((G + 1,), fill, red.dtype).at[gid].min(vals)
        out = part[gid]
    elif combine == "sum":
        vals = jnp.where(live, red[rows], jnp.zeros((), red.dtype))
        part = jnp.zeros((G + 1,), red.dtype).at[gid].add(vals)
        out = part[gid]
    elif combine == "hindex":
        rn = nbr[rows]
        ve = jnp.where(rn >= 0, field.astype(jnp.int32)[jnp.clip(rn, 0)], -1)
        t = jnp.arange(1, mirror.Km + 1, dtype=jnp.int32)
        hist = jnp.sum(ve[:, :, None] >= t[None, None, :], axis=1)
        hist = jnp.where(live[:, None], hist, 0)
        cnt = jnp.zeros((G + 1, mirror.Km), hist.dtype).at[gid].add(hist)
        out = jnp.sum(cnt >= t[None, :], axis=1).astype(red.dtype)[gid]
    else:
        raise ValueError(
            f"combine {combine!r} has no mirror merge; count_common routes "
            "through core.hub_split.run_common_mirror")
    tgt = jnp.where(live, rows, red.shape[0])  # OOB scatter drops pad writes
    return red.at[tgt].set(jnp.where(live, out, jnp.zeros((), red.dtype)))


def _mirror_merged(red, field, nbr, mirror, program):
    """Apply `_mirror_merge` per field of a (possibly multi-) program."""
    if program.combine == "multi":
        return tuple(
            _mirror_merge(r, f, nbr, mirror, c)
            for r, f, c in zip(red, field, program.combines))
    return _mirror_merge(red, field, nbr, mirror, program.combine)


@functools.partial(
    jax.jit, static_argnames=("program", "b", "interpret", "max_steps",
                              "n_real"))
def _block_program_fused(g, state0, adj, mirror, program, b: str,
                         interpret: bool, max_steps: int, n_real: int):
    """The generic fused fixpoint: program supersteps in ONE while_loop.

    The loop body is (halo field -> backend combine -> block-local update
    -> local halt verdict); nothing inside touches the host, so a run
    costs ZERO per-superstep transfers on every backend and the superstep
    count comes back as a device scalar, exactly like the dedicated
    coreness fixpoints of PR 4.

    `mirror` (a `core.hub_split.MirrorPlan` or None) arms the vertex-cut
    dataflow: the update ctx carries the LOGICAL degrees and real-node
    count, and a `_mirror_merge` stage between combine and update folds
    per-slice partials into per-vertex aggregates.  The plan rides as a
    jit OPERAND (its statics are treedef metadata), so single-device
    mirrored streams never recompile on plan content changes.
    """
    deg = g.deg if mirror is None else mirror.ldeg
    ctx = BlockCtx(deg=jnp.asarray(deg, jnp.int32), node_mask=g.node_mask,
                   n_real=n_real)

    def red_of(field):
        _count_gather()  # trace-time accounting: 1 per fused dispatch
        if program.combine == "multi":
            if b == "jnp":
                return _combine_multi_jnp(g.nbr, field, program.combines)
            if b == "ell":
                return _combine_multi_ell(g.nbr, field, program.combines,
                                          interpret, None)
            return _combine_multi_dense(adj, field, program.combines, g.Cd)
        if b == "jnp":
            return _combine_jnp(g.nbr, field, program.combine)
        if b == "ell":
            return _combine_ell(g.nbr, field, program.combine, interpret,
                                None)
        return _combine_dense(adj, field, program.combine, g.Cd)

    def cond(c):
        _, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        state, _, it = c
        field = program.halo_field(state)
        red = red_of(field)
        if mirror is not None:
            red = _mirror_merged(red, field, g.nbr, mirror, program)
        new = program.update(ctx, state, red)
        return new, program.changed(state, new), it + 1

    state, _, steps = jax.lax.while_loop(
        cond, body, (state0, jnp.bool_(True), jnp.int32(0)))
    return state, steps


def _mirror_init_view(g, mirror):
    """Logical facade for `program.init` under a mirrored run.

    init formulas read degrees and the real-node mask (e.g. PageRank's
    1/deg contributions and teleport mass); on a split graph the LOGICAL
    quantities live in the plan, so init sees them through a replaced
    view — then `mirror_state` replicates the per-primary values onto
    mirror rows so replicas start (and stay) in lockstep.
    """
    import dataclasses as _dc
    return _dc.replace(g, deg=mirror.ldeg, node_mask=mirror.primary_mask)


def _mirror_state0(program, state0, mirror):
    """Replicate a whole-graph state onto mirror rows (idempotent)."""
    rep = getattr(program, "mirror_state", None)
    if rep is not None:
        return rep(state0, mirror.primary_row)
    return jax.tree_util.tree_map(lambda a: a[mirror.primary_row], state0)


def run_block_program(
    g,  # GraphBlocks (duck-typed)
    program,  # core.engine.BlockProgram (hashable static)
    backend: str = "auto",
    max_steps: Optional[int] = None,
    interpret: Optional[bool] = None,
    executor=None,
    with_steps: bool = False,
    state0: Optional[Any] = None,
    mirror=None,  # core.hub_split.MirrorPlan for a hub-split graph
) -> Union[Any, Tuple[Any, jax.Array]]:
    """Run a `BlockProgram` to its halt fixpoint, via the chosen backend.

    The structured contract (init → halo field → named combine → update →
    halt reduction) is what makes ONE runner serve every backend: on
    jnp/dense/ell the whole superstep loop fuses into a single jitted
    `lax.while_loop` (`_block_program_fused`); on ell_spmd the identical
    program runs over the worker mesh through `SpmdEngine.run_spmd`'s
    fused loop, with the halo field served by a real W2W all-to-all and
    the halt decision psum'd on-mesh.  Either way: ZERO per-superstep
    host transfers, superstep counts as device scalars.

    Host-boundary entry (like the ell_spmd dispatch paths): `program.init`
    and the real-node count read need concrete arrays — do not call under
    an outer jit trace.  Mesh loops should pass a long-lived
    `SpmdExecutor` via `executor=`; `max_steps=None` takes the program's
    own bound.  Returns the final program state, plus the executed
    superstep count when `with_steps=True`.

    `state0` (optional) warm-starts the fixpoint from a caller-supplied
    state instead of `program.init(g)` — the serving path's snapshot
    refresh uses this to resume monotone programs (min-label CC, min-H
    coreness) AT their fixpoint, where one pass through `update` is the
    identity, so maintained fields ride through bit-unchanged while
    fixed-iteration sub-programs (PageRank) still execute.  The caller
    owns the contract that the state matches `program.init`'s structure
    (same pytree, shapes, dtypes).

    `mirror` (optional) declares `g` a hub-split graph and arms the
    vertex-cut dataflow (`core.hub_split`): init runs against the
    logical degree/mask view, the state replicates onto mirror rows
    (`program.mirror_state`), the per-superstep ctx carries logical
    degrees and real-node count, and a merge stage folds per-slice
    partials per replica group between combine and update —
    "count_common" programs route through the exact
    `hub_split.run_common_mirror` pass instead.  Results match the
    unsplit graph exactly (bit-exact for integer combines).
    """
    b = resolve_backend(backend, g.N)
    if program.combine != "multi" and program.combine not in COMBINES:
        raise ValueError(
            f"unknown combine {program.combine!r}; expected one of "
            f"{COMBINES + ('multi',)}")
    if mirror is not None and program.combine == "count_common":
        from ..core.hub_split import run_common_mirror  # lazy: no cycle

        return run_common_mirror(
            g, mirror, program, backend=b, interpret=interpret,
            with_steps=with_steps, state0=state0)
    ms = int(program.max_steps if max_steps is None else max_steps)
    # GraphBlocks property read (duck-typed, host sync) — under a mirror
    # the ctx must carry the LOGICAL vertex count, not the row count.
    n_real = int(g.n_real) if mirror is None else int(mirror.n_logical)
    if state0 is None:
        state0 = program.init(g if mirror is None
                              else _mirror_init_view(g, mirror))
    if mirror is not None:
        state0 = _mirror_state0(program, state0, mirror)
    if b == "ell_spmd":
        from ..runtime.spmd import (  # lazy: no import cycle
            SpmdBlockProgram, SpmdEngine, SpmdExecutor)

        ex = executor if executor is not None else SpmdExecutor(g)
        eng = SpmdEngine(g, executor=ex)
        state, _ = eng.run_spmd(
            SpmdBlockProgram(program, n_real, mirror=mirror), state0, None,
            max_supersteps=ms)
        steps = jnp.int32(len(eng.traces))
        return (state, steps) if with_steps else state
    if interpret is None:
        interpret = not _on_tpu()
    adj = ref.ell_to_dense(g.nbr, g.N) if b == "dense" else None
    state, steps = _block_program_fused(
        g, state0, adj, mirror, program=program, b=b, interpret=interpret,
        max_steps=ms, n_real=n_real)
    return (state, steps) if with_steps else state
