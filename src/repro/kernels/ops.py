"""Public jit'd wrappers around the Pallas kernels.

Handle padding to hardware-aligned shapes, dtype conversion, platform
dispatch (interpret=True off-TPU), and expose a dense-path coreness solver
used by benchmarks and the optional kernel execution path in `core.kcore`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kcore_hindex import hindex_counts as _hindex_pallas
from .frontier import frontier_step as _frontier_pallas


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def hindex(
    adj: jax.Array,
    est: jax.Array,
    K: Optional[int] = None,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """h-index per node via the dense-tile kernel (pads N, K as needed)."""
    N = adj.shape[0]
    if K is None:
        K = int(jax.device_get(jnp.max(est))) + 1
    Kp = max(128, _pad_to(K, 128))
    Tp = min(T, max(128, _pad_to(N, 128)))
    Np = _pad_to(N, Tp)
    if interpret is None:
        interpret = not _on_tpu()
    adj_p = jnp.zeros((Np, Np), jnp.bfloat16).at[:N, :N].set(adj.astype(jnp.bfloat16))
    est_p = jnp.full((Np,), -1, jnp.int32).at[:N].set(est.astype(jnp.int32))
    h = _hindex_pallas(adj_p, est_p, K=Kp, T=Tp, interpret=interpret)
    return h[:N]


def frontier_step(
    adj: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Masked BFS hop; pads N to tile and R to 128 lanes."""
    N, R = f.shape
    Rp = max(128, _pad_to(R, 128))
    Tp = min(T, max(128, _pad_to(N, 128)))
    Np = _pad_to(N, Tp)
    if interpret is None:
        interpret = not _on_tpu()
    adj_p = jnp.zeros((Np, Np), jnp.bfloat16).at[:N, :N].set(adj.astype(jnp.bfloat16))
    f_p = jnp.zeros((Np, Rp), jnp.bfloat16).at[:N, :R].set(f.astype(jnp.bfloat16))
    e_p = jnp.zeros((Np,), jnp.int8).at[:N].set(eligible.astype(jnp.int8))
    v_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(visited.astype(jnp.int8))
    nxt = _frontier_pallas(adj_p, f_p, e_p, v_p, T=Tp, interpret=interpret)
    return nxt[:N, :R]


def coreness_dense(
    adj: jax.Array,
    T: int = 256,
    max_steps: int = 10_000,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Full coreness via the kernelized min-H iteration (dense path).

    Matches `ref.coreness_dense_ref` and `core.kcore.coreness` exactly.
    """
    N = adj.shape[0]
    deg = jnp.sum(adj > 0, axis=1).astype(jnp.int32)
    K = int(jax.device_get(jnp.max(deg))) + 1 if N else 1
    est = deg
    for _ in range(max_steps):
        h = hindex(adj, est, K=K, T=T, interpret=interpret)
        new = jnp.minimum(est, h)
        if bool(jax.device_get(jnp.all(new == est))):
            break
        est = new
    return est
