"""Kernel backend registry: one dispatch layer for every BLADYG hot loop.

Three interchangeable executions of the two core graph primitives (h-index
of neighbor estimates; masked frontier hop), all exact and bit-identical:

  "jnp"    pure-jnp ELL gather/scatter (`ref.py`) — XLA everywhere, the
           oracle, and the fastest CPU path.
  "dense"  dense-tile Pallas kernels (`kcore_hindex.py`, `frontier.py`) —
           materializes an O(N^2) bf16 adjacency; MXU matmuls; only viable
           for small blocks.
  "ell"    ELL block-sparse Pallas kernels (`ell_hindex.py`,
           `ell_frontier.py`) — consumes `GraphBlocks.nbr` tiles directly,
           O(N*Cd) memory; the scaling path.

A fourth, explicit-only backend executes over the device mesh:

  "ell_spmd"  shard_map over the `workers` mesh axis (`repro.runtime`):
              each device owns a fold of blocks, the neighbor gather is a
              real halo exchange (all-to-all per the precomputed
              `HaloPlan`).  Never chosen by "auto"; host-boundary only —
              the halo plan derives from concrete adjacency, so calls
              under an outer jit trace raise.

`backend="auto"` resolves per call: jnp off-TPU (Pallas would run in the
interpreter), dense for blocks small enough to densify profitably
(N <= DENSE_AUTO_MAX), ell beyond.  `core.kcore`, `core.kcore_dynamic`, and
the benchmarks call the primitives *only* through this layer — adding a
backend (the shard_map multi-device path arrived exactly this way) is a
registry entry, not a core-algorithm change.

The GraphBlocks-level entry points (`hindex_blocks`, `frontier_blocks`,
`coreness_blocks`) duck-type on `.nbr`/`.deg`/`.node_mask`/`.N`/`.Cd` so this
module never imports `repro.core` (no import cycle).

The raw dense wrappers (`hindex`, `frontier_step`, `coreness_dense`) keep
their historical adjacency-matrix signatures for the kernel sweep tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kcore_hindex import hindex_counts as _hindex_pallas
from .frontier import frontier_step as _frontier_pallas
from .ell_hindex import hindex_ell as _hindex_ell_pallas
from .ell_frontier import frontier_step_ell as _frontier_ell_pallas

BACKENDS = ("jnp", "dense", "ell", "ell_spmd")

#: auto picks the dense MXU path up to this many (padded) nodes; beyond it
#: the O(N^2) adjacency dominates memory and ELL wins (see EXPERIMENTS.md).
DENSE_AUTO_MAX = 4096


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _tile_dims(N: int, T: int) -> tuple:
    """(Tp, Np): clamp the tile to the 128-lane-padded N, pad N to tiles.

    Single source of truth for the node-axis padding of every kernel
    wrapper — `dense_bytes` relies on it, so the >4 GiB infeasibility
    estimate always matches what the dense wrapper would allocate.
    """
    Tp = min(T, max(128, _pad_to(N, 128)))
    return Tp, _pad_to(N, Tp)


def resolve_backend(backend: Optional[str], N: int) -> str:
    """Resolve "auto" (or None) to a concrete backend name for a graph size."""
    if backend in (None, "auto"):
        if not _on_tpu():
            return "jnp"  # Pallas would run interpreted — jnp is the fast path
        return "dense" if N <= DENSE_AUTO_MAX else "ell"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS + ('auto',)}")
    return backend


def dense_bytes(N: int, T: int = 256) -> int:
    """HBM the dense backend would need for its padded bf16 adjacency."""
    _, Np = _tile_dims(N, T)
    return Np * Np * 2


# ---------------------------------------------------------------------------
# Dense-path wrappers (historical adjacency-matrix API, kept for the sweeps).
# ---------------------------------------------------------------------------


def hindex(
    adj: jax.Array,
    est: jax.Array,
    K: Optional[int] = None,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """h-index per node via the dense-tile kernel (pads N, K as needed)."""
    N = adj.shape[0]
    if K is None:
        K = int(jax.device_get(jnp.max(est))) + 1
    Kp = max(128, _pad_to(K, 128))
    Tp, Np = _tile_dims(N, T)
    if interpret is None:
        interpret = not _on_tpu()
    adj_p = jnp.zeros((Np, Np), jnp.bfloat16).at[:N, :N].set(adj.astype(jnp.bfloat16))
    est_p = jnp.full((Np,), -1, jnp.int32).at[:N].set(est.astype(jnp.int32))
    h = _hindex_pallas(adj_p, est_p, K=Kp, T=Tp, interpret=interpret)
    return h[:N]


def frontier_step(
    adj: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Masked BFS hop; pads N to tile and R to 128 lanes."""
    N, R = f.shape
    Rp = max(128, _pad_to(R, 128))
    Tp, Np = _tile_dims(N, T)
    if interpret is None:
        interpret = not _on_tpu()
    adj_p = jnp.zeros((Np, Np), jnp.bfloat16).at[:N, :N].set(adj.astype(jnp.bfloat16))
    f_p = jnp.zeros((Np, Rp), jnp.bfloat16).at[:N, :R].set(f.astype(jnp.bfloat16))
    e_p = jnp.zeros((Np,), jnp.int8).at[:N].set(eligible.astype(jnp.int8))
    v_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(visited.astype(jnp.int8))
    nxt = _frontier_pallas(adj_p, f_p, e_p, v_p, T=Tp, interpret=interpret)
    return nxt[:N, :R]


def coreness_dense(
    adj: jax.Array,
    T: int = 256,
    max_steps: int = 10_000,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Full coreness via the kernelized min-H iteration (dense path).

    Matches `ref.coreness_dense_ref` and `core.kcore.coreness` exactly.
    """
    N = adj.shape[0]
    deg = jnp.sum(adj > 0, axis=1).astype(jnp.int32)
    K = int(jax.device_get(jnp.max(deg))) + 1 if N else 1
    est = deg
    for _ in range(max_steps):
        h = hindex(adj, est, K=K, T=T, interpret=interpret)
        new = jnp.minimum(est, h)
        if bool(jax.device_get(jnp.all(new == est))):
            break
        est = new
    return est


# ---------------------------------------------------------------------------
# ELL-path wrappers (pad N to tile, Cd and R to 128 lanes).
# ---------------------------------------------------------------------------


def hindex_ell(
    nbr: jax.Array,
    est: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """h-index per node via the ELL block-sparse kernel — O(N*Cd) memory."""
    N, Cd = nbr.shape
    Cdp = max(128, _pad_to(Cd, 128))
    Tp, Np = _tile_dims(N, T)
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p = jnp.full((Np, Cdp), -1, jnp.int32).at[:N, :Cd].set(nbr.astype(jnp.int32))
    est_p = jnp.full((Np,), -1, jnp.int32).at[:N].set(est.astype(jnp.int32))
    h = _hindex_ell_pallas(nbr_p, est_p, K=Cdp, T=Tp, interpret=interpret)
    return h[:N]


def frontier_step_ell(
    nbr: jax.Array,
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    T: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Masked BFS hop over the ELL adjacency; eligible is (N, R) per-column."""
    N, Cd = nbr.shape
    R = f.shape[1]
    Cdp = max(128, _pad_to(Cd, 128))
    Rp = max(128, _pad_to(R, 128))
    Tp, Np = _tile_dims(N, T)
    if interpret is None:
        interpret = not _on_tpu()
    nbr_p = jnp.full((Np, Cdp), -1, jnp.int32).at[:N, :Cd].set(nbr.astype(jnp.int32))
    f_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(f.astype(jnp.int8))
    e_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(eligible.astype(jnp.int8))
    v_p = jnp.zeros((Np, Rp), jnp.int8).at[:N, :R].set(visited.astype(jnp.int8))
    nxt = _frontier_ell_pallas(nbr_p, f_p, e_p, v_p, T=Tp, interpret=interpret)
    return nxt[:N, :R]


# ---------------------------------------------------------------------------
# GraphBlocks-level dispatch — the only entry points core code may use.
# ---------------------------------------------------------------------------


def hindex_blocks(
    g,  # GraphBlocks (duck-typed: .nbr, .N, .Cd)
    est: jax.Array,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    adj: Optional[jax.Array] = None,
) -> jax.Array:
    """h-index of neighbor estimates for every node, via the chosen backend.

    All backends are exact and identical (h <= deg <= Cd, so the static
    threshold bound K = Cd keeps the kernel paths jit-safe).  Loops that
    call the dense backend repeatedly should densify once and pass `adj`
    (see `dense_adj`) instead of paying the O(N^2) scatter per call.
    """
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        return ref.ell_hindex_ref(g.nbr, est).astype(jnp.int32)
    if b == "ell":
        return hindex_ell(g.nbr, est, interpret=interpret)
    if b == "ell_spmd":
        from ..runtime.spmd import hindex_spmd  # lazy: no import cycle

        return hindex_spmd(g, est)
    if adj is None:
        adj = ref.ell_to_dense(g.nbr, g.N)
    return hindex(adj, est, K=g.Cd + 1, interpret=interpret)


def _eligible_cols(eligible: jax.Array, R: int) -> jax.Array:
    """Broadcast a shared (N,) eligibility mask to the (N, R) column form."""
    if eligible.ndim == 1:
        return jnp.broadcast_to(eligible[:, None], (eligible.shape[0], R))
    return eligible


def dense_adj(g, backend: str) -> Optional[jax.Array]:
    """Densify once for a loop over dense-backend calls; None otherwise."""
    if resolve_backend(backend, g.N) == "dense":
        return ref.ell_to_dense(g.nbr, g.N)
    return None


def frontier_blocks(
    g,  # GraphBlocks (duck-typed)
    f: jax.Array,
    eligible: jax.Array,
    visited: jax.Array,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    adj: Optional[jax.Array] = None,
) -> jax.Array:
    """One masked BFS hop for R stacked frontiers, via the chosen backend.

    f, visited: (N, R) bool; eligible: (N,) shared or (N, R) per-column.
    Returns the next frontier as (N, R) bool.  As with `hindex_blocks`,
    pass a precomputed `adj` when looping over dense-backend hops.
    """
    R = f.shape[1]
    elig = _eligible_cols(eligible, R)
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        return ref.ell_frontier_hop_ref(g.nbr, f, elig, visited)
    if b == "ell":
        return frontier_step_ell(g.nbr, f, elig, visited, interpret=interpret) > 0
    if b == "ell_spmd":
        from ..runtime.spmd import frontier_spmd  # lazy: no import cycle

        return frontier_spmd(g, f, elig, visited)
    # dense kernel takes a shared (N,) eligibility; fold the per-column mask
    # into `visited` (a node ineligible for column r can never enter it).
    if adj is None:
        adj = ref.ell_to_dense(g.nbr, g.N)
    vis_aug = visited.astype(bool) | ~elig.astype(bool)
    ones = jnp.ones((g.N,), jnp.int8)
    return frontier_step(adj, f, ones, vis_aug, interpret=interpret) > 0


@functools.partial(jax.jit, static_argnames=("max_steps",))
def _coreness_blocks_jnp(g, max_steps: int = 10_000) -> jax.Array:
    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        h = ref.ell_hindex_ref(g.nbr, est)
        new = jnp.where(g.node_mask, jnp.minimum(est, h), est)
        return new, jnp.any(new != est), it + 1

    est, _, _ = jax.lax.while_loop(cond, body, (est0, jnp.bool_(True), 0))
    return est


def coreness_blocks(
    g,  # GraphBlocks (duck-typed)
    backend: str = "auto",
    max_steps: int = 10_000,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Full min-H coreness of every node (0 on padding rows), any backend."""
    b = resolve_backend(backend, g.N)
    if b == "jnp":
        return _coreness_blocks_jnp(g, max_steps)
    if b == "ell_spmd":
        from ..runtime.spmd import coreness_spmd  # lazy: no import cycle

        return coreness_spmd(g, max_steps=max_steps)
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    adj = ref.ell_to_dense(g.nbr, g.N) if b == "dense" else None
    for _ in range(max_steps):
        if b == "dense":
            h = hindex(adj, est, K=g.Cd + 1, interpret=interpret)
        else:
            h = hindex_ell(g.nbr, est, interpret=interpret)
        new = jnp.where(g.node_mask, jnp.minimum(est, h), est)
        if bool(jax.device_get(jnp.all(new == est))):
            break
        est = new
    return est
