"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: kernels must `assert_allclose` against
them for every shape/dtype in the sweep tests.  They are also the "jnp"
backend of the `ops.py` registry — the fallback execution path on platforms
without Pallas support.  Nothing here imports from `repro.core`; the ELL
oracles take the raw `nbr` array so they stay dependency-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hindex_counts_ref(adj: jax.Array, est: jax.Array, K: int) -> jax.Array:
    """h-index of every node from a dense adjacency.

    adj: (N, N) 0/1 matrix (any float/int dtype), est: (N,) int32.
    cnt[u, k] = #neighbors v of u with est[v] >= k+1, k = 0..K-1 (via matmul);
    h[u] = max{k in 1..K : cnt[u, k-1] >= k}.  Requires K >= max(est)+1 for
    exactness (h <= max est always, so thresholds above K never fire).
    """
    ks = jnp.arange(1, K + 1, dtype=jnp.int32)  # thresholds
    B = (est[:, None] >= ks[None, :]).astype(jnp.float32)  # (N, K)
    cnt = adj.astype(jnp.float32) @ B  # (N, K)
    ok = cnt >= ks.astype(jnp.float32)[None, :]
    return jnp.sum(ok, axis=1).astype(jnp.int32)  # prefix-monotone -> sum = h


def frontier_step_ref(
    adj: jax.Array, f: jax.Array, eligible: jax.Array, visited: jax.Array
) -> jax.Array:
    """One BFS hop for R stacked frontiers.

    adj: (N, N) 0/1; f: (N, R) 0/1; eligible: (N,) bool; visited: (N, R) bool.
    next[u, r] = (∃v~u: f[v, r]) ∧ eligible[u] ∧ ¬visited[u, r].
    """
    hit = adj.astype(jnp.float32) @ f.astype(jnp.float32) > 0
    return hit & eligible[:, None] & ~visited


def coreness_dense_ref(adj: jax.Array, max_steps: int = 10_000) -> jax.Array:
    """Full min-H coreness iteration on a dense adjacency (oracle)."""
    deg = jnp.sum(adj > 0, axis=1).astype(jnp.int32)
    K = int(jax.device_get(jnp.max(deg))) + 1 if deg.size else 1

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        h = hindex_counts_ref(adj, est, K)
        new = jnp.minimum(est, h)
        return new, jnp.any(new != est), it + 1

    est, _, _ = jax.lax.while_loop(cond, body, (deg, jnp.bool_(True), 0))
    return est


# ---------------------------------------------------------------------------
# ELL (block-sparse) oracles — the jnp backend of the ops.py registry.
# ---------------------------------------------------------------------------


def hindex_rows(vals: jax.Array) -> jax.Array:
    """Row-wise h-index of a padded value matrix (PAD/-1 entries ignored).

    h = max{k : at least k entries >= k}.  Computed by descending sort +
    position compare; the Pallas kernels (`kcore_hindex`, `ell_hindex`)
    compute the same thing via threshold counting.
    """
    Cd = vals.shape[-1]
    s = -jnp.sort(-vals, axis=-1)  # descending
    ranks = jnp.arange(1, Cd + 1, dtype=vals.dtype)
    return jnp.sum(s >= ranks, axis=-1).astype(vals.dtype)


def ell_gather(nbr: jax.Array, est: jax.Array) -> jax.Array:
    """Gather est over the ELL adjacency; PAD slots -> -1 (ignored by hindex)."""
    vals = est[jnp.clip(nbr, 0, None)]
    return jnp.where(nbr >= 0, vals, -1)


def ell_hindex_ref(nbr: jax.Array, est: jax.Array) -> jax.Array:
    """h-index of every node over the ELL adjacency (gather + row h-index)."""
    return hindex_rows(ell_gather(nbr, est))


def ell_frontier_hop_ref(
    nbr: jax.Array, f: jax.Array, eligible: jax.Array, visited: jax.Array
) -> jax.Array:
    """One masked BFS hop for R stacked frontiers over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded); f, visited: (N, R) bool;
    eligible: (N, R) bool (per-frontier k-level masks).
    next[u, r] = (exists j: f[nbr[u, j], r]) & eligible[u, r] & ~visited[u, r]
    — the gather formulation; equal to the scatter-or for undirected ELL
    storage (each edge stored in both endpoint rows).
    """
    N = nbr.shape[0]
    f_pad = jnp.concatenate([f.astype(bool), jnp.zeros((1, f.shape[1]), bool)])
    idx = jnp.where(nbr >= 0, nbr, N)  # PAD -> the all-False sentinel row
    hit = jnp.any(f_pad[idx], axis=1)  # (N, Cd, R) -> (N, R)
    return hit & eligible.astype(bool) & ~visited.astype(bool)


def ell_to_dense(nbr: jax.Array, N: int) -> jax.Array:
    """ELL adjacency (rows of padded neighbor ids) -> dense 0/1 (N, N)."""
    rows = jnp.repeat(jnp.arange(N), nbr.shape[1])
    cols = nbr.reshape(-1)
    ok = cols >= 0
    dense = jnp.zeros((N, N), jnp.float32)
    return dense.at[rows, jnp.clip(cols, 0)].max(ok.astype(jnp.float32))
