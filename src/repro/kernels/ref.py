"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: kernels must `assert_allclose` against
them for every shape/dtype in the sweep tests.  They are also the "jnp"
backend of the `ops.py` registry — the fallback execution path on platforms
without Pallas support.  Nothing here imports from `repro.core`; the ELL
oracles take the raw `nbr` array so they stay dependency-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hindex_counts_ref(adj: jax.Array, est: jax.Array, K: int) -> jax.Array:
    """h-index of every node from a dense adjacency.

    adj: (N, N) 0/1 matrix (any float/int dtype), est: (N,) int32.
    cnt[u, k] = #neighbors v of u with est[v] >= k+1, k = 0..K-1 (via matmul);
    h[u] = max{k in 1..K : cnt[u, k-1] >= k}.  Requires K >= max(est)+1 for
    exactness (h <= max est always, so thresholds above K never fire).
    """
    ks = jnp.arange(1, K + 1, dtype=jnp.int32)  # thresholds
    B = (est[:, None] >= ks[None, :]).astype(jnp.float32)  # (N, K)
    cnt = adj.astype(jnp.float32) @ B  # (N, K)
    ok = cnt >= ks.astype(jnp.float32)[None, :]
    return jnp.sum(ok, axis=1).astype(jnp.int32)  # prefix-monotone -> sum = h


def frontier_step_ref(
    adj: jax.Array, f: jax.Array, eligible: jax.Array, visited: jax.Array
) -> jax.Array:
    """One BFS hop for R stacked frontiers.

    adj: (N, N) 0/1; f: (N, R) 0/1; eligible: (N,) bool; visited: (N, R) bool.
    next[u, r] = (∃v~u: f[v, r]) ∧ eligible[u] ∧ ¬visited[u, r].
    """
    hit = adj.astype(jnp.float32) @ f.astype(jnp.float32) > 0
    return hit & eligible[:, None] & ~visited


def coreness_dense_ref(adj: jax.Array, max_steps: int = 10_000) -> jax.Array:
    """Full min-H coreness iteration on a dense adjacency (oracle)."""
    deg = jnp.sum(adj > 0, axis=1).astype(jnp.int32)
    K = int(jax.device_get(jnp.max(deg))) + 1 if deg.size else 1

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        h = hindex_counts_ref(adj, est, K)
        new = jnp.minimum(est, h)
        return new, jnp.any(new != est), it + 1

    est, _, _ = jax.lax.while_loop(cond, body, (deg, jnp.bool_(True), 0))
    return est


# ---------------------------------------------------------------------------
# ELL (block-sparse) oracles — the jnp backend of the ops.py registry.
# ---------------------------------------------------------------------------


def hindex_rows(vals: jax.Array) -> jax.Array:
    """Row-wise h-index of a padded value matrix (PAD/-1 entries ignored).

    h = max{k : at least k entries >= k}.  Computed by descending sort +
    position compare; the Pallas kernels (`kcore_hindex`, `ell_hindex`)
    compute the same thing via threshold counting.
    """
    Cd = vals.shape[-1]
    s = -jnp.sort(-vals, axis=-1)  # descending
    ranks = jnp.arange(1, Cd + 1, dtype=vals.dtype)
    return jnp.sum(s >= ranks, axis=-1).astype(vals.dtype)


def ell_gather(nbr: jax.Array, est: jax.Array) -> jax.Array:
    """Gather est over the ELL adjacency; PAD slots -> -1 (ignored by hindex)."""
    vals = est[jnp.clip(nbr, 0, None)]
    return jnp.where(nbr >= 0, vals, -1)


def ell_hindex_ref(nbr: jax.Array, est: jax.Array) -> jax.Array:
    """h-index of every node over the ELL adjacency (gather + row h-index)."""
    return hindex_rows(ell_gather(nbr, est))


def ell_frontier_hop_ref(
    nbr: jax.Array, f: jax.Array, eligible: jax.Array, visited: jax.Array
) -> jax.Array:
    """One masked BFS hop for R stacked frontiers over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded); f, visited: (N, R) bool;
    eligible: (N, R) bool (per-frontier k-level masks).
    next[u, r] = (exists j: f[nbr[u, j], r]) & eligible[u, r] & ~visited[u, r]
    — the gather formulation; equal to the scatter-or for undirected ELL
    storage (each edge stored in both endpoint rows).
    """
    N = nbr.shape[0]
    f_pad = jnp.concatenate([f.astype(bool), jnp.zeros((1, f.shape[1]), bool)])
    idx = jnp.where(nbr >= 0, nbr, N)  # PAD -> the all-False sentinel row
    hit = jnp.any(f_pad[idx], axis=1)  # (N, Cd, R) -> (N, R)
    return hit & eligible.astype(bool) & ~visited.astype(bool)


# ---------------------------------------------------------------------------
# Neighbor-combine oracles (the BlockProgram reductions of `ops.COMBINES`).
# The *_rows forms reduce already-gathered (n, Cd, ...) neighbor values —
# shared by the jnp backend and the mesh backend's post-halo local reduce;
# the ell_* forms bundle the ELL gather for whole-graph use.
# ---------------------------------------------------------------------------


def min_rows(vals: jax.Array) -> jax.Array:
    """Row-wise min of gathered neighbor values: (n, Cd) -> (n,).

    PAD slots must already hold an absorbing fill (int32 max for the CC
    label exchange) so empty slots never win the min.
    """
    return jnp.min(vals, axis=-1)


def sum_rows(vals: jax.Array) -> jax.Array:
    """Row-wise sum of gathered neighbor values: (n, Cd) -> (n,).

    PAD slots must already hold 0 (the "sum" combine's absorbing fill).
    """
    return jnp.sum(vals, axis=-1)


def common_rows(own_rows: jax.Array, nb_rows: jax.Array) -> jax.Array:
    """Directed common-neighbor counts: ((n, Cd), (n, Cd, Cd)) -> (n,).

    own_rows[u] is u's padded neighbor list; nb_rows[u, j] is the padded
    neighbor list of u's j-th neighbor (all ids global, -1 = PAD — PAD
    slots never match because both sides are masked to ids >= 0).
    Returns red[u] = sum_j |N(u) ∩ N(nbr[u, j])|, which counts every
    triangle through u exactly twice (once per non-u corner).
    """
    own = own_rows[:, None, :, None]        # (n, 1, Cd_own, 1)
    nb = nb_rows[:, :, None, :]             # (n, Cd, 1, Cd_nb)
    match = (own == nb) & (own >= 0) & (nb >= 0)
    return jnp.sum(match, axis=(1, 2, 3)).astype(jnp.int32)


def combine_rows(combine: str, field: jax.Array, nb_vals: jax.Array) -> jax.Array:
    """Reduce already-gathered neighbor values by combine name.

    field: (n, ...) this node's own exchanged values; nb_vals: (n, Cd, ...)
    the neighbors' values with PAD slots holding the combine's absorbing
    fill.  This is the mesh backend's post-halo local reduce and the
    semantic contract every kernel-backed combine must match.
    """
    if combine == "min":
        return min_rows(nb_vals)
    if combine == "sum":
        return sum_rows(nb_vals)
    if combine == "hindex":
        return hindex_rows(nb_vals)
    if combine == "count_common":
        return common_rows(field, nb_vals)
    raise ValueError(f"unknown combine {combine!r}")


def ell_min_ref(nbr: jax.Array, field: jax.Array) -> jax.Array:
    """Gather + row-min over the ELL adjacency (PAD -> dtype max)."""
    fill = jnp.iinfo(field.dtype).max if jnp.issubdtype(
        field.dtype, jnp.integer) else jnp.inf
    vals = jnp.where(nbr >= 0, field[jnp.clip(nbr, 0)], fill)
    return min_rows(vals)


def ell_sum_ref(nbr: jax.Array, field: jax.Array) -> jax.Array:
    """Gather + row-sum over the ELL adjacency (PAD -> 0)."""
    vals = jnp.where(nbr >= 0, field[jnp.clip(nbr, 0)],
                     jnp.zeros((), field.dtype))
    return sum_rows(vals)


def ell_common_ref(nbr: jax.Array, rows: jax.Array) -> jax.Array:
    """Gather neighbor rows + common-neighbor counts over the ELL adjacency.

    `rows` is the (N, Cd) per-node neighbor-row field being exchanged —
    for whole-graph execution it is simply `nbr` itself; keeping it a
    separate argument is what lets the mesh backend hand in halo-served
    rows instead.
    """
    nb_rows = jnp.where(
        (nbr >= 0)[:, :, None], rows[jnp.clip(nbr, 0)], -1)  # (N, Cd, Cd)
    return common_rows(rows, nb_rows)


def ell_to_dense(nbr: jax.Array, N: int) -> jax.Array:
    """ELL adjacency (rows of padded neighbor ids) -> dense 0/1 (N, N)."""
    rows = jnp.repeat(jnp.arange(N), nbr.shape[1])
    cols = nbr.reshape(-1)
    ok = cols >= 0
    dense = jnp.zeros((N, N), jnp.float32)
    return dense.at[rows, jnp.clip(cols, 0)].max(ok.astype(jnp.float32))
