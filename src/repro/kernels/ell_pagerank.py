"""Pallas TPU kernel: ELL block-sparse neighbor-sum sweep (PageRank push).

The push-style PageRank superstep is the "sum" neighbor combine of the
`BlockProgram` contract: every node's exchanged field is its outgoing
contribution rank/deg, and each superstep sums the contributions of its
neighbors.  Same ELL tiling as the h-index/min kernels, float32 payload:

    nbr[N, Cd]   int32    padded neighbor ids (-1 = empty slot)
    field[N]     float32  per-node contribution (rank[u] / deg[u])

Per row tile of T nodes (grid axis i):
  1. gather   vals[t, j] = field[nbr[t, j]]     (PAD slots -> 0.0, the
              sum-combine's absorbing fill)
  2. reduce   out[t] = sum_j vals[t, j]

The accumulation order within a row is the same axis-1 reduction the jnp
oracle performs, so cross-backend drift stays at normal float32
reassociation noise (the parity tests use allclose, not bit equality).
O(N*Cd) memory; the full contribution vector rides in VMEM as a (1, N)
float32 row.  A max-degree column bound K < Cd (left-filled rows) is
honored like the sibling kernels.  Validated in interpret mode against
`ref.ell_sum_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams


def _ell_sum_kernel(nbr_ref, field_ref, out_ref, *, T: int):
    nbr = nbr_ref[...]  # (T, C) int32, -1 padded
    vals = jnp.where(
        nbr >= 0,
        jnp.take(field_ref[0], jnp.clip(nbr, 0), axis=0),
        jnp.float32(0.0),
    )
    out_ref[...] = jnp.sum(vals, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret"))
def neighbor_sum_ell(
    nbr: jax.Array,
    field: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise sum of neighbor field values over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded); field: (N,) float32; K: column bound
    (exact for K >= Cd, or K < Cd on left-filled rows).  Returns (N,)
    float32 with 0.0 on neighborless rows.  N % T == 0 and Cd, K
    multiples of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert field.shape == (N,), (field.shape, N)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    C = min(Cd, K)
    ni = N // T

    out = pl.pallas_call(
        functools.partial(_ell_sum_kernel, T=T),
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-list row tile
            pl.BlockSpec((1, N), lambda i: (0, 0)),   # full contribution row
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], field.astype(jnp.float32)[None, :])
    return out[:, 0]
