"""Pallas TPU kernel: ELL block-sparse neighbor-row intersection (triangles).

Triangle counting is the "count_common" neighbor combine of the
`BlockProgram` contract: the exchanged field is each node's *neighbor row
itself* ((N, Cd), global ids), and the per-node reduction is

    red[u] = sum_j |N(u) ∩ N(nbr[u, j])|

— the number of ordered (v, w) pairs closing a triangle at u, i.e. twice
the per-node triangle count.  Ids are compared for equality only, so the
global padded ids work unchanged whether the neighbor rows arrive from
the local matrix (this kernel) or from a halo exchange (the ell_spmd
path, where `ref.common_rows` reduces the halo-served (S, Cd, Cd) rows).

Per row tile of T nodes (grid axis i), a `fori_loop` over the C neighbor
slots: slot j gathers the j-th neighbor's full row from the resident
(N, C) row matrix and scores the (T, C, C) all-pairs id match against the
tile's own rows — PAD entries (-1) are masked on both sides, and slots
with no neighbor contribute nothing.  O(N * Cd^3) work and O(N * Cd)
memory: the classic set-intersection cost without ever densifying, the
same trade the dense backend's diag(A^3) matmul makes at O(N^2) memory.

A max-degree column bound K < Cd (left-filled rows, `ops.degree_bound`)
bounds BOTH sides of the intersection — the swept slots and the row
columns compared — which cubes the savings.  Validated in interpret mode
against `ref.ell_common_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams


def _ell_common_kernel(nbr_ref, own_ref, rows_ref, out_ref, *, C: int, T: int):
    nbr = nbr_ref[...]    # (T, C) int32 neighbor ids, -1 padded
    own = own_ref[...]    # (T, C) int32 this tile's exchanged rows
    rows = rows_ref[...]  # (N, C) int32 full row matrix (the field)
    own_ok = own >= 0

    def body(j, acc):
        col = jax.lax.dynamic_slice(nbr, (0, j), (T, 1))[:, 0]      # (T,)
        v_rows = jnp.take(rows, jnp.clip(col, 0), axis=0)           # (T, C)
        match = (
            (own[:, :, None] == v_rows[:, None, :])
            & own_ok[:, :, None]
            & (v_rows >= 0)[:, None, :]
        )
        cnt = jnp.sum(match.astype(jnp.int32), axis=(1, 2))          # (T,)
        return acc + jnp.where(col >= 0, cnt, 0)

    red = jax.lax.fori_loop(0, C, body, jnp.zeros((T,), jnp.int32))
    out_ref[...] = red[:, None]


@functools.partial(jax.jit, static_argnames=("K", "T", "interpret"))
def neighbor_common_ell(
    nbr: jax.Array,
    rows: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Directed common-neighbor counts over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded) — the adjacency swept; rows: (N, Cd)
    int32 — the exchanged per-node row field intersected (equal to `nbr`
    for whole-graph execution).  K bounds the columns of BOTH (exact for
    K >= Cd, or K < Cd on left-filled rows).  Returns (N,) int32:
    red[u] = sum_j |rows[u] ∩ rows[nbr[u, j]]| over valid slots j.
    N % T == 0 and Cd, K multiples of 128 (pad via the ops.py wrapper).
    """
    N, Cd = nbr.shape
    assert rows.shape == (N, Cd), (rows.shape, nbr.shape)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    C = min(Cd, K)
    ni = N // T

    out = pl.pallas_call(
        functools.partial(_ell_common_kernel, C=C, T=T),
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-id row tile
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # own exchanged rows
            pl.BlockSpec((N, C), lambda i: (0, 0)),   # full row matrix
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], rows[:, :C], rows[:, :C])
    return out[:, 0]
