"""Pallas TPU kernel: ELL block-sparse neighbor-row intersection (triangles).

Triangle counting is the "count_common" neighbor combine of the
`BlockProgram` contract: the exchanged field is each node's *neighbor row
itself* ((N, Cd), global ids), and the per-node reduction is

    red[u] = sum_j |N(u) ∩ N(nbr[u, j])|

— the number of ordered (v, w) pairs closing a triangle at u, i.e. twice
the per-node triangle count.  Ids are compared for equality only, so the
global padded ids work unchanged whether the neighbor rows arrive from
the local matrix (this kernel) or from a halo exchange (the ell_spmd
path, where `ref.common_rows` reduces the halo-served (S, Cd, Cd) rows).

Two variants (`VARIANTS`):

``merge`` (default) — exploits the **sorted-ELL invariant** (`core.graph`):
  every row's valid slots ascend with pads on the right, so after keying
  pads to int32-max each row is monotone and membership is a binary
  search.  Per swept slot j the kernel gathers the neighbor's keyed row
  and locates every element of the tile's own rows with ceil(log2 C)
  vectorized lo/hi probe rounds (`take_along_axis` over the (T, C) mid
  matrix) — O(N * Cd^2 * log Cd) work instead of the all-pairs cube, and
  the probes are full-tile vector ops, not scalar loops.  The slot sweep
  early-exits at the highest occupied column of the tile (pad-right rows
  make column occupancy monotone).  The ops.py wrapper re-keys + sorts
  the row field on the way in, which is a no-op permutation under the
  invariant but makes the kernel correct for arbitrary slot orders too.

``allpairs`` — the legacy O(N * Cd^3) formulation: per swept slot a
  (T, C, C) all-pairs id match against the tile's own rows, PAD masked on
  both sides.  Kept as the measuring stick for the merge speedup and as
  the fallback that assumes nothing about slot order.

Both variants: O(N * Cd) memory (never densifies), a max-degree column
bound K < Cd (left-filled rows, `ops.degree_bound`) restricts both the
swept slots and the compared columns.  Validated in interpret mode
against `ref.ell_common_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from ._compat import CompilerParams as _CompilerParams

#: intersection variants: sorted binary-probe merge vs legacy all-pairs
VARIANTS = ("merge", "allpairs")

#: key pads sort/compare above every real id (ids are < N <= int32 max)
_PAD_KEY = jnp.iinfo(jnp.int32).max


def _occupied_cols(nbr, C):
    """Highest occupied column + 1 of a (T, C) tile (0 if all-pad)."""
    cols_any = jnp.any(nbr >= 0, axis=0)
    return jnp.max(jnp.where(cols_any, jnp.arange(C, dtype=jnp.int32) + 1, 0))


def _ell_merge_kernel(nbr_ref, own_ref, rows_ref, out_ref, *, C: int, T: int):
    nbr = nbr_ref[...]    # (T, C) int32 neighbor ids, -1 padded
    own = own_ref[...]    # (T, C) int32 keyed sorted rows (pads = _PAD_KEY)
    rows = rows_ref[...]  # (N, C) int32 keyed sorted row matrix
    own_ok = own != _PAD_KEY
    # lower-bound bisect needs the [lo, hi) interval to close to length 0,
    # i.e. ceil(log2 C) + 1 = C.bit_length() rounds for C a power of two
    n_bits = max(1, C.bit_length())

    def body(j, acc):
        col = jax.lax.dynamic_slice(nbr, (0, j), (T, 1))[:, 0]      # (T,)
        v_row = jnp.take(rows, jnp.clip(col, 0), axis=0)             # (T, C)

        # vectorized lower/upper-bound bisect of every own[t, i] in
        # v_row[t, :]; ub - lb = occurrence count, so duplicate ids (legal
        # in raw ELL fields, not in validated graphs) score like all-pairs
        def probe(_, st):
            lb_lo, lb_hi, ub_lo, ub_hi = st
            mid_l = (lb_lo + lb_hi) >> 1
            mv_l = jnp.take_along_axis(v_row, jnp.clip(mid_l, 0, C - 1), axis=1)
            right_l = mv_l < own
            mid_u = (ub_lo + ub_hi) >> 1
            mv_u = jnp.take_along_axis(v_row, jnp.clip(mid_u, 0, C - 1), axis=1)
            right_u = mv_u <= own
            return (
                jnp.where(right_l, mid_l + 1, lb_lo),
                jnp.where(right_l, lb_hi, mid_l),
                jnp.where(right_u, mid_u + 1, ub_lo),
                jnp.where(right_u, ub_hi, mid_u),
            )

        zeros = jnp.zeros((T, C), jnp.int32)
        full = jnp.full((T, C), C, jnp.int32)
        lb, _, ub, _ = jax.lax.fori_loop(
            0, n_bits, probe, (zeros, full, zeros, full))
        occ = jnp.where(own_ok, ub - lb, 0)
        cnt = jnp.sum(occ, axis=1)                                   # (T,)
        return acc + jnp.where(col >= 0, cnt, 0)

    jmax = _occupied_cols(nbr, C)  # early exit: pad-right ⇒ slots ≥ jmax empty
    red = jax.lax.fori_loop(0, jmax, body, jnp.zeros((T,), jnp.int32))
    out_ref[...] = red[:, None]


def _ell_allpairs_kernel(nbr_ref, own_ref, rows_ref, out_ref, *, C: int, T: int):
    nbr = nbr_ref[...]    # (T, C) int32 neighbor ids, -1 padded
    own = own_ref[...]    # (T, C) int32 this tile's exchanged rows
    rows = rows_ref[...]  # (N, C) int32 full row matrix (the field)
    own_ok = own >= 0

    def body(j, acc):
        col = jax.lax.dynamic_slice(nbr, (0, j), (T, 1))[:, 0]      # (T,)
        v_rows = jnp.take(rows, jnp.clip(col, 0), axis=0)           # (T, C)
        match = (
            (own[:, :, None] == v_rows[:, None, :])
            & own_ok[:, :, None]
            & (v_rows >= 0)[:, None, :]
        )
        cnt = jnp.sum(match.astype(jnp.int32), axis=(1, 2))          # (T,)
        return acc + jnp.where(col >= 0, cnt, 0)

    red = jax.lax.fori_loop(0, C, body, jnp.zeros((T,), jnp.int32))
    out_ref[...] = red[:, None]


@functools.partial(
    jax.jit, static_argnames=("K", "T", "interpret", "variant"))
def neighbor_common_ell(
    nbr: jax.Array,
    rows: jax.Array,
    K: int,
    T: int = 256,
    interpret: bool = True,
    variant: str = "merge",
) -> jax.Array:
    """Directed common-neighbor counts over the ELL adjacency.

    nbr: (N, Cd) int32 (-1 padded) — the adjacency swept; rows: (N, Cd)
    int32 — the exchanged per-node row field intersected (equal to `nbr`
    for whole-graph execution).  K bounds the columns of BOTH (exact for
    K >= Cd, or K < Cd on left-filled rows).  Returns (N,) int32:
    red[u] = sum_j |rows[u] ∩ rows[nbr[u, j]]| over valid slots j.
    N % T == 0 and Cd, K multiples of 128 (pad via the ops.py wrapper).

    variant="merge" canonicalizes the row field (key pads to int32-max,
    sort ascending — a no-op under the sorted-ELL invariant) and binary-
    probes memberships; "allpairs" is the legacy cubic match.  Counts are
    intersection sizes, so both variants are bit-identical.
    """
    N, Cd = nbr.shape
    assert rows.shape == (N, Cd), (rows.shape, nbr.shape)
    assert N % T == 0, (N, T)
    assert Cd % 128 == 0 and K % 128 == 0, (Cd, K)
    assert variant in VARIANTS, variant
    C = min(Cd, K)
    ni = N // T

    if variant == "merge":
        kernel = functools.partial(_ell_merge_kernel, C=C, T=T)
        field = jnp.sort(
            jnp.where(rows[:, :C] >= 0, rows[:, :C], _PAD_KEY), axis=1)
    else:
        kernel = functools.partial(_ell_allpairs_kernel, C=C, T=T)
        field = rows[:, :C]

    out = pl.pallas_call(
        kernel,
        grid=(ni,),
        in_specs=[
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # neighbor-id row tile
            pl.BlockSpec((T, C), lambda i: (i, 0)),  # own exchanged rows
            pl.BlockSpec((N, C), lambda i: (0, 0)),   # full row matrix
        ],
        out_specs=pl.BlockSpec((T, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(nbr[:, :C], field, field)
    return out[:, 0]
