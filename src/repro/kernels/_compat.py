"""jax version compatibility for the Pallas kernel modules.

jax renamed TPUCompilerParams -> CompilerParams across releases; resolve
the name once here so every kernel file imports the same symbol.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
