"""Serving observability: latency percentiles, throughput, staleness, sheds.

Pure host-side accounting — nothing here touches the device.  The server
records one `observe_batch` per answered query batch (per-query latencies
measured submit -> answer, the batch's busy time, and the snapshot
staleness its answers were served at) and one `observe_shed` per request
rejected by admission control.  `summary()` flattens everything into a
JSON-able dict: the shape `benchmarks/bench_service.py` reports from.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np


class ServiceMetrics:
    """Per-query-kind counters + latency reservoirs for one server.

    Latencies are kept raw (seconds, one float per answered query) so
    percentiles are exact, not sketched — serving runs here are test- and
    bench-sized, thousands of queries, where a reservoir of everything is
    cheaper than being wrong about p99.
    """

    def __init__(self) -> None:
        self.answered: Dict[str, int] = {}   # kind -> queries answered
        self.shed: Dict[str, int] = {}       # kind -> queries rejected
        self.batches = 0                     # answered batches
        self.busy_s = 0.0                    # time spent answering batches
        self._lat: Dict[str, List[float]] = {}
        self._staleness: List[int] = []      # windows behind head, per batch

    # -- recording ---------------------------------------------------------

    def observe_batch(self, kind: str, latencies_s: Iterable[float],
                      staleness: int, busy_s: float) -> None:
        lats = list(latencies_s)
        self.answered[kind] = self.answered.get(kind, 0) + len(lats)
        self._lat.setdefault(kind, []).extend(lats)
        self._staleness.append(int(staleness))
        self.busy_s += float(busy_s)
        self.batches += 1

    def observe_shed(self, kind: str) -> None:
        self.shed[kind] = self.shed.get(kind, 0) + 1

    # -- reading -----------------------------------------------------------

    @property
    def total_answered(self) -> int:
        return sum(self.answered.values())

    @property
    def total_shed(self) -> int:
        return sum(self.shed.values())

    def qps(self) -> float:
        """Answered queries per second of batch-answering busy time."""
        return self.total_answered / self.busy_s if self.busy_s > 0 else 0.0

    def latency_percentile(self, p: float,
                           kind: Optional[str] = None) -> float:
        """p-th percentile answer latency in seconds (NaN if unobserved).

        `kind=None` pools every kind — the whole-service view.
        """
        if kind is None:
            lats = [x for xs in self._lat.values() for x in xs]
        else:
            lats = self._lat.get(kind, [])
        return float(np.percentile(lats, p)) if lats else float("nan")

    def staleness_max(self) -> int:
        return max(self._staleness) if self._staleness else 0

    def staleness_mean(self) -> float:
        return float(np.mean(self._staleness)) if self._staleness else 0.0

    def summary(self) -> dict:
        """JSON-able rollup: totals, qps, staleness, per-kind p50/p99."""
        kinds = sorted(set(self.answered) | set(self.shed))
        return {
            "answered": self.total_answered,
            "shed": self.total_shed,
            "batches": self.batches,
            "qps": self.qps(),
            "staleness_max": self.staleness_max(),
            "staleness_mean": self.staleness_mean(),
            "p50_ms": self.latency_percentile(50) * 1e3,
            "p99_ms": self.latency_percentile(99) * 1e3,
            "per_kind": {
                k: {
                    "answered": self.answered.get(k, 0),
                    "shed": self.shed.get(k, 0),
                    "p50_ms": self.latency_percentile(50, k) * 1e3,
                    "p99_ms": self.latency_percentile(99, k) * 1e3,
                }
                for k in kinds
            },
        }
