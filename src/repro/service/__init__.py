"""Graph query service: serve reads from device-resident analytics while
the stream writes.

BLADYG's premise is a graph that is *queried while it changes*; this
package is the querying half.  It layers on the streaming runtime
without forking it — one `StreamSession` (runtime/stream.py) applies
update windows, and between windows the server answers typed query
batches against versioned epoch snapshots of the maintained analytics:

  state.py    — `AnalyticsState` / `EpochSnapshot`: consistent,
                immutable (coreness, CC labels, PageRank, topology)
                records cut by a warm-started `fused_analytics` pass and
                published by reference swap (double buffering).
  queries.py  — the typed query set (`core_of`, `degree_of`,
                `nbr_max_core_of`, `same_component`, `topk_pagerank`):
                jitted batched gathers, pow2-padded so the jit cache
                keeps hitting; ONE device_get per answered batch.
  server.py   — `QueryServer`: bounded-queue admission with a reject-new
                shed policy, bucket-by-kind batching, and the scheduling
                loop interleaving query batches between stream windows.
  metrics.py  — `ServiceMetrics`: per-kind latency percentiles,
                queries/sec, snapshot staleness, shed counts.

Everything runs on the session's one executor with zero steady-state
recompiles — counter-asserted in tests/test_service.py via
`kernels.ops.gather_trace_count`, `queries.query_trace_count`, and
`runtime.spmd.step_build_count`.
"""
from ..configs.service import ServiceConfig
from .metrics import ServiceMetrics
from .queries import (
    KINDS,
    Query,
    core_of,
    degree_of,
    nbr_max_core_of,
    query_trace_count,
    same_component,
    topk_pagerank,
)
from .server import QueryServer, Request
from .state import AnalyticsState, EpochSnapshot

__all__ = [
    "ServiceConfig", "ServiceMetrics",
    "KINDS", "Query", "core_of", "degree_of", "nbr_max_core_of",
    "same_component", "topk_pagerank", "query_trace_count",
    "QueryServer", "Request",
    "AnalyticsState", "EpochSnapshot",
]
