"""Typed graph queries: jitted batched gathers over an epoch snapshot.

Every query kind is answered for a whole same-kind batch at once by ONE
jitted device function and ONE `jax.device_get` of the compact answer
array — queries never pull whole analytics vectors to the host.  Batches
are padded to the pow2 bucket above their fill (`kernels.ops._pow2_bucket`
floor 8) and top-k widths are pow2-bucketed the same way, so a steady
query mix compiles each (kind, bucket) pair once and then only ever hits
the jit cache; `_QUERY_TRACES` counts the compiles the same way
`kernels.ops.gather_trace_count` counts adjacency-gather lowerings, and
the serving tests assert it stops moving after warmup.

Addressing: node arguments are global padded ids of the SNAPSHOT's
epoch (the session's id space when the snapshot was cut; migrations make
later epochs' spaces differ — `EpochSnapshot.orig_id` maps back to input
ids).  Out-of-range ids are rejected at submit time by the server;
padding-row ids are legal and answer with the padding conventions
(core 0, degree 0, label -1).  Hub-split snapshots carry a host-side
`primary` map (`core.hub_split.MirrorPlan.primary_row`): every queried
id resolves through it before the gather, so a replica-row id answers
with its hub's values, and `nbr_max_core` reads the snapshot's
pre-merged `nbr_max` field (a hub's neighbors are sharded across its
replica slices — no single row's gather sees them all).

Query kinds:

  core            — coreness of u                       -> int
  degree          — degree of u                          -> int
  nbr_max_core    — max coreness among u's neighbors     -> int (-1 if
                    isolated; exercises the (N, Cd) adjacency gather)
  same_component  — are u and v in one CC                -> bool
  topk_pagerank   — ids + ranks of the k highest-rank    -> ([ids], [ranks])
                    nodes, PageRank-descending
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import _pow2_bucket
from .state import EpochSnapshot

#: every query kind the service answers (the server's bucket axis)
KINDS = ("core", "degree", "nbr_max_core", "same_component",
         "topk_pagerank")

#: smallest padded batch — tiny buckets would fragment the jit cache
BATCH_FLOOR = 8


class Query(NamedTuple):
    """One typed request; build via the constructors below."""

    kind: str
    u: int = 0
    v: int = 0
    k: int = 0


def core_of(u: int) -> Query:
    return Query("core", u=int(u))


def degree_of(u: int) -> Query:
    return Query("degree", u=int(u))


def nbr_max_core_of(u: int) -> Query:
    return Query("nbr_max_core", u=int(u))


def same_component(u: int, v: int) -> Query:
    return Query("same_component", u=int(u), v=int(v))


def topk_pagerank(k: int) -> Query:
    if k < 1:
        raise ValueError(f"topk_pagerank needs k >= 1, got {k}")
    return Query("topk_pagerank", k=int(k))


# ---------------------------------------------------------------------------
# Trace accounting: bumped at TRACE time inside each jitted answer fn, so
# steady-state serving (stable kind/bucket mix) holds the count constant.
# ---------------------------------------------------------------------------

_QUERY_TRACES = 0


def _count_trace() -> None:
    global _QUERY_TRACES
    _QUERY_TRACES += 1


def query_trace_count() -> int:
    """Query-kernel lowerings traced so far (see module docstring)."""
    return _QUERY_TRACES


# ---------------------------------------------------------------------------
# The jitted batch kernels — one per kind, shapes are the cache key.
# ---------------------------------------------------------------------------


@jax.jit
def _batch_gather(field: jax.Array, ids: jax.Array) -> jax.Array:
    """(N,) field, (B,) ids -> (B,) values (serves core AND degree)."""
    _count_trace()
    return field[ids]


@jax.jit
def _batch_nbr_max_core(core: jax.Array, nbr: jax.Array,
                        ids: jax.Array) -> jax.Array:
    """Max coreness over each queried node's neighbor row; -1 if none."""
    _count_trace()
    rows = nbr[ids]                          # (B, Cd)
    vals = jnp.where(rows >= 0, core[jnp.clip(rows, 0)], -1)
    return jnp.max(vals, axis=1)


@jax.jit
def _batch_same_component(labels: jax.Array, us: jax.Array,
                          vs: jax.Array) -> jax.Array:
    _count_trace()
    return labels[us] == labels[vs]


@functools.partial(jax.jit, static_argnames=("k",))
def _batch_topk(rank: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """(values, ids) of the k highest-rank nodes, rank-descending."""
    _count_trace()
    return jax.lax.top_k(rank, k)


def batch_bucket(n: int) -> int:
    """Padded batch width for an n-query batch (pow2, floor 8)."""
    return _pow2_bucket(n, floor=BATCH_FLOOR)


def topk_bucket(k: int, N: int) -> int:
    """Static top-k width for a requested k (pow2-bucketed, capped at N)."""
    return min(_pow2_bucket(k, floor=BATCH_FLOOR), N)


def _pad_ids(vals: List[int], B: int) -> jax.Array:
    out = np.zeros(B, np.int32)
    out[:len(vals)] = vals
    return jnp.asarray(out)


def _resolve(snap: EpochSnapshot, ids: List[int]) -> List[int]:
    """Map queried ids through the hub-split primary map (host-side,
    no-op on unsplit snapshots)."""
    if snap.primary is None:
        return ids
    return [int(snap.primary[i]) for i in ids]


def run_batch(snap: EpochSnapshot, kind: str, queries: List[Query],
              k: int = 0) -> list:
    """Answer one same-kind batch against a snapshot.

    Pads to the pow2 bucket, runs the kind's jitted kernel, pulls the
    compact answers with exactly ONE `jax.device_get`, and returns one
    python answer per query (ints/bools; `topk_pagerank` returns
    ([ids], [ranks]) sliced to each query's own k).  For
    `topk_pagerank` the caller passes the shared bucketed width `k`
    (`topk_bucket`); the server's bucketing guarantees every query in
    the batch fits it.
    """
    n = len(queries)
    if n == 0:
        return []
    if kind == "topk_pagerank":
        vals, ids = _batch_topk(snap.rank, k=k)
        vals_h, ids_h = jax.device_get((vals, ids))
        return [(ids_h[:q.k].tolist(), vals_h[:q.k].tolist())
                for q in queries]
    B = batch_bucket(n)
    us = _resolve(snap, [q.u for q in queries])
    if kind == "core":
        out = _batch_gather(snap.core, _pad_ids(us, B))
    elif kind == "degree":
        out = _batch_gather(snap.deg, _pad_ids(us, B))
    elif kind == "nbr_max_core":
        if snap.nbr_max is not None:  # hub-split: pre-merged across slices
            out = _batch_gather(snap.nbr_max, _pad_ids(us, B))
        else:
            out = _batch_nbr_max_core(snap.core, snap.nbr, _pad_ids(us, B))
    elif kind == "same_component":
        out = _batch_same_component(
            snap.labels, _pad_ids(us, B),
            _pad_ids(_resolve(snap, [q.v for q in queries]), B))
    else:
        raise ValueError(f"unknown query kind {kind!r}; expected {KINDS}")
    ans = jax.device_get(out)
    return [x.item() for x in ans[:n]]
