"""The serving loop: admission -> kind buckets -> batches between windows.

`QueryServer` owns a `StreamSession` (writes) and an `AnalyticsState`
(reads) and time-slices the ONE device program between them:

    submit(q)                # admission control: bounded queue or shed
    step(window)             # apply a stream window, refresh the epoch
                             # snapshot on cadence, answer all batches
    serve(updates, feed)     # the whole interleaved loop

Requests bucket by query kind (and by bucketed k for top-k), so every
batch is answered by one already-compiled kernel; admission control is a
single bounded count across buckets with a reject-new shed policy —
under overload the queue cannot grow latency without bound, and sheds
are counted per kind in the metrics rather than silently dropped.

Nothing here is threaded: "concurrent" means interleaved on the device
timeline, the same way the paper's coordinator alternates worker compute
with masterCompute.  That is what makes answers exact — a batch runs
strictly between windows, against an immutable snapshot whose epoch is
recorded on every request it answers.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Iterable, List, Optional, Tuple

from ..configs.service import ServiceConfig
from ..runtime.stream import StreamResult, StreamSession, _iter_windows
from . import queries as q
from .metrics import ServiceMetrics
from .state import AnalyticsState


@dataclass
class Request:
    """One admitted query: filled in place when its batch is answered."""

    query: q.Query
    t_submit: float
    done: bool = False
    answer: object = None
    epoch: int = -1          # snapshot epoch the answer was read from
    latency_s: float = field(default=float("nan"))


class QueryServer:
    """Bucket-batching query front end over one stream session.

    `session` must track CC labels (see `AnalyticsState`); `state` may
    be passed to share one across servers, else it is built from the
    config's `alpha`/`pr_steps`.  All knobs live on `ServiceConfig`.
    """

    def __init__(self, session: StreamSession,
                 state: Optional[AnalyticsState] = None,
                 config: Optional[ServiceConfig] = None):
        self.config = config if config is not None else ServiceConfig()
        self.session = session
        self.state = state if state is not None else AnalyticsState(
            session, alpha=self.config.alpha, pr_steps=self.config.pr_steps)
        self.metrics = ServiceMetrics()
        self._N = int(session.g.N)
        #: FIFO buckets: (kind[, bucketed k]) -> admitted requests
        self._buckets: "OrderedDict[Tuple, Deque[Request]]" = OrderedDict()
        self._depth = 0

    # -- admission ---------------------------------------------------------

    @property
    def queued(self) -> int:
        """Requests admitted but not yet answered."""
        return self._depth

    def _bucket_key(self, query: q.Query) -> Tuple:
        if query.kind == "topk_pagerank":
            return (query.kind, q.topk_bucket(query.k, self._N))
        return (query.kind,)

    def submit(self, query: q.Query) -> Optional[Request]:
        """Admit one query, or shed it (returns None) at the queue bound.

        Node arguments are validated against the padded id range here —
        a malformed id must fail the submitter, not poison a batch.
        """
        if query.kind not in q.KINDS:
            raise ValueError(
                f"unknown query kind {query.kind!r}; expected {q.KINDS}")
        if query.kind != "topk_pagerank":
            ids = ((query.u, query.v) if query.kind == "same_component"
                   else (query.u,))
            for i in ids:
                if not 0 <= i < self._N:
                    raise ValueError(
                        f"node id {i} outside the padded range "
                        f"[0, {self._N})")
        elif not 1 <= query.k <= self._N:
            raise ValueError(
                f"topk_pagerank k={query.k} outside [1, {self._N}]")
        if self._depth >= self.config.max_queue:
            self.metrics.observe_shed(query.kind)
            return None
        req = Request(query=query, t_submit=time.perf_counter())
        self._buckets.setdefault(self._bucket_key(query),
                                 deque()).append(req)
        self._depth += 1
        return req

    # -- answering ---------------------------------------------------------

    def _answer_batch(self, key: Tuple, batch: List[Request]) -> None:
        snap = self.state.snapshot
        kind = key[0]
        t0 = time.perf_counter()
        answers = q.run_batch(snap, kind, [r.query for r in batch],
                              k=key[1] if len(key) > 1 else 0)
        t1 = time.perf_counter()
        for req, ans in zip(batch, answers):
            req.answer = ans
            req.done = True
            req.epoch = snap.epoch
            req.latency_s = t1 - req.t_submit
        self.metrics.observe_batch(
            kind, [r.latency_s for r in batch],
            staleness=self.state.staleness(), busy_s=t1 - t0)

    def pump(self, max_batches: Optional[int] = None) -> int:
        """Answer queued batches (round-robin over buckets, FIFO within).

        Each turn drains at most `config.max_batch` requests from the
        oldest non-empty bucket; `max_batches` bounds the turns (None =
        drain everything).  Returns the number of queries answered.
        """
        answered = 0
        turns = 0
        while self._depth and (max_batches is None or turns < max_batches):
            key, dq = next(iter(self._buckets.items()))
            batch = [dq.popleft()
                     for _ in range(min(len(dq), self.config.max_batch))]
            self._depth -= len(batch)
            # rotate: re-queue a non-empty bucket at the back, so one hot
            # kind cannot starve the others
            del self._buckets[key]
            if dq:
                self._buckets[key] = dq
            self._answer_batch(key, batch)
            answered += len(batch)
            turns += 1
        return answered

    # -- the scheduling loop ----------------------------------------------

    def step(self, window: List[Tuple[int, int, int]]) -> int:
        """One serving turn: window -> cadenced refresh -> query batches.

        Returns the number of queries answered this turn.
        """
        self.session.apply_window(window)
        if self.session.windows_applied % self.config.refresh_every == 0:
            self.state.refresh()
        return self.pump()

    def serve(self, updates: Iterable[Tuple[int, int, int]],
              query_feed: Optional[Callable[[int], Iterable[q.Query]]]
              = None) -> StreamResult:
        """Drive the whole interleaved run over an update stream.

        Slices `updates` into the session's R-wide windows; before each
        window, submits `query_feed(window_index)`'s queries (sheds past
        the admission bound are recorded, not raised).  Drains any
        remaining queue after the last window, refreshing once more if
        the cadence left the final windows unsnapshotted, and returns
        the session's `StreamResult`.
        """
        for i, window in enumerate(_iter_windows(updates, self.session.R)):
            if query_feed is not None:
                for query in query_feed(i):
                    self.submit(query)
            self.step(window)
        if self.state.staleness() > 0:
            self.state.refresh()
        self.pump()
        return self.session.result()
