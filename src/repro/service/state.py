"""Device-resident analytics state, published as versioned epoch snapshots.

The serving layer never reads the stream's live arrays: between the
moment a window is applied and the moment its analytics are refreshed,
`session.core`/`session.labels` and the graph describe DIFFERENT epochs,
and the apply path donates graph buffers besides.  `AnalyticsState` is
the consistency boundary — after any prefix of windows it cuts an
`EpochSnapshot`: one immutable record of (coreness, CC labels, PageRank,
degrees, adjacency) all describing the same graph, copied out of the
donation-recycled buffers.

Snapshot refresh is ONE fused superstep loop, not three recomputes: the
stream hooks already keep coreness and CC labels exact, and both are
fixpoints of their own monotone updates (min-H of true coreness is the
coreness; min-label of canonical labels is the labels) — so
`fused_analytics(init=(session.core, session.labels))` warm-starts them
AT the fixpoint, where they ride through bit-unchanged, while the
fixed-iteration PageRank sub-program does the actual work off the same
shared adjacency gather.  Every field of the published snapshot is
therefore bit-identical to a from-scratch recompute on that epoch's
graph (`coreness`, `connected_components`,
`pagerank(tol=None, max_steps=pr_steps)`) — the parity contract
`tests/test_service.py` enforces per backend.

Double buffering: snapshots are immutable NamedTuples, so "front" and
"back" collapse to an attribute swap — queries in flight keep whatever
snapshot record they started with; `refresh()` builds the next epoch's
record off to the side and publishes it by a single assignment.

Hub-split sessions (`runtime.stream.MirrorStream`, or any session whose
`.mirror` is a `core.hub_split.MirrorPlan`) refresh through the same
fused loop under the vertex-cut dataflow: coreness/CC stay bit-exact at
primaries, PageRank is allclose (float slice partials re-associate),
and the snapshot gains the `primary`/`nbr_max` resolution fields the
query layer uses (see `EpochSnapshot`).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.algorithms import fused_analytics


class EpochSnapshot(NamedTuple):
    """One epoch's consistent, immutable analytics + topology record.

    All arrays are device-resident COPIES (the stream's apply path
    donates the live graph buffers, so shared references would be
    invalidated mid-epoch).  Node addressing is the session's padded id
    space at this epoch; `orig_id` maps back to pre-partition input ids
    (stable across §4.2 migrations).

    Hub-split sessions (`MirrorStream` / any session exposing a
    `core.hub_split.MirrorPlan` on `.mirror`) publish two extra fields:
    `primary` — the host-side row -> primary-row map queries resolve
    through (replica-row ids answer with the hub's values), and
    `nbr_max` — the group-merged neighbor-max-coreness field (a hub's
    neighbors are sharded across its replica slices, so the plain
    (N, Cd) gather on one row would see only one slice).  `deg` then
    holds LOGICAL degrees and `rank` is masked to primaries (replica
    rows read 0.0, so `topk_pagerank` never lists a hub twice).

    Padded row ids are only comparable between two snapshots whose
    `(Cn, grows)` match: a capacity escalation (`StreamSession.grow`)
    re-keys every padded id monotonically, so a row id cached from an
    older epoch silently points at a different vertex afterwards.
    Cross-epoch joins must go through `orig_id`, the stable key.
    """

    epoch: int               # snapshot version, 0 at session open
    windows: int             # stream windows ingested when this was cut
    core: jax.Array          # (N,) int32 coreness (0 on padding)
    labels: jax.Array        # (N,) int32 CC labels (-1 on padding)
    rank: jax.Array          # (N,) float32 PageRank (0.0 on padding)
    deg: jax.Array           # (N,) int32 degrees (logical under mirror)
    nbr: jax.Array           # (N, Cd) int32 sorted-ELL adjacency
    node_mask: jax.Array     # (N,) bool real-node mask
    orig_id: jax.Array       # (N,) int32 original input ids
    primary: Optional[np.ndarray] = None   # (N,) host row->primary map
    nbr_max: Optional[jax.Array] = None    # (N,) group-merged nbr max core
    Cn: int = 0              # per-block node capacity at this epoch
    Cd: int = 0              # degree capacity at this epoch
    grows: int = 0           # capacity escalations before this epoch


class AnalyticsState:
    """Maintained analytics over a `StreamSession`, read via snapshots.

    Requires the session to be tracking CC labels (open it with
    `cc_labels=connected_components(g)`): label maintenance is what lets
    the refresh warm-start at the fixpoint instead of budgeting its own
    convergence supersteps.  The session's executor (if any) serves the
    refresh too — one device program, updates and analytics alike.
    """

    def __init__(self, session, alpha: float = 0.85, pr_steps: int = 30):
        if session.labels is None:
            raise ValueError(
                "AnalyticsState needs a label-tracking session: open "
                "StreamSession with cc_labels=connected_components(g) "
                "(or MirrorStream with cc_labels=True) so the refresh "
                "can warm-start CC at its maintained fixpoint.")
        self._session = session
        self.alpha = float(alpha)
        self.pr_steps = int(pr_steps)
        self.refreshes = 0
        self._front: Optional[EpochSnapshot] = None
        self.refresh()  # epoch 0: serve from the open-time graph

    @property
    def snapshot(self) -> EpochSnapshot:
        """The published (front) snapshot — what queries read."""
        return self._front

    @property
    def epoch(self) -> int:
        return self._front.epoch

    def staleness(self) -> int:
        """Stream windows applied since the published snapshot was cut."""
        return self._session.windows_applied - self._front.windows

    def refresh(self) -> EpochSnapshot:
        """Cut + publish the next epoch's snapshot from the session head.

        One fused-analytics pass (see module docstring) plus one copy of
        the topology arrays; the publish itself is a reference swap, so
        a reader can never observe a half-built snapshot.
        """
        sess = self._session
        g = sess.g
        mirror = getattr(sess, "mirror", None)
        core, labels, rank = fused_analytics(
            g, alpha=self.alpha, steps=self.pr_steps,
            backend=sess.backend, executor=sess.executor,
            init=(sess.core, sess.labels), mirror=mirror)
        if mirror is None:
            deg, primary, nbr_max = g.deg, None, None
        else:
            # hub-split session: publish logical degrees, resolve queries
            # through the primary map, mask replica ranks out of top-k,
            # and pre-merge neighbor-max-coreness across replica slices
            # (one (N, Cd) gather + scatter-max per refresh — a single
            # row's slice would see only part of a hub's neighborhood)
            prow = jnp.asarray(mirror.primary_row, jnp.int32)
            deg = jnp.asarray(mirror.ldeg, jnp.int32)
            rank = jnp.where(jnp.asarray(mirror.primary_mask), rank, 0.0)
            row_max = jnp.max(
                jnp.where(g.nbr >= 0, core[jnp.clip(g.nbr, 0)], -1),
                axis=1).astype(jnp.int32)
            grp_max = jnp.full(g.N, -1, jnp.int32).at[prow].max(row_max)
            nbr_max = grp_max[prow]
            primary = np.asarray(mirror.primary_row, np.int32)
        back = EpochSnapshot(
            epoch=0 if self._front is None else self._front.epoch + 1,
            windows=sess.windows_applied,
            core=jnp.copy(core),
            labels=jnp.copy(labels),
            rank=jnp.copy(rank),
            deg=jnp.copy(deg),
            nbr=jnp.copy(g.nbr),
            node_mask=jnp.copy(g.node_mask),
            orig_id=jnp.copy(g.orig_id),
            primary=primary,
            nbr_max=None if nbr_max is None else jnp.copy(nbr_max),
            Cn=int(g.Cn),
            Cd=int(g.Cd),
            grows=int(getattr(sess, "_grows", 0)),
        )
        self._front = back  # publish
        self.refreshes += 1
        return back
