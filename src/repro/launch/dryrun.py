import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh, record memory/cost analysis and the collective
schedule for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro import optim
from repro.configs import ARCHS, SHAPES, SHAPES_BY_NAME, cell_applicable
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _line_result_bytes(line: str) -> int:
    """Sum bytes of the result type(s) on an HLO instruction line."""
    lhs = line.split("=", 1)
    if len(lhs) != 2:
        return 0
    # result type sits between '=' and the op name
    head = lhs[1]
    m = _COLL_RE.search(line)
    if m:
        head = head[: m.start(1) - len(lhs[0]) - 1]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-device bytes by collective op kind (post-partitioning module)."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        kind = m.group(1).lower()
        out[kind] = out.get(kind, 0) + _line_result_bytes(line)
    return out


def _cost(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _memory(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        if hasattr(ma, f):
            out[f] = int(getattr(ma, f))
    return out or str(ma)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             mla_absorbed: bool = False, ring: bool = False,
             prefill_last_only: bool = False, verbose: bool = True):
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mla_absorbed": mla_absorbed,
        "ring": ring,
        "prefill_last_only": prefill_last_only,
        "unrolled": os.environ.get("REPRO_SCAN_UNROLL", "0") == "1",
    }
    if not ok:
        rec["status"] = "SKIP"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    ocfg = optim.AdamWConfig()
    t0 = time.perf_counter()
    with mesh:
        step, kwargs, donate = SP.abstract_cell(
            cfg, shape, mesh, ocfg, mla_absorbed=mla_absorbed, ring=ring,
            prefill_last_only=prefill_last_only)
        jitted = jax.jit(step, donate_argnums=donate)
        lowered = jitted.lower(**kwargs)
        t1 = time.perf_counter()
        # backend optimization level 0: we need the partitioned module +
        # analyses, not fast host code (halves CPU compile time).
        compiled = lowered.compile(
            compiler_options={"xla_backend_optimization_level": 0})
        t2 = time.perf_counter()

    coll = collective_bytes(compiled.as_text())
    cost = _cost(compiled)
    mem = _memory(compiled)

    chips = 512 if multi_pod else 256
    flops = float(cost.get("flops", 0.0))          # per-device (partitioned)
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    rec.update({
        "status": "OK",
        "chips": chips,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "per_device_flops": flops,
        "per_device_bytes": bytes_acc,
        "collective_bytes_per_device": coll,
        "collective_bytes_total": coll_total,
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": bytes_acc / HBM_BW,
        "collective_term_s": coll_total / LINK_BW,
        "memory_analysis": mem,
    })
    if verbose:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] OK "
              f"compile={rec['compile_s']}s flops/dev={flops:.3e} "
              f"bytes/dev={bytes_acc:.3e} coll/dev={coll_total:.3e}")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis keys: flops={flops:.3e} bytes={bytes_acc:.3e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--ring", action="store_true",
                    help="window-sized ring KV caches for sliding layers")
    ap.add_argument("--prefill-last-only", action="store_true",
                    help="prefill computes last-position logits only")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans for exact flop/collective "
                         "counts (slower compiles; used for the roofline pass)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.unroll:
        os.environ["REPRO_SCAN_UNROLL"] = "1"

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            if args.mla_absorbed:
                tag += "_absorbed"
            if args.ring:
                tag += "_ring"
            if args.prefill_last_only:
                tag += "_lastonly"
            if args.unroll:
                tag += "_unrolled"
            fp = outdir / f"{tag}.json"
            try:
                rec = run_cell(arch, shape, mp, mla_absorbed=args.mla_absorbed,
                               ring=args.ring,
                               prefill_last_only=args.prefill_last_only)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                failures += 1
                print(f"[{arch} × {shape}] FAIL: {rec['error'][:200]}")
            fp.write_text(json.dumps(rec, indent=2, default=str))
    print(f"done; {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
