"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, zero allocation) for every model input of
every (arch × shape) cell, plus the step functions that get lowered.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import sharding as SH
from repro.models import build
from repro.models.layers import _dtype


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _shard_like(tree_shapes, shardings):
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), tree_shapes, shardings
    )


def abstract_params(cfg: ArchConfig, mesh: Mesh):
    bundle = build(cfg)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    return _shard_like(shapes, SH.param_shardings(shapes, mesh))


def abstract_opt_state(cfg: ArchConfig, mesh: Mesh, params_abs, ocfg):
    shapes = jax.eval_shape(functools.partial(optim.init, cfg=ocfg), params_abs)
    shardings = SH.opt_shardings(shapes, params_abs, mesh)
    return optim.AdamWState(
        step=_sds((), jnp.int32, shardings.step),
        master=_shard_like(shapes.master, shardings.master),
        m=_shard_like(shapes.m, shardings.m),
        v=_shard_like(shapes.v, shardings.v),
    )


def abstract_batch(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, train: bool):
    B, S = shape.global_batch, shape.seq_len
    bspec = NamedSharding(mesh, SH.batch_spec(mesh, B, 1))
    out: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32, bspec)}
    if train:
        out["labels"] = _sds((B, S), jnp.int32, bspec)
    if cfg.n_prefix_tokens:
        e3 = NamedSharding(mesh, SH.batch_spec(mesh, B, 2))
        out["prefix_embeds"] = _sds(
            (B, cfg.n_prefix_tokens, cfg.prefix_dim), jnp.bfloat16, e3)
    if cfg.is_encdec:
        e3 = NamedSharding(mesh, SH.batch_spec(mesh, B, 2))
        out["src_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, e3)
    return out


def abstract_caches(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    ring: bool = False):
    bundle = build(cfg)
    B, S = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(
        functools.partial(bundle.cache_init, B, S, ring=ring))
    return _shard_like(shapes, SH.cache_shardings(shapes, mesh))


def make_train_step(cfg: ArchConfig, ocfg):
    bundle = build(cfg)
    compute_dtype = _dtype(cfg.dtype)

    def train_step(params, opt_state, batch):
        def loss_of(p):
            loss, aux = bundle.loss_fn(p, batch, moe_path="capacity", remat=True)
            return loss
        loss, grads = jax.value_and_grad(loss_of)(params)
        new_params, new_state = optim.update(grads, opt_state, ocfg, compute_dtype)
        return new_params, new_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ArchConfig, *, last_only: bool = False):
    bundle = build(cfg)

    def prefill_step(params, batch):
        if cfg.is_encdec:
            out, aux = bundle.prefill_fn(params, batch)
        else:
            out, aux = bundle.prefill_fn(params, batch, last_only=last_only)
        return out

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, mla_absorbed: bool = False):
    bundle = build(cfg)

    def serve_step(params, token, caches, pos):
        logits, new_caches = bundle.decode_fn(
            params, token, caches, pos, mla_absorbed=mla_absorbed)
        return logits, new_caches

    return serve_step


def abstract_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, ocfg,
                  *, mla_absorbed: bool = False, ring: bool = False,
                  prefill_last_only: bool = False):
    """Returns (step_fn, kwargs of abstract inputs, donate_argnums)."""
    params = abstract_params(cfg, mesh)
    if shape.kind == "train":
        step = make_train_step(cfg, ocfg)
        opt = abstract_opt_state(cfg, mesh, params, ocfg)
        batch = abstract_batch(cfg, shape, mesh, train=True)
        return step, dict(params=params, opt_state=opt, batch=batch), (0, 1)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, last_only=prefill_last_only)
        batch = abstract_batch(cfg, shape, mesh, train=False)
        return step, dict(params=params, batch=batch), ()
    if shape.kind == "decode":
        step = make_serve_step(cfg, mla_absorbed=mla_absorbed)
        B = shape.global_batch
        tok_spec = NamedSharding(mesh, SH.batch_spec(mesh, B, 1))
        token = _sds((B, 1), jnp.int32, tok_spec)
        caches = abstract_caches(cfg, shape, mesh, ring=ring)
        pos = _sds((), jnp.int32, NamedSharding(mesh, P()))
        return step, dict(params=params, token=token, caches=caches, pos=pos), (2,)
    raise ValueError(shape.kind)
