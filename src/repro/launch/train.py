"""Training launcher: mesh + model + data + optimizer + checkpointing +
fault handling, end to end.

CPU (this container): reduced configs, tiny mesh — the same code path that
targets pods.  TPU pods: run under your cluster launcher with
``--mesh single|multi``; XLA latency-hiding scheduler flags for
compute/comm overlap are applied automatically for TPU backends.

Examples
--------
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ck --resume auto

Fault-tolerance drill (exits 42, restart resumes):
  ... --simulate-failure 7
"""
from __future__ import annotations

import argparse
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.checkpoint import CheckpointManager, save_train_state
from repro.configs import get_arch
from repro.data.pipeline import SyntheticTokens
from repro.distributed import sharding as SH
from repro.distributed.fault import (
    Heartbeat, StragglerMonitor, SimulatedFailure, RESTART_EXIT_CODE)
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import build
from repro.models.layers import _dtype

# XLA flags for compute/comm overlap on TPU (no-ops on CPU): enable the
# latency-hiding scheduler and async collectives so the per-layer DP
# all-reduces overlap the backward pass.
TPU_OVERLAP_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def build_mesh(kind: str):
    if kind in ("single", "multi"):
        return make_production_mesh(multi_pod=(kind == "multi"))
    n = jax.device_count()
    return make_test_mesh(dp=n, tp=1)


def make_step(bundle, ocfg, cfg, grad_compression: bool, mesh):
    compute_dtype = _dtype(cfg.dtype)

    if not grad_compression:
        def train_step(params, opt_state, batch):
            def loss_of(p):
                loss, aux = bundle.loss_fn(p, batch, remat=True)
                return loss
            loss, grads = jax.value_and_grad(loss_of)(params)
            new_params, new_state = optim.update(grads, opt_state, ocfg,
                                                 compute_dtype)
            return new_params, new_state, loss
        return jax.jit(train_step, donate_argnums=(0, 1))

    # int8-compressed DP gradient sync: per-shard grads + compressed psum
    # inside shard_map over the data axis, then the optimizer update.
    from jax.experimental.shard_map import shard_map
    from repro.optim.compress import compressed_psum_mean

    dp = SH.dp_axes(mesh)

    def train_step(params, opt_state, ef, batch):
        @partial(
            shard_map, mesh=mesh,
            in_specs=(P(), jax.tree.map(lambda _: P(), ef),
                      jax.tree.map(lambda _: P(dp), batch)),
            out_specs=(P(), P(), jax.tree.map(lambda _: P(), ef)),
            check_rep=False,
        )
        def grads_sync(p, ef_, local_batch):
            def loss_of(pp):
                loss, aux = bundle.loss_fn(pp, local_batch, remat=True)
                return loss
            loss, g = jax.value_and_grad(loss_of)(p)
            for ax in dp:
                loss = jax.lax.pmean(loss, ax)
            g, ef2 = compressed_psum_mean(g, ef_, dp[0])
            return loss, g, ef2

        loss, grads, ef2 = grads_sync(params, ef, batch)
        new_params, new_state = optim.update(grads, opt_state, ocfg,
                                             compute_dtype)
        return new_params, new_state, ef2, loss

    return jax.jit(train_step, donate_argnums=(0, 1, 2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="test", choices=["test", "single", "multi"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="raise a simulated node failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = build_mesh(args.mesh)
    bundle = build(cfg)
    ocfg = optim.AdamWConfig(total_steps=max(args.steps, 10))

    with mesh:
        params = bundle.init(jax.random.PRNGKey(args.seed))
        pshard = SH.param_shardings(params, mesh)
        params = jax.device_put(params, pshard)
        opt_state = optim.init(params, ocfg)
        # de-alias cached constant buffers (zeros/ones leaves can share a
        # device buffer, which breaks donation)
        params = jax.tree.map(lambda x: x.copy(), params)
        opt_state = jax.tree.map(lambda x: x.copy(), opt_state)

        data = SyntheticTokens(cfg.vocab, args.seq, args.batch, seed=args.seed)
        start_step = 0
        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if mgr and args.resume == "auto":
            latest = CheckpointManager(str(mgr.dir / "params")).latest_step()
            if latest is not None:
                params = CheckpointManager(str(mgr.dir / "params")).restore(
                    latest, params, pshard)
                opt_state = CheckpointManager(str(mgr.dir / "opt")).restore(
                    latest, opt_state)
                start_step = latest
                print(f"[resume] restored step {latest}")

        ef = None
        if args.grad_compression:
            from repro.optim.compress import init_error_feedback
            ef = init_error_feedback(params)
        step_fn = make_step(bundle, ocfg, cfg, args.grad_compression, mesh)

        hb = Heartbeat(f"/tmp/repro_heartbeat_{args.arch}.json")
        strag = StragglerMonitor()
        bspec = NamedSharding(mesh, SH.batch_spec(mesh, args.batch, 1))

        t_start = time.time()
        for step in range(start_step, args.steps):
            hostb = data.batch(step)
            batch = {k: jax.device_put(v, bspec) for k, v in hostb.items()}
            if cfg.n_prefix_tokens:
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_prefix_tokens, cfg.prefix_dim),
                    jnp.float32)
            if cfg.is_encdec:
                batch["src_embeds"] = jax.device_put(
                    np.random.default_rng(step).normal(
                        size=(args.batch, args.seq, cfg.d_model)
                    ).astype(np.float32) * 0.1)
            t0 = time.time()
            try:
                if args.simulate_failure is not None and step == args.simulate_failure:
                    raise SimulatedFailure(f"injected failure at step {step}")
                if args.grad_compression:
                    params, opt_state, ef, loss = step_fn(params, opt_state, ef, batch)
                else:
                    params, opt_state, loss = step_fn(params, opt_state, batch)
                loss = float(loss)
            except SimulatedFailure as e:
                print(f"[fault] {e}; flushing checkpoint and exiting "
                      f"{RESTART_EXIT_CODE} for restart")
                if mgr:
                    save_train_state(mgr, step, params, opt_state)
                sys.exit(RESTART_EXIT_CODE)
            dt = time.time() - t0
            hb.beat(step)
            if strag.observe(dt):
                print(f"[straggler] step {step} took {dt:.2f}s (>3x EWMA)")
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} ({dt:.2f}s)")
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {step}")
            if mgr and (step + 1) % args.ckpt_every == 0:
                save_train_state(mgr, step + 1, params, opt_state,
                                 blocking=False)
        if mgr:
            save_train_state(mgr, args.steps, params, opt_state)
        print(f"done: {args.steps - start_step} steps in "
              f"{time.time() - t_start:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
