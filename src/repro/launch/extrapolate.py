import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
os.environ["REPRO_SCAN_UNROLL"] = "1"  # exact per-layer counts

"""Exact roofline terms via affine layer-count extrapolation.

Fully unrolling a 61-layer MoE train step at 512-way SPMD takes ~45 min of
XLA time per cell — the full 40-cell sweep would not fit any budget.  But
every scan group in our models has an IDENTICAL body, so per-device flops /
HLO bytes / collective bytes are **affine in the per-group layer counts**:

    cost(L_1, ..., L_g) = a + Σ_i b_i · L_i

We lower each cell at g+1 small layer-count settings (1-2 layers per group
— seconds to compile even unrolled), solve the affine system exactly, and
evaluate at the real depths.  This is exact up to cross-layer fusion at the
group boundary (empirically <1%, validated against the fully-unrolled
internlm2/mamba2/seamless cells in EXPERIMENTS.md §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.extrapolate --all \
      --out experiments/roofline
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import numpy as np

from repro.configs import ARCHS, SHAPES, SHAPES_BY_NAME, cell_applicable
from repro.launch import dryrun as DR


def group_counts(cfg):
    """The per-group layer-count knobs for this arch, as (names, values)."""
    if cfg.is_encdec:
        return ["enc_layers", "n_layers"], [cfg.enc_layers, cfg.n_layers]
    if cfg.mixer == "mamba" and cfg.shared_attn_period:
        p = cfg.shared_attn_period
        return ["_periods", "_tail"], [cfg.n_layers // p, cfg.n_layers % p]
    if cfg.n_experts and cfg.first_k_dense:
        return ["first_k_dense", "_moe"], [cfg.first_k_dense,
                                           cfg.n_layers - cfg.first_k_dense]
    if cfg.local_global_period:
        p = cfg.local_global_period
        return ["_periods", "_tail"], [cfg.n_layers // p, cfg.n_layers % p]
    return ["n_layers"], [cfg.n_layers]


def with_counts(cfg, names, values):
    """Rebuild a config with the given per-group counts."""
    kw = {}
    vals = dict(zip(names, values))
    if cfg.is_encdec:
        kw["enc_layers"] = vals["enc_layers"]
        kw["n_layers"] = vals["n_layers"]
    elif "_periods" in vals and cfg.shared_attn_period:
        kw["n_layers"] = (vals["_periods"] * cfg.shared_attn_period
                          + vals["_tail"])
    elif "_periods" in vals:
        kw["n_layers"] = (vals["_periods"] * cfg.local_global_period
                          + vals["_tail"])
    elif "first_k_dense" in vals:
        kw["first_k_dense"] = vals["first_k_dense"]
        kw["n_layers"] = vals["first_k_dense"] + vals["_moe"]
    else:
        kw["n_layers"] = vals["n_layers"]
    return dataclasses.replace(cfg, **kw)


def probe_points(real):
    """Affine in g unknowns + constant -> g+1 probe settings.

    Probes sit at depths 2 and 6 per group: XLA fusion at depth 1 is
    slightly unrepresentative (boundary fusions dominate), so the slope is
    taken between mid-depths — validated against fully-unrolled cells to
    within ~4% on flops (EXPERIMENTS §Roofline, methodology note).
    """
    g = len(real)
    base = [2 if r > 0 else 0 for r in real]
    pts = [tuple(base)]
    for i in range(g):
        if real[i] > 0:
            p = list(base)
            p[i] = base[i] + 4
            pts.append(tuple(p))
    return pts


def measure(cfg, shape_name, multi_pod=False):
    """Lower+compile one (small) config; returns metric dict."""
    import jax
    from repro import optim
    from repro.launch import specs as SP
    from repro.launch.mesh import make_production_mesh

    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        step, kwargs, donate = SP.abstract_cell(cfg, shape, mesh,
                                                optim.AdamWConfig())
        lowered = jax.jit(step, donate_argnums=donate).lower(**kwargs)
        compiled = lowered.compile(
            compiler_options={"xla_backend_optimization_level": 0})
    coll = DR.collective_bytes(compiled.as_text())
    cost = DR._cost(compiled)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_by_kind": coll,
    }


def extrapolate_cell(arch: str, shape_name: str, verbose=True):
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": "16x16",
           "extrapolated": True, "unrolled": True, "mla_absorbed": False}
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec

    names, real = group_counts(cfg)
    pts = probe_points(real)
    t0 = time.perf_counter()
    ms = [measure(with_counts(cfg, names, p), shape_name) for p in pts]

    # solve the affine system  cost = a + sum b_i * L_i  exactly
    A = np.array([[1.0] + list(map(float, p)) for p in pts])
    rec_metrics = {}
    for key in ("flops", "bytes", "coll"):
        y = np.array([m[key] for m in ms])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        full = float(coef[0] + sum(c * r for c, r in zip(coef[1:], real)))
        rec_metrics[key] = max(0.0, full)
    # collective kinds: extrapolate each kind the same way
    kinds = sorted({k for m in ms for k in m["coll_by_kind"]})
    coll_kinds = {}
    for k in kinds:
        y = np.array([m["coll_by_kind"].get(k, 0.0) for m in ms])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        coll_kinds[k] = max(0.0, float(
            coef[0] + sum(c * r for c, r in zip(coef[1:], real))))

    rec.update({
        "status": "OK",
        "chips": 256,
        "probe_points": [list(p) for p in pts],
        "group_names": names,
        "group_counts": real,
        "compile_s": round(time.perf_counter() - t0, 2),
        "per_device_flops": rec_metrics["flops"],
        "per_device_bytes": rec_metrics["bytes"],
        "collective_bytes_per_device": coll_kinds,
        "collective_bytes_total": rec_metrics["coll"],
        "compute_term_s": rec_metrics["flops"] / DR.PEAK_FLOPS,
        "memory_term_s": rec_metrics["bytes"] / DR.HBM_BW,
        "collective_term_s": rec_metrics["coll"] / DR.LINK_BW,
        "memory_analysis": None,  # from the scanned full-depth pass
    })
    if verbose:
        print(f"[{arch} × {shape_name}] extrapolated "
              f"flops/dev={rec_metrics['flops']:.3e} "
              f"bytes/dev={rec_metrics['bytes']:.3e} "
              f"coll/dev={rec_metrics['coll']:.3e} "
              f"({rec['compile_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = ([(a, s.name) for a in ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    failures = 0
    for arch, shape in cells:
        fp = outdir / f"{arch}_{shape}_single_extrap.json"
        real = outdir / f"{arch}_{shape}_single_unrolled.json"
        if args.skip_existing and (fp.exists() or real.exists()):
            print(f"[{arch} × {shape}] exists, skipping")
            continue
        try:
            rec = extrapolate_cell(arch, shape)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": "16x16",
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-1500:]}
            failures += 1
            print(f"[{arch} × {shape}] FAIL {rec['error'][:150]}", flush=True)
        fp.write_text(json.dumps(rec, indent=2, default=str))
    print(f"done; {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
