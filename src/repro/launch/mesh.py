"""Mesh construction.  IMPORTANT: functions only — importing this module
never touches jax device state (jax locks the device count on first use).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e production mesh: 16x16 per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(dp: int = 1, tp: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((dp, tp), ("data", "model"))
