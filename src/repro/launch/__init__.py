"""Launch/roofline substrate from the seed repo (dryrun, specs, train).

seed_fixtures: quarantined seed substrate — the training-launch stack
(mesh planning, dry-run cost model, train loop) is exercised by its own
tests but never imported by the BLADYG product packages.  The
`dead-seed` audit (`python -m repro.analysis`) accepts this marker; do
not grow graph-side dependencies on anything in here.

Marker-only package ``__init__``: importing it must stay side-effect
free (no submodule imports), so the audit marker never drags the seed
stack into product import graphs.
"""
