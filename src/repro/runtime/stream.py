"""Streaming update ingestion: route batches to owner blocks, escalate
cross-block work to the coordinator.

BLADYG's dynamic side is a *stream* of edge updates arriving at the
coordinator.  This module is that ingestion path over the block runtime:

  1. a window of up to R updates is taken off the stream and validated
     at the host boundary (against the *current* graph — streams may be
     generators, so there is no up-front whole-stream pass);
  2. one batched Theorem-1 candidate search (on the frontier kernels' R
     axis, or on the worker mesh under `backend="ell_spmd"`) determines
     each update's candidate set;
  3. updates that are **block-local** — both endpoints in one block and
     the candidate set confined to it — and independent of everything
     earlier in the window are applied together, with ONE joint clamped
     recompute (each update's recompute only moves nodes of its own
     block: the paper's workerCompute-only fast path);
  4. everything else escalates to the coordinator path (exact sequential
     maintenance, original stream order): cross-block endpoints,
     candidate sets that spill over the block boundary, and conflicts
     with earlier in-window updates.

Escalation order is what keeps this exact: an update is only hoisted
into the block-local batch if its candidate set is disjoint from every
*earlier* window column — the same commutation argument as
`core.kcore_dynamic.maintain_batch` — so the final coreness is
bit-identical to processing the stream one update at a time.
"""
from __future__ import annotations

from itertools import islice
from typing import Dict, Iterable, Iterator, List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import kcore_dynamic as kd
from ..core.kcore_dynamic import SPMD_BACKEND


class StreamStats(NamedTuple):
    """Routing + superstep accounting for one `run_stream` pass."""

    updates: int                 # total updates ingested
    batches: int                 # windows taken off the stream
    block_local: int             # applied on the block-local batched path
    escalated_cross_block: int   # endpoints in two blocks -> coordinator
    escalated_spill: int         # candidates left the owner block
    escalated_conflict: int      # overlapped an earlier window column
    bfs_steps: int               # frontier supersteps (all paths)
    recompute_steps: int         # clamped min-H supersteps (all paths)
    per_block: Tuple[int, ...]   # block-local updates applied per block

    @property
    def escalated(self) -> int:
        return (self.escalated_cross_block + self.escalated_spill
                + self.escalated_conflict)


def _owner_blocks(g, ids) -> np.ndarray:
    """Owning block of global padded node ids — THE routing rule (block-
    contiguous relabeling makes it pure arithmetic); every ownership
    decision in this module goes through here."""
    return np.asarray(ids) // g.Cn


def owner_block(g, u: int) -> int:
    """Owning block of a global padded node id (host-side routing key)."""
    return int(_owner_blocks(g, u))


def route_updates(
    g, updates: Iterable[Tuple[int, int, int]]
) -> Tuple[Dict[int, List[Tuple[int, int, int]]], List[Tuple[int, int, int]]]:
    """Host-side router: split a batch into per-owner-block queues plus the
    cross-block remainder the coordinator must handle itself.

    An update is routed to block b iff both endpoints live in b (the M2W
    directive then targets a single worker); otherwise it stays with the
    coordinator.  Returns ({block: [updates]}, cross_block_updates).
    """
    per_block: Dict[int, List[Tuple[int, int, int]]] = {}
    cross: List[Tuple[int, int, int]] = []
    for u, v, op in updates:
        bu, bv = owner_block(g, u), owner_block(g, v)
        if bu == bv:
            per_block.setdefault(bu, []).append((u, v, op))
        else:
            cross.append((u, v, op))
    return per_block, cross


def _iter_windows(updates, R: int) -> Iterator[list]:
    it = iter(updates)
    while True:
        window = list(islice(it, R))
        if not window:
            return
        yield window


def run_stream(
    g,
    core,
    updates: Iterable[Tuple[int, int, int]],
    R: int = 8,
    backend: str = "jnp",
    W=None,
):
    """Ingest an update stream; returns (g', core', StreamStats).

    `updates` may be any iterable (including a generator) of (u, v, op)
    with op = +1 insert / -1 delete, ids global padded.  Exactness: the
    final coreness equals sequential per-update maintenance.  With
    `backend="ell_spmd"` every superstep runs on the worker mesh.

    NOTE: consumes `g` via jit buffer donation on the escalation path
    (like `maintain_batch`) — use the returned graph.
    """
    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    spmd = backend == SPMD_BACKEND
    core = jnp.asarray(core)
    tot = dict(bfs=0, rec=0, cand=0, batched=0, seq=0, batches=0)
    n_updates = 0
    n_local = 0
    esc_cross = esc_spill = esc_conflict = 0
    per_block = np.zeros(g.P, np.int64)

    for window in _iter_windows(updates, R):
        kd._validate_updates_host(g, window)
        tot["batches"] += 1
        n = len(window)
        n_updates += n
        us = np.zeros(R, np.int32)
        vs = np.zeros(R, np.int32)
        ops_ = np.zeros(R, np.int32)
        us[:n] = [u for u, _, _ in window]
        vs[:n] = [v for _, v, _ in window]
        ops_[:n] = [op for _, _, op in window]
        valid = np.zeros(R, bool)
        valid[:n] = True

        if spmd:
            cand, steps = kd._batch_candidates_spmd(
                kd._spmd_executor(g, W), g, core, us, vs, valid)
        else:
            cand, steps = kd._batch_candidates(
                g, core, jnp.asarray(us), jnp.asarray(vs),
                jnp.asarray(valid), backend=backend)
        tot["bfs"] += int(steps)
        cand_np = np.asarray(cand)

        # routing decisions, host-side (same rule as `route_updates`)
        block_of = _owner_blocks(g, np.arange(g.N))
        owner_u = _owner_blocks(g, us[:n])
        intra = owner_u == _owner_blocks(g, vs[:n])
        spill = np.array([
            bool((cand_np[:, r] & (block_of != owner_u[r])).any())
            for r in range(n)
        ])
        overlap = cand_np.T.astype(np.int64) @ cand_np.astype(np.int64)

        accepted: List[int] = []
        escalated: List[int] = []
        for r in range(n):
            conflicts = bool(overlap[r, :r].any())
            if intra[r] and not spill[r] and not conflicts:
                accepted.append(r)
                continue
            escalated.append(r)
            if not intra[r]:
                esc_cross += 1
            elif spill[r]:
                esc_spill += 1
            else:
                esc_conflict += 1

        if accepted:
            acc = np.asarray(accepted)
            ins_cols = acc[ops_[acc] > 0]
            del_cols = acc[ops_[acc] < 0]
            cand_ins = jnp.asarray(cand_np[:, ins_cols].any(axis=1))
            cand_del = jnp.asarray(cand_np[:, del_cols].any(axis=1))
            us_a = np.zeros(R, np.int32)
            vs_a = np.zeros(R, np.int32)
            ops_a = np.zeros(R, np.int32)
            us_a[:len(acc)] = us[acc]
            vs_a[:len(acc)] = vs[acc]
            ops_a[:len(acc)] = ops_[acc]
            if spmd:
                g, core, rec = kd._apply_and_recompute_spmd(
                    g, core, us_a, vs_a, ops_a, cand_ins, cand_del, W=W)
            else:
                g, core, rec = kd._apply_and_recompute(
                    g, core,
                    jnp.asarray(us_a), jnp.asarray(vs_a), jnp.asarray(ops_a),
                    cand_ins, cand_del, backend=backend)
            tot["rec"] += int(rec)
            n_local += len(accepted)
            np.add.at(per_block, owner_u[acc], 1)

        # coordinator path, original stream order within the window
        for r in escalated:
            g, core = kd._maintain_one(g, core, window[r], tot, backend, W=W)

    stats = StreamStats(
        updates=n_updates,
        batches=tot["batches"],
        block_local=n_local,
        escalated_cross_block=esc_cross,
        escalated_spill=esc_spill,
        escalated_conflict=esc_conflict,
        bfs_steps=tot["bfs"],
        recompute_steps=tot["rec"],
        per_block=tuple(int(x) for x in per_block),
    )
    return g, core, stats
