"""Streaming update ingestion: route batches to owner blocks, escalate
cross-block work to the coordinator.

BLADYG's dynamic side is a *stream* of edge updates arriving at the
coordinator.  This module is that ingestion path over the block runtime:

  1. a window of up to R updates is taken off the stream and validated
     at the host boundary (against the *current* graph — streams may be
     generators, so there is no up-front whole-stream pass);
  2. one batched Theorem-1 candidate search (on the frontier kernels' R
     axis, or on the worker mesh under `backend="ell_spmd"`) determines
     each update's candidate set;
  3. updates that are **block-local** — both endpoints in one block and
     the candidate set confined to it — and independent of everything
     earlier in the window are applied together, with ONE joint clamped
     recompute (each update's recompute only moves nodes of its own
     block: the paper's workerCompute-only fast path);
  4. everything else escalates to the coordinator path (exact sequential
     maintenance, original stream order): cross-block endpoints,
     candidate sets that spill over the block boundary, and conflicts
     with earlier in-window updates.

Escalation order is what keeps this exact: an update is only hoisted
into the block-local batch if its candidate set is disjoint from every
*earlier* window column — the same commutation argument as
`core.kcore_dynamic.maintain_batch` — so the final coreness is
bit-identical to processing the stream one update at a time.

The routing verdict itself is computed ON DEVICE (`_route_window`, one
jitted function per window): the candidate-overlap matrix, the spill
test, and the accept/escalate scan all run where the candidate matrix
already lives, and only compact (R,)-masks plus per-block counts cross
to the host — queue management (window slicing, escalation dispatch,
migration bookkeeping) is all that remains host-side.

Two runtime-maintenance loops close over the stream:

  * **Executor reuse** — under `backend="ell_spmd"` ONE `SpmdExecutor`
    threads through the whole stream; every applied edit maintains its
    halo plan incrementally (`SpmdExecutor.apply_updates`, dirty workers
    only).  `StreamStats.plan_updates`/`plan_rebuilds` count the two
    paths: a steady-state stream performs ZERO full plan rebuilds.
  * **Live rebalancing** (`rebalance_threshold`) — after each window the
    §4.2 threshold protocol runs: per-block load summaries
    (workerCompute, `partition_dynamic.block_loads`) and the W2W pair
    matrix (`graph.halo_pair_counts`) reach the coordinator, which —
    when max/mean load exceeds the threshold — picks boundary-vertex
    moves (`partition_dynamic.choose_node_moves`) and executes them with
    `graph.migrate_vertices`: a pure node-axis permutation under fixed
    (P, Cn, Cd), so nothing recompiles and coreness is bit-preserved.
    Later stream updates still name nodes by their *pre-stream* padded
    ids; the router composes the migration permutations and remaps each
    window on ingest.

The loop body lives in `StreamSession` — a resumable stepper (open ->
`apply_window` -> `result`) so other device work can interleave between
windows; `run_stream` wraps it and returns a `StreamResult`, the uniform
(g, core, stats, labels) record (legacy tuple unpacking is shimmed with
a DeprecationWarning).  The query-serving layer (`repro.service`) is the
primary session consumer: it alternates update windows with query
batches on the one long-lived executor.
"""
from __future__ import annotations

import warnings
from functools import partial
from itertools import islice
from typing import (Any, Dict, Iterable, Iterator, List, NamedTuple,
                    Optional, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from ..core import kcore_dynamic as kd
from ..core import partition_dynamic as pd
from ..core.algorithms import connected_components, merge_labels
from ..core.graph import (CapacityError, add_vertices_host, grow_blocks,
                          halo_pair_counts, migrate_vertices, relocate_rows)
from ..core.kcore_dynamic import SPMD_BACKEND
from .halo import _pow2_ceil


class StreamStats(NamedTuple):
    """Routing + superstep accounting for one `run_stream` pass."""

    updates: int                 # total updates ingested
    batches: int                 # windows taken off the stream
    block_local: int             # applied on the block-local batched path
    escalated_cross_block: int   # endpoints in two blocks -> coordinator
    escalated_spill: int         # candidates left the owner block
    escalated_conflict: int      # overlapped an earlier window column
    bfs_steps: int               # frontier supersteps (all paths)
    recompute_steps: int         # clamped min-H supersteps (all paths)
    per_block: Tuple[int, ...]   # block-local updates applied per block
    plan_updates: int = 0        # incremental halo-plan maintenances (spmd)
    plan_rebuilds: int = 0       # full plan rebuilds (spmd; 0 in steady state)
    migrations: int = 0          # §4.2 rebalance rounds executed
    migrated_vertices: int = 0   # vertices moved across blocks in total
    cc_merges: int = 0           # CC labels maintained by O(1) label merges
    cc_recomputes: int = 0       # CC label recomputations (delete/migration)
    grows: int = 0               # capacity escalations (Cn/Cd pad-and-rekey)

    @property
    def escalated(self) -> int:
        return (self.escalated_cross_block + self.escalated_spill
                + self.escalated_conflict)


class StreamResult(NamedTuple):
    """Uniform `run_stream` / `StreamSession.result` return value.

    `labels` is None unless CC maintenance was armed (`cc_labels=`).
    Tuple-unpacking a StreamResult still works — `__iter__` yields the
    legacy arity (3 fields, or 4 when labels were maintained) with a
    DeprecationWarning — but new code should read the named fields;
    indexing and `len()` see all 4 fields, NamedTuple-style.
    """

    g: Any                       # post-stream GraphBlocks
    core: jax.Array              # (N,) int32 maintained coreness
    stats: StreamStats
    labels: Optional[jax.Array] = None   # (N,) int32 CC labels or None

    def __iter__(self):
        warnings.warn(
            "tuple-unpacking run_stream's result is deprecated; read "
            ".g/.core/.stats/.labels on the returned StreamResult",
            DeprecationWarning, stacklevel=2)
        legacy = (self.g, self.core, self.stats)
        if self.labels is not None:
            legacy += (self.labels,)
        return iter(legacy)


def _owner_blocks(g, ids) -> np.ndarray:
    """Owning block of global padded node ids — THE routing rule (block-
    contiguous relabeling makes it pure arithmetic); every ownership
    decision in this module goes through here."""
    return np.asarray(ids) // g.Cn


def owner_block(g, u: int) -> int:
    """Owning block of a global padded node id (host-side routing key)."""
    return int(_owner_blocks(g, u))


def route_updates(
    g, updates: Iterable[Tuple[int, int, int]]
) -> Tuple[Dict[int, List[Tuple[int, int, int]]], List[Tuple[int, int, int]]]:
    """Host-side router: split a batch into per-owner-block queues plus the
    cross-block remainder the coordinator must handle itself.

    An update is routed to block b iff both endpoints live in b (the M2W
    directive then targets a single worker); otherwise it stays with the
    coordinator.  Returns ({block: [updates]}, cross_block_updates).
    """
    per_block: Dict[int, List[Tuple[int, int, int]]] = {}
    cross: List[Tuple[int, int, int]] = []
    for u, v, op in updates:
        bu, bv = owner_block(g, u), owner_block(g, v)
        if bu == bv:
            per_block.setdefault(bu, []).append((u, v, op))
        else:
            cross.append((u, v, op))
    return per_block, cross


class RouteMasks(NamedTuple):
    """Compact device-side routing verdict for one update window.

    accept/cross/spill/conflict partition the valid columns: each column
    lands in exactly ONE mask, by escalation precedence (cross-block wins
    over spill wins over conflict) — `spill`/`conflict` are escalation
    *reasons*, not the raw conditions (a cross-block column whose
    candidates also spill appears only in `cross`).
    """

    accept: jax.Array        # (R,) bool — block-local, no spill, no conflict
    cross: jax.Array         # (R,) bool — endpoints in two blocks
    spill: jax.Array         # (R,) bool — intra-block, candidates left the
                             #             owner block
    conflict: jax.Array      # (R,) bool — intra-block, no spill, overlapped
                             #             an earlier window column
    cand_ins: jax.Array      # (N,) bool — union candidates of accepted inserts
    cand_del: jax.Array      # (N,) bool — union candidates of accepted deletes
    per_block: jax.Array     # (P,) int32 — accepted updates per owner block


@partial(jax.jit, static_argnames=("Cn",))
def _route_window(cand, us, vs, ops_, valid, Cn: int) -> RouteMasks:
    """Device-side window routing: ONE fused kernel instead of the old host
    numpy pass (the O(N*R^2) `cand.T @ cand` overlap matmul, the spill
    matrix, and the accept/escalate scan).

    Escalation reasons replicate the host rule exactly: cross-block wins
    over spill wins over conflict, and a column conflicts iff its candidate
    set overlaps ANY earlier valid column (accepted or escalated) — the
    same commutation argument as `kcore_dynamic._independent_prefix`.
    Only the (R,)/(P,) compact outputs ever reach the host; the (N, R)
    candidate matrix stays on device.
    """
    N, R = cand.shape
    owner = us // Cn                                   # (R,) owning blocks
    intra = owner == (vs // Cn)
    block_of = jnp.arange(N, dtype=us.dtype) // Cn
    candv = cand & valid[None, :]
    spill = jnp.any(candv & (block_of[:, None] != owner[None, :]), axis=0)
    overlap = jnp.matmul(candv.T.astype(jnp.int32), candv.astype(jnp.int32))
    earlier = jnp.tril(jnp.ones((R, R), bool), k=-1)   # strictly lower
    conflict = jnp.any((overlap > 0) & earlier, axis=1)
    accept = valid & intra & ~spill & ~conflict
    cross = valid & ~intra
    esc_spill = valid & intra & spill
    esc_conflict = valid & intra & ~spill & conflict
    cand_ins = jnp.any(candv & (accept & (ops_ > 0))[None, :], axis=1)
    cand_del = jnp.any(candv & (accept & (ops_ < 0))[None, :], axis=1)
    per_block = jnp.zeros(N // Cn, jnp.int32).at[owner].add(
        accept.astype(jnp.int32))
    return RouteMasks(accept, cross, esc_spill, esc_conflict,
                      cand_ins, cand_del, per_block)


def _iter_windows(updates, R: int) -> Iterator[list]:
    it = iter(updates)
    while True:
        window = list(islice(it, R))
        if not window:
            return
        yield window


class StreamSession:
    """Resumable stream stepper: open -> `apply_window` -> `result`.

    Holds everything `run_stream` used to keep in loop locals — the
    current graph, maintained coreness (and optionally CC labels), the
    long-lived executor, the migration remap, and the routing/superstep
    counters — so a caller can interleave OTHER device work between
    windows: the query-serving loop (`repro.service`) applies one window,
    refreshes its analytics snapshot, answers a few query batches, and
    comes back, all on the ONE executor with zero steady-state
    recompiles.  `run_stream` is now a thin wrapper that opens a session
    and drains the whole iterable through it.

    Window contract: `apply_window` takes a list of at most `R` updates
    `(u, v, op)` with ids global padded *as of session open* (later
    migrations are remapped internally, exactly as `run_stream` always
    did); windows narrower than R are padded to the fixed width, so the
    compiled window kernels keep hitting.  Exactness guarantees are
    unchanged — the session IS `run_stream`'s loop body, extracted.

    NOTE: consumes the graph passed at open via jit buffer donation on
    the apply path (like `maintain_batch`); read `.g` back, and never
    hold references to a previous window's graph arrays.
    """

    def __init__(
        self,
        g,
        core,
        R: int = 8,
        backend: str = "jnp",
        W=None,
        executor=None,
        rebalance_threshold: Optional[float] = None,
        rebalance_max_moves: int = 8,
        cc_labels: Optional[jax.Array] = None,
        auto_grow: bool = False,
    ):
        if R < 1:
            raise ValueError(f"R must be >= 1, got {R}")
        spmd = backend == SPMD_BACKEND
        if executor is not None and not spmd:
            raise ValueError(
                f"executor= requires backend={SPMD_BACKEND!r} (got "
                f"{backend!r}); a non-mesh stream would leave the "
                "executor's halo plan stale."
            )
        self.R = int(R)
        self.backend = backend
        self._spmd = spmd
        self._W = W
        self.executor = None
        if spmd:
            self.executor = (executor if executor is not None
                             else kd._spmd_executor(g, W))
        self._ex_updates0 = self.executor.plan_updates if spmd else 0
        self._ex_rebuilds0 = self.executor.full_rebuilds if spmd else 0
        self.g = g
        self.core = jnp.asarray(core)
        self._track_labels = cc_labels is not None
        self.labels = (jnp.asarray(cc_labels) if self._track_labels
                       else None)
        self._rebalance_threshold = rebalance_threshold
        self._rebalance_max_moves = int(rebalance_max_moves)
        self._tot = dict(bfs=0, rec=0, cand=0, batched=0, seq=0, batches=0)
        # recompute supersteps of the block-local accepted path accumulate
        # ON DEVICE — apply_window never blocks on them; stats() pulls the
        # scalar once when asked
        self._rec_dev = jnp.int32(0)
        self._n_updates = 0
        self._n_local = 0
        self._esc_cross = self._esc_spill = self._esc_conflict = 0
        self._per_block = np.zeros(g.P, np.int64)
        self._migrations = self._migrated = 0
        self._remap: Optional[np.ndarray] = None  # open-time -> current ids
        self._cc_merges = self._cc_recomputes = 0
        #: capacity escalation — `apply_window`/`add_vertices` grow the
        #: blocks (pad-and-rekey) instead of raising CapacityError
        self._auto_grow = bool(auto_grow)
        self._grows = 0
        #: id space size at open: window ids below this are open-time
        #: padded ids; ids at/above are `add_vertices` handles resolved
        #: through `_virtual` (their CURRENT padded ids, kept composed
        #: across migrations and grows just like `_remap`)
        self._n_open = g.N
        self._virtual: List[int] = []
        # hub-split plan slot: always None on the plain session; the
        # serving layer reads getattr(session, "mirror") uniformly across
        # StreamSession and MirrorStream
        self.mirror = None

    @property
    def windows_applied(self) -> int:
        """Windows ingested so far (the serving layer's staleness clock)."""
        return self._tot["batches"]

    def apply_window(self, window: List[Tuple[int, int, int]]) -> None:
        """Ingest ONE window of at most R updates (see class docstring)."""
        if len(window) > self.R:
            raise ValueError(
                f"window of {len(window)} updates exceeds R={self.R}")
        if not window:
            return
        backend, W, tot = self.backend, self._W, self._tot
        window = [(self._cur(u), self._cur(v), op) for u, v, op in window]
        while True:
            try:
                kd._validate_updates_host(self.g, window)
                break
            except CapacityError:
                if not self._auto_grow:
                    raise
                # a row in this window is out of degree capacity: escalate
                # Cd to the next pow2 and re-key the window ids (the grow
                # relocates every row), then re-validate — one doubling
                # almost always suffices (a window adds at most R edges).
                rekey = self.grow(Cd=_pow2_ceil(self.g.Cd + 1))
                window = [(int(rekey[u]), int(rekey[v]), op)
                          for u, v, op in window]
        g, core, ex, spmd = self.g, self.core, self.executor, self._spmd
        tot["batches"] += 1
        R = self.R
        n = len(window)
        self._n_updates += n
        us = np.zeros(R, np.int32)
        vs = np.zeros(R, np.int32)
        ops_ = np.zeros(R, np.int32)
        us[:n] = [u for u, _, _ in window]
        vs[:n] = [v for _, v, _ in window]
        ops_[:n] = [op for _, _, op in window]
        valid = np.zeros(R, bool)
        valid[:n] = True

        if spmd:
            cand, steps = kd._batch_candidates_spmd(
                ex, g, core, us, vs, valid)
        else:
            cand, steps = kd._batch_candidates(
                g, core, jnp.asarray(us), jnp.asarray(vs),
                jnp.asarray(valid), backend=backend)

        # routing on device: the (N, R) candidate matrix never reaches the
        # host — ONE transfer per window pulls the compact (R,)/(P,)
        # verdict (bundled with the superstep counter).
        route = _route_window(
            jnp.asarray(cand), jnp.asarray(us), jnp.asarray(vs),
            jnp.asarray(ops_), jnp.asarray(valid), Cn=g.Cn)
        steps_h, accept, cross, spl, conf, nblk = jax.device_get(
            (steps, route.accept, route.cross, route.spill, route.conflict,
             route.per_block))
        tot["bfs"] += int(steps_h)
        self._esc_cross += int(cross.sum())
        self._esc_spill += int(spl.sum())
        self._esc_conflict += int(conf.sum())

        if accept.any():
            # accepted updates stay at their window position; op=0 turns the
            # non-accepted columns into no-ops for the fixed-width apply
            us_a = np.where(accept, us, 0).astype(np.int32)
            vs_a = np.where(accept, vs, 0).astype(np.int32)
            ops_a = np.where(accept, ops_, 0).astype(np.int32)
            if spmd:
                g, core, rec = kd._apply_and_recompute_spmd(
                    g, core, us_a, vs_a, ops_a, route.cand_ins,
                    route.cand_del, W=W, ex=ex)
            else:
                g, core, rec = kd._apply_and_recompute(
                    g, core,
                    jnp.asarray(us_a), jnp.asarray(vs_a), jnp.asarray(ops_a),
                    route.cand_ins, route.cand_del, backend=backend)
            self._rec_dev = self._rec_dev + rec  # async; no host sync here
            self._n_local += int(accept.sum())
            self._per_block += nblk.astype(np.int64)

        # coordinator path, original stream order within the window
        for r in np.flatnonzero(cross | spl | conf):
            g, core = kd._maintain_one(g, core, window[r], tot, backend,
                                       W=W, ex=ex)

        # §4.2 repartition-threshold protocol, live: workerCompute load
        # summaries (W2M) -> masterCompute threshold + move selection ->
        # an executed node migration (a permutation, nothing recompiles)
        migrated_now = False
        if self._rebalance_threshold is not None:
            if pd.block_balance(g) > self._rebalance_threshold:
                moves = pd.choose_node_moves(
                    g, max_moves=self._rebalance_max_moves,
                    pair_counts=halo_pair_counts(g))
                if moves:
                    g, perm, core = migrate_vertices(g, moves, core)
                    self._compose_perm(perm)
                    self._migrations += 1
                    self._migrated += len(moves)
                    migrated_now = True
                    if spmd:
                        ex.rebuild(g)

        # CC label maintenance: inserts only ever JOIN components, so an
        # insert-only window is an O(1)-superstep on-device label merge;
        # deletions (possible splits) and migrations (canonical ids are
        # padded ids, which a migration permutes) re-propagate once on
        # the post-window graph.
        if self._track_labels:
            ins_mask = valid & (ops_ > 0)
            if (valid & (ops_ < 0)).any() or migrated_now:
                self.labels = connected_components(g, backend=backend,
                                                   executor=ex)
                self._cc_recomputes += 1
            elif ins_mask.any():
                self.labels = merge_labels(
                    self.labels, jnp.asarray(us), jnp.asarray(vs),
                    jnp.asarray(ins_mask))
                self._cc_merges += int(ins_mask.sum())
        self.g, self.core = g, core

    # ---- elastic growth / recovery surface ------------------------------

    def _cur(self, u) -> int:
        """Resolve an open-time id (or `add_vertices` handle) to the
        CURRENT padded id, through the composed migration/grow remap."""
        u = int(u)
        if u >= self._n_open:
            i = u - self._n_open
            if i >= len(self._virtual):
                raise ValueError(
                    f"unknown vertex handle {u} (have "
                    f"{len(self._virtual)} post-open vertices)")
            return self._virtual[i]
        if self._remap is None:
            return u
        cur = int(self._remap[u])
        if cur < 0:
            raise ValueError(f"open-time id {u} no longer exists")
        return cur

    def _compose_perm(self, perm: np.ndarray) -> None:
        """Fold a node-axis permutation/rekey into the open-time id maps."""
        if self._remap is None:
            self._remap = np.asarray(perm, np.int64).copy()
        else:
            self._remap = np.where(
                self._remap >= 0, perm[np.maximum(self._remap, 0)], -1)
        self._virtual = [int(perm[x]) for x in self._virtual]

    def grow(self, Cn: Optional[int] = None,
             Cd: Optional[int] = None) -> np.ndarray:
        """Capacity escalation on the LIVE session: pad-and-rekey the
        blocks to (Cn, Cd) — see `core.graph.grow_blocks` — relocating
        the maintained coreness and CC labels along (label *values* are
        padded ids, so they ride the same monotone rekey and stay
        canonical), folding the rekey into the open-time id remap, and
        re-keying the executor's mesh/plan (`SpmdExecutor.grow`).  The
        compiled caches re-specialize exactly once per grow; steady
        state stays at zero recompiles.  Returns the rekey map.
        """
        g2, rekey = grow_blocks(self.g, Cn, Cd)
        core = relocate_rows(jax.device_get(self.core), rekey, g2.N, 0)
        self.core = jnp.asarray(core)
        if self.labels is not None:
            lab = relocate_rows(jax.device_get(self.labels), rekey, g2.N, -1)
            lab = np.where(lab >= 0, rekey[np.maximum(lab, 0)], -1)
            self.labels = jnp.asarray(lab.astype(np.int32))
        self._compose_perm(rekey)
        self.g = g2
        if self._spmd:
            self.executor.grow(g2)
        self._grows += 1
        return rekey

    def add_vertices(self, block: int, count: int = 1) -> List[int]:
        """Vertex arrival: activate `count` fresh degree-0 nodes in
        `block` (`core.graph.add_vertices_host`), growing Cn first when
        the block is full and auto-grow is armed.  Returns stable
        HANDLES — ids in the session's open-time id space, usable in
        later windows like any open-time id (they survive migrations and
        grows; allocation is deterministic, so a replayed log hands back
        the same handles)."""
        while True:
            try:
                g2, rows = add_vertices_host(self.g, block, count)
                break
            except CapacityError:
                if not self._auto_grow:
                    raise
                self.grow(Cn=_pow2_ceil(self.g.Cn + 1))
        self.g = g2
        if self._spmd:
            self.executor.refresh_fields(g2)
        if self._track_labels:
            # a fresh isolated vertex is its own component (canonical
            # label == own padded id); coreness 0 already holds
            r = jnp.asarray(rows)
            self.labels = self.labels.at[r].set(
                r.astype(self.labels.dtype))
        base = self._n_open + len(self._virtual)
        self._virtual.extend(int(x) for x in rows)
        return list(range(base, base + len(rows)))

    def migrate(self, moves) -> np.ndarray:
        """Execute an explicit vertex migration (caller-chosen moves —
        the worker-loss recovery path evacuates a dead worker's blocks
        through this).  Same machinery as the §4.2 rebalance: a pure
        node-axis permutation composed into the id remap, an executor
        plan rebuild, and one CC re-propagation when labels are tracked
        (canonical ids are padded ids, which the permutation renames).
        Returns the permutation."""
        g, perm, core = migrate_vertices(self.g, moves, self.core)
        self.g, self.core = g, core
        self._compose_perm(perm)
        self._migrations += 1
        self._migrated += len(moves)
        if self._spmd:
            self.executor.rebuild(g)
        if self._track_labels:
            self.labels = connected_components(
                g, backend=self.backend, executor=self.executor)
            self._cc_recomputes += 1
        return perm

    def state_dict(self):
        """Everything needed to resume this stream elsewhere: a flat
        dict of arrays (a pytree `checkpoint.CheckpointManager` can
        save) plus a JSON-able meta dict of statics and counters.  The
        snapshot is topology-independent — `from_state` may rebuild on a
        different worker mesh (see `checkpoint.elastic`).  Arrays are
        COPIES: the apply path donates the live graph buffers, so shared
        references would die with the next window."""
        g = self.g
        arrays = {
            "core": jnp.copy(self.core),
            "g.deg": jnp.copy(g.deg),
            "g.nbr": jnp.copy(g.nbr),
            "g.node_mask": jnp.copy(g.node_mask),
            "g.orig_id": jnp.copy(g.orig_id),
            "rec_dev": jnp.copy(self._rec_dev),
        }
        if self.labels is not None:
            arrays["labels"] = jnp.copy(self.labels)
        if self._remap is not None:
            arrays["remap"] = jnp.asarray(self._remap)
        spmd, ex = self._spmd, self.executor
        meta = {
            "kind": "stream_session",
            "P": g.P, "Cn": g.Cn, "Cd": g.Cd,
            "R": self.R, "backend": self.backend,
            "auto_grow": self._auto_grow,
            "track_labels": self._track_labels,
            "has_remap": self._remap is not None,
            "n_open": self._n_open,
            "virtual": [int(x) for x in self._virtual],
            "rebalance_threshold": self._rebalance_threshold,
            "rebalance_max_moves": self._rebalance_max_moves,
            "tot": {k: int(v) for k, v in self._tot.items()},
            "counters": {
                "n_updates": self._n_updates,
                "n_local": self._n_local,
                "esc_cross": self._esc_cross,
                "esc_spill": self._esc_spill,
                "esc_conflict": self._esc_conflict,
                "migrations": self._migrations,
                "migrated": self._migrated,
                "cc_merges": self._cc_merges,
                "cc_recomputes": self._cc_recomputes,
                "grows": self._grows,
                "plan_updates":
                    (ex.plan_updates - self._ex_updates0) if spmd else 0,
                "plan_rebuilds":
                    (ex.full_rebuilds - self._ex_rebuilds0) if spmd else 0,
                "per_block": [int(x) for x in self._per_block],
            },
        }
        return arrays, meta

    @classmethod
    def from_state(cls, arrays, meta, W=None, backend: Optional[str] = None,
                   executor=None) -> "StreamSession":
        """Rebuild a session from `state_dict` output.  `W`/`backend`/
        `executor` override the snapshot's mesh shape — the elastic
        remesh path: the arrays are full logical (N,)/(N, Cd) values, so
        any worker count with W | P can adopt them."""
        from ..core.graph import GraphBlocks
        g = GraphBlocks(
            nbr=jnp.asarray(arrays["g.nbr"], jnp.int32),
            deg=jnp.asarray(arrays["g.deg"], jnp.int32),
            node_mask=jnp.asarray(arrays["g.node_mask"]),
            orig_id=jnp.asarray(arrays["g.orig_id"], jnp.int32),
            P=int(meta["P"]), Cn=int(meta["Cn"]), Cd=int(meta["Cd"]))
        sess = cls(
            g, arrays["core"], R=int(meta["R"]),
            backend=meta["backend"] if backend is None else backend,
            W=W, executor=executor,
            rebalance_threshold=meta["rebalance_threshold"],
            rebalance_max_moves=int(meta["rebalance_max_moves"]),
            cc_labels=arrays.get("labels") if meta["track_labels"] else None,
            auto_grow=bool(meta["auto_grow"]))
        sess._rec_dev = jnp.asarray(arrays["rec_dev"], jnp.int32)
        sess._remap = (np.asarray(jax.device_get(arrays["remap"]), np.int64)
                       if meta["has_remap"] else None)
        sess._n_open = int(meta["n_open"])
        sess._virtual = [int(x) for x in meta["virtual"]]
        sess._tot = {k: int(v) for k, v in meta["tot"].items()}
        c = meta["counters"]
        sess._n_updates = int(c["n_updates"])
        sess._n_local = int(c["n_local"])
        sess._esc_cross = int(c["esc_cross"])
        sess._esc_spill = int(c["esc_spill"])
        sess._esc_conflict = int(c["esc_conflict"])
        sess._migrations = int(c["migrations"])
        sess._migrated = int(c["migrated"])
        sess._cc_merges = int(c["cc_merges"])
        sess._cc_recomputes = int(c["cc_recomputes"])
        sess._grows = int(c["grows"])
        sess._per_block = np.asarray(c["per_block"], np.int64)
        if sess._spmd:
            # re-base the executor counter offsets so stats() keeps
            # counting from the snapshot's accumulated totals
            sess._ex_updates0 = (sess.executor.plan_updates
                                 - int(c["plan_updates"]))
            sess._ex_rebuilds0 = (sess.executor.full_rebuilds
                                  - int(c["plan_rebuilds"]))
        return sess

    def stats(self) -> StreamStats:
        """Routing/superstep accounting over every window applied so far."""
        spmd, ex = self._spmd, self.executor
        return StreamStats(
            updates=self._n_updates,
            batches=self._tot["batches"],
            block_local=self._n_local,
            escalated_cross_block=self._esc_cross,
            escalated_spill=self._esc_spill,
            escalated_conflict=self._esc_conflict,
            bfs_steps=self._tot["bfs"],
            recompute_steps=(self._tot["rec"]
                             + int(jax.device_get(self._rec_dev))),
            per_block=tuple(int(x) for x in self._per_block),
            plan_updates=(ex.plan_updates - self._ex_updates0) if spmd else 0,
            plan_rebuilds=(ex.full_rebuilds - self._ex_rebuilds0)
            if spmd else 0,
            migrations=self._migrations,
            migrated_vertices=self._migrated,
            cc_merges=self._cc_merges,
            cc_recomputes=self._cc_recomputes,
            grows=self._grows,
        )

    def result(self) -> StreamResult:
        """Close out: the session's state as a `StreamResult` snapshot.

        The session stays usable afterwards (`result` is cheap and
        side-effect free); `close` is the self-documenting alias for the
        final call.
        """
        return StreamResult(g=self.g, core=self.core, stats=self.stats(),
                            labels=self.labels)

    close = result


def run_stream(
    g,
    core,
    updates: Iterable[Tuple[int, int, int]],
    R: int = 8,
    backend: str = "jnp",
    W=None,
    executor=None,
    rebalance_threshold: Optional[float] = None,
    rebalance_max_moves: int = 8,
    cc_labels: Optional[jax.Array] = None,
    auto_grow: bool = False,
) -> StreamResult:
    """Ingest an update stream; returns a `StreamResult` (g, core, stats,
    labels).

    Thin wrapper: opens a `StreamSession` and drains `updates` through it
    window by window — use the session directly to interleave other work
    (e.g. query serving) between windows.

    g: GraphBlocks (P blocks of Cn rows, nbr (N, Cd)); core: (N,) int32
    coreness of `g` (as `core.kcore.coreness` returns it).  `updates`
    may be any iterable (including a generator) of (u, v, op) with
    op = +1 insert / -1 delete, ids global padded *as of the call*
    (migrations remap later windows internally).  R is the window width
    (the stacked-frontier axis of the batched candidate search).
    Exactness: the final coreness equals sequential per-update
    maintenance — under live rebalancing up to the node-axis
    permutation, i.e. bit-identical when read through `orig_id`.  With
    `backend="ell_spmd"` every superstep runs on the worker mesh through
    ONE long-lived executor (pass `executor` to thread an existing
    `SpmdExecutor` across calls) whose halo plan is maintained
    incrementally per window.

    `rebalance_threshold` (e.g. 1.2) arms the §4.2 repartition-threshold
    protocol after every window: blocks report load summaries, the
    coordinator migrates boundary vertices when max/mean load exceeds
    the threshold.  `None` disables it.

    `cc_labels` (optional) arms connected-component maintenance: pass the
    canonical labels of the PRE-stream graph (as
    `core.algorithms.connected_components` returns them: (N,) int32, min
    member padded id per component, -1 on padding rows) and the stream
    keeps them exact window by window in `result.labels`.  Insert-only
    windows are maintained with O(1)-superstep label merges on device
    (inserts can only *join* components — `algorithms.merge_labels`); a
    window containing a deletion or followed by a §4.2 migration
    triggers one fresh propagation on the post-window graph (splits
    cannot be merged; node permutations relabel the canonical ids).
    `StreamStats.cc_merges` / `cc_recomputes` count the two paths, and
    the final labels are bit-identical to `connected_components(g')`.

    Returns `StreamResult(g, core, stats, labels)`; `labels` is None
    when `cc_labels` was not passed.  Legacy tuple unpacking (3 fields,
    or 4 with `cc_labels`) still works behind a DeprecationWarning.

    NOTE: consumes `g` via jit buffer donation on the escalation path
    (like `maintain_batch`) — use the returned graph.
    """
    session = StreamSession(
        g, core, R=R, backend=backend, W=W, executor=executor,
        rebalance_threshold=rebalance_threshold,
        rebalance_max_moves=rebalance_max_moves, cc_labels=cc_labels,
        auto_grow=auto_grow)
    for window in _iter_windows(updates, R):
        session.apply_window(window)
    return session.result()


class MirrorStream:
    """Stream ingestion over a hub-split graph (vertex-cut maintenance).

    `StreamSession`'s sibling for graphs that went through
    `core.hub_split.split_hubs`: holds the split `GraphBlocks` plus its
    `MirrorPlan` and ingests `(u, v, op)` edit windows where ids are
    PRIMARY row ids of the split graph.  Each window goes through
    `hub_split.apply_mirrored_edits` at the host boundary:

      * inserts land in the endpoint's first serving row with spare
        slice capacity — and when a vertex crosses the split threshold,
        a fresh replica row is allocated from the block's padding pool
        and the edge lands there (the ON-LINE split: no whole-graph
        re-split, no rewiring of existing slots);
      * deletes locate the unique serving-row pair that carries the
        edge (MIRRORED delete) and splice it out of both sorted slices.

    After each window the maintained analytics refresh with
    mirror-aware runs — `kcore.coreness(..., mirror=plan)` and
    optionally `connected_components(..., mirror=plan)` — which is
    exact by the split==unsplit parity guarantee.  (The Theorem-1
    clamped-recompute machinery reasons in the unsplit id space; a
    candidate-bounded mirrored maintenance pass is future work, so this
    session recomputes.  The rebuilt plan also carries a fresh `uid`,
    so the mirrored SPMD step recompiles per edit window — stick to
    single-device backends for fine-grained mirrored streams.)

    Duck-types the slice of `StreamSession` the serving layer consumes:
    `.g`, `.core`, `.labels`, `.backend`, `.executor` (always None —
    plan maintenance under `SpmdExecutor` is future work),
    `.windows_applied`, `.mirror`, and `result()`.
    """

    def __init__(self, g, plan, backend: str = "jnp",
                 cc_labels: bool = False, auto_grow: bool = False):
        from ..core.hub_split import apply_mirrored_edits  # noqa: F401
        from ..core.kcore import coreness

        self.g = g
        self.mirror = plan
        self.backend = backend
        self.executor = None
        self._windows = 0
        self._n_updates = 0
        self.core = coreness(g, backend=backend, mirror=plan)
        self._track_labels = bool(cc_labels)
        self.labels = (connected_components(g, backend=backend, mirror=plan)
                       if self._track_labels else None)
        #: grow Cn (pad-and-rekey, plan relocated) when the replica pool
        #: runs dry mid-window, instead of raising CapacityError
        self._auto_grow = bool(auto_grow)
        self._grows = 0
        #: open-time row ids -> current (grows rekey every row); window
        #: ids stay open-time primary-row ids, like StreamSession's
        self._remap: Optional[np.ndarray] = None

    @property
    def windows_applied(self) -> int:
        return self._windows

    def grow(self, Cn: Optional[int] = None,
             Cd: Optional[int] = None) -> np.ndarray:
        """Capacity escalation under the vertex cut: pad-and-rekey the
        split graph (`core.graph.grow_blocks`) and relocate the
        `MirrorPlan` along (`core.hub_split.grow_plan` — fresh uid, so
        the mirrored compiled step re-keys once).  Analytics recompute
        mirror-aware, which is exact by split==unsplit parity.  Returns
        the rekey map."""
        from ..core.hub_split import grow_plan
        from ..core.kcore import coreness

        g2, rekey = grow_blocks(self.g, Cn, Cd)
        self.mirror = grow_plan(self.mirror, rekey, g2)
        self.g = g2
        self._remap = (np.asarray(rekey, np.int64).copy()
                       if self._remap is None
                       else np.where(self._remap >= 0,
                                     rekey[np.maximum(self._remap, 0)], -1))
        self._grows += 1
        self.core = coreness(g2, backend=self.backend, mirror=self.mirror)
        if self._track_labels:
            self.labels = connected_components(
                g2, backend=self.backend, mirror=self.mirror)
        return rekey

    def apply_window(self, window: List[Tuple[int, int, int]]) -> None:
        """Apply one edit window (open-time primary-row ids) and refresh
        analytics.  With auto-grow armed, a window that exhausts the
        replica pool grows Cn IN FLIGHT: `apply_mirrored_edits` mutates
        copies, so the failed attempt leaves no partial state — the
        whole window re-applies on the grown graph."""
        from ..core.hub_split import apply_mirrored_edits
        from ..core.kcore import coreness

        if not window:
            return
        if self._remap is not None:
            window = [(int(self._remap[u]), int(self._remap[v]), op)
                      for u, v, op in window]
        while True:
            try:
                g2, plan2 = apply_mirrored_edits(self.g, self.mirror, window)
                break
            except CapacityError:
                if not self._auto_grow:
                    raise
                rekey = self.grow(Cn=_pow2_ceil(self.g.Cn + 1))
                window = [(int(rekey[u]), int(rekey[v]), op)
                          for u, v, op in window]
        self.g, self.mirror = g2, plan2
        self._windows += 1
        self._n_updates += len(window)
        self.core = coreness(self.g, backend=self.backend,
                             mirror=self.mirror)
        if self._track_labels:
            self.labels = connected_components(
                self.g, backend=self.backend, mirror=self.mirror)

    def state_dict(self):
        """Snapshot arrays + meta, `StreamSession.state_dict`-shaped
        (graph and plan leaves in the flat dict, statics in meta)."""
        g, p = self.g, self.mirror
        arrays = {
            "core": jnp.copy(self.core),
            "g.deg": jnp.copy(g.deg),
            "g.nbr": jnp.copy(g.nbr),
            "g.node_mask": jnp.copy(g.node_mask),
            "g.orig_id": jnp.copy(g.orig_id),
            "plan.grp_gid": jnp.copy(p.grp_gid),
            "plan.grp_rows": jnp.copy(p.grp_rows),
            "plan.ldeg": jnp.copy(p.ldeg),
            "plan.primary_mask": jnp.copy(p.primary_mask),
            "plan.primary_row": jnp.copy(p.primary_row),
            "plan.row_gid": jnp.copy(p.row_gid),
        }
        if self.labels is not None:
            arrays["labels"] = jnp.copy(self.labels)
        if self._remap is not None:
            arrays["remap"] = jnp.asarray(self._remap)
        meta = {
            "kind": "mirror_stream",
            "P": g.P, "Cn": g.Cn, "Cd": g.Cd,
            "backend": self.backend,
            "auto_grow": self._auto_grow,
            "track_labels": self._track_labels,
            "has_remap": self._remap is not None,
            "Gmax": p.Gmax, "Km": p.Km, "threshold": p.threshold,
            "n_logical": p.n_logical,
            "windows": self._windows,
            "n_updates": self._n_updates,
            "grows": self._grows,
        }
        return arrays, meta

    @classmethod
    def from_state(cls, arrays, meta,
                   backend: Optional[str] = None) -> "MirrorStream":
        """Rebuild a mirrored session from `state_dict` output.  The
        restored plan carries a fresh uid (plan identity is per-process),
        so the first mirrored step after restore compiles once."""
        from ..core.graph import GraphBlocks
        from ..core.hub_split import MirrorPlan, _next_uid
        g = GraphBlocks(
            nbr=jnp.asarray(arrays["g.nbr"], jnp.int32),
            deg=jnp.asarray(arrays["g.deg"], jnp.int32),
            node_mask=jnp.asarray(arrays["g.node_mask"]),
            orig_id=jnp.asarray(arrays["g.orig_id"], jnp.int32),
            P=int(meta["P"]), Cn=int(meta["Cn"]), Cd=int(meta["Cd"]))
        plan = MirrorPlan(
            primary_row=jnp.asarray(arrays["plan.primary_row"], jnp.int32),
            ldeg=jnp.asarray(arrays["plan.ldeg"], jnp.int32),
            primary_mask=jnp.asarray(arrays["plan.primary_mask"]),
            grp_rows=jnp.asarray(arrays["plan.grp_rows"], jnp.int32),
            grp_gid=jnp.asarray(arrays["plan.grp_gid"], jnp.int32),
            row_gid=jnp.asarray(arrays["plan.row_gid"], jnp.int32),
            Gmax=int(meta["Gmax"]), Km=int(meta["Km"]),
            threshold=int(meta["threshold"]),
            n_logical=int(meta["n_logical"]), uid=_next_uid())
        sess = cls(g, plan,
                   backend=meta["backend"] if backend is None else backend,
                   cc_labels=bool(meta["track_labels"]),
                   auto_grow=bool(meta["auto_grow"]))
        # restore the maintained analytics verbatim (the ctor recomputed
        # them — bit-identical by the parity contract, but the snapshot
        # is the source of truth)
        sess.core = jnp.asarray(arrays["core"], jnp.int32)
        if meta["track_labels"]:
            sess.labels = jnp.asarray(arrays["labels"], jnp.int32)
        sess._remap = (np.asarray(jax.device_get(arrays["remap"]), np.int64)
                       if meta["has_remap"] else None)
        sess._windows = int(meta["windows"])
        sess._n_updates = int(meta["n_updates"])
        sess._grows = int(meta["grows"])
        return sess

    def result(self) -> StreamResult:
        """Current state as a `StreamResult` (routing/superstep stats are
        not metered on the mirrored path; those counters report zeros)."""
        zeros = StreamStats(
            updates=self._n_updates, batches=self._windows, block_local=0,
            escalated_cross_block=0, escalated_spill=0,
            escalated_conflict=0, bfs_steps=0, recompute_steps=0,
            per_block=tuple(0 for _ in range(self.g.P)),
            grows=self._grows)
        return StreamResult(g=self.g, core=self.core, stats=zeros,
                            labels=self.labels)

    close = result
