"""Halo plan: the executed form of BLADYG's W2W exchange.

`GraphBlocks.nbr` stores *global* padded neighbor ids.  Under the worker
mesh each device only holds its own `S = B*Cn` node rows, so every valid
neighbor slot is either served locally (the neighbor lives on the same
worker) or from the *halo* — values fetched from the owning worker each
superstep.  This module precomputes, host-side from the concrete
adjacency, everything that exchange needs:

  * per worker pair (r needs-from s): the sorted unique remote node ids,
    deduplicated — a node read by many local neighbor slots crosses the
    wire once per superstep, exactly the paper's one-message-per-boundary
    -vertex W2W semantics;
  * `send_idx[s, r, k]` — local row on sender s of the k-th value it
    serves to receiver r (the all-to-all send-buffer gather);
  * `recv_pos[r, s, k]` — where receiver r scatters that value inside its
    halo buffer (size H, padded entries land on a dump slot);
  * `nbr_local` — the adjacency remapped to each worker's local frame:
    own neighbors index the local shard `[0, S)`, remote neighbors index
    `S + halo position`, PAD slots index a sentinel that always reads the
    ignore value.

Message accounting lives here too, at two granularities:

  * `slot_counts()` — (intra, inter) valid neighbor slots at *block*
    granularity.  Blocks are the paper's messaging unit (one worker per
    block); the device fold is an execution detail, so these are the
    numbers the engine's metering (`graph.halo_slot_counts`) must match.
  * `device_elems` / `pair_elems` — unique values actually moved through
    the all-to-all per superstep (worker granularity, deduplicated), and
    the (W, W) per-pair breakdown.

Shapes are static (`K` = max pair payload, `H` = max halo size), so the
plan drops straight into `shard_map`/`jit`.  The plan is a pure function
of `nbr` **contents** — rebuild it after structural updates.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

import jax

from .mesh import WorkerMesh, make_worker_mesh


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    """Precomputed W2W exchange for one (graph, worker mesh) pair."""

    wm: WorkerMesh
    K: int                 # max values any (sender, receiver) pair moves
    H: int                 # max halo-buffer entries on any worker
    send_idx: np.ndarray   # (W, W, K) int32 — [sender, receiver, k] local row
    recv_pos: np.ndarray   # (W, W, K) int32 — [receiver, sender, k] halo pos
    halo_len: np.ndarray   # (W,) int64 — real halo entries per worker
    nbr_local: np.ndarray  # (N, Cd) int32 — local-frame adjacency
    pair_elems: np.ndarray  # (W, W) int64 — unique values moved s -> r
    slot_intra: int        # valid slots inside their own *block*
    slot_inter: int        # valid slots crossing a *block* boundary

    def slot_counts(self) -> Tuple[int, int]:
        """(intra, inter) at block granularity == `graph.halo_slot_counts`."""
        return self.slot_intra, self.slot_inter

    @property
    def device_elems(self) -> int:
        """Unique values crossing a *device* boundary per superstep."""
        off = ~np.eye(self.wm.W, dtype=bool)
        return int(self.pair_elems[off].sum())

    @property
    def padded_elems(self) -> int:
        """Physical all-to-all payload per superstep (static padding)."""
        return self.wm.W * self.wm.W * self.K

    #: index (into the concat [local values | halo buffer]) that always
    #: holds the ignore value — PAD neighbor slots point here.
    @property
    def pad_slot(self) -> int:
        return self.wm.S + self.H + 1


def build_halo_plan(g, wm: WorkerMesh = None, W: int = None) -> HaloPlan:
    """Derive the halo plan from a *concrete* `GraphBlocks.nbr`.

    Raises if called under a trace: the plan is host-side preprocessing
    and cannot be derived from abstract values — build it outside `jit`
    and close over it (the `ell_spmd` entry points do exactly that).
    """
    if isinstance(g.nbr, jax.core.Tracer):
        raise TypeError(
            "build_halo_plan needs concrete neighbor arrays; it cannot run "
            "under jit/vmap tracing. Build the plan (or SpmdExecutor) at "
            "the host boundary and reuse it across supersteps."
        )
    if wm is None:
        wm = make_worker_mesh(g, W=W)
    nbr = np.asarray(g.nbr)
    N, Cd = nbr.shape
    S, Wn = wm.S, wm.W
    assert N == wm.N, (N, wm.N)

    valid = nbr >= 0
    own_block = np.arange(N) // g.Cn
    inter_blk = valid & (np.where(valid, nbr // g.Cn, -1) != own_block[:, None])
    slot_inter = int(inter_blk.sum())
    slot_intra = int(valid.sum()) - slot_inter

    # Per-receiver unique remote ids.  Sorting by global id groups by owner
    # automatically (owner = id // S is monotone in id), so "position in the
    # sorted unique array" doubles as the halo-buffer layout.
    uniq = []
    for r in range(Wn):
        nb = nbr[r * S:(r + 1) * S]
        v = nb >= 0
        remote = nb[v & (np.where(v, nb // S, -1) != r)]
        uniq.append(np.unique(remote))

    halo_len = np.array([len(u) for u in uniq], np.int64)
    H = int(max(1, halo_len.max() if Wn else 1))
    pair_elems = np.zeros((Wn, Wn), np.int64)
    for r in range(Wn):
        owners = uniq[r] // S
        cnt = np.bincount(owners, minlength=Wn) if len(owners) else \
            np.zeros(Wn, np.int64)
        pair_elems[:, r] = cnt  # column r: what each sender moves to r
    K = int(max(1, pair_elems.max()))

    send_idx = np.zeros((Wn, Wn, K), np.int32)
    recv_pos = np.full((Wn, Wn, K), H, np.int32)  # default: dump slot
    for r in range(Wn):
        for s in range(Wn):
            ids = uniq[r][uniq[r] // S == s]
            if not len(ids):
                continue
            pos = np.searchsorted(uniq[r], ids).astype(np.int32)
            send_idx[s, r, :len(ids)] = (ids - s * S).astype(np.int32)
            recv_pos[r, s, :len(ids)] = pos

    # local-frame adjacency: [0, S) own rows, [S, S+H) halo, S+H+1 PAD
    nbr_local = np.full((N, Cd), S + H + 1, np.int32)
    for r in range(Wn):
        rows = slice(r * S, (r + 1) * S)
        nb = nbr[rows]
        v = nb >= 0
        ownm = v & (np.where(v, nb // S, -1) == r)
        rem = v & ~ownm
        out = nbr_local[rows]
        out[ownm] = (nb[ownm] - r * S).astype(np.int32)
        out[rem] = (S + np.searchsorted(uniq[r], nb[rem])).astype(np.int32)
        nbr_local[rows] = out

    return HaloPlan(
        wm=wm, K=K, H=H, send_idx=send_idx, recv_pos=recv_pos,
        halo_len=halo_len, nbr_local=nbr_local, pair_elems=pair_elems,
        slot_intra=slot_intra, slot_inter=slot_inter,
    )
