"""Halo plan: the executed form of BLADYG's W2W exchange.

`GraphBlocks.nbr` stores *global* padded neighbor ids.  Under the worker
mesh each device only holds its own `S = B*Cn` node rows, so every valid
neighbor slot is either served locally (the neighbor lives on the same
worker) or from the *halo* — values fetched from the owning worker each
superstep.  This module precomputes, host-side from the concrete
adjacency, everything that exchange needs:

  * per worker pair (r needs-from s): the sorted unique remote node ids,
    deduplicated — a node read by many local neighbor slots crosses the
    wire once per superstep, exactly the paper's one-message-per-boundary
    -vertex W2W semantics;
  * `send_idx[s, r, k]` — local row on sender s of the k-th value it
    serves to receiver r (the all-to-all send-buffer gather);
  * `recv_pos[r, s, k]` — where receiver r scatters that value inside its
    halo buffer (size H, padded entries land on a dump slot);
  * `halo_ids[r]` — the halo-buffer layout itself: the sorted unique
    remote ids worker r reads, padded with -1 (position k in this row IS
    halo position k);
  * `nbr_local` — the adjacency remapped to each worker's local frame:
    own neighbors index the local shard `[0, S)`, remote neighbors index
    `S + halo position`, PAD slots index a sentinel that always reads the
    ignore value.

Message accounting lives here too, at two granularities:

  * `slot_counts()` — (intra, inter) valid neighbor slots at *block*
    granularity.  Blocks are the paper's messaging unit (one worker per
    block); the device fold is an execution detail, so these are the
    numbers the engine's metering (`graph.halo_slot_counts`) must match.
  * `device_elems` / `pair_elems` — unique values actually moved through
    the all-to-all per superstep (worker granularity, deduplicated), and
    the (W, W) per-pair breakdown.

Shapes are static (`K` = pair-payload capacity, `H` = halo capacity),
so the plan drops straight into `shard_map`/`jit`.  Both capacities are
rounded up to powers of two (with `H_min`/`K_min` floors): the runtime's
compiled step functions are cached per (mesh, H), so the slack absorbs
small halo growth under streaming updates without recompiling, and when
growth does overflow a capacity the doubling lands incremental and
from-scratch plans on the same value.

The plan is a pure function of `nbr` **contents**.  After structural
updates either rebuild it (`build_halo_plan`) or — the streaming hot
path — maintain it incrementally with `HaloPlan.apply_updates`: an edge
touches at most two blocks, so only the workers owning its endpoints
need their halo tables re-derived.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

import jax

from .mesh import WorkerMesh, make_worker_mesh


def _pow2_ceil(x: int) -> int:
    """Smallest power of two >= max(1, x) — the capacity slack policy."""
    x = max(1, int(x))
    return 1 << (x - 1).bit_length()


def _check_concrete(nbr) -> None:
    if isinstance(nbr, jax.core.Tracer):
        raise TypeError(
            "halo plans need concrete neighbor arrays; they cannot be "
            "derived under jit/vmap tracing. Build/update the plan (or "
            "SpmdExecutor) at the host boundary and reuse it across "
            "supersteps."
        )


def _worker_uniq(nbr: np.ndarray, r: int, S: int) -> np.ndarray:
    """Sorted unique remote ids referenced by worker r's rows."""
    nb = nbr[r * S:(r + 1) * S]
    v = nb >= 0
    remote = nb[v & (np.where(v, nb // S, -1) != r)]
    return np.unique(remote)


def _fill_receiver(
    send_idx: np.ndarray, recv_pos: np.ndarray, uniq_r: np.ndarray,
    r: int, S: int, W: int, H: int,
) -> None:
    """(Re)derive the send/recv tables of receiver column r from uniq_r.

    Sorting by global id groups by owner automatically (owner = id // S
    is monotone in id), so "position in the sorted unique array" doubles
    as the halo-buffer layout.
    """
    send_idx[:, r, :] = 0
    recv_pos[r, :, :] = H  # default: dump slot
    for s in range(W):
        ids = uniq_r[uniq_r // S == s]
        if not len(ids):
            continue
        pos = np.searchsorted(uniq_r, ids).astype(np.int32)
        send_idx[s, r, :len(ids)] = (ids - s * S).astype(np.int32)
        recv_pos[r, s, :len(ids)] = pos


def _local_rows(
    nbr_rows: np.ndarray, uniq_r: np.ndarray, r: int, S: int, H: int
) -> np.ndarray:
    """Remap global-id adjacency rows of worker r to its local frame:
    [0, S) own rows, [S, S+H) halo positions, S+H+1 the PAD sentinel."""
    out = np.full(nbr_rows.shape, S + H + 1, np.int32)
    v = nbr_rows >= 0
    ownm = v & (np.where(v, nbr_rows // S, -1) == r)
    rem = v & ~ownm
    out[ownm] = (nbr_rows[ownm] - r * S).astype(np.int32)
    out[rem] = (S + np.searchsorted(uniq_r, nbr_rows[rem])).astype(np.int32)
    return out


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    """Precomputed W2W exchange for one (graph, worker mesh) pair."""

    wm: WorkerMesh
    K: int                 # pair-payload capacity (pow2-padded max)
    H: int                 # halo-buffer capacity (pow2-padded max)
    send_idx: np.ndarray   # (W, W, K) int32 — [sender, receiver, k] local row
    recv_pos: np.ndarray   # (W, W, K) int32 — [receiver, sender, k] halo pos
    halo_len: np.ndarray   # (W,) int64 — real halo entries per worker
    halo_ids: np.ndarray   # (W, H) int64 — sorted unique remote ids, -1 pad
    nbr_local: np.ndarray  # (N, Cd) int32 — local-frame adjacency
    pair_elems: np.ndarray  # (W, W) int64 — unique values moved s -> r
    slot_intra: int        # valid slots inside their own *block*
    slot_inter: int        # valid slots crossing a *block* boundary

    def slot_counts(self) -> Tuple[int, int]:
        """(intra, inter) at block granularity == `graph.halo_slot_counts`."""
        return self.slot_intra, self.slot_inter

    @property
    def device_elems(self) -> int:
        """Unique values crossing a *device* boundary per superstep."""
        off = ~np.eye(self.wm.W, dtype=bool)
        return int(self.pair_elems[off].sum())

    @property
    def padded_elems(self) -> int:
        """Physical all-to-all payload per superstep (static padding)."""
        return self.wm.W * self.wm.W * self.K

    #: index (into the concat [local values | halo buffer]) that always
    #: holds the ignore value — PAD neighbor slots point here.
    @property
    def pad_slot(self) -> int:
        return self.wm.S + self.H + 1

    # -----------------------------------------------------------------
    # incremental maintenance (the streaming hot path)
    # -----------------------------------------------------------------

    def apply_updates(self, g, edits: Sequence[Tuple[int, int, int]]
                      ) -> "HaloPlan":
        """Incrementally maintain the plan after edge `edits`.

        `g` is the POST-update graph (its `nbr` already reflects the
        edits); `edits` is a sequence of (u, v, op) with op = +1 insert /
        -1 delete (op == 0 padding entries are skipped).  An edge touches
        at most two blocks, hence at most two workers: only those dirty
        workers get their halo layout (`halo_ids`, send/recv column,
        local-frame rows) re-derived — O(dirty · S · Cd) instead of the
        builder's O(N · Cd) — plus O(1) rows for worker-local edits.

        Capacity growth follows the doubling policy: H/K only ever grow,
        to the next power of two that fits, so the result is
        field-for-field identical to
        `build_halo_plan(g, wm, H_min=self.H, K_min=self.K)`.

        Returns the maintained `HaloPlan` (a new frozen instance; `self`
        unchanged — and returned as-is when every edit is an op == 0
        no-op).  Host-side preprocessing: raises under a jit trace.
        """
        _check_concrete(g.nbr)
        wm = self.wm
        S, W, Cn = wm.S, wm.W, g.Cn
        nbr = np.asarray(g.nbr)
        edits = [(int(u), int(v), int(op)) for u, v, op in edits
                 if int(op) != 0]
        if not edits:
            return self

        # slot counts move by +-2 per edit (one slot per endpoint row)
        slot_intra, slot_inter = self.slot_intra, self.slot_inter
        dirty: set = set()
        touched: set = set()
        for u, v, op in edits:
            d = 2 if op > 0 else -2
            if u // Cn == v // Cn:
                slot_intra += d
            else:
                slot_inter += d
            touched.add(u)
            touched.add(v)
            if u // S != v // S:  # remote reference created/removed
                dirty.add(u // S)
                dirty.add(v // S)

        halo_len = self.halo_len.copy()
        pair_elems = self.pair_elems.copy()
        uniq_new = {r: _worker_uniq(nbr, r, S) for r in sorted(dirty)}
        for r, u_ in uniq_new.items():
            halo_len[r] = len(u_)
            pair_elems[:, r] = (np.bincount(u_ // S, minlength=W)
                                if len(u_) else 0)

        H = max(self.H, _pow2_ceil(int(halo_len.max()) if W else 1))
        K = max(self.K, _pow2_ceil(int(pair_elems.max())))

        # grow tables (stale capacity-dependent sentinels are remapped:
        # the dump slot H and the PAD sentinel S+H+1 move with H)
        if K != self.K:
            send_idx = np.zeros((W, W, K), np.int32)
            send_idx[:, :, :self.K] = self.send_idx
            recv_pos = np.full((W, W, K), self.H, np.int32)
            recv_pos[:, :, :self.K] = self.recv_pos
        else:
            send_idx = self.send_idx.copy()
            recv_pos = self.recv_pos.copy()
        if H != self.H:
            recv_pos = np.where(recv_pos == self.H, H, recv_pos
                                ).astype(np.int32)
            nbr_local = np.where(self.nbr_local == S + self.H + 1,
                                 S + H + 1, self.nbr_local).astype(np.int32)
            halo_ids = np.full((W, H), -1, np.int64)
            halo_ids[:, :self.H] = self.halo_ids
        else:
            nbr_local = self.nbr_local.copy()
            halo_ids = self.halo_ids.copy()

        for r, u_ in uniq_new.items():
            _fill_receiver(send_idx, recv_pos, u_, r, S, W, H)
            halo_ids[r, :] = -1
            halo_ids[r, :len(u_)] = u_
            rows = slice(r * S, (r + 1) * S)
            nbr_local[rows] = _local_rows(nbr[rows], u_, r, S, H)

        # rows touched by worker-local edits: the halo layout of their
        # worker is unchanged (the stored halo_ids row is its layout),
        # only the row contents moved (insert appends, delete swaps)
        for u in sorted(touched):
            r = u // S
            if r in uniq_new:
                continue
            u_ = halo_ids[r, :halo_len[r]]
            nbr_local[u] = _local_rows(nbr[u:u + 1], u_, r, S, H)[0]

        return HaloPlan(
            wm=wm, K=K, H=H, send_idx=send_idx, recv_pos=recv_pos,
            halo_len=halo_len, halo_ids=halo_ids, nbr_local=nbr_local,
            pair_elems=pair_elems,
            slot_intra=slot_intra, slot_inter=slot_inter,
        )


def build_halo_plan(
    g, wm: WorkerMesh = None, W: int = None,
    H_min: int = 1, K_min: int = 1,
) -> HaloPlan:
    """Derive the halo plan from a *concrete* `GraphBlocks.nbr`.

    `H_min`/`K_min` floor the capacities (a plan maintained through
    `apply_updates` never shrinks its compiled-cache key); both are then
    padded up to powers of two by the slack policy.

    Raises if called under a trace: the plan is host-side preprocessing
    and cannot be derived from abstract values — build it outside `jit`
    and close over it (the `ell_spmd` entry points do exactly that).
    """
    _check_concrete(g.nbr)
    if wm is None:
        wm = make_worker_mesh(g, W=W)
    nbr = np.asarray(g.nbr)
    N, Cd = nbr.shape
    S, Wn = wm.S, wm.W
    assert N == wm.N, (N, wm.N)

    valid = nbr >= 0
    own_block = np.arange(N) // g.Cn
    inter_blk = valid & (np.where(valid, nbr // g.Cn, -1) != own_block[:, None])
    slot_inter = int(inter_blk.sum())
    slot_intra = int(valid.sum()) - slot_inter

    uniq = [_worker_uniq(nbr, r, S) for r in range(Wn)]
    halo_len = np.array([len(u) for u in uniq], np.int64)
    H = max(int(H_min), _pow2_ceil(int(halo_len.max()) if Wn else 1))
    pair_elems = np.zeros((Wn, Wn), np.int64)
    for r in range(Wn):
        owners = uniq[r] // S
        cnt = np.bincount(owners, minlength=Wn) if len(owners) else \
            np.zeros(Wn, np.int64)
        pair_elems[:, r] = cnt  # column r: what each sender moves to r
    K = max(int(K_min), _pow2_ceil(int(pair_elems.max())))

    send_idx = np.zeros((Wn, Wn, K), np.int32)
    recv_pos = np.full((Wn, Wn, K), H, np.int32)  # default: dump slot
    halo_ids = np.full((Wn, H), -1, np.int64)
    nbr_local = np.full((N, Cd), S + H + 1, np.int32)
    for r in range(Wn):
        _fill_receiver(send_idx, recv_pos, uniq[r], r, S, Wn, H)
        halo_ids[r, :len(uniq[r])] = uniq[r]
        rows = slice(r * S, (r + 1) * S)
        nbr_local[rows] = _local_rows(nbr[rows], uniq[r], r, S, H)

    return HaloPlan(
        wm=wm, K=K, H=H, send_idx=send_idx, recv_pos=recv_pos,
        halo_len=halo_len, halo_ids=halo_ids, nbr_local=nbr_local,
        pair_elems=pair_elems,
        slot_intra=slot_intra, slot_inter=slot_inter,
    )


def mirror_merge_payload(plan, n_fields: int = 1) -> int:
    """Per-superstep collective payload of the hub-mirror merge, in elements.

    A mirrored run (see `core.hub_split`) adds one combine-then-broadcast
    collective per merged field per superstep: each worker folds its
    resident replica-group rows into a dense (Gmax + 1,) per-group
    partial table (hindex: (Gmax + 1, Km) count histograms) and the
    tables merge with a single pmin/psum over the worker axis.  That
    table IS the wire payload — independent of how many replica rows
    exist or where they live, which is the point: the merge cost is
    bounded by the number of split hubs, not by hub degree.

    Returns elements per superstep for `n_fields` min/sum fields; an
    hindex field costs `(Gmax + 1) * Km` instead, which callers account
    for by passing the histogram width as extra fields if they need the
    exact figure.  Counter only — no device code.
    """
    return (int(plan.Gmax) + 1) * int(n_fields)
