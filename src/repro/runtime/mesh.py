"""Worker mesh: one `workers` device axis, blocks folded onto devices.

The paper assigns one block per Akka worker.  Here a *worker* is a JAX
device on the 1-D `workers` mesh axis; when the graph has more blocks than
there are devices, `B = P // W` consecutive blocks fold onto each device
(block-contiguous relabeling makes the fold a plain leading-axis shard:
device w owns padded node rows `[w*B*Cn, (w+1)*B*Cn)`).

On hardware the axis spans real accelerators; on CPU CI it spans the
host devices created by `XLA_FLAGS=--xla_force_host_platform_device_count=N`
(set before the first jax import — jax locks the device count on first
use, which is why nothing in this module touches device state at import
time).  With a single device everything still runs: W = 1 and the whole
graph folds onto it, so the SPMD path is testable anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

#: the one mesh axis of the block runtime
AXIS = "workers"


def best_worker_count(P: int, n_devices: int) -> int:
    """Largest W <= n_devices with W | P (every device gets B = P/W blocks).

    Divisibility keeps the fold exact: a non-divisor W would strand partial
    blocks on shard boundaries and break `block_of(u) = u // Cn` locality.
    """
    if P < 1:
        raise ValueError(f"need at least one block, got P={P}")
    for w in range(min(P, max(1, n_devices)), 0, -1):
        if P % w == 0:
            return w
    return 1


@dataclasses.dataclass(frozen=True)
class WorkerMesh:
    """A 1-D device mesh plus the static block-fold geometry.

    Attributes
    ----------
    mesh: the jax Mesh with the single `workers` axis.
    W:    worker (device) count along the axis.
    P:    number of graph blocks.
    B:    blocks per worker (the fold), B * W == P.
    Cn:   node capacity per block (from the graph).
    """

    mesh: Mesh
    W: int
    P: int
    B: int
    Cn: int

    @property
    def S(self) -> int:
        """Padded nodes per worker shard (B blocks of Cn rows)."""
        return self.B * self.Cn

    @property
    def N(self) -> int:
        return self.P * self.Cn

    def worker_of(self, u) -> int:
        """Owning worker of a global padded node id."""
        return u // self.S

    def node_sharding(self) -> NamedSharding:
        """Sharding for node arrays: leading (P*Cn) axis split over workers."""
        return NamedSharding(self.mesh, PartitionSpec(AXIS))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())


def make_worker_mesh(
    g,  # GraphBlocks (duck-typed: .P, .Cn)
    W: Optional[int] = None,
    devices: Optional[Sequence[jax.Device]] = None,
) -> WorkerMesh:
    """Build the worker mesh for a block-partitioned graph.

    W defaults to `best_worker_count(P, #devices)`; pass it explicitly to
    force a deeper fold (e.g. W=2 on an 8-device host to exercise B=4).
    """
    devices = list(jax.devices() if devices is None else devices)
    if W is None:
        W = best_worker_count(g.P, len(devices))
    if W < 1 or W > len(devices):
        raise ValueError(f"W={W} outside [1, {len(devices)} devices]")
    if g.P % W:
        raise ValueError(f"W={W} must divide P={g.P} (blocks-per-device fold)")
    mesh = Mesh(np.asarray(devices[:W]), (AXIS,))
    return WorkerMesh(mesh=mesh, W=W, P=g.P, B=g.P // W, Cn=g.Cn)
