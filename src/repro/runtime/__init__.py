"""Distributed block runtime: BLADYG's architecture on a JAX device mesh.

The paper's deployment is a coordinator plus one Akka worker per block,
exchanging messages across block boundaries.  This package is that
architecture on SPMD JAX:

  mesh.py   — `WorkerMesh`: the `workers` device axis (multi-device on
              hardware, `XLA_FLAGS=--xla_force_host_platform_device_count=N`
              on CPU CI) with a blocks-per-device fold when P > #devices.
  halo.py   — `HaloPlan`: which neighbor slots cross shard boundaries and
              the all-to-all gather indices that serve them, precomputed
              host-side from `GraphBlocks.nbr`.
  spmd.py   — `SpmdExecutor` (compiled halo-exchange primitives) and
              `SpmdEngine.run_spmd`, the shard_map superstep executor:
              W2W is an executed halo exchange, W2M an all-gather of
              per-worker summaries, M2W the replicated master directive.
  stream.py — streaming update ingestion: route each batch to owner
              blocks host-side, drive `maintain_batch` block-locally,
              escalate cross-block conflicts to the coordinator path;
              one long-lived executor with incremental halo-plan
              maintenance, plus the §4.2 live-rebalancing trigger
              (threshold protocol -> `migrate_vertices`).

Everything here duck-types `GraphBlocks` (`.nbr`, `.deg`, `.node_mask`,
`.P`, `.Cn`, `.Cd`, `.N`) the same way `kernels.ops` does, so the kernel
registry can lazily dispatch into this package without an import cycle.
"""
from .mesh import AXIS, WorkerMesh, best_worker_count, make_worker_mesh
from .halo import HaloPlan, build_halo_plan
from .spmd import (
    SpmdBlockProgram,
    SpmdCorenessProgram,
    SpmdEngine,
    SpmdExecutor,
    SpmdProgram,
    coreness_spmd,
    frontier_spmd,
    hindex_spmd,
)
from .stream import (
    MirrorStream, StreamResult, StreamSession, StreamStats, route_updates,
    run_stream)
from .recovery import (
    ElasticCoordinator, WindowLog, blocks_of_worker, evacuate_blocks,
    kill_session, plan_evacuation, recover_worker)

__all__ = [
    "AXIS", "WorkerMesh", "best_worker_count", "make_worker_mesh",
    "HaloPlan", "build_halo_plan",
    "SpmdExecutor", "SpmdEngine", "SpmdProgram", "SpmdCorenessProgram",
    "SpmdBlockProgram",
    "coreness_spmd", "hindex_spmd", "frontier_spmd",
    "MirrorStream", "StreamResult", "StreamSession", "StreamStats",
    "route_updates", "run_stream",
    "ElasticCoordinator", "WindowLog", "blocks_of_worker",
    "evacuate_blocks", "kill_session", "plan_evacuation", "recover_worker",
]
