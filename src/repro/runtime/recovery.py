"""Coordinator-style crash recovery: window log, worker loss, replay.

BLADYG's coordinator treats worker join/leave as first-class protocol
(Aridhi et al. §4): when a worker disappears, its blocks are re-assigned
across the survivors and processing resumes.  This module is that
protocol over the elastic substrate the rest of the PR provides:

  * `WindowLog` — the coordinator's durable record of every mutation it
    fed the session since the last snapshot (edit windows and vertex
    arrivals, in order).  Entries hold OPEN-TIME ids — exactly what the
    caller handed in — so replay after a restore goes through the same
    `StreamSession` id-resolution machinery as the original run.
  * `ElasticCoordinator` — wraps a `StreamSession` + `CheckpointManager`:
    feeds windows (applied to the session first, logged on success, so a
    rejected window never pollutes the log), cuts snapshots that embed
    the log cursor, and performs `recover_worker`.
  * `recover_worker` — the failure drill: restore the last COMMITTED
    snapshot onto the surviving mesh (W' | P), evacuate the dead
    worker's blocks across survivors with the §4.2 `migrate_vertices`
    permutation machinery (growing Cn first when the survivors lack
    free rows), then replay the log tail.  Replay is deterministic and
    the snapshot carries the composed id remap, so the recovered
    session is bit-identical to one that never crashed.

`kill_session` deletes the dead session's device buffers outright, so a
test that accidentally keeps serving from pre-loss state fails loudly —
recovery must come from the snapshot, never the corpse.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import CapacityError
from .halo import _pow2_ceil


class WindowLog:
    """Ordered record of the mutations fed to a session since open.

    Two entry kinds: ``("window", [(u, v, op), ...])`` and
    ``("vertices", block, count)``.  The coordinator's snapshots embed
    the entry count at save time (the *cursor*); recovery replays
    ``entries[cursor:]``.  Entries are plain python values (JSON-able),
    so a production log could be a durable queue — here it lives in
    memory, which is exactly the simulated-loss model: the coordinator
    survives, the workers' device state does not.
    """

    def __init__(self):
        self.entries: List[tuple] = []

    def __len__(self) -> int:
        return len(self.entries)

    def append_window(self, window) -> None:
        self.entries.append(
            ("window", [(int(u), int(v), int(op)) for u, v, op in window]))

    def append_vertices(self, block: int, count: int) -> None:
        self.entries.append(("vertices", int(block), int(count)))

    def replay(self, session, cursor: int = 0) -> int:
        """Re-apply ``entries[cursor:]`` to `session` in order.  Returns
        the number of entries replayed."""
        tail = self.entries[cursor:]
        for entry in tail:
            if entry[0] == "window":
                session.apply_window(entry[1])
            elif entry[0] == "vertices":
                session.add_vertices(entry[1], entry[2])
            else:
                raise ValueError(f"unknown log entry kind {entry[0]!r}")
        return len(tail)


def blocks_of_worker(w: int, P: int, W: int) -> List[int]:
    """Blocks owned by worker `w` under the W-worker fold of P blocks
    (`runtime.mesh`: B = P // W consecutive blocks per worker)."""
    if W < 1 or P % W:
        raise ValueError(f"W={W} must divide P={P}")
    if not 0 <= w < W:
        raise ValueError(f"worker {w} outside [0, {W})")
    B = P // W
    return list(range(w * B, (w + 1) * B))


def plan_evacuation(g, dead_blocks: Sequence[int]
                    ) -> List[Tuple[int, int]]:
    """Moves re-assigning every real node of `dead_blocks` across the
    survivors, most-free-block-first (balanced, deterministic).  Raises
    `CapacityError` when the survivors lack free rows in total — the
    caller's cue to grow Cn first."""
    mask = np.asarray(g.node_mask)
    dead = set(int(b) for b in dead_blocks)
    if not dead:
        return []
    if not all(0 <= b < g.P for b in dead):
        raise ValueError(f"dead blocks {sorted(dead)} outside [0, {g.P})")
    survivors = [b for b in range(g.P) if b not in dead]
    if not survivors:
        raise ValueError("cannot evacuate every block at once")
    # max-heap of free rows per survivor; ties broken by block id
    heap = [(-int(g.Cn - mask[b * g.Cn:(b + 1) * g.Cn].sum()), b)
            for b in survivors]
    heapq.heapify(heap)
    moves: List[Tuple[int, int]] = []
    for b in sorted(dead):
        for u in np.flatnonzero(mask[b * g.Cn:(b + 1) * g.Cn]) + b * g.Cn:
            negfree, dest = heapq.heappop(heap)
            if negfree == 0:
                raise CapacityError(
                    f"survivors out of free node rows after {len(moves)} "
                    f"moves (Cn={g.Cn}); grow Cn and retry")
            moves.append((int(u), dest))
            heapq.heappush(heap, (negfree + 1, dest))
    return moves


def evacuate_blocks(session, dead_blocks: Sequence[int]) -> int:
    """Move every real node out of `dead_blocks` onto the survivors via
    `StreamSession.migrate`, growing Cn (pad-and-rekey) until the
    survivors can take them.  Returns the number of vertices moved."""
    while True:
        try:
            moves = plan_evacuation(session.g, dead_blocks)
            break
        except CapacityError:
            session.grow(Cn=_pow2_ceil(session.g.Cn + 1))
    if moves:
        session.migrate(moves)
    return len(moves)


def kill_session(session) -> None:
    """Simulate the worker loss on the LIVE session: delete its device
    buffers.  Anything that keeps reading the dead session afterwards
    raises — recovery must serve from the snapshot, not the corpse."""
    arrays = [session.g.nbr, session.g.deg, session.g.node_mask,
              session.g.orig_id, session.core]
    if getattr(session, "labels", None) is not None:
        arrays.append(session.labels)
    for arr in arrays:
        try:
            arr.delete()
        except Exception:
            pass  # already deleted / donated away


def recover_worker(mgr, log: WindowLog, dead_worker: int,
                   W_old: Optional[int] = None, W: Optional[int] = None,
                   backend: Optional[str] = None, step: Optional[int] = None):
    """The coordinator's failure drill, as a standalone function.

    1. restore the last committed snapshot from `mgr` onto the surviving
       mesh (`W` workers; default lets the runtime pick) — torn
       ``step_XXXX.tmp`` directories are never considered;
    2. evacuate the blocks worker `dead_worker` owned under the old
       `W_old`-worker fold (default: one block per worker, the paper's
       deployment) across the survivors;
    3. replay the log tail recorded after the snapshot's cursor.

    Returns ``(session, replayed)``.  Bit-exactness: the snapshot is
    exact, `migrate_vertices` is a pure permutation, and replay runs the
    identical maintenance path on identically-valued state.
    """
    from ..checkpoint import restore_session

    step, session, meta = restore_session(
        mgr, step=step, W=W, backend=backend)
    P = session.g.P
    W_old = P if W_old is None else int(W_old)
    evacuate_blocks(session, blocks_of_worker(int(dead_worker), P, W_old))
    cursor = int((meta.get("extra") or {}).get("log_cursor", 0))
    replayed = log.replay(session, cursor)
    return session, replayed


class ElasticCoordinator:
    """Coordinator wrapper: one session, one checkpoint dir, one log.

    Feed mutations through `apply_window` / `add_vertices` (applied to
    the session first, logged on success), cut snapshots with
    `checkpoint` (embeds the log cursor), and on a simulated worker loss
    call `recover_worker(w)` — the coordinator swaps in the recovered
    session and keeps going; the stream never notices.
    """

    def __init__(self, session, mgr, log: Optional[WindowLog] = None):
        self.session = session
        self.mgr = mgr
        self.log = WindowLog() if log is None else log

    def apply_window(self, window) -> None:
        self.session.apply_window(window)
        self.log.append_window(window)

    def add_vertices(self, block: int, count: int = 1) -> List[int]:
        handles = self.session.add_vertices(block, count)
        self.log.append_vertices(block, count)
        return handles

    def checkpoint(self, blocking: bool = True) -> int:
        """Snapshot the session; the manifest meta records how much of
        the log the snapshot already contains."""
        from ..checkpoint import save_session

        return save_session(
            self.mgr, self.session, blocking=blocking,
            extra_meta={"log_cursor": len(self.log)})

    def recover_worker(self, dead_worker: int, W_old: Optional[int] = None,
                       W: Optional[int] = None,
                       backend: Optional[str] = None):
        """Drop worker `dead_worker`'s shards (the live session's buffers
        are deleted — see `kill_session`), restore, evacuate, replay.
        The recovered session replaces `self.session` and is returned."""
        if W_old is None and getattr(self.session, "executor", None) is not None:
            W_old = self.session.executor.wm.W
        kill_session(self.session)
        session, _ = recover_worker(
            self.mgr, self.log, dead_worker, W_old=W_old, W=W,
            backend=backend)
        self.session = session
        return session
