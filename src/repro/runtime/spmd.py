"""shard_map superstep execution: BLADYG modes as real collectives.

`SpmdExecutor` compiles the graph primitives over the worker mesh with the
halo plan baked in:

  W2W   — `_halo_exchange`: gather the send buffers, `lax.all_to_all`
          across the `workers` axis, scatter into the halo buffer; the
          neighbor read is then a purely local gather through the
          plan's local-frame adjacency.  By default the read is
          **split-phase** (`_overlap_select`): local slots gather from
          the field shard without waiting on the collective, only halo
          slots consume the all_to_all — bit-identical values, zero
          serialized collective phases per superstep
          (`SpmdExecutor(overlap=False)` restores strict ordering).
  W2M   — per-block summaries leave the shard through the sharded
          output (an all-gather) or a `lax.psum` for reduced flags.
  M2W   — the master's directive enters the next superstep replicated.
  Local — everything else: h-index / frontier math on the shard.

`SpmdEngine.run_spmd` is the program-level executor (the distributed
counterpart of `core.engine.BladygEngine.run`): it drives an
`SpmdProgram`'s worker/master ops and records per-superstep
`SuperstepTrace`s whose W2W numbers come from the **executed** halo plan
(`HaloPlan.slot_counts`), not from shape reconstruction.

Compiled step functions are cached per (mesh, halo capacity H): the plan
tables are *arguments*, not closure constants, so maintenance loops that
thread one executor through a stream (updating its plan in place via
`SpmdExecutor.apply_updates` — the halo changes with the adjacency)
reuse the compiled executables as long as the halo capacity holds, and
the capacity doubling policy makes sure it almost always does — jit's
shape cache handles the rest.

Bit-exactness: all math is int32/bool and identical to the single-device
reference (`kernels.ref`), so `coreness_spmd` equals
`ops.coreness_blocks(backend="jnp")` exactly for any worker count,
including the blocks-per-device fold and W = 1.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from ..kernels.ops import BlockCtx
from ..kernels.ref import combine_rows, hindex_rows
from .halo import HaloPlan, build_halo_plan
from .mesh import AXIS, WorkerMesh, make_worker_mesh

P_ = PartitionSpec


def _halo_exchange(x_local, send_idx, recv_pos, H: int, fill):
    """One W2W round for a per-node field shard.

    x_local: (S, ...) — this worker's values.
    send_idx: (W, K)  — local rows to serve each receiver.
    recv_pos: (W, K)  — halo positions for each sender's values (pad
                        entries target the dump slot H).
    Returns the (H+2, ...) halo buffer: [0, H) real entries, H the dump
    slot, H+1 the PAD sentinel pinned at `fill`.
    """
    tail = x_local.shape[1:]
    sendbuf = x_local[send_idx]                              # (W, K, ...)
    recvbuf = jax.lax.all_to_all(
        sendbuf, AXIS, split_axis=0, concat_axis=0, tiled=True
    )
    buf = jnp.full((H + 2,) + tail, fill, x_local.dtype)
    return buf.at[recv_pos.reshape(-1)].set(
        recvbuf.reshape((-1,) + tail)
    ).at[H + 1].set(fill)


def _neighbor_vals(x_local, halo_buf, nbr_local):
    """Local gather through the plan's local-frame adjacency: (S, Cd, ...).

    The strict-ordered form: concatenating the halo buffer ahead of the
    gather makes EVERY neighbor read data-depend on the all_to_all, so
    the compute phase serializes behind the collective.
    """
    vals = jnp.concatenate([x_local, halo_buf], axis=0)
    return vals[nbr_local]


def _overlap_select(x_local, halo_buf, nbr_local):
    """Split-phase neighbor read: local slots bypass the halo buffer.

    Local-frame ids < S index this worker's own rows — their values are a
    pure local gather of `x_local` with NO data dependence on the
    all_to_all, so the scheduler is free to run that gather while the
    collective is still in flight; only the halo slots (ids >= S) wait.
    The select picks, slot for slot, exactly the values the strict
    concat-gather reads, so both orderings are bit-identical (the
    poisoned-halo test in tests/test_overlap.py pins the independence).
    """
    S = x_local.shape[0]
    is_local = nbr_local < S
    local_vals = jnp.take(x_local, jnp.clip(nbr_local, 0, S - 1), axis=0)
    halo_vals = jnp.take(
        halo_buf, jnp.clip(nbr_local - S, 0, halo_buf.shape[0] - 1), axis=0)
    mask = is_local.reshape(
        is_local.shape + (1,) * (local_vals.ndim - is_local.ndim))
    return jnp.where(mask, local_vals, halo_vals)


def _any_global(x) -> jax.Array:
    """Replicated 'any' across all shards (the W2M reduced flag)."""
    return jax.lax.psum(jnp.any(x).astype(jnp.int32), AXIS) > 0


def _exchange_gather(field, nbrl, send, recv, H, fill, overlap: bool = False):
    """W2W exchange + local gather: field (S, ...) -> (S, Cd, ...).

    send/recv arrive with their sharded leading worker axis of size 1.
    `overlap=True` uses the split-phase read (`_overlap_select`): the
    all_to_all is issued first and only halo slots consume it, local
    slots gather straight from `field` — same values, one fewer
    serialized collective phase per superstep.
    """
    halo = _halo_exchange(field, send[0], recv[0], H, fill)
    if overlap:
        return _overlap_select(field, halo, nbrl)
    return _neighbor_vals(field, halo, nbrl)


def _gather_field(field, nbrl, send, recv, H, fill, overlap: bool):
    """`_exchange_gather` over a declared halo field, tuple-aware.

    MultiPrograms declare tuple fields/fills (one per fused sub-program);
    each leaf exchanges with its own fill and dtype.
    """
    if isinstance(field, tuple):
        return tuple(
            _exchange_gather(f, nbrl, send, recv, H,
                             jnp.asarray(fl, f.dtype), overlap)
            for f, fl in zip(field, fill))
    return _exchange_gather(field, nbrl, send, recv, H,
                            jnp.asarray(fill, field.dtype), overlap)


# ---------------------------------------------------------------------------
# Compiled step functions, cached per (mesh, H).  Plan tables and state are
# arguments, so executors rebuilt after graph updates hit this cache.
# ---------------------------------------------------------------------------

#: how many mesh step functions have been BUILT (jit-wrapped on a compiled-
#: cache miss): every `_smap` call bumps it, so a steady-state serving loop
#: — session windows + snapshot refreshes + query batches on one executor —
#: holds it constant after warmup.  Python-side and monotonic, the mesh
#: analogue of `kernels.ops.gather_trace_count`; tests snapshot it around
#: the post-warmup phase to assert ZERO recompiles.
_STEP_BUILDS = 0


def step_build_count() -> int:
    """Mesh step functions built so far (see `_STEP_BUILDS`)."""
    return _STEP_BUILDS


def _smap(fn, mesh, n_lead: int, n_rep: int, out_specs):
    """shard_map + jit: `n_lead` node-sharded args, `n_rep` replicated args,
    then the three plan tables (nbr_local / send / recv, worker-sharded)."""
    global _STEP_BUILDS
    _STEP_BUILDS += 1
    specs = [P_(AXIS)] * n_lead + [P_()] * n_rep + [P_(AXIS)] * 3
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=tuple(specs), out_specs=out_specs,
        check_rep=False,
    ))


@functools.lru_cache(maxsize=128)
def _compiled_hindex(mesh, H: int, overlap: bool):
    def local(est, nbrl, send, recv):
        vals = _exchange_gather(est, nbrl, send, recv, H, jnp.int32(-1),
                                overlap)
        return hindex_rows(vals)

    return _smap(local, mesh, 1, 0, P_(AXIS))


@functools.lru_cache(maxsize=128)
def _compiled_frontier(mesh, H: int, overlap: bool):
    def local(f, elig, vis, nbrl, send, recv):
        vals = _exchange_gather(
            f.astype(jnp.int8), nbrl, send, recv, H, jnp.int8(0), overlap)
        return jnp.any(vals > 0, axis=1) & elig & ~vis

    return _smap(local, mesh, 3, 0, P_(AXIS))


@functools.lru_cache(maxsize=128)
def _compiled_coreness(mesh, H: int, overlap: bool):
    def local(est, mask, max_steps, nbrl, send, recv):
        def cond(c):
            _, changed, it = c
            return changed & (it < max_steps)

        def body(c):
            est, _, it = c
            vals = _exchange_gather(est, nbrl, send, recv, H, jnp.int32(-1),
                                    overlap)
            new = jnp.where(mask, jnp.minimum(est, hindex_rows(vals)), est)
            return new, _any_global(new != est), it + 1

        est, _, steps = jax.lax.while_loop(
            cond, body, (est, jnp.bool_(True), jnp.int32(0)))
        return est, steps

    return _smap(local, mesh, 2, 1, (P_(AXIS), P_()))


@functools.lru_cache(maxsize=128)
def _compiled_reach(mesh, H: int, overlap: bool):
    def local(core, mask, roots, ks, max_steps, nbrl, send, recv):
        elig = (core[:, None] == ks[None, :]) & mask[:, None]
        visited0 = roots & elig

        def cond(c):
            _, _, cont, it = c
            return cont & (it < max_steps)

        def body(c):
            visited, frontier, _, it = c
            vals = _exchange_gather(
                frontier.astype(jnp.int8), nbrl, send, recv, H, jnp.int8(0),
                overlap)
            nxt = jnp.any(vals > 0, axis=1) & elig & ~visited
            return visited | nxt, nxt, _any_global(nxt), it + 1

        visited, _, _, steps = jax.lax.while_loop(
            cond, body,
            (visited0, visited0, _any_global(visited0), jnp.int32(0)))
        return visited, steps

    return _smap(local, mesh, 3, 2, (P_(AXIS), P_()))


@functools.lru_cache(maxsize=128)
def _compiled_recompute(mesh, H: int, overlap: bool):
    def local(est, cand, mask, max_steps, nbrl, send, recv):
        move = cand & mask

        def cond(c):
            _, changed, it = c
            return changed & (it < max_steps)

        def body(c):
            est, _, it = c
            vals = _exchange_gather(est, nbrl, send, recv, H, jnp.int32(-1),
                                    overlap)
            new = jnp.where(move, jnp.minimum(est, hindex_rows(vals)), est)
            return new, _any_global(new != est), it + 1

        est, _, steps = jax.lax.while_loop(
            cond, body, (est, jnp.bool_(True), jnp.int32(0)))
        return est, steps

    return _smap(local, mesh, 3, 1, (P_(AXIS), P_()))


class LocalCtx(NamedTuple):
    """Per-shard context handed to `SpmdProgram.worker_local`."""

    deg: jax.Array        # (S,) int32
    node_mask: jax.Array  # (S,) bool
    B: int                # blocks on this worker (fold)
    Cn: int               # nodes per block
    Cd: int


class SpmdExecutor:
    """Compiled halo-exchange primitives for one (graph, mesh) pair.

    Holds the worker mesh, the halo plan, and the per-(mesh, H) compiled
    step functions.  The plan is a function of `nbr` *contents*: after
    structural updates keep ONE executor alive and call `apply_updates`
    (dirty-worker incremental plan maintenance — the streaming hot path)
    or, after wholesale changes such as a vertex migration, `rebuild`.
    Both preserve the capacity floors, so the per-(mesh, H) compiled
    executables keep hitting; `full_rebuilds`/`plan_updates` count which
    path ran (a steady-state stream performs zero full rebuilds).

    `overlap` (default True) selects the split-phase neighbor read
    (`_overlap_select`): local slots gather without waiting on the
    all_to_all, so per superstep the compute serializes behind ZERO
    collective phases instead of one.  `overlap=False` is the
    strict-ordering fallback (the concat-gather of PR 3/4); both produce
    bit-identical values, and the executed count lands in each
    `SuperstepTrace.serialized_collectives`.
    """

    def __init__(self, g, W: Optional[int] = None,
                 wm: Optional[WorkerMesh] = None,
                 plan: Optional[HaloPlan] = None,
                 overlap: bool = True):
        self.wm = wm if wm is not None else make_worker_mesh(g, W=W)
        self.plan = plan if plan is not None else build_halo_plan(g, self.wm)
        #: split-phase halo read (False = strict-ordering fallback)
        self.overlap = bool(overlap)
        #: full from-scratch plan rebuilds after construction (`rebuild`)
        self.full_rebuilds = 0
        #: incremental plan maintenance calls (`apply_updates`)
        self.plan_updates = 0
        #: capacity escalations followed (`grow`) — each re-keys the
        #: compiled caches exactly once
        self.grows = 0
        self._refresh(g)

    def _refresh(self, g) -> None:
        """Re-stage the plan tables and per-node fields on device."""
        self.node_mask = jnp.asarray(g.node_mask)
        self.deg = jnp.asarray(g.deg, jnp.int32)
        self._nbrl = jnp.asarray(self.plan.nbr_local)
        self._send = jnp.asarray(self.plan.send_idx)
        self._recv = jnp.asarray(self.plan.recv_pos)

    def apply_updates(self, g, edits) -> None:
        """Incrementally maintain the halo plan after edge `edits`.

        `g` is the POST-update graph; `edits` are (u, v, op) triples
        (op = +1 insert / -1 delete / 0 padding no-op).  Only the workers
        owning an endpoint of a cross-worker edit are re-derived; the
        capacity doubling policy keeps the compiled caches warm.
        """
        self.plan = self.plan.apply_updates(g, edits)
        self._refresh(g)
        self.plan_updates += 1

    def rebuild(self, g) -> None:
        """Full from-scratch plan rebuild (e.g. after `migrate_vertices`
        permuted the blocks).  Keeps the H/K capacity floors so compiled
        step functions survive the rebuild."""
        self.plan = build_halo_plan(
            g, self.wm, H_min=self.plan.H, K_min=self.plan.K)
        self._refresh(g)
        self.full_rebuilds += 1

    def grow(self, g) -> None:
        """Follow a capacity escalation (`core.graph.grow_blocks`): refit
        the worker mesh to the new Cn — same W, same devices, only the
        block-fold geometry changes — and build a fresh halo plan at the
        new capacities (the old H/K floors describe the old id space, so
        they do not carry over).  Downstream, the per-(mesh, H) compiled
        steps re-specialize on the new shard shapes exactly once per
        grow and then keep hitting — the same pow2-bucket policy that
        keeps the steady-state stream at zero recompiles.
        """
        self.wm = make_worker_mesh(
            g, W=self.wm.W, devices=list(self.wm.mesh.devices.flat))
        self.plan = build_halo_plan(g, self.wm)
        self._refresh(g)
        self.grows += 1

    def refresh_fields(self, g) -> None:
        """Re-stage per-node fields (node_mask/deg) after a change that
        leaves the adjacency — and hence the halo plan — untouched
        (e.g. vertex arrival on padding rows)."""
        self._refresh(g)

    @property
    def _tables(self):
        return self._nbrl, self._send, self._recv

    def hindex(self, est: jax.Array) -> jax.Array:
        """h-index of neighbor estimates — one executed W2W superstep.

        est: (N,) int32 (N = P*Cn, sharded over workers as (S,) each);
        returns (N,) int32.
        """
        fn = _compiled_hindex(self.wm.mesh, self.plan.H, self.overlap)
        return fn(est.astype(jnp.int32), *self._tables)

    def frontier(self, f, eligible, visited) -> jax.Array:
        """One masked BFS hop for R stacked frontiers.

        f, eligible, visited: (N, R) bool; returns the next frontier as
        (N, R) bool (`f & eligible & ~visited` semantics of
        `ref.ell_frontier_hop_ref`).
        """
        fn = _compiled_frontier(self.wm.mesh, self.plan.H, self.overlap)
        return fn(f.astype(bool), eligible.astype(bool),
                  visited.astype(bool), *self._tables)

    def coreness(self, max_steps: int = 10_000) -> Tuple[jax.Array, jax.Array]:
        """Full min-H coreness on the mesh.

        Returns ((N,) int32 coreness, device int32 superstep count); the
        whole fixpoint is one on-mesh `lax.while_loop` (zero per-superstep
        host transfers).
        """
        fn = _compiled_coreness(self.wm.mesh, self.plan.H, self.overlap)
        est0 = jnp.where(self.node_mask, self.deg, 0).astype(jnp.int32)
        return fn(est0, self.node_mask, jnp.int32(max_steps), *self._tables)

    def k_reachable_batch(self, core, roots, ks, max_steps: int = 10_000):
        """R stacked k-reachability searches (semantics of
        `core.kcore_dynamic.k_reachable_batch`).

        core: (N,) int32; roots: (N, R) bool; ks: (R,) int32 per-search
        k levels.  Returns ((N, R) bool visited, device superstep count).
        """
        fn = _compiled_reach(self.wm.mesh, self.plan.H, self.overlap)
        return fn(jnp.asarray(core, jnp.int32), self.node_mask,
                  roots.astype(bool), jnp.asarray(ks, jnp.int32),
                  jnp.int32(max_steps), *self._tables)

    def restricted_recompute(self, est0, cand, max_steps: int = 10_000):
        """Clamped min-H iteration (only `cand` nodes move) on the mesh.

        est0: (N,) int32 upper bounds; cand: (N,) bool movable mask.
        Returns ((N,) int32 fixpoint, device superstep count).
        """
        fn = _compiled_recompute(self.wm.mesh, self.plan.H, self.overlap)
        return fn(jnp.asarray(est0, jnp.int32), cand.astype(bool),
                  self.node_mask, jnp.int32(max_steps), *self._tables)


# ---------------------------------------------------------------------------
# Program-level executor: the distributed BladygEngine.
# ---------------------------------------------------------------------------


class SpmdProgram:
    """A BLADYG program in per-shard form.

    `worker_local` sees only this worker's rows plus the halo-served
    neighbor values of the declared exchange field; `master_compute` runs
    replicated on the gathered per-block summaries, exactly the paper's
    masterCompute.
    """

    #: value PAD / dump slots read as (must match the field dtype)
    halo_fill = -1

    #: True iff worker_local AND master_compute are jit-pure with
    #: structure-stable state (mstate/directive pytrees keep their shape
    #: across supersteps) — `SpmdEngine.run_spmd` then fuses the whole
    #: superstep loop into one on-device `lax.while_loop` (W2M as a real
    #: all-gather, the halt decision never leaving the mesh).  Programs
    #: with host-side master logic keep the default (one halt transfer per
    #: superstep).
    fusable = False

    def halo_field(self, wstate) -> jax.Array:
        """The (S, ...) per-node array whose values neighbors read (W2W)."""
        return wstate

    def worker_local(self, ctx: LocalCtx, wstate, nb_vals, directive):
        """(ctx, local state, (S, Cd, ...) neighbor values, directive)
        -> (local state', per-block summary with leading axis B)."""
        raise NotImplementedError

    def master_compute(self, mstate, summary):
        """(master state, gathered (P, ...) summaries)
        -> (master state', directive, halt)."""
        raise NotImplementedError


class SpmdCorenessProgram(SpmdProgram):
    """min-H coreness as an SPMD program (`core.kcore.CorenessProgram`
    routed through the mesh): the estimate vector is the exchanged field,
    the per-block changed flags are the W2M summary, the halt decision is
    the replicated M2W directive."""

    halo_fill = -1
    fusable = True  # pure worker/master ops: the loop runs on-device

    # stateless: any two instances are interchangeable, so they share the
    # engine's compiled-step cache entry
    def __hash__(self):
        return hash(type(self))

    def __eq__(self, other):
        return type(other) is type(self)

    def worker_local(self, ctx, est, nb_vals, directive):
        new = jnp.where(
            ctx.node_mask, jnp.minimum(est, hindex_rows(nb_vals)), est)
        changed = jnp.any(
            (new != est).reshape(ctx.B, ctx.Cn), axis=1)  # per-block W2M
        return new, changed

    def master_compute(self, mstate, summary):
        return mstate, None, jnp.logical_not(jnp.any(summary))


def _mirror_merge_shard(red, nb_vals, mirror, combine: str, base, S: int):
    """Cross-worker replica-group merge of per-slice partials (mesh form).

    The on-mesh twin of `kernels.ops._mirror_merge`: each worker folds
    only the group rows resident in its shard into the (Gmax+1[, Km])
    per-group partial table, the tables merge across workers with ONE
    pmin/psum collective per merged field, and every worker writes the
    merged aggregates back to its own group rows — the combine-then-
    broadcast step of the vertex-cut dataflow, riding the same mesh as
    the halo exchange.  hindex merges through count-histogram partials
    off the already-halo-served `nb_vals` (so no second exchange);
    min/sum fold the per-slice reductions directly.  Scatter targets of
    foreign/pad entries are pushed out of bounds (dropped).
    """
    G = mirror.Gmax
    rows = jnp.asarray(mirror.grp_rows, jnp.int32)
    gid = jnp.asarray(mirror.grp_gid, jnp.int32)
    lrow = rows - base
    mine = (gid < G) & (lrow >= 0) & (lrow < S)
    li = jnp.clip(lrow, 0, S - 1)
    if combine == "min":
        fill = jnp.iinfo(red.dtype).max
        vals = jnp.where(mine, red[li], fill)
        part = jnp.full((G + 1,), fill, red.dtype).at[gid].min(vals)
        out = jax.lax.pmin(part, AXIS)[gid]
    elif combine == "sum":
        vals = jnp.where(mine, red[li], jnp.zeros((), red.dtype))
        part = jnp.zeros((G + 1,), red.dtype).at[gid].add(vals)
        out = jax.lax.psum(part, AXIS)[gid]
    elif combine == "hindex":
        ve = nb_vals[li].astype(jnp.int32)       # (Rp, Cd) halo-served
        t = jnp.arange(1, mirror.Km + 1, dtype=jnp.int32)
        hist = jnp.sum(ve[:, :, None] >= t[None, None, :], axis=1)
        hist = jnp.where(mine[:, None], hist, 0)
        cnt = jnp.zeros((G + 1, mirror.Km), hist.dtype).at[gid].add(hist)
        cnt = jax.lax.psum(cnt, AXIS)
        out = jnp.sum(cnt >= t[None, :], axis=1).astype(red.dtype)[gid]
    else:
        raise ValueError(
            f"combine {combine!r} has no mirror merge; count_common routes "
            "through core.hub_split.run_common_mirror")
    tgt = jnp.where(mine, li, S)  # OOB scatter drops foreign/pad writes
    return red.at[tgt].set(jnp.where(mine, out, jnp.zeros((), red.dtype)))


class SpmdBlockProgram(SpmdProgram):
    """Adapter: any `core.engine.BlockProgram` as an SPMD program.

    This is the ell_spmd execution of the structured superstep contract:
    the program's declared halo field is the exchanged W2W payload, its
    named combine runs as the post-halo local reduce
    (`kernels.ref.combine_rows` on the halo-served (S, Cd, ...) values),
    its update is per-shard workerCompute, and its local changed verdict
    is the W2M summary the replicated master folds into the halt
    decision.  `fusable=True`: the whole loop runs on-mesh through
    `SpmdEngine.run_spmd` with zero per-superstep host transfers.

    `mirror` (a `core.hub_split.MirrorPlan`) arms the vertex-cut
    dataflow: the update ctx carries the worker's slice of the LOGICAL
    degrees, and `_mirror_merge_shard` folds per-slice partials per
    replica group between combine and update.  The plan arrays are
    closure-captured into the compiled step (shard_map constants), so
    the plan's `uid` is part of program identity — and of the engine's
    compiled-step cache key (see CACHE_SCHEMAS): mirrored mesh streams
    recompile per plan rebuild, by design.

    Hash/eq delegate to the wrapped program (plus the static real-node
    count and mirror identity), so reusing a program object reuses the
    per-(mesh, H) compiled superstep.
    """

    fusable = True

    def __init__(self, prog, n_real: int, mirror=None):
        self.prog = prog
        self.n_real = int(n_real)
        self.halo_fill = prog.halo_fill
        self.mirror = mirror
        self.mirror_uid = None if mirror is None else mirror.uid

    def __hash__(self):
        return hash((type(self), self.prog, self.n_real, self.mirror_uid))

    def __eq__(self, other):
        return (type(other) is type(self) and other.prog == self.prog
                and other.n_real == self.n_real
                and other.mirror_uid == self.mirror_uid)

    def summary_shape(self):
        """Static W2M summary shape (the per-worker changed flag).

        `SpmdEngine._summary_shape` uses this instead of abstract-eval:
        the mirrored `worker_local` calls `lax.axis_index`, which only
        exists inside shard_map — eval_shape outside the mesh would
        fail, and the summary shape is a structural constant anyway.
        """
        return jax.ShapeDtypeStruct((1,), jnp.bool_)

    def halo_field(self, wstate):
        return self.prog.halo_field(wstate)

    def worker_local(self, ctx: LocalCtx, state, nb_vals, directive):
        deg = ctx.deg
        S = deg.shape[0]
        if self.mirror is not None:
            base = jax.lax.axis_index(AXIS) * S
            deg = jax.lax.dynamic_slice(
                jnp.asarray(self.mirror.ldeg, jnp.int32), (base,), (S,))
        bctx = BlockCtx(deg=deg, node_mask=ctx.node_mask,
                        n_real=self.n_real)
        field = self.prog.halo_field(state)
        if self.prog.combine == "multi":
            # fused lockstep supersteps: one exchange per sub-field, one
            # shared halt reduction — per-field reduces are the standalone
            # formulations, so results match sub-programs run alone.
            red = tuple(
                combine_rows(c, f, nb) for c, f, nb
                in zip(self.prog.combines, field, nb_vals))
        else:
            red = combine_rows(self.prog.combine, field, nb_vals)
        if self.mirror is not None:
            base = jax.lax.axis_index(AXIS) * S
            if self.prog.combine == "multi":
                red = tuple(
                    _mirror_merge_shard(r, nb, self.mirror, c, base, S)
                    for r, nb, c in zip(red, nb_vals, self.prog.combines))
            else:
                red = _mirror_merge_shard(
                    red, nb_vals, self.mirror, self.prog.combine, base, S)
        new = self.prog.update(bctx, state, red)
        changed = self.prog.changed(state, new)
        return new, changed.reshape(1)  # per-worker W2M flag

    def master_compute(self, mstate, summary):
        return mstate, None, jnp.logical_not(jnp.any(summary))


class SpmdEngine:
    """Superstep scheduler over the worker mesh (cf. `BladygEngine`).

    Differences from the single-device engine: workerCompute executes
    under `shard_map` with a real halo exchange, and the recorded
    per-superstep W2W counts come from the executed `HaloPlan`
    (`plan.slot_counts()`), not from declared shapes.
    """

    #: compiled program steps, keyed by (mesh, H, B, Cn, Cd, program
    #: instance) — the program is part of the key because the closure
    #: captures it, so reusing one program object across runs (as
    #: `coreness_via_spmd` does) reuses the compiled superstep.
    _step_cache: dict = {}

    def __init__(self, g, W: Optional[int] = None,
                 executor: Optional[SpmdExecutor] = None):
        self.g = g
        self.ex = executor if executor is not None else SpmdExecutor(g, W=W)
        self.traces = []

    def _step_fn(self, program: SpmdProgram):
        ex = self.ex
        H = ex.plan.H
        B, Cn = ex.wm.B, ex.wm.Cn
        Cd = ex.plan.nbr_local.shape[1]
        overlap = ex.overlap
        mirror = getattr(program, "mirror_uid", None)
        key = (ex.wm.mesh, H, B, Cn, Cd, overlap, program, mirror)
        cached = self._step_cache.get(key)
        if cached is not None:
            return cached

        def local(wstate, deg, mask, directive, nbrl, send, recv):
            field = program.halo_field(wstate)
            nb_vals = _gather_field(
                field, nbrl, send, recv, H, program.halo_fill, overlap)
            ctx = LocalCtx(deg=deg, node_mask=mask, B=B, Cn=Cn, Cd=Cd)
            return program.worker_local(ctx, wstate, nb_vals, directive)

        fn = _smap(local, ex.wm.mesh, 3, 1, (P_(AXIS), P_(AXIS)))
        self._step_cache[key] = fn
        return fn

    def _fused_fn(self, program: SpmdProgram):
        """Whole superstep loop as ONE shard_map'd `lax.while_loop`.

        The W2M summary becomes a real all-gather, masterCompute runs
        replicated on every worker, and the halt flag never reaches the
        host — the superstep count comes back as a device scalar.
        `max_supersteps` is an operand (like `_compiled_coreness`), so
        varying the cap never recompiles.
        """
        ex = self.ex
        H = ex.plan.H
        B, Cn = ex.wm.B, ex.wm.Cn
        Cd = ex.plan.nbr_local.shape[1]
        overlap = ex.overlap
        mirror = getattr(program, "mirror_uid", None)
        key = ("fused", ex.wm.mesh, H, B, Cn, Cd, overlap, program, mirror)
        cached = self._step_cache.get(key)
        if cached is not None:
            return cached

        def local(wstate, deg, mask, mstate, directive, max_supersteps,
                  nbrl, send, recv):
            ctx = LocalCtx(deg=deg, node_mask=mask, B=B, Cn=Cn, Cd=Cd)

            def cond(c):
                _, _, _, halt, it = c
                return (~halt) & (it < max_supersteps)

            def body(c):
                wstate, mstate, d, _, it = c
                field = program.halo_field(wstate)
                nb_vals = _gather_field(
                    field, nbrl, send, recv, H, program.halo_fill, overlap)
                wstate2, summary = program.worker_local(
                    ctx, wstate, nb_vals, d)
                full = jax.lax.all_gather(summary, AXIS, axis=0, tiled=True)
                mstate2, d2, halt = program.master_compute(mstate, full)
                if d2 is None:  # trace-time: keep carrying the placeholder
                    d2 = d
                return wstate2, mstate2, d2, halt, it + 1

            wstate, mstate, _, _, n = jax.lax.while_loop(
                cond, body,
                (wstate, mstate, directive, jnp.bool_(False), jnp.int32(0)))
            return wstate, mstate, n

        fn = _smap(local, ex.wm.mesh, 3, 3, (P_(AXIS), P_(), P_()))
        self._step_cache[key] = fn
        return fn

    def _summary_shape(self, program: SpmdProgram, wstate, directive):
        """Abstract-eval the gathered W2M summary (coordinator granularity:
        leading axis P) for post-loop trace reconstruction.

        Programs may declare the shape statically via `summary_shape()`
        (mirrored `SpmdBlockProgram`s must: their worker_local calls
        `lax.axis_index`, which has no meaning outside shard_map)."""
        hint = getattr(program, "summary_shape", None)
        if hint is not None:
            return hint()
        Cd = self.ex.plan.nbr_local.shape[1]
        field_s = jax.eval_shape(program.halo_field, wstate)
        nb_s = jax.tree_util.tree_map(
            lambda fs: jax.ShapeDtypeStruct(
                (self.g.N, Cd) + tuple(fs.shape[1:]), fs.dtype),
            field_s)  # tuple fields (MultiProgram) map leaf-wise
        # ctx rides in by closure: its B/Cn/Cd ints must stay concrete
        # (eval_shape would abstract NamedTuple leaves into tracers)
        ctx = LocalCtx(deg=self.ex.deg, node_mask=self.ex.node_mask,
                       B=self.g.P, Cn=self.ex.wm.Cn, Cd=Cd)
        _, summary_s = jax.eval_shape(
            lambda w, nb, d: program.worker_local(ctx, w, nb, d),
            wstate, nb_s, directive)
        return summary_s

    def run_spmd(
        self,
        program: SpmdProgram,
        wstate: Any,
        mstate: Any,
        directive: Any = None,
        max_supersteps: int = 10_000,
        fuse: Optional[bool] = None,
    ) -> Tuple[Any, Any]:
        """Execute the program; worker steps run sharded on the mesh.

        `fuse=None` follows `program.fusable`: fusable programs run the
        whole loop device-resident (zero per-superstep host transfers —
        the halt flag is a mesh-side psum/all-gather decision and the
        superstep count comes back once, with the final state); other
        programs fall back to the host-driven loop below.  Either way the
        trace's W2W numbers are the executed halo plan's slot counts
        (block granularity — identical accounting to the paper's one
        worker per block, independent of the device fold).
        """
        from ..core.engine import BladygEngine, Mode, SuperstepTrace

        w2w = self.ex.plan.slot_counts()
        modes = getattr(program, "modes",
                        Mode.LOCAL | Mode.M2W | Mode.W2M | Mode.W2W)
        # collective phases the compute waited on per superstep: the strict
        # concat-gather serializes behind the halo all_to_all (1); the
        # split-phase overlap read serializes behind none (0).
        ser = 0 if self.ex.overlap else 1
        if fuse is None:
            fuse = getattr(program, "fusable", False)
        if fuse:
            d0 = directive if directive is not None else jnp.int32(0)
            fn = self._fused_fn(program)
            wstate, mstate, n = fn(
                wstate, self.ex.deg, self.ex.node_mask, mstate, d0,
                jnp.int32(max_supersteps), *self.ex._tables)
            # per-superstep message sizes are static: reconstruct the trace
            # in one bulk extend, metering the *initial* directive (as
            # BladygEngine.run_jit does) and the abstract summary shape.
            stats = BladygEngine._meter(
                self._summary_shape(program, wstate, d0), directive, w2w)
            (n_steps,) = jax.device_get((n,))
            self.traces.extend(
                SuperstepTrace(s, modes, stats, serialized_collectives=ser)
                for s in range(int(n_steps)))
            return wstate, mstate

        step = self._step_fn(program)
        it = 0
        while it < max_supersteps:
            # None directives still need an array through shard_map; the
            # metering sees the real (None) directive.
            d = directive if directive is not None else jnp.int32(0)
            wstate, summary = step(
                wstate, self.ex.deg, self.ex.node_mask, d, *self.ex._tables)
            mstate, directive, halt = program.master_compute(mstate, summary)
            self.traces.append(SuperstepTrace(
                it, modes, BladygEngine._meter(summary, directive, w2w),
                serialized_collectives=ser))
            it += 1
            if bool(halt):
                break
        return wstate, mstate

    def message_totals(self):
        from ..core.engine import MessageStats

        tot = MessageStats()
        for t in self.traces:
            tot = tot + t.stats
        return tot


# ---------------------------------------------------------------------------
# Functional entry points (what `kernels.ops` dispatches to).
# ---------------------------------------------------------------------------


def coreness_spmd(g, W: Optional[int] = None, max_steps: int = 10_000,
                  executor: Optional[SpmdExecutor] = None) -> jax.Array:
    """Full coreness on the worker mesh — bit-identical to the jnp path."""
    ex = executor if executor is not None else SpmdExecutor(g, W=W)
    est, _ = ex.coreness(max_steps=max_steps)
    return est


def hindex_spmd(g, est, W: Optional[int] = None,
                executor: Optional[SpmdExecutor] = None) -> jax.Array:
    """One h-index superstep on the mesh.  Builds an executor per call —
    loops should construct `SpmdExecutor` once and call `.hindex`."""
    ex = executor if executor is not None else SpmdExecutor(g, W=W)
    return ex.hindex(est)


def frontier_spmd(g, f, eligible, visited, W: Optional[int] = None,
                  executor: Optional[SpmdExecutor] = None) -> jax.Array:
    """One masked BFS hop on the mesh (eligible may be (N,) or (N, R))."""
    ex = executor if executor is not None else SpmdExecutor(g, W=W)
    if eligible.ndim == 1:
        eligible = jnp.broadcast_to(eligible[:, None], f.shape)
    return ex.frontier(f, eligible, visited)
