"""Fault-tolerant checkpointing (atomic, keep-N, async, elastic remesh)."""
from .manager import CheckpointManager
from .elastic import remesh_restore, save_train_state

__all__ = ["CheckpointManager", "remesh_restore", "save_train_state"]
