"""Fault-tolerant checkpointing for the graph runtime: atomic keep-N
snapshots of `GraphBlocks` + analytics + stream-session state, restorable
onto a different mesh shape (elastic remesh after worker loss)."""
from .manager import CheckpointManager
from .elastic import (remesh_restore, restore_session, save_session,
                      save_train_state)

__all__ = ["CheckpointManager", "remesh_restore", "restore_session",
           "save_session", "save_train_state"]
