"""Elastic restart: resume a graph stream on a different mesh shape.

Worker-loss runbook (simulated on CPU in tests/test_faults.py):

  1. A worker drops out mid-stream (preemption, hardware loss) — its
     shards are gone.  The coordinator (`runtime.recovery`) stops
     feeding windows.
  2. The surviving topology restarts: `restore_session` rebuilds the
     stream session from the last COMMITTED snapshot.  Checkpoints are
     topology-independent (full logical arrays, see manager.py), so the
     restore may target ANY worker count with W | P — pass `W` to remesh
     onto the survivors; node arrays are placed with the new mesh's
     leading-axis sharding.
  3. The coordinator re-assigns the dead worker's blocks across the
     survivors (`StreamSession.migrate` — the §4.2 permutation
     machinery) and replays the window-log tail recorded since the
     snapshot.  Replay is deterministic and the snapshot carries the
     composed id remap, so the recovered state is bit-identical to a
     run that never crashed (see `runtime.recovery.recover_worker`).

The same path implements scale-UP (new devices join): restore with a
larger `W`.  Snapshots carry everything `StreamSession.state_dict` /
`MirrorStream.state_dict` emit — graph blocks, maintained analytics
(coreness / CC labels), the open-time id remap, and every counter — plus
the capacities (P, Cn, Cd) in the manifest meta, so a restore works even
after capacity escalations the restoring process never saw.
"""
from __future__ import annotations

from typing import Optional, Tuple

from .manager import CheckpointManager


def save_session(mgr: CheckpointManager, session, step: Optional[int] = None,
                 blocking: bool = True, extra_meta: Optional[dict] = None
                 ) -> int:
    """Snapshot a `StreamSession` / `MirrorStream` at `step` (default:
    its `windows_applied` clock).  `extra_meta` (JSON-able) rides along
    under meta["extra"] — the recovery coordinator stores its window-log
    cursor there.  Returns the step saved."""
    arrays, meta = session.state_dict()
    if extra_meta is not None:
        meta = {**meta, "extra": extra_meta}
    if step is None:
        step = int(session.windows_applied)
    mgr.save(step, arrays, blocking=blocking, meta=meta)
    return step


def restore_session(mgr: CheckpointManager, step: Optional[int] = None,
                    W=None, backend: Optional[str] = None,
                    executor=None) -> Tuple[int, object, dict]:
    """Rebuild a stream session from the latest (or given) committed
    snapshot — onto a possibly DIFFERENT mesh shape.

    `W`/`backend`/`executor` override the snapshot's topology (the
    remesh path; W must divide the snapshot's P).  Under the SPMD
    backend the graph's node arrays are device_put with the new worker
    mesh's leading-axis sharding before the session adopts them.
    Returns ``(step, session, meta)``; meta is the manifest meta (the
    coordinator reads its log cursor out of meta.get("extra")).
    """
    if step is None:
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint to restore in {mgr.dir}")
    meta = mgr.load_meta(step)
    if not meta or "kind" not in meta:
        raise ValueError(
            f"step {step} carries no session meta; was it saved with "
            "save_session?")
    shardings = None
    if meta["kind"] == "stream_session":
        from ..core.kcore_dynamic import SPMD_BACKEND
        be = meta["backend"] if backend is None else backend
        if be == SPMD_BACKEND:
            shardings = _node_shardings(meta, W)
    arrays = mgr.restore_dict(step, shardings=shardings)
    if meta["kind"] == "mirror_stream":
        from ..runtime.stream import MirrorStream
        session = MirrorStream.from_state(arrays, meta, backend=backend)
    elif meta["kind"] == "stream_session":
        from ..runtime.stream import StreamSession
        session = StreamSession.from_state(
            arrays, meta, W=W, backend=backend, executor=executor)
    else:
        raise ValueError(f"unknown snapshot kind {meta['kind']!r}")
    return step, session, meta


#: restore_session IS the remesh path — the alias documents intent at
#: call sites that restore onto a different worker count after a loss
remesh_restore = restore_session


def _node_shardings(meta: dict, W) -> Optional[dict]:
    """Leading-axis shardings for the graph's node arrays on the restore
    mesh (None when the mesh would be trivial)."""
    import jax

    from ..runtime.mesh import best_worker_count, make_worker_mesh

    P, Cn = int(meta["P"]), int(meta["Cn"])
    if W is None:
        W = best_worker_count(P, len(jax.devices()))
    if W <= 1:
        return None

    class _Geom:  # duck-typed GraphBlocks for make_worker_mesh
        pass

    g = _Geom()
    g.P, g.Cn = P, Cn
    sh = make_worker_mesh(g, W=W).node_sharding()
    keys = ("core", "labels", "g.deg", "g.nbr", "g.node_mask", "g.orig_id")
    return {k: sh for k in keys}


def save_train_state(mgr: CheckpointManager, step: int, params, opt_state,
                     blocking: bool = True):
    """Save params and optimizer state as sibling sub-checkpoints (the
    seed-era LLM launch path, kept for `repro.launch.train`)."""
    CheckpointManager(str(mgr.dir / "params"), mgr.keep_n).save(
        step, params, blocking=blocking)
    CheckpointManager(str(mgr.dir / "opt"), mgr.keep_n).save(
        step, opt_state, blocking=blocking)
