"""Elastic restart: resume the same checkpoint on a different mesh.

Node-failure runbook (documented here, simulated on CPU in tests):

  1. A collective times out / heartbeat misses -> the run controller marks
     the slice degraded and tears the job down (distributed/fault.py).
  2. The launcher restarts on the surviving topology (e.g. 15x16 instead of
     16x16, or single-pod instead of 2 pods), passing --resume auto.
  3. `remesh_restore` rebuilds the sharding rules against the NEW mesh and
     restores the latest committed checkpoint onto it.  Because checkpoints
     are topology-independent (full logical arrays, see manager.py), no
     reshard preprocessing job is needed.
  4. The data pipeline cursor (saved with the train state) makes batch
     delivery exactly-once across the restart.

The same path implements scale-UP (new nodes join): restore onto the larger
mesh and continue.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.distributed import sharding as SH
from .manager import CheckpointManager


def remesh_restore(
    mgr: CheckpointManager,
    step: Optional[int],
    params_like: Any,
    opt_like: Any,
    new_mesh: Mesh,
):
    """Restore (params, opt_state) onto `new_mesh` with recomputed shardings.

    `*_like` are pytrees of ShapeDtypeStruct or arrays describing the target
    structure (e.g. from jax.eval_shape of init on the new mesh).
    """
    if step is None:
        step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoint to restore in {mgr.dir}")
    pshard = SH.param_shardings(params_like, new_mesh)
    params = mgr_restore_tree(mgr, step, "params", params_like, pshard)
    oshard = SH.opt_shardings(opt_like, params_like, new_mesh)
    opt = mgr_restore_tree(mgr, step, "opt", opt_like, oshard)
    return step, params, opt


def mgr_restore_tree(mgr: CheckpointManager, step: int, name: str, like, shardings):
    sub = CheckpointManager(str(mgr.dir / name), keep_n=mgr.keep_n)
    return sub.restore(step, like, shardings)


def save_train_state(mgr: CheckpointManager, step: int, params, opt_state,
                     blocking: bool = True):
    """Save params and optimizer state as sibling sub-checkpoints."""
    CheckpointManager(str(mgr.dir / "params"), mgr.keep_n).save(
        step, params, blocking=blocking)
    CheckpointManager(str(mgr.dir / "opt"), mgr.keep_n).save(
        step, opt_state, blocking=blocking)
