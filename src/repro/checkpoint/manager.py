"""Checkpointing: atomic, keep-N, topology-independent, async-capable.

Layout (one directory per step):

    <dir>/step_000120/
        manifest.json        # leaf paths, shapes, dtypes, pytree structure
        leaf_00000.npy ...   # one file per leaf (full/unsharded logical array)
        COMMIT               # written last: marks the checkpoint complete

Atomicity: leaves + manifest are written into ``step_XXXX.tmp`` and renamed
to ``step_XXXX`` after the COMMIT marker is in place — a crashed save can
never be mistaken for a valid checkpoint.

Topology independence / elastic restart: leaves are saved as full logical
arrays, so a restore may target ANY mesh — `restore(..., shardings=...)`
device_puts each leaf with the new sharding (see elastic.py for the
remesh-after-failure path).  For multi-host production this generalizes to
per-host shard files keyed by shard index; the manifest format already
carries shape/dtype per leaf to support that extension.

Async: `save(..., blocking=False)` snapshots to host memory synchronously
(cheap) and writes files on a daemon thread, overlapping I/O with the next
training steps.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, List, Optional

import jax
import numpy as np

Params = Any


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    # one in-flight async writer per directory, across manager instances
    _threads: dict = {}
    _lock = threading.Lock()

    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n

    @property
    def _thread(self) -> Optional[threading.Thread]:
        return CheckpointManager._threads.get(str(self.dir.resolve()))

    @_thread.setter
    def _thread(self, t: Optional[threading.Thread]):
        with CheckpointManager._lock:
            CheckpointManager._threads[str(self.dir.resolve())] = t

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Params, blocking: bool = True,
             meta: Optional[dict] = None):
        """Snapshot `tree` at `step`.  Non-blocking saves copy to host first.

        `meta` (JSON-able dict) rides in the manifest — the graph path
        stores session statics/counters there so a restore needs NO
        pre-built `like` template (`restore_dict` + `load_meta`): crash
        recovery cannot know the capacities the stream had grown to.
        When `tree` is a flat dict of arrays, the manifest also records
        the key order, making the checkpoint fully self-describing.
        """
        flat, treedef = _flatten_with_paths(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in flat]
        treedef_str = str(treedef)
        keys = (sorted(str(k) for k in tree)
                if isinstance(tree, dict) and len(tree) == len(flat)
                else None)

        if self._thread is not None:
            self._thread.join()  # one in-flight async save at a time

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "treedef": treedef_str,
                        "leaves": []}
            if keys is not None:
                manifest["keys"] = keys
            if meta is not None:
                manifest["meta"] = meta
            for i, leaf in enumerate(host_leaves):
                np.save(tmp / f"leaf_{i:05d}.npy", leaf)
                manifest["leaves"].append(
                    {"i": i, "shape": list(leaf.shape), "dtype": str(leaf.dtype)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMIT").write_text("ok")
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "COMMIT").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _manifest(self, step: int) -> dict:
        d = self.dir / f"step_{step:08d}"
        if not (d / "COMMIT").exists():
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        return json.loads((d / "manifest.json").read_text())

    def load_meta(self, step: int) -> Optional[dict]:
        """The `meta` dict saved with `step` (None if none was)."""
        return self._manifest(step).get("meta")

    def restore_dict(self, step: int, shardings: Optional[dict] = None
                     ) -> dict:
        """Restore a flat-dict checkpoint WITHOUT a `like` template.

        Only valid for checkpoints saved from a flat dict of arrays (the
        manifest then carries the key order) — the elastic graph path:
        shapes/dtypes come from the files themselves, so the caller need
        not know what capacities the graph had grown to.  `shardings`
        optionally maps keys to NamedShardings for placement on a new
        mesh; unlisted keys get default placement.
        """
        manifest = self._manifest(step)
        keys = manifest.get("keys")
        if keys is None:
            raise ValueError(
                f"step {step} was not saved from a flat dict; use "
                "restore(step, like) with a structure template")
        d = self.dir / f"step_{step:08d}"
        out = {}
        for i, k in enumerate(keys):
            arr = np.load(d / f"leaf_{i:05d}.npy")
            sh = (shardings or {}).get(k)
            out[k] = (jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
        return out

    def restore(self, step: int, like: Params, shardings: Params = None) -> Params:
        """Restore into the structure of `like` (shapes validated).

        `shardings`: optional pytree of NamedSharding — the elastic-restart
        path: the same checkpoint restores onto any mesh.
        """
        d = self.dir / f"step_{step:08d}"
        if not (d / "COMMIT").exists():
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        if len(manifest["leaves"]) != len(flat_like):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"model expects {len(flat_like)} — architecture mismatch")
        flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat_like))
        out = []
        for i, (ref, sh) in enumerate(zip(flat_like, flat_sh)):
            arr = np.load(d / f"leaf_{i:05d}.npy")
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
            arr = arr.astype(ref.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return treedef.unflatten(out)
