"""Partitioners — the BLADYG `partitioner worker`.

The paper ships four predefined techniques (hash, random, vertex-cut,
edge-cut) and lets users plug others (METIS, JaBeJa, DFEP).  We implement:

*Node partitioners* (used by k-core / degree tasks, where a block is a set of
nodes + their adjacency):
    - ``node_hash_partition``   — hash(node) % P
    - ``node_random_partition`` — balanced random
    - ``node_bfs_partition``    — balanced multi-source BFS growth (edge-cut
      flavored: connected, near-equal blocks, few crossing edges)

*Edge partitioners* (used by the dynamic-partitioning experiments, Tables
3-5, where the unit being assigned is an edge):
    - ``edge_hash_partition``, ``edge_random_partition``
    - ``vertex_cut_greedy``     — the PowerGraph greedy heuristic (paper §2)
    - ``vertex_cut_update``     — greedy continuation over new edges only
      (the IncrementalPart counterpart of ``vertex_cut_greedy``)
    - ``dfep``                  — funding-based Distributed Edge Partitioning
      [Guerrieri & Montresor, Europar'15], vectorized rounds
    - ``ub_update``             — DynamicDFEP's Unit-Based incremental
      assignment of new edges [Sakouhi et al., IDEAS'16]

Partitioning is setup/orchestration work (the paper's `partitioner worker`
runs once, on ingest), so these run host-side in NumPy; the *maintenance*
hot paths are the jitted functions in `partition_dynamic.py` / `kcore_dynamic.py`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "node_hash_partition",
    "node_random_partition",
    "node_bfs_partition",
    "edge_hash_partition",
    "edge_random_partition",
    "vertex_cut_greedy",
    "vertex_cut_update",
    "dfep",
    "ub_update",
    "edge_balance",
]


# ---------------------------------------------------------------------------
# Node partitioners
# ---------------------------------------------------------------------------

def node_hash_partition(n: int, P: int, seed: int = 0) -> np.ndarray:
    """Deterministic multiplicative hash of node id -> block."""
    ids = np.arange(n, dtype=np.uint64)
    key = np.uint64(0x9E3779B97F4A7C15 + 2 * seed + 1)
    h = (ids * key) >> np.uint64(17)
    return np.asarray(h % np.uint64(P), dtype=np.int64)


def node_random_partition(n: int, P: int, seed: int = 0) -> np.ndarray:
    """Balanced random: a shuffled round-robin."""
    rng = np.random.default_rng(seed)
    assign = np.arange(n, dtype=np.int64) % P
    rng.shuffle(assign)
    return assign


def node_bfs_partition(
    edges: np.ndarray, n: int, P: int, seed: int = 0
) -> np.ndarray:
    """Balanced multi-source BFS growth (edge-cut style).

    P random seeds grow in rounds; each block stops claiming at capacity
    ceil(n/P).  Unreached nodes (other components) go to the smallest blocks.
    """
    rng = np.random.default_rng(seed)
    cap = -(-n // P)
    adj_head, adj_next, adj_dst = _csr_ish(edges, n)
    assign = np.full(n, -1, dtype=np.int64)
    size = np.zeros(P, dtype=np.int64)
    seeds = rng.choice(n, size=min(P, n), replace=False)
    frontiers = []
    for p, s in enumerate(seeds):
        if assign[s] == -1:
            assign[s] = p
            size[p] += 1
            frontiers.append([s])
        else:
            frontiers.append([])
    active = True
    while active:
        active = False
        for p in range(P):
            if size[p] >= cap or not frontiers[p]:
                continue
            nxt = []
            for u in frontiers[p]:
                e = adj_head[u]
                while e != -1:
                    v = adj_dst[e]
                    e = adj_next[e]
                    if assign[v] == -1 and size[p] < cap:
                        assign[v] = p
                        size[p] += 1
                        nxt.append(v)
            frontiers[p] = nxt
            if nxt:
                active = True
    left = np.flatnonzero(assign == -1)
    for u in left:
        p = int(np.argmin(size))
        assign[u] = p
        size[p] += 1
    return assign


def _csr_ish(edges: np.ndarray, n: int):
    """Linked-list adjacency (head/next arrays) — O(m) build, no sorting."""
    m2 = 2 * len(edges)
    adj_head = np.full(n, -1, dtype=np.int64)
    adj_next = np.full(m2, -1, dtype=np.int64)
    adj_dst = np.empty(m2, dtype=np.int64)
    k = 0
    for a, b in edges:
        adj_dst[k] = b
        adj_next[k] = adj_head[a]
        adj_head[a] = k
        k += 1
        adj_dst[k] = a
        adj_next[k] = adj_head[b]
        adj_head[b] = k
        k += 1
    return adj_head, adj_next, adj_dst


# ---------------------------------------------------------------------------
# Edge partitioners
# ---------------------------------------------------------------------------

def edge_hash_partition(edges: np.ndarray, P: int, seed: int = 0) -> np.ndarray:
    lo = np.minimum(edges[:, 0], edges[:, 1]).astype(np.uint64)
    hi = np.maximum(edges[:, 0], edges[:, 1]).astype(np.uint64)
    key = np.uint64(0x9E3779B97F4A7C15 + 2 * seed + 1)
    h = (lo * key ^ (hi + np.uint64(0x517CC1B727220A95))) * key
    return np.asarray((h >> np.uint64(19)) % np.uint64(P), dtype=np.int64)


def edge_random_partition(edges: np.ndarray, P: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    assign = np.arange(len(edges), dtype=np.int64) % P
    rng.shuffle(assign)
    return assign


def _vertex_cut_assign(
    edges: np.ndarray,
    parts_of: list,
    size: np.ndarray,
    remaining: np.ndarray,
    start: int,
    P: int,
    balance_slack: float,
) -> np.ndarray:
    """The greedy vertex-cut inner loop over `edges`, continuing from the
    given per-node partition sets / sizes, with the running capacity
    indexed from global edge position `start` (so a continuation is
    bit-identical to the static greedy over the concatenated stream)."""
    out = np.empty(len(edges), dtype=np.int64)
    for j, (u, v) in enumerate(edges):
        cap = balance_slack * ((start + j) / P) + 1.0
        pu, pv = parts_of[u], parts_of[v]

        def pick(cands):
            ok = [q for q in cands if size[q] < cap]
            if ok:
                return min(ok, key=lambda q: size[q])
            return int(np.argmin(size))

        common = pu & pv
        if common:
            p = pick(common)
        elif pu and pv:
            picker = u if remaining[u] >= remaining[v] else v
            p = pick(parts_of[picker])
        elif pu or pv:
            p = pick(pu or pv)
        else:
            p = int(np.argmin(size))
        out[j] = p
        size[p] += 1
        pu.add(p)
        pv.add(p)
        remaining[u] -= 1
        remaining[v] -= 1
    return out


def vertex_cut_greedy(
    edges: np.ndarray, n: int, P: int, balance_slack: float = 1.1
) -> np.ndarray:
    """PowerGraph greedy vertex-cut (paper §2 description, processed in order).

    Rules for edge (u, v):
      1. common partition of u and v -> that partition (least loaded of them)
      2. only one endpoint placed   -> one of its partitions (least loaded)
      3. both placed, disjoint      -> endpoint with more remaining edges
                                       picks its least-loaded partition
      4. neither placed             -> globally least-loaded partition

    A capacity bound (`balance_slack` x running mean) guards against the
    known degeneracy of the pure greedy on ordered edge streams (growth
    models feed every new edge an already-placed endpoint, cascading all
    edges into partition 0); over-full candidates fall back to rule 4 — the
    standard greedy-with-capacity variant.
    """
    edges = np.asarray(edges, dtype=np.int64)
    remaining = np.zeros(n, dtype=np.int64)
    np.add.at(remaining, edges[:, 0], 1)
    np.add.at(remaining, edges[:, 1], 1)
    parts_of = [set() for _ in range(n)]
    size = np.zeros(P, dtype=np.int64)
    return _vertex_cut_assign(
        edges, parts_of, size, remaining, 0, P, balance_slack)


def vertex_cut_update(
    edges: np.ndarray,
    owner: np.ndarray,
    new_edges: np.ndarray,
    n: int,
    P: int,
    balance_slack: float = 1.1,
) -> np.ndarray:
    """Greedy vertex-cut *continuation*: assign only `new_edges`, resuming
    from the state the static greedy would hold after `edges`/`owner`.

    Reconstructs the per-node partition sets and sizes from the existing
    assignment and restarts the greedy with the running-capacity index
    offset by `len(edges)`.  `remaining` at that point counts only the
    not-yet-processed (new) edges — exactly the static greedy's state at
    index `len(edges)` of the concatenated stream.  Parity contract:
    `vertex_cut_greedy(concat(edges, new))` equals
    `concat(owner, vertex_cut_update(...))` bit-for-bit whenever `owner`
    is that static run's own prefix assignment.  (A greedy run over the
    prefix *alone* is a different state — its `remaining` tie-break never
    saw the future edges — so chaining `initial_partition` +
    `incremental_part` matches the heuristic, not necessarily the
    one-shot static output.)  Never touches the existing assignment
    either way, which is the IncrementalPart contract.
    """
    edges = np.asarray(edges, dtype=np.int64)
    new_edges = np.asarray(new_edges, dtype=np.int64)
    owner = np.asarray(owner, dtype=np.int64)
    parts_of = [set() for _ in range(n)]
    for (u, v), p in zip(edges, owner):
        parts_of[u].add(int(p))
        parts_of[v].add(int(p))
    size = np.bincount(owner, minlength=P).astype(np.int64)
    remaining = np.zeros(n, dtype=np.int64)
    if len(new_edges):
        np.add.at(remaining, new_edges[:, 0], 1)
        np.add.at(remaining, new_edges[:, 1], 1)
    return _vertex_cut_assign(
        new_edges, parts_of, size, remaining, len(edges), P, balance_slack)


def dfep(
    edges: np.ndarray,
    n: int,
    P: int,
    seed: int = 0,
    init_funding: float = 10.0,
    round_funding: float = 10.0,
    max_rounds: int = 10_000,
) -> np.ndarray:
    """DFEP — funding-based distributed edge partitioning (vectorized rounds).

    Faithful to the paper's 4-step description (§5.2.2): random seed node per
    partition with initial funding; partitions buy adjacent unowned edges
    with funding; the coordinator tops partitions up inversely proportional
    to their size; repeat until all edges are bought.  Conflicts in a round
    resolve in favor of the currently-smallest partition.  Edges unreachable
    from any seed (other components) are swept to the smallest partitions at
    the end, as in the reference implementation.
    """
    edges = np.asarray(edges, dtype=np.int64)
    m = len(edges)
    rng = np.random.default_rng(seed)
    owner = np.full(m, -1, dtype=np.int64)
    funding = np.full(P, float(init_funding))
    size = np.zeros(P, dtype=np.int64)

    in_part = np.zeros((P, n), dtype=bool)  # vertex touched by partition p
    seeds = rng.choice(n, size=min(P, n), replace=False)
    for p, s in enumerate(seeds):
        in_part[p, s] = True

    u_end, v_end = edges[:, 0], edges[:, 1]
    for _ in range(max_rounds):
        if (owner >= 0).all():
            break
        progress = False
        unowned = owner == -1
        for p in np.argsort(size, kind="stable"):  # smallest spends first
            budget = int(funding[p])
            if budget <= 0:
                continue
            # adjacent unowned edges: either endpoint touched by p (O(m) vec)
            cand = np.flatnonzero(
                unowned & (in_part[p][u_end] | in_part[p][v_end]))
            if not len(cand):
                continue
            buy = cand[:budget] if len(cand) > budget else cand
            owner[buy] = p
            unowned[buy] = False
            funding[p] -= len(buy)
            size[p] += len(buy)
            ends = edges[buy].reshape(-1)
            in_part[p, ends] = True
            progress = True
        # coordinator: top-up inversely proportional to size
        mean_size = max(1.0, float(size.mean()))
        funding += round_funding * mean_size / np.maximum(size, 1)
        if not progress:
            # everything reachable is bought; sweep stragglers
            left = np.flatnonzero(owner == -1)
            for e in left:
                p = int(np.argmin(size))
                owner[e] = p
                size[p] += 1
                in_part[p, edges[e]] = True
            break
    left = np.flatnonzero(owner == -1)
    for e in left:
        p = int(np.argmin(size))
        owner[e] = p
        size[p] += 1
    return owner


def ub_update(
    edges: np.ndarray,
    owner: np.ndarray,
    new_edges: np.ndarray,
    n: int,
    P: int,
) -> np.ndarray:
    """Unit-Based incremental assignment (DynamicDFEP UB-UPDATE flavor).

    Each new edge goes to the partition that already owns the most edges
    incident to its endpoints (ties -> smaller partition); if no endpoint is
    known, to the globally smallest partition.  O(new · deg) — never touches
    the existing assignment, which is the whole point (IncrementalPart).
    """
    owner = np.asarray(owner)
    size = np.bincount(owner, minlength=P).astype(np.int64)
    # per-node partition histograms (sparse dict-of-rows to stay O(m))
    node_part = [dict() for _ in range(n)]
    for (u, v), p in zip(np.asarray(edges, dtype=np.int64), owner):
        node_part[u][p] = node_part[u].get(p, 0) + 1
        node_part[v][p] = node_part[v].get(p, 0) + 1
    out = np.empty(len(new_edges), dtype=np.int64)
    for i, (u, v) in enumerate(np.asarray(new_edges, dtype=np.int64)):
        score: dict = {}
        for d in (node_part[u], node_part[v]):
            for p, c in d.items():
                score[p] = score.get(p, 0) + c
        if score:
            best = min(score.items(), key=lambda kv: (-kv[1], size[kv[0]]))[0]
        else:
            best = int(np.argmin(size))
        out[i] = best
        size[best] += 1
        node_part[u][best] = node_part[u].get(best, 0) + 1
        node_part[v][best] = node_part[v].get(best, 0) + 1
    return out


def edge_balance(owner: np.ndarray, P: int) -> float:
    """Imbalance metric: max partition size / mean size (1.0 = perfect)."""
    size = np.bincount(np.asarray(owner), minlength=P)
    return float(size.max() / max(1.0, size.mean()))
