"""Incremental-change plumbing: update batches, scenario sampling, routing.

The paper's "incremental changes are continuously read from the data
sources"; here a deterministic sampler produces the two experimental
scenarios of §5.2.1:

  * inter-partition — endpoints in *different* blocks,
  * intra-partition — endpoints in *the same* block,

for both insertions (non-adjacent pairs) and deletions (existing edges).
`apply_updates_host` is the checked host boundary: capacity / duplicate /
existence validation happens here, never on the TPU path.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .graph import CapacityError, GraphBlocks, insert_edge, delete_edge, PAD

Update = Tuple[int, int, int]  # (u, v, op)  op=+1 insert, -1 delete


def classify(g: GraphBlocks, u: int, v: int) -> str:
    return "intra" if (u // g.Cn) == (v // g.Cn) else "inter"


def _real_nodes_by_block(g: GraphBlocks) -> List[np.ndarray]:
    mask = np.asarray(g.node_mask)
    ids = np.arange(g.N)
    return [ids[(ids // g.Cn == b) & mask] for b in range(g.P)]


def _adjacent(nbr_np: np.ndarray, u: int, v: int) -> bool:
    return bool((nbr_np[u] == v).any())


def sample_insertions(
    g: GraphBlocks, count: int, scenario: str, seed: int = 0
) -> List[Update]:
    """Sample `count` non-adjacent node pairs for insertion.

    scenario: 'intra' -> same block, 'inter' -> different blocks.
    """
    rng = np.random.default_rng(seed)
    nbr_np = np.asarray(g.nbr)
    by_block = _real_nodes_by_block(g)
    nonempty = [b for b in range(g.P) if len(by_block[b]) >= 1]
    out: List[Update] = []
    taken: set = set()
    guard = 0
    while len(out) < count:
        guard += 1
        if guard > count * 1000:
            raise RuntimeError(f"could not sample {count} {scenario} insertions")
        if scenario == "intra":
            b = int(rng.choice([b for b in nonempty if len(by_block[b]) >= 2]))
            u, v = rng.choice(by_block[b], size=2, replace=False)
        else:
            b1, b2 = rng.choice(nonempty, size=2, replace=False)
            u = int(rng.choice(by_block[b1]))
            v = int(rng.choice(by_block[b2]))
        u, v = int(u), int(v)
        key = (min(u, v), max(u, v))
        if u == v or key in taken or _adjacent(nbr_np, u, v):
            continue
        taken.add(key)
        out.append((u, v, +1))
    return out


def sample_deletions(
    g: GraphBlocks, count: int, scenario: str, seed: int = 0
) -> List[Update]:
    """Sample `count` existing edges to delete, by scenario."""
    rng = np.random.default_rng(seed)
    nbr_np = np.asarray(g.nbr)
    src = np.repeat(np.arange(g.N), g.Cd)
    dst = nbr_np.reshape(-1)
    ok = (dst >= 0) & (src < dst)
    src, dst = src[ok], dst[ok]
    same = (src // g.Cn) == (dst // g.Cn)
    pick = same if scenario == "intra" else ~same
    src, dst = src[pick], dst[pick]
    if len(src) < count:
        raise RuntimeError(
            f"only {len(src)} {scenario} edges available, need {count}"
        )
    idx = rng.choice(len(src), size=count, replace=False)
    return [(int(src[i]), int(dst[i]), -1) for i in idx]


def _insert_sorted(nbr: np.ndarray, deg: np.ndarray, u: int, v: int) -> None:
    """Splice v into row u at its sorted position (sorted-ELL invariant)."""
    d = deg[u]
    pos = int(np.searchsorted(nbr[u, :d], v))
    nbr[u, pos + 1:d + 1] = nbr[u, pos:d]
    nbr[u, pos] = v
    deg[u] += 1


def _delete_sorted(nbr: np.ndarray, deg: np.ndarray, u: int, v: int) -> None:
    """Remove v from row u, shifting left over the hole (invariant kept)."""
    d = deg[u]
    pos = int(np.searchsorted(nbr[u, :d], v))
    nbr[u, pos:d - 1] = nbr[u, pos + 1:d]
    nbr[u, d - 1] = PAD
    deg[u] -= 1


def apply_updates_host(g: GraphBlocks, updates: List[Update]) -> GraphBlocks:
    """Apply updates with host-side validation (capacity, dup, existence).

    Produces the same sorted canonical rows as the jitted
    `insert_edge`/`delete_edge` path, so replaying a batch through either
    path yields bit-identical `nbr` arrays.
    """
    deg = np.asarray(g.deg).copy()
    nbr = np.asarray(g.nbr).copy()
    for u, v, op in updates:
        if not (0 <= u < g.N and 0 <= v < g.N):
            # negative ids would silently wrap under numpy/jax indexing
            raise ValueError(f"update ({u},{v}) out of range [0, {g.N})")
        if u == v:
            # the jitted insert_edge/delete_edge assume no self-loops (module
            # invariant of graph.py); reject here, at the host boundary
            raise ValueError(f"self-loop update ({u},{v}) rejected")
        if op > 0:
            if (nbr[u] == v).any():
                raise ValueError(f"edge ({u},{v}) already present")
            if deg[u] >= g.Cd or deg[v] >= g.Cd:
                raise CapacityError(
                    f"degree capacity Cd={g.Cd} exceeded at ({u},{v})")
            _insert_sorted(nbr, deg, u, v)
            _insert_sorted(nbr, deg, v, u)
        else:
            if not (nbr[u] == v).any():
                raise ValueError(f"edge ({u},{v}) not present")
            _delete_sorted(nbr, deg, u, v)
            _delete_sorted(nbr, deg, v, u)
    import dataclasses

    return dataclasses.replace(
        g, nbr=jnp.asarray(nbr), deg=jnp.asarray(deg.astype(np.int32))
    )
