"""Block-partitioned graph representation for BLADYG-on-TPU.

The paper's *block* (a connected subgraph held by one Akka worker) becomes a
fixed-capacity, padded array shard:

- Nodes are **relabeled block-contiguously**: block ``b`` owns the global
  padded index range ``[b*Cn, (b+1)*Cn)``.  ``block_of(u) = u // Cn`` — no
  lookup tables on the hot path, and sharding the leading axis of every node
  array over the ``workers`` mesh axis gives exactly one block per device.
- Adjacency is **ELL-padded**: ``nbr[N_pad, Cd]`` holds global padded
  neighbor ids, ``-1`` for padding.  Undirected edges are stored twice (once
  per endpoint), matching the degree semantics of the paper.
- Rows obey the **sorted-ELL invariant**: the valid slots of every row are
  in strictly ascending id order and the ``-1`` pads sit on the right
  (``nbr[u, :deg[u]]`` ascending, ``nbr[u, deg[u]:] == PAD``).  Every
  construction and mutation path (`build_blocks`, `build_ell_random`,
  `insert_edge`, `delete_edge`, `apply_updates_host`, `migrate_vertices`)
  maintains it, so sorted rows are the *canonical* form: the host and jitted
  update paths produce bit-identical arrays, and kernels may binary-search
  or merge-intersect neighbor rows instead of scanning them linearly.
- All shapes are static (``jit``/``shard_map`` friendly).  Capacity overflow
  is checked at the host boundary (`build_blocks`, `apply_updates_host`) and
  raises — the TPU path never reallocates.

This is the TPU-native analogue of the paper's per-worker hash-map state: the
price is padding, the payoff is that every BLADYG superstep is a dense,
statically-shaped SPMD program.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = -1  # padding sentinel for neighbor slots / node ids

#: sort key for PAD slots — larger than any node id, so an ascending sort
#: leaves valid ids first (in order) and pads on the right
_PAD_KEY = np.iinfo(np.int32).max


class CapacityError(ValueError):
    """An operation needs more node (Cn) or degree (Cd) capacity than the
    blocks hold.  Subclasses ValueError so existing overflow handling keeps
    working; the elastic stream path catches this specifically to grow."""


def sort_nbr_rows(nbr: np.ndarray) -> np.ndarray:
    """Canonicalize ELL rows to the sorted-ELL invariant (host-side).

    Maps pads to +inf (int32 max), sorts each row ascending, and maps the
    pads back — valid slots end up ascending with pads on the right.  A
    no-op on rows that already satisfy the invariant.
    """
    keyed = np.where(nbr >= 0, nbr, _PAD_KEY)
    keyed = np.sort(keyed, axis=-1)
    return np.where(keyed == _PAD_KEY, PAD, keyed).astype(nbr.dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBlocks:
    """A block-partitioned undirected graph with static capacities.

    Attributes
    ----------
    nbr:       (P*Cn, Cd) int32 — padded neighbor lists (global padded ids).
    deg:       (P*Cn,)    int32 — true degree of each node (0 for padding).
    node_mask: (P*Cn,)    bool  — True for real nodes.
    orig_id:   (P*Cn,)    int32 — original node id (PAD for padding rows).
    P, Cn, Cd: static ints — #blocks, node capacity / block, degree capacity.
    """

    nbr: jax.Array
    deg: jax.Array
    node_mask: jax.Array
    orig_id: jax.Array
    P: int = dataclasses.field(metadata=dict(static=True))
    Cn: int = dataclasses.field(metadata=dict(static=True))
    Cd: int = dataclasses.field(metadata=dict(static=True))

    # ---- static helpers -------------------------------------------------
    @property
    def N(self) -> int:
        """Padded node count (P*Cn)."""
        return self.P * self.Cn

    def block_of(self, u):
        return u // self.Cn

    @property
    def n_real(self) -> int:
        return int(np.asarray(jnp.sum(self.node_mask)))

    @property
    def m_real(self) -> int:
        return int(np.asarray(jnp.sum(self.deg))) // 2

    def valid_nbr_mask(self) -> jax.Array:
        return self.nbr >= 0

    def is_boundary(self) -> jax.Array:
        """True for nodes with at least one neighbor in another block."""
        nb_block = jnp.where(self.nbr >= 0, self.nbr // self.Cn, PAD)
        own = (jnp.arange(self.N) // self.Cn)[:, None]
        return jnp.any((nb_block != own) & (self.nbr >= 0), axis=1)

    def edge_cut(self) -> jax.Array:
        """Number of undirected edges crossing blocks."""
        nb_block = self.nbr // self.Cn
        own = (jnp.arange(self.N) // self.Cn)[:, None]
        cross = (nb_block != own) & (self.nbr >= 0)
        return jnp.sum(cross) // 2

    def grow(self, Cn: Optional[int] = None, Cd: Optional[int] = None):
        """Capacity escalation — see `grow_blocks`.  Returns (g2, rekey)."""
        return grow_blocks(self, Cn, Cd)


def _relabel(
    n: int, assign: np.ndarray, P: int, Cn: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Map original ids -> block-contiguous padded ids.

    Returns (new_of_old (n,), old_of_new (P*Cn,)).
    """
    new_of_old = np.full(n, PAD, dtype=np.int64)
    old_of_new = np.full(P * Cn, PAD, dtype=np.int64)
    counts = np.zeros(P, dtype=np.int64)
    order = np.argsort(assign, kind="stable")
    for old in order:
        b = assign[old]
        slot = counts[b]
        if slot >= Cn:
            raise ValueError(
                f"block {b} overflows node capacity Cn={Cn} "
                f"(needs at least {np.sum(assign == b)})"
            )
        new = b * Cn + slot
        new_of_old[old] = new
        old_of_new[new] = old
        counts[b] += 1
    return new_of_old, old_of_new


def build_blocks(
    edges: np.ndarray,
    n: int,
    assign: np.ndarray,
    P: int,
    Cn: Optional[int] = None,
    Cd: Optional[int] = None,
    deg_slack: int = 8,
    node_slack: int = 0,
) -> GraphBlocks:
    """Construct GraphBlocks from an edge list and a node->block assignment.

    Parameters
    ----------
    edges: (m, 2) int array of original node ids (undirected, no dups/loops
           required; they are cleaned here).
    n:     number of original nodes.
    assign:(n,) block id per node in [0, P).
    Cn:    node capacity per block (default: max block population, padded to
           a multiple of 8).
    Cd:    degree capacity (default: max degree + deg_slack) — insertions
           beyond this raise at the host boundary.
    node_slack: extra padding rows reserved per block on top of the default
           Cn (ignored when Cn is given explicitly).  Padding rows are the
           raw material of both `migrate_vertices` destinations and
           `core.hub_split.split_hubs` mirror replicas — split-aware builds
           reserve room here so hub slices can land in their readers'
           blocks without growing Cn (which would re-key every row id).
    """
    edges = np.asarray(edges, dtype=np.int64)
    if edges.size:
        # canonicalize: drop self loops + duplicates
        u, v = edges[:, 0], edges[:, 1]
        keep = u != v
        lo = np.minimum(u[keep], v[keep])
        hi = np.maximum(u[keep], v[keep])
        edges = np.unique(np.stack([lo, hi], 1), axis=0)
    assign = np.asarray(assign, dtype=np.int64)
    assert assign.shape == (n,), (assign.shape, n)
    assert P >= 1 and (assign >= 0).all() and (assign < P).all()

    pop = np.bincount(assign, minlength=P)
    if Cn is None:
        Cn = int(-(-(max(1, pop.max()) + max(0, int(node_slack))) // 8) * 8)
    deg = np.zeros(n, dtype=np.int64)
    if edges.size:
        np.add.at(deg, edges[:, 0], 1)
        np.add.at(deg, edges[:, 1], 1)
    if Cd is None:
        Cd = int(max(1, deg.max()) + deg_slack)
    if deg.size and deg.max() > Cd:
        raise ValueError(f"max degree {deg.max()} exceeds Cd={Cd}")

    new_of_old, old_of_new = _relabel(n, assign, P, Cn)
    N = P * Cn
    nbr = np.full((N, Cd), PAD, dtype=np.int64)
    fill = np.zeros(N, dtype=np.int64)
    for a, b in edges:
        na, nb_ = new_of_old[a], new_of_old[b]
        nbr[na, fill[na]] = nb_
        fill[na] += 1
        nbr[nb_, fill[nb_]] = na
        fill[nb_] += 1
    nbr = sort_nbr_rows(nbr)  # establish the sorted-ELL invariant
    node_mask = old_of_new >= 0

    return GraphBlocks(
        nbr=jnp.asarray(nbr, jnp.int32),
        deg=jnp.asarray(fill, jnp.int32),
        node_mask=jnp.asarray(node_mask),
        orig_id=jnp.asarray(old_of_new, jnp.int32),
        P=P,
        Cn=Cn,
        Cd=Cd,
    )


def _occurrence_ranks(ends: np.ndarray) -> np.ndarray:
    """rank[i] = how many earlier entries of `ends` equal ends[i] (O(m log m))."""
    order = np.argsort(ends, kind="stable")
    s = ends[order]
    starts = np.flatnonzero(np.r_[True, s[1:] != s[:-1]])
    counts = np.diff(np.r_[starts, len(s)])
    grouprank = np.arange(len(s)) - np.repeat(starts, counts)
    rank = np.empty(len(s), np.int64)
    rank[order] = grouprank
    return rank


def build_ell_random(
    N: int, Cd: int = 8, seed: int = 0, m_factor: float = 2.2
) -> GraphBlocks:
    """ER-style random graph built straight into ELL form (single block).

    Skips the edge-list + relabel path of `build_blocks` (too slow beyond
    ~10^5 nodes) by sampling ~m_factor*N node pairs and filling neighbor
    rows with vectorized passes: canonicalize + `np.unique` kills
    self-loops and duplicates, then each pass ranks the surviving pairs
    per endpoint and accepts those whose rank still fits the remaining
    degree capacity; pairs rejected only because an *earlier* pair was
    itself rejected get another chance next pass (the loop ends when a
    pass accepts nothing).  O(m log m) per pass, 2-3 passes in practice —
    minutes-to-seconds at the benchmark N vs the old per-pair Python loop.

    Deterministic per (N, Cd, seed, m_factor).  Structure note: the old
    loop filled rows in raw sample order (first-come, capacity greedy);
    this one processes pairs in canonical sorted order, so the *specific*
    edges kept at capacity pressure differ from the pre-vectorization
    version — same distributional shape, different instance.  Used by
    the large-N benchmarks/tests where the dense (N, N) adjacency is
    infeasible; random structure also keeps the min-H iteration's
    superstep count low (near-ring graphs cascade instead).
    """
    rng = np.random.default_rng(seed)
    uv = rng.integers(0, N, (int(m_factor * N), 2))
    lo = np.minimum(uv[:, 0], uv[:, 1])
    hi = np.maximum(uv[:, 0], uv[:, 1])
    keep = lo != hi
    pending = np.unique(np.stack([lo[keep], hi[keep]], 1), axis=0)

    nbr = np.full((N, Cd), PAD, np.int32)
    deg = np.zeros(N, np.int64)
    while len(pending):
        u, v = pending[:, 0], pending[:, 1]
        ranks = _occurrence_ranks(np.concatenate([u, v]))
        ok = ((deg[u] + ranks[:len(u)] < Cd)
              & (deg[v] + ranks[len(u):] < Cd))
        if not ok.any():
            break
        acc = pending[ok]
        au, av = acc[:, 0], acc[:, 1]
        ranks = _occurrence_ranks(np.concatenate([au, av]))
        nbr[au, deg[au] + ranks[:len(au)]] = av
        nbr[av, deg[av] + ranks[len(au):]] = au
        np.add.at(deg, np.concatenate([au, av]), 1)
        pending = pending[~ok]
    nbr = sort_nbr_rows(nbr)  # establish the sorted-ELL invariant
    return GraphBlocks(
        nbr=jnp.asarray(nbr), deg=jnp.asarray(deg, jnp.int32),
        node_mask=jnp.ones(N, bool),
        orig_id=jnp.arange(N, dtype=jnp.int32), P=1, Cn=N, Cd=Cd,
    )


def halo_slot_counts(g: GraphBlocks) -> Tuple[int, int]:
    """(intra, inter) valid neighbor-slot counts — the W2W halo payload.

    A superstep that gathers one value per neighbor slot (e.g. the min-H
    estimate exchange) moves exactly `intra` values inside blocks and
    `inter` values across block boundaries.  Host-side ints, cheap enough
    to recompute per engine run.
    """
    nbr = np.asarray(g.nbr)
    valid = nbr >= 0
    own = (np.arange(g.N) // g.Cn)[:, None]
    inter = int(np.sum(valid & (nbr // g.Cn != own)))
    return int(np.sum(valid)) - inter, inter


def halo_pair_counts(g: GraphBlocks) -> np.ndarray:
    """(P, P) matrix: valid neighbor slots in block-row b reading block b'.

    Row b column b' counts the per-superstep W2W values block b pulls
    from block b' under a one-value-per-neighbor-slot exchange; the
    diagonal is the intra-block traffic.  `halo_slot_counts` is the
    (trace of this matrix, off-diagonal sum) pair; the runtime's
    `HaloPlan` serves exactly the off-diagonal entries (deduplicated per
    boundary vertex at device granularity).
    """
    nbr = np.asarray(g.nbr)
    valid = nbr >= 0
    own = np.repeat(np.arange(g.N) // g.Cn, g.Cd).reshape(g.N, g.Cd)
    pairs = np.zeros((g.P, g.P), np.int64)
    np.add.at(pairs, (own[valid], nbr[valid] // g.Cn), 1)
    return pairs


def migrate_vertices(g: GraphBlocks, moves, *arrays):
    """Live §4.2 rebalancing: move real nodes to other blocks in place.

    `moves` is a sequence of (u, dest_block) with `u` a global padded id
    of a real node.  Each move swaps the node's row with a *padding* row
    of the destination block, so the whole migration is a permutation of
    the node axis under fixed (P, Cn, Cd): shapes never change and
    compiled kernels never re-specialize.  Node ids DO change — the
    returned `perm` (old id -> new id) lets the caller remap anything it
    holds (pending stream updates, cached id sets); `orig_id` rides the
    permutation, so original-id semantics are preserved automatically.

    Any extra `arrays` (coreness, per-node estimates, ...) are permuted
    along and returned in order.  Host-side preprocessing, like the
    partitioners: raises under a trace, on moving padding/duplicate
    nodes, on no-op moves, and when a destination block has no free
    padding slots (slots vacated by this very migration do NOT count —
    capacity is checked against the pre-migration layout).

    Returns (g', perm, *arrays').  Coreness is invariant under the
    permutation: `core'[perm[u]] == core[u]` bit-exactly (min-H is a
    pointwise fixpoint, indifferent to node order).
    """
    if isinstance(g.nbr, jax.core.Tracer):
        raise TypeError(
            "migrate_vertices is host-side preprocessing; it cannot run "
            "under jit/vmap tracing."
        )
    nbr = np.asarray(g.nbr)
    mask = np.asarray(g.node_mask)
    N, Cn = g.N, g.Cn
    perm = np.arange(N, dtype=np.int64)
    free = {
        b: list(np.flatnonzero(~mask[b * Cn:(b + 1) * Cn]) + b * Cn)
        for b in range(g.P)
    }
    seen: set = set()
    for u, b2 in moves:
        u, b2 = int(u), int(b2)
        if not (0 <= u < N) or not mask[u]:
            raise ValueError(f"cannot migrate non-real node {u}")
        if not (0 <= b2 < g.P):
            raise ValueError(f"destination block {b2} outside [0, {g.P})")
        if b2 == u // Cn:
            raise ValueError(f"no-op move: node {u} already in block {b2}")
        if u in seen:
            raise ValueError(f"duplicate move for node {u}")
        if not free[b2]:
            raise CapacityError(
                f"block {b2} has no free node capacity (Cn={Cn})")
        seen.add(u)
        t = free[b2].pop(0)
        perm[u], perm[t] = t, u  # swap node row with the padding row

    inv = np.empty(N, dtype=np.int64)
    inv[perm] = np.arange(N)
    remap_vals = np.where(nbr >= 0, perm[np.maximum(nbr, 0)], PAD)
    # remapping ids scrambles in-row order; re-sort to keep the invariant
    g2 = dataclasses.replace(
        g,
        nbr=jnp.asarray(sort_nbr_rows(remap_vals[inv]), jnp.int32),
        deg=jnp.asarray(np.asarray(g.deg)[inv], jnp.int32),
        node_mask=jnp.asarray(mask[inv]),
        orig_id=jnp.asarray(np.asarray(g.orig_id)[inv], jnp.int32),
    )
    out = tuple(jnp.asarray(np.asarray(a)[inv]) for a in arrays)
    return (g2, perm) + out


def grow_blocks(g: GraphBlocks, Cn: Optional[int] = None,
                Cd: Optional[int] = None):
    """Capacity escalation: pure pad-and-rekey to new (Cn, Cd).

    Block ``b``'s rows move from ``[b*Cn, b*Cn+Cn)`` to ``[b*Cn2,
    b*Cn2+Cn2)`` keeping their in-block slot ``r``, so the id map is

        ``rekey[b*Cn + r] = b*Cn2 + r``

    which is *globally monotone* whenever ``Cn2 >= Cn`` — remapped
    neighbor rows therefore stay ascending and the sorted-ELL invariant
    survives the rekey without a re-sort.  ``orig_id`` rides the
    relocation, so original-id semantics are untouched.  Growing is
    always legal; *shrinking* is legal exactly when the contents fit
    (every real node sits at ``r < Cn2`` and every degree is ``<= Cd2``)
    — the inverse of a grow that saw no migrations qualifies, which is
    what makes grow-then-shrink an id-stable round trip.

    Host-side preprocessing (raises under a trace), like
    `migrate_vertices`.  Returns ``(g2, rekey)`` with ``rekey`` the
    (N_old,) old-id -> new-id map (-1 for rows dropped by a shrink —
    necessarily padding).  Relocate any per-node arrays you hold with
    `relocate_rows`; note CC labels also need their *values* rekeyed
    (they hold padded ids): relocation first, then ``rekey[label]``.
    Min-member label canonicality commutes with the monotone rekey, so
    relabeled labels stay canonical bit-for-bit.
    """
    if isinstance(g.nbr, jax.core.Tracer):
        raise TypeError(
            "grow_blocks is host-side preprocessing; it cannot run "
            "under jit/vmap tracing."
        )
    Cn2 = g.Cn if Cn is None else int(Cn)
    Cd2 = g.Cd if Cd is None else int(Cd)
    if Cn2 < 1 or Cd2 < 1:
        raise ValueError(f"capacities must be >= 1, got Cn={Cn2} Cd={Cd2}")
    mask = np.asarray(g.node_mask)
    deg = np.asarray(g.deg)
    if Cn2 < g.Cn:
        slots = np.flatnonzero(mask) % g.Cn
        if slots.size and slots.max() >= Cn2:
            raise CapacityError(
                f"cannot shrink Cn {g.Cn} -> {Cn2}: a real node occupies "
                f"slot {int(slots.max())}")
    if Cd2 < g.Cd and deg.size and deg.max() > Cd2:
        raise CapacityError(
            f"cannot shrink Cd {g.Cd} -> {Cd2}: max degree is "
            f"{int(deg.max())}")
    N2 = g.P * Cn2
    old_r = np.arange(g.N) % g.Cn
    rekey = np.where(old_r < Cn2,
                     (np.arange(g.N) // g.Cn) * Cn2 + old_r, -1)
    r2 = np.arange(N2) % Cn2
    src = np.where(r2 < g.Cn, (np.arange(N2) // Cn2) * g.Cn + r2, -1)
    have = src >= 0
    srcc = np.maximum(src, 0)
    Cmin = min(g.Cd, Cd2)
    nbr = np.asarray(g.nbr)
    vals = nbr[srcc, :Cmin]
    vals = np.where(vals >= 0, rekey[np.maximum(vals, 0)], PAD)
    nbr2 = np.full((N2, Cd2), PAD, nbr.dtype)
    nbr2[:, :Cmin] = np.where(have[:, None], vals, PAD)
    g2 = GraphBlocks(
        nbr=jnp.asarray(nbr2, jnp.int32),
        deg=jnp.asarray(np.where(have, deg[srcc], 0), jnp.int32),
        node_mask=jnp.asarray(np.where(have, mask[srcc], False)),
        orig_id=jnp.asarray(
            np.where(have, np.asarray(g.orig_id)[srcc], PAD), jnp.int32),
        P=g.P, Cn=Cn2, Cd=Cd2,
    )
    return g2, rekey


def relocate_rows(arr, rekey: np.ndarray, N2: int, fill=0) -> np.ndarray:
    """Scatter an (N_old, ...) per-node array onto the post-`grow_blocks`
    node axis: row ``u`` lands at ``rekey[u]``; unsourced rows get `fill`.
    Host-side (numpy in, numpy out)."""
    arr = np.asarray(arr)
    out = np.full((N2,) + arr.shape[1:], fill, arr.dtype)
    ok = rekey >= 0
    out[rekey[ok]] = arr[ok]
    return out


def add_vertices_host(g: GraphBlocks, block: int, count: int = 1,
                      orig_ids=None):
    """Vertex arrival: activate `count` padding rows of `block` as fresh
    real (degree-0) nodes.

    Rows are taken lowest-index-first (deterministic, so a replayed log
    reproduces the same ids).  New nodes get original ids `orig_ids`, or
    consecutive ids after the current max when omitted.  Raises
    `CapacityError` when the block lacks free rows — the caller's cue to
    `grow_blocks` and retry.  Returns ``(g2, new_ids)`` with `new_ids`
    the (count,) padded ids of the new vertices.  Host-side.
    """
    if isinstance(g.nbr, jax.core.Tracer):
        raise TypeError(
            "add_vertices_host is host-side preprocessing; it cannot "
            "run under jit/vmap tracing."
        )
    b, count = int(block), int(count)
    if not 0 <= b < g.P:
        raise ValueError(f"block {b} outside [0, {g.P})")
    if count < 1:
        raise ValueError(f"count must be >= 1, got {count}")
    mask = np.asarray(g.node_mask).copy()
    free = np.flatnonzero(~mask[b * g.Cn:(b + 1) * g.Cn]) + b * g.Cn
    if len(free) < count:
        raise CapacityError(
            f"block {b} has {len(free)} free node rows, needs {count} "
            f"(Cn={g.Cn})")
    rows = free[:count]
    orig = np.asarray(g.orig_id).copy()
    if orig_ids is None:
        base = int(orig.max(initial=-1)) + 1
        orig_ids = np.arange(base, base + count)
    orig_ids = np.asarray(orig_ids, np.int64)
    if orig_ids.shape != (count,):
        raise ValueError(f"need {count} orig_ids, got {orig_ids.shape}")
    mask[rows] = True
    orig[rows] = orig_ids
    g2 = dataclasses.replace(
        g, node_mask=jnp.asarray(mask), orig_id=jnp.asarray(orig, jnp.int32))
    return g2, rows


def to_networkx_edges(g: GraphBlocks) -> np.ndarray:
    """Extract the (m, 2) edge list in *original* ids (test oracle helper)."""
    nbr = np.asarray(g.nbr)
    orig = np.asarray(g.orig_id)
    src = np.repeat(np.arange(g.N), g.Cd)
    dst = nbr.reshape(-1)
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    e = np.stack([orig[src], orig[dst]], 1)
    e = e[e[:, 0] < e[:, 1]]
    return np.unique(e, axis=0)


# ---------------------------------------------------------------------------
# Single-edge jitted updates (the maintenance hot path: paper measures
# per-edge insertion/deletion latency).  Both preserve the sorted-ELL
# invariant: insertion shifts the row right at the sorted position,
# deletion shifts it left over the hole.  O(Cd) vectorized per row — the
# static row shape means the shift compiles to a single select, no
# data-dependent control flow.
# ---------------------------------------------------------------------------


def _sorted_insert_row(row: jax.Array, val: jax.Array) -> jax.Array:
    """Insert `val` into a sorted ELL row, keeping valid slots ascending."""
    key = jnp.where(row >= 0, row, _PAD_KEY)
    pos = jnp.sum(key < val)  # insertion point among the valid prefix
    idx = jnp.arange(row.shape[0])
    shifted = row[jnp.maximum(idx - 1, 0)]  # row shifted right by one
    return jnp.where(idx < pos, row, jnp.where(idx == pos, val, shifted))


def _sorted_delete_row(row: jax.Array, val: jax.Array, deg: jax.Array):
    """Remove `val` from a sorted ELL row, shifting left over the hole."""
    C = row.shape[0]
    pos = jnp.argmax(row == val)
    idx = jnp.arange(C)
    shifted = row[jnp.minimum(idx + 1, C - 1)]  # row shifted left by one
    out = jnp.where(idx >= pos, shifted, row)
    return out.at[deg - 1].set(PAD)  # deg is the pre-delete degree


@jax.jit
def insert_edge(g: GraphBlocks, u: jax.Array, v: jax.Array) -> GraphBlocks:
    """Insert undirected edge (u, v); ids are global padded ids.

    Assumes u != v, capacity available, and the edge absent — all validated
    at the host boundary (`updates.apply_updates_host`, which rejects
    self-loops per the module invariant; duplicates would corrupt degree
    counts).  The TPU path itself never branches on those conditions.
    """
    vd = v.astype(g.nbr.dtype)
    ud = u.astype(g.nbr.dtype)
    nbr = g.nbr.at[u].set(_sorted_insert_row(g.nbr[u], vd))
    nbr = nbr.at[v].set(_sorted_insert_row(nbr[v], ud))
    deg = g.deg.at[u].add(1).at[v].add(1)
    return dataclasses.replace(g, nbr=nbr, deg=deg)


@jax.jit
def delete_edge(g: GraphBlocks, u: jax.Array, v: jax.Array) -> GraphBlocks:
    """Delete undirected edge (u, v) — shift-left in both sorted rows."""
    nbr = g.nbr.at[u].set(_sorted_delete_row(g.nbr[u], v, g.deg[u]))
    nbr = nbr.at[v].set(_sorted_delete_row(nbr[v], u, g.deg[v]))
    deg = g.deg.at[u].add(-1).at[v].add(-1)
    return dataclasses.replace(g, nbr=nbr, deg=deg)


def has_edge(g: GraphBlocks, u, v) -> jax.Array:
    return jnp.any(g.nbr[u] == v)
