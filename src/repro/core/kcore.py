"""Distributed k-core decomposition — BLADYG application #1 (paper §4.1).

Algorithm: the locality-based distributed coreness computation of
[Montresor, De Pellegrini, Miorandi, TPDS'13], expressed as BLADYG
supersteps.  Each node keeps a coreness *estimate*; one superstep applies

    est' = min(est, H(est))        H(est)(u) = h-index of {est(v) : v ~ u}

Correctness (why the fixpoint is exactly the coreness):
  * est starts at deg >= core and H is monotone, H(core) = core, so est >= core
    is invariant under est' = min(est, H(est)).
  * the sequence is pointwise non-increasing and integral -> converges to
    some x with H(x) >= x.
  * for such x, every u with x(u) = k has >= k neighbors with x >= k, so each
    level set S_k = {v : x(v) >= k} induces a subgraph of min degree >= k,
    i.e. S_k is inside the k-core and x <= core pointwise.  Hence x = core.

The same argument is *local*: clamping any set of nodes at their true
coreness and iterating only on the rest still converges to the true
coreness of the rest — that is what makes the incremental maintenance in
`kcore_dynamic.py` exact.

Communication pattern (BLADYG modes): the gather of neighbor estimates is
the W2W halo exchange; the convergence test is a W2M reduction; the loop
continuation is the master's M2W broadcast.  Under `jit` with sharded
arrays, XLA emits exactly those collectives (all-gather for the halo,
all-reduce for the flag) — see EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .graph import GraphBlocks


def hindex_rows(vals: jax.Array) -> jax.Array:
    """Row-wise h-index of a padded value matrix (PAD/-1 entries ignored).

    h = max{k : at least k entries >= k}.  Computed by descending sort +
    position compare — the pure-jnp oracle; the Pallas dense-tile kernel in
    `repro.kernels.kcore_hindex` computes the same thing MXU-style.
    """
    Cd = vals.shape[-1]
    s = -jnp.sort(-vals, axis=-1)  # descending
    ranks = jnp.arange(1, Cd + 1, dtype=vals.dtype)
    return jnp.sum(s >= ranks, axis=-1).astype(vals.dtype)


def neighbor_estimates(g: GraphBlocks, est: jax.Array) -> jax.Array:
    """Gather est over the ELL adjacency; PAD slots -> -1 (ignored by hindex)."""
    vals = est[jnp.clip(g.nbr, 0, None)]
    return jnp.where(g.nbr >= 0, vals, -1)


def coreness_step(g: GraphBlocks, est: jax.Array, active: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One BLADYG superstep on an `active` node mask; returns (est', changed)."""
    h = hindex_rows(neighbor_estimates(g, est))
    new = jnp.where(active & g.node_mask, jnp.minimum(est, h), est)
    return new, jnp.any(new != est)


@partial(jax.jit, static_argnames=("max_steps",))
def coreness(g: GraphBlocks, max_steps: int = 10_000) -> jax.Array:
    """Coreness of every node (0 on padding rows)."""
    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    active = g.node_mask

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        est2, changed = coreness_step(g, est, active)
        return est2, changed, it + 1

    est, _, _ = jax.lax.while_loop(cond, body, (est0, jnp.bool_(True), 0))
    return est


def coreness_with_stats(g: GraphBlocks, max_steps: int = 10_000):
    """Python-loop variant that reports superstep count (for benchmarks)."""
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    steps = 0
    while steps < max_steps:
        est2, changed = jax.jit(coreness_step)(g, est, g.node_mask)
        steps += 1
        if not bool(changed):
            break
        est = est2
    return est, steps


def max_coreness(g: GraphBlocks) -> int:
    return int(jax.device_get(jnp.max(coreness(g))))
