"""Distributed k-core decomposition — BLADYG application #1 (paper §4.1).

Algorithm: the locality-based distributed coreness computation of
[Montresor, De Pellegrini, Miorandi, TPDS'13], expressed as BLADYG
supersteps.  Each node keeps a coreness *estimate*; one superstep applies

    est' = min(est, H(est))        H(est)(u) = h-index of {est(v) : v ~ u}

Correctness (why the fixpoint is exactly the coreness):
  * est starts at deg >= core and H is monotone, H(core) = core, so est >= core
    is invariant under est' = min(est, H(est)).
  * the sequence is pointwise non-increasing and integral -> converges to
    some x with H(x) >= x.
  * for such x, every u with x(u) = k has >= k neighbors with x >= k, so each
    level set S_k = {v : x(v) >= k} induces a subgraph of min degree >= k,
    i.e. S_k is inside the k-core and x <= core pointwise.  Hence x = core.

The same argument is *local*: clamping any set of nodes at their true
coreness and iterating only on the rest still converges to the true
coreness of the rest — that is what makes the incremental maintenance in
`kcore_dynamic.py` exact.

Execution: the H(est) primitive is obtained *only* through the kernel
backend registry (`repro.kernels.ops`) — `backend="jnp"|"dense"|"ell"`
selects pure-jnp, dense-tile Pallas, or ELL block-sparse Pallas, all exact;
"auto" resolves by platform and graph size; the explicit `"ell_spmd"`
backend runs the same supersteps sharded over the `workers` device mesh
with a real halo exchange (`repro.runtime`).  See EXPERIMENTS.md
§Backends and §Runtime.

Communication pattern (BLADYG modes): the gather of neighbor estimates is
the W2W halo exchange; the convergence test is a W2M reduction; the loop
continuation is the master's M2W broadcast.  Under `jit` with sharded
arrays, XLA emits exactly those collectives (all-gather for the halo,
all-reduce for the flag) — see EXPERIMENTS.md §Dry-run.  `CorenessProgram`
runs the same superstep through `BladygEngine` with the halo payload
declared, so the engine's per-mode message metering reproduces the paper's
inter- vs intra-partition accounting.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.ref import ell_gather, hindex_rows  # noqa: F401 (re-export)
from .engine import BladygProgram, Mode
from .graph import GraphBlocks, halo_slot_counts


def neighbor_estimates(g: GraphBlocks, est: jax.Array) -> jax.Array:
    """Gather est over the ELL adjacency; PAD slots -> -1 (ignored by hindex)."""
    return ell_gather(g.nbr, est)


def coreness_step(
    g: GraphBlocks, est: jax.Array, active: jax.Array, backend: str = "jnp"
) -> Tuple[jax.Array, jax.Array]:
    """One BLADYG superstep on an `active` node mask; returns (est', changed)."""
    h = ops.hindex_blocks(g, est, backend=backend)
    new = jnp.where(active & g.node_mask, jnp.minimum(est, h), est)
    return new, jnp.any(new != est)


def coreness(
    g: GraphBlocks, max_steps: int = 10_000, backend: str = "auto",
    executor=None, mirror=None,
) -> jax.Array:
    """Coreness of every node (0 on padding rows), via the chosen backend.

    Every backend runs the whole min-H fixpoint as a single fused
    `lax.while_loop` (Pallas kernels inside the body on dense/ell, the
    shard_map'd halo loop on ell_spmd) — zero per-superstep host syncs.
    All backends return identical integers.  On the mesh backend pass a
    long-lived `SpmdExecutor` via `executor=` to skip the per-call halo
    plan build.

    `mirror` (a `core.hub_split.MirrorPlan` for a split `g`) routes
    through the generic `CorenessBlockProgram` under the vertex-cut
    dataflow: per-slice h-index partials merge through count histograms,
    so every row of a replica group carries the hub's exact coreness —
    bit-identical at primaries to the unsplit run.
    """
    if mirror is not None:
        from .algorithms import CorenessBlockProgram

        est = ops.run_block_program(
            g, CorenessBlockProgram(), backend=backend, executor=executor,
            max_steps=max_steps, mirror=mirror)
        return jnp.where(g.node_mask, est, 0)
    return ops.coreness_blocks(g, backend=backend, max_steps=max_steps,
                               executor=executor)


def coreness_with_stats(
    g: GraphBlocks, max_steps: int = 10_000, backend: str = "jnp"
):
    """Coreness plus the superstep count (host int, for benchmarks).

    Same fused fixpoint as `coreness`; the step count comes back as a
    device scalar and is fetched in one transfer at the end — the old
    host-driven loop (one transfer per superstep) is gone.
    """
    est, steps = ops.coreness_blocks(
        g, backend=backend, max_steps=max_steps, with_steps=True)
    return est, int(jax.device_get(steps))


def max_coreness(g: GraphBlocks) -> int:
    return int(jax.device_get(jnp.max(coreness(g))))


class CorenessProgram(BladygProgram):
    """min-H coreness as an engine program (paper §4.1 step 1).

    Worker state is the estimate vector; each superstep gathers the neighbor
    halo (W2W — the payload is one estimate per valid neighbor slot, intra or
    inter depending on the slot's block), applies min-H, and reports the
    changed flag (W2M).  The master broadcasts continue/halt (M2W).
    """

    modes = Mode.LOCAL | Mode.M2W | Mode.W2M | Mode.W2W

    def __init__(self, backend: str = "jnp"):
        self.backend = backend

    def worker_compute(self, g: GraphBlocks, est, directive):
        new, changed = coreness_step(g, est, g.node_mask, backend=self.backend)
        return new, changed

    def master_compute(self, mstate, summary):
        return mstate, None, jnp.logical_not(summary)

    def w2w_payload(self, g: GraphBlocks) -> Tuple[int, int]:
        # one estimate flows across every valid neighbor slot per superstep
        return halo_slot_counts(g)


def coreness_via_engine(g: GraphBlocks, backend: str = "jnp"):
    """Run CorenessProgram through BladygEngine; returns (core, engine).

    The engine's traces carry the metered message counts per superstep —
    the benchmark hook for the paper's message accounting.
    """
    from .engine import BladygEngine

    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    eng = BladygEngine(g)
    est, _ = eng.run(CorenessProgram(backend=backend), est0, None)
    return jnp.where(g.node_mask, est, 0), eng


def coreness_via_spmd(g: GraphBlocks, W=None):
    """CorenessProgram routed through the distributed runtime.

    Runs the same min-H supersteps under `runtime.SpmdEngine.run_spmd`:
    the neighbor gather is an executed halo exchange on the `workers`
    mesh and the returned engine's traces carry the *executed* W2W
    counts (`HaloPlan.slot_counts`) instead of the declared payload.
    Returns (core, SpmdEngine); core is bit-identical to
    `coreness_via_engine`'s.
    """
    from ..runtime.spmd import SpmdCorenessProgram, SpmdEngine

    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    eng = SpmdEngine(g, W=W)
    est, _ = eng.run_spmd(SpmdCorenessProgram(), est0, None)
    return jnp.where(g.node_mask, est, 0), eng
