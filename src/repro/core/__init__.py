"""BLADYG core: block-centric processing of large dynamic graphs in JAX."""
from .graph import (
    CapacityError, GraphBlocks, add_vertices_host, build_blocks,
    build_ell_random, grow_blocks, insert_edge, delete_edge,
    migrate_vertices, relocate_rows, to_networkx_edges, halo_slot_counts,
    halo_pair_counts,
)
from .engine import (
    BladygEngine, BladygProgram, BlockCtx, BlockProgram, Mode, MessageStats,
    MultiProgram,
)
from .algorithms import (
    ConnectedComponentsProgram, CorenessBlockProgram, PageRankProgram,
    TriangleCountProgram, connected_components, fused_analytics,
    merge_labels, pagerank, triangle_counts, triangle_total,
)
from .kcore import (
    coreness, coreness_with_stats, coreness_via_engine, coreness_via_spmd,
    hindex_rows, CorenessProgram,
)
from .kcore_dynamic import (
    insert_edge_maintain,
    delete_edge_maintain,
    maintain_batch,
    maintain_batch_host,
    k_reachable,
    k_reachable_batch,
    MaintenanceStats,
    BatchMaintenanceStats,
)
from .degree import compute_degrees, maintain_degrees_insert, maintain_degrees_delete
from .cliques import MaximalCliques, bron_kerbosch
from . import partition, partition_dynamic, updates

__all__ = [
    "CapacityError", "GraphBlocks", "add_vertices_host", "build_blocks",
    "build_ell_random", "grow_blocks", "insert_edge", "delete_edge",
    "migrate_vertices", "relocate_rows", "to_networkx_edges",
    "halo_slot_counts", "halo_pair_counts",
    "BladygEngine", "BladygProgram", "BlockCtx", "BlockProgram",
    "MultiProgram",
    "ConnectedComponentsProgram", "CorenessBlockProgram", "PageRankProgram",
    "TriangleCountProgram", "connected_components", "fused_analytics",
    "merge_labels", "pagerank", "triangle_counts", "triangle_total",
    "Mode", "MessageStats", "coreness", "coreness_with_stats",
    "coreness_via_engine", "coreness_via_spmd", "hindex_rows",
    "CorenessProgram",
    "insert_edge_maintain", "delete_edge_maintain", "maintain_batch",
    "maintain_batch_host", "k_reachable", "k_reachable_batch",
    "MaintenanceStats", "BatchMaintenanceStats", "compute_degrees",
    "maintain_degrees_insert", "maintain_degrees_delete",
    "MaximalCliques", "bron_kerbosch", "partition", "partition_dynamic",
    "updates",
]
