"""BLADYG core: block-centric processing of large dynamic graphs in JAX."""
from .graph import GraphBlocks, build_blocks, insert_edge, delete_edge, to_networkx_edges
from .engine import BladygEngine, BladygProgram, Mode, MessageStats
from .kcore import coreness, coreness_with_stats, hindex_rows
from .kcore_dynamic import (
    insert_edge_maintain,
    delete_edge_maintain,
    maintain_batch_host,
    k_reachable,
    MaintenanceStats,
)
from .degree import compute_degrees, maintain_degrees_insert, maintain_degrees_delete
from .cliques import MaximalCliques, bron_kerbosch
from . import partition, partition_dynamic, updates

__all__ = [
    "GraphBlocks", "build_blocks", "insert_edge", "delete_edge",
    "to_networkx_edges", "BladygEngine", "BladygProgram", "Mode",
    "MessageStats", "coreness", "coreness_with_stats", "hindex_rows",
    "insert_edge_maintain", "delete_edge_maintain", "maintain_batch_host",
    "k_reachable", "MaintenanceStats", "compute_degrees",
    "maintain_degrees_insert", "maintain_degrees_delete",
    "MaximalCliques", "bron_kerbosch", "partition", "partition_dynamic",
    "updates",
]
