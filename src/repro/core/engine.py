"""BLADYG computational model: master/worker supersteps + messaging modes.

The paper's abstractions, mapped to SPMD JAX:

  workerCompute()  — a pure function applied to the block-sharded arrays
                     (all blocks advance together; on hardware each device
                     holds one block via the `workers` mesh axis).
  masterCompute()  — a pure function of per-block summaries; its result is
                     replicated (broadcast) to all workers.
  M2W / W2M        — the broadcast of the master directive / the all-gather
                     of per-block summaries around each superstep.
  W2W              — any neighbor-state exchange inside workerCompute (halo
                     gathers across the block boundary).
  Local            — block-local compute, no collectives.

A BLADYG *computation* (paper §3.1) = input graph + incremental changes +
a sequence of worker/master operations + output.  `BladygEngine.run`
executes that sequence; `run_jit` fuses it into a single `lax.while_loop`
when both operations are jittable.

Two program notions live here:

  `BladygProgram`  — the free-form worker/master contract (any pytree
                     state, any collective inside workerCompute).  Coreness
                     uses it for the paper's message-accounting runs.
  `BlockProgram`   — the *structured* superstep contract every workload in
                     `core.algorithms` is written against: init state →
                     per-node halo field → named neighbor combine →
                     block-local update → halt reduction.  Because the
                     neighbor access is declared (not hidden inside
                     workerCompute), one runner per backend executes any
                     BlockProgram: `kernels.ops.run_block_program` fuses
                     the whole fixpoint into a single `lax.while_loop` on
                     the jnp/dense/ell backends and routes `ell_spmd`
                     through the worker mesh with a real halo exchange
                     (`runtime.spmd.SpmdBlockProgram`).

The engine also meters messages per mode — this is how the benchmarks
reproduce the paper's inter- vs intra-partition accounting.  The W2W
numbers here are *declared* (shape-reconstructed) because the halo gather
fuses inside jit; the distributed runtime (`repro.runtime.SpmdEngine`)
executes the same supersteps over the `workers` device mesh and records
the counts of its executed `HaloPlan` instead — `w2w_override` lets a
caller stamp those executed counts into this engine's traces when
cross-checking the two (EXPERIMENTS.md §Runtime).
"""
from __future__ import annotations

import dataclasses
import enum
import weakref
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import BlockCtx  # noqa: F401  (re-export: contract type)
from .graph import GraphBlocks


class Mode(enum.Flag):
    LOCAL = enum.auto()
    M2W = enum.auto()
    W2M = enum.auto()
    W2W = enum.auto()


class MessageStats(NamedTuple):
    m2w: int = 0
    w2m: int = 0
    w2w_intra: int = 0
    w2w_inter: int = 0

    def __add__(self, o):  # type: ignore[override]
        return MessageStats(*(a + b for a, b in zip(self, o)))


@dataclasses.dataclass
class SuperstepTrace:
    step: int
    mode: Mode
    stats: MessageStats
    #: collective phases the step's compute had to WAIT on before touching
    #: neighbor values: 1 under the strict-ordered halo exchange, 0 when the
    #: runtime overlapped the exchange with block-local gathers
    #: (`runtime.spmd.SpmdExecutor(overlap=True)`).
    serialized_collectives: int = 0


class BladygProgram:
    """Base class for user programs (paper's workerCompute/masterCompute).

    Subclasses override `worker_compute` and `master_compute`.  Both must be
    pure (jit-safe) if the program is run through `run_jit`.
    """

    #: modes this program is allowed to activate (checked by the engine)
    modes: Mode = Mode.LOCAL | Mode.M2W | Mode.W2M | Mode.W2W

    def w2w_payload(self, g: GraphBlocks) -> Tuple[int, int]:
        """(intra, inter) W2W halo element counts moved per superstep.

        The engine cannot see inside `worker_compute` (under jit the halo
        gather is a fused XLA collective), so programs *declare* their halo
        payload — e.g. via `graph.halo_slot_counts` for a one-value-per-
        neighbor-slot exchange.  Default: no W2W traffic.
        """
        return (0, 0)

    def worker_compute(
        self, g: GraphBlocks, wstate: Any, directive: Any
    ) -> Tuple[Any, Any]:
        """(graph, worker state, master directive) -> (worker state', summary).

        `summary` is the W2M payload: any pytree whose leaves have a leading
        P axis (one row per block) or are global reductions.
        """
        raise NotImplementedError

    def master_compute(
        self, mstate: Any, summary: Any
    ) -> Tuple[Any, Any, jax.Array]:
        """(master state, summaries) -> (master state', directive, halt)."""
        raise NotImplementedError


class BlockProgram:
    """The structured BLADYG superstep contract (tentpole abstraction).

    A BlockProgram factors one superstep into four declared phases, which
    is exactly what lets a single runner execute it on every backend of
    the kernel registry:

      1. **init state**     — `init(g)`: whole-graph worker state (a
         pytree whose array leaves all carry the leading node axis, so
         the state shards over the `workers` mesh axis unchanged).
      2. **halo exchange**  — `halo_field(state)`: the (n, ...) per-node
         values neighbors read this superstep, plus `halo_fill`, the
         value PAD neighbor slots (and, on the mesh, halo dump slots)
         read as.  This *declares* the W2W payload instead of hiding it
         inside workerCompute.
      3. **kernel step**    — `combine` names the neighbor reduction
         (see `kernels.ops.COMBINES`: "min" | "sum" | "hindex" |
         "count_common"); each backend supplies its own execution of it
         (pure-jnp gather, dense-adjacency form, ELL Pallas kernel, or
         halo-exchange + local reduce on the mesh).  `update(ctx, state,
         red)` is then pure block-local math on the reduced (n, ...)
         values.
      4. **halt reduction** — `changed(old, new)`: the local
         convergence verdict; the runner reduces it globally (a `psum`
         on the mesh) and stops when no worker changed or `max_steps`
         supersteps ran.  Fixed-iteration programs return True
         unconditionally and bound the loop with `max_steps`.

    Programs must be *hashable statics*: instances ride into `jax.jit` as
    static arguments and into the per-(mesh, H) compiled-step caches, so
    equality/hash derive from `(type, _key())` — include every
    behavior-changing constructor parameter in `_key()`.

    See `core.algorithms` for the shipped workloads (connected
    components, PageRank, triangle counting, coreness) and
    `kernels.ops.run_block_program` for the runner.
    """

    #: neighbor combine name, resolved per backend by `kernels.ops`
    combine: str = "min"
    #: value PAD slots read as; must be absorbing for `combine` and match
    #: the halo field dtype (e.g. int32 max for "min", 0.0 for "sum")
    halo_fill: Any = -1
    #: superstep bound (the whole loop is device-resident; the bound is a
    #: loop-carried operand, never a host decision)
    max_steps: int = 10_000

    def _key(self) -> Tuple:
        """Static identity: every parameter that changes traced behavior."""
        return ()

    def __hash__(self):
        return hash((type(self), self._key()))

    def __eq__(self, other):
        return type(other) is type(self) and other._key() == self._key()

    def init(self, g: GraphBlocks) -> Any:
        """Whole-graph initial worker state (host boundary, pre-shard).

        Every array leaf must have the padded node count N as its leading
        axis so the ell_spmd backend can shard the state over workers.
        """
        raise NotImplementedError

    def halo_field(self, state: Any) -> jax.Array:
        """The (n, ...) per-node array whose values neighbors read (W2W)."""
        raise NotImplementedError

    def update(self, ctx: BlockCtx, state: Any, red: jax.Array) -> Any:
        """One block-local step: (ctx, state, reduced neighbor values) ->
        state'.  Must be jit-pure and elementwise over the node axis."""
        raise NotImplementedError

    def mirror_state(self, state: Any, primary_row: jax.Array) -> Any:
        """Replicate per-vertex state onto hub mirror rows (vertex cut).

        Under a hub-split graph (`core.hub_split`) every mirror row must
        carry its primary's state so neighbors reading a replica see the
        logical value and replicas advance in lockstep through `update`.
        The default gathers every array leaf through `primary_row` —
        correct whenever all leaves are per-VERTEX (N-leading) values.
        Programs with per-ROW state (e.g. triangle counting's neighbor-
        row field) override this to protect those leaves.  Must be
        idempotent: the runner applies it to caller warm starts too.
        """
        return jax.tree_util.tree_map(lambda a: a[primary_row], state)

    def changed(self, old: Any, new: Any) -> jax.Array:
        """Local convergence verdict (device bool scalar); the runner
        halts when no worker reports a change.  Default: any array leaf
        differs bit-wise."""
        leaves_o = jax.tree_util.tree_leaves(old)
        leaves_n = jax.tree_util.tree_leaves(new)
        flags = [jnp.any(a != b) for a, b in zip(leaves_o, leaves_n)]
        out = jnp.bool_(False)
        for f in flags:
            out = out | f
        return out


class MultiProgram(BlockProgram):
    """Several BlockPrograms advancing in lockstep off ONE neighbor gather.

    Run separately, k programs cost k adjacency sweeps per superstep —
    and the (N, Cd) neighbor matrix is the roofline-dominant operand of
    every sweep.  A MultiProgram declares the fusion instead: its state,
    halo field, and fill are *tuples* (one leaf per sub-program), its
    combine is the sentinel ``"multi"`` with the per-field names in
    `combines`, and the runners (`kernels.ops.run_block_program`, the
    ell_spmd mesh path) read the neighbor slots ONCE per superstep and
    serve every field's gather + reduce off the shared index matrix.
    Each fused reduce reproduces its standalone formulation exactly, so
    per-field results are bit-identical to running the sub-programs
    alone for the same superstep count.

    Sub-program combines must come from `kernels.ops.MULTI_COMBINES`
    ("min" | "sum" | "hindex" — "count_common" exchanges whole rows,
    which would defeat the shared gather).  Halting: a fused step runs
    until EVERY sub-program's `changed` goes quiet (OR reduction) or
    `max_steps` supersteps ran; include a fixed-iteration sub-program
    (e.g. `PageRankProgram(tol=None)`) and the loop runs exactly
    `max_steps` supersteps, during which already-converged min-style
    sub-programs idle at their fixpoints (their updates are idempotent).
    """

    combine = "multi"

    def __init__(self, programs: Tuple[BlockProgram, ...],
                 max_steps: int = 10_000):
        from ..kernels.ops import MULTI_COMBINES  # cycle-free late import
        programs = tuple(programs)
        if not programs:
            raise ValueError("MultiProgram needs at least one sub-program")
        for p in programs:
            if p.combine not in MULTI_COMBINES:
                raise ValueError(
                    f"sub-program combine {p.combine!r} not fusable; "
                    f"expected one of {MULTI_COMBINES}")
        self.programs = programs
        self.combines: Tuple[str, ...] = tuple(p.combine for p in programs)
        self.halo_fill = tuple(p.halo_fill for p in programs)
        self.max_steps = int(max_steps)

    def _key(self):
        return (self.programs, self.max_steps)

    def init(self, g: GraphBlocks) -> Tuple[Any, ...]:
        return tuple(p.init(g) for p in self.programs)

    def halo_field(self, state: Tuple[Any, ...]) -> Tuple[jax.Array, ...]:
        return tuple(p.halo_field(s) for p, s in zip(self.programs, state))

    def update(self, ctx: "BlockCtx", state: Tuple[Any, ...],
               red: Tuple[jax.Array, ...]) -> Tuple[Any, ...]:
        return tuple(
            p.update(ctx, s, r)
            for p, s, r in zip(self.programs, state, red))

    def changed(self, old: Tuple[Any, ...],
                new: Tuple[Any, ...]) -> jax.Array:
        out = jnp.bool_(False)
        for p, o, n in zip(self.programs, old, new):
            out = out | p.changed(o, n)
        return out

    def mirror_state(self, state: Tuple[Any, ...],
                     primary_row: jax.Array) -> Tuple[Any, ...]:
        return tuple(p.mirror_state(s, primary_row)
                     for p, s in zip(self.programs, state))


# One jitted wrapper per program INSTANCE, kept for the instance's
# lifetime: a fresh `jax.jit(...)` per run() would discard the compile
# cache and retrace every call (tracelint: retrace-hazard).
_JIT_WORKERS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _jitted_worker(program: BladygProgram) -> Callable:
    """Memoized `jax.jit(program.worker_compute)` keyed on the instance."""
    fn = _JIT_WORKERS.get(program)
    if fn is None:
        fn = _JIT_WORKERS[program] = jax.jit(program.worker_compute)
    return fn


class BladygEngine:
    """Superstep scheduler over a block-partitioned graph."""

    def __init__(self, g: GraphBlocks):
        self.g = g
        self.traces: list[SuperstepTrace] = []

    # -- host-driven loop (flexible; each superstep individually jitted) ----
    def run(
        self,
        program: BladygProgram,
        wstate: Any,
        mstate: Any,
        directive: Any = None,
        max_supersteps: int = 10_000,
        jit_steps: bool = True,
        w2w_override: Optional[Tuple[int, int]] = None,
    ) -> Tuple[Any, Any]:
        worker = _jitted_worker(program) if jit_steps \
            else program.worker_compute
        master = program.master_compute
        step = 0
        g = self.g
        w2w = w2w_override if w2w_override is not None else program.w2w_payload(g)
        while step < max_supersteps:
            wstate, summary = worker(g, wstate, directive)          # Local/W2W
            mstate, directive, halt = master(mstate, summary)        # W2M+M2W
            self.traces.append(
                SuperstepTrace(
                    step, program.modes, self._meter(summary, directive, w2w)
                )
            )
            step += 1
            if bool(halt):
                break
        return wstate, mstate

    # -- fully-jitted loop ---------------------------------------------------
    def run_jit(
        self,
        program: BladygProgram,
        wstate: Any,
        mstate: Any,
        directive: Any,
        max_supersteps: int = 10_000,
        w2w_override: Optional[Tuple[int, int]] = None,
    ) -> Tuple[Any, Any]:
        g = self.g

        def cond(c):
            _, _, _, halt, it = c
            return (~halt) & (it < max_supersteps)

        def body(c):
            wstate, mstate, directive, _, it = c
            wstate, summary = program.worker_compute(g, wstate, directive)
            mstate, directive, halt = program.master_compute(mstate, summary)
            return wstate, mstate, directive, halt, it + 1

        # Per-superstep message sizes are static (jit-shaped pytrees), so the
        # trace can be reconstructed after the fused loop: abstract-eval the
        # worker for the summary shape, use the declared W2W payload, and
        # multiply by the executed superstep count.
        _, summary_shape = jax.eval_shape(
            program.worker_compute, g, wstate, directive
        )
        w2w = w2w_override if w2w_override is not None else program.w2w_payload(g)

        wstate, mstate, _, _, n = jax.lax.while_loop(
            cond, body, (wstate, mstate, directive, jnp.bool_(False), jnp.int32(0))
        )
        stats = self._meter(summary_shape, directive, w2w)
        # ONE host transfer for the whole run: the superstep count rides the
        # same device_get that blocks on the final state; the traces are then
        # reconstructed in a single bulk extend (per-superstep stats are
        # static, so no per-step host work remains).  wstate/mstate stay on
        # device for the caller.
        (n_steps,) = jax.device_get((n,))
        self.traces.extend(
            SuperstepTrace(step, program.modes, stats)
            for step in range(int(n_steps))
        )
        return wstate, mstate

    @staticmethod
    def _meter(
        summary: Any, directive: Any, w2w: Tuple[int, int] = (0, 0)
    ) -> MessageStats:
        def count(tree):
            tot = 0
            for leaf in jax.tree_util.tree_leaves(tree):
                tot += int(getattr(leaf, "size", 1))
            return tot

        return MessageStats(
            m2w=count(directive),
            w2m=count(summary),
            w2w_intra=int(w2w[0]),
            w2w_inter=int(w2w[1]),
        )

    def message_totals(self) -> MessageStats:
        tot = MessageStats()
        for t in self.traces:
            tot = tot + t.stats
        return tot
