"""Distributed maximal clique maintenance — BLADYG application #3 (paper §4.3).

The algorithm is [Xu, Cheng, Fu, Bu 2014]'s incremental MCE: on inserting
(u, v), cliques contained in (adj(u) ∩ adj(v)) ∪ {u, v} that contain u or v
may become non-maximal and are removed; the new maximal cliques are those of
the subgraph induced by the common neighborhood, extended by {u, v}.  On
deleting (u, v), every clique containing both splits into two candidate
cliques which are re-maximalized.

TPU note (DESIGN §2): prefix-tree maintenance over data-dependent clique
sets is pointer-chasing, combinatorial work with no MXU/VPU analogue — the
paper itself runs it inside CPU actors.  We therefore keep MCE host-side
(pure Python/NumPy, one `CliqueWorker` per block to preserve the BLADYG
structure), and it is excluded from the TPU roofline.

The per-node prefix tree T_u of the paper is represented as the set of
maximal cliques indexed by their minimum vertex (the tree root); this keeps
the same asymptotics for the paper's operations (locate cliques rooted at u,
insert/delete a root-to-leaf path == a clique).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, List, Set, Tuple

Clique = FrozenSet[int]


def bron_kerbosch(adj: Dict[int, Set[int]], subset: Set[int] | None = None) -> List[Clique]:
    """Maximal cliques (with pivoting).  `subset`: restrict to induced subgraph."""
    if subset is not None:
        adj = {u: (adj[u] & subset) for u in subset}
    out: List[Clique] = []

    def expand(r: Set[int], p: Set[int], x: Set[int]):
        if not p and not x:
            out.append(frozenset(r))
            return
        pivot = max(p | x, key=lambda w: len(adj[w] & p))
        for v in list(p - adj[pivot]):
            expand(r | {v}, p & adj[v], x & adj[v])
            p.remove(v)
            x.add(v)

    expand(set(), set(adj.keys()), set())
    return out


class MaximalCliques:
    """Maintained set of maximal cliques of a dynamic undirected graph."""

    def __init__(self, n: int, edges: Iterable[Tuple[int, int]] = ()):
        self.adj: Dict[int, Set[int]] = {u: set() for u in range(n)}
        for a, b in edges:
            if a != b:
                self.adj[a].add(b)
                self.adj[b].add(a)
        self.cliques: Set[Clique] = set(bron_kerbosch(self.adj))
        # paper's T_u: cliques indexed by root (minimum vertex)
        self.by_root: Dict[int, Set[Clique]] = {}
        for c in self.cliques:
            self.by_root.setdefault(min(c), set()).add(c)

    # -- internal index maintenance ---------------------------------------
    def _add(self, c: Clique):
        if c not in self.cliques:
            self.cliques.add(c)
            self.by_root.setdefault(min(c), set()).add(c)

    def _remove(self, c: Clique):
        if c in self.cliques:
            self.cliques.discard(c)
            r = min(c)
            self.by_root[r].discard(c)
            if not self.by_root[r]:
                del self.by_root[r]

    # -- updates ------------------------------------------------------------
    def insert_edge(self, u: int, v: int) -> Tuple[int, int]:
        """Returns (#cliques added, #removed) — the workerCompute payload."""
        if v in self.adj[u]:
            return (0, 0)
        common = self.adj[u] & self.adj[v]
        self.adj[u].add(v)
        self.adj[v].add(u)
        # 1) existing cliques that become non-maximal: contain u or v and are
        #    a subset of common ∪ {u, v}   [Xu et al., paper §4.3]
        closure = common | {u, v}
        dead = [
            c
            for c in self.cliques
            if (u in c or v in c) and c <= closure
        ]
        # 2) new maximal cliques: {u, v} ∪ C for C maximal in G[common]
        if common:
            born = [c | {u, v} for c in bron_kerbosch(self.adj, common)]
        else:
            born = [frozenset({u, v})]
        for c in dead:
            self._remove(c)
        added = 0
        for c in born:
            if c not in self.cliques:
                self._add(c)
                added += 1
        return (added, len(dead))

    def delete_edge(self, u: int, v: int) -> Tuple[int, int]:
        if v not in self.adj[u]:
            return (0, 0)
        self.adj[u].discard(v)
        self.adj[v].discard(u)
        dead = [c for c in self.cliques if u in c and v in c]
        added = 0
        for c in dead:
            self._remove(c)
        for c in dead:
            for w in (u, v):
                cand = set(c) - {v if w == u else u}
                # re-maximalize cand in the new graph
                ext = self._maximalize(cand)
                if ext not in self.cliques and self._is_maximal(ext):
                    self._add(ext)
                    added += 1
        return (added, len(dead))

    def _maximalize(self, c: Set[int]) -> Clique:
        cand = set(c)
        common = set.intersection(*(self.adj[x] for x in cand)) - cand
        while common:
            w = min(common)  # deterministic
            cand.add(w)
            common &= self.adj[w]
            common -= {w}
        return frozenset(cand)

    def _is_maximal(self, c: Clique) -> bool:
        common = set.intersection(*(self.adj[x] for x in c)) - set(c)
        return not common

    def check(self) -> bool:
        """Invariant: every stored clique is a clique and maximal."""
        for c in self.cliques:
            for a in c:
                if not (c - {a}) <= self.adj[a]:
                    return False
            if not self._is_maximal(c):
                return False
        return True
