"""Incremental k-core maintenance — the heart of BLADYG (paper §4.1).

On an edge update the coordinator does NOT recompute coreness from scratch.
Per Theorem 1 [Li, Yu, Mao, TKDE'14] only nodes *k-reachable* from the
lower-coreness endpoint can change, where k = min(core(u), core(v)):
a node w is k-reachable from r if there is a path r ~> w whose nodes all
have coreness exactly k.

BLADYG execution plan (paper fig. 5 generalized):
  1. M2W: master ships the update (u, v) to the blocks owning u and v.
  2. workerCompute: frontier search for the candidate set, propagating
     W2W whenever the frontier crosses a block boundary.
  3. W2M: candidate summary back to the master.
  4. masterCompute: restricted recomputation on the candidate set only
     (clamped min-H supersteps; see kcore.py for the exactness argument),
     candidates' new coreness is written back.

Bounds used (both from Li-Yu-Mao): insertion can only *raise* a candidate's
coreness, by at most 1; deletion can only *lower* it, by at most 1.  So the
restricted iteration starts from `core + 1` (insert) / `core` (delete) on
candidates — a valid pointwise upper bound — and clamps everyone else.

We take the union of the k-reachable sets from both endpoints (a superset of
the theorem's candidate set in the unequal-coreness cases; supersets only
cost work, never correctness).  The search runs in the *pre-update* graph
for insertions (the theorem's "original graph G") and in the pre-update
graph for deletions as well, then the edge is applied and the restricted
iteration runs on the post-update graph.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .graph import GraphBlocks, insert_edge, delete_edge
from .kcore import hindex_rows, neighbor_estimates


class MaintenanceStats(NamedTuple):
    candidates: jax.Array      # int32 — |candidate set|
    bfs_steps: jax.Array       # int32 — frontier supersteps (W2W rounds)
    recompute_steps: jax.Array # int32 — clamped min-H supersteps
    blocks_touched: jax.Array  # int32 — #blocks containing candidates
    inter_partition: jax.Array # bool  — update crossed a block boundary


def k_reachable(
    g: GraphBlocks, core: jax.Array, roots: jax.Array, k: jax.Array,
    max_steps: int = 10_000,
) -> Tuple[jax.Array, jax.Array]:
    """Mask of nodes k-reachable from `roots` (incl. roots with core==k).

    Frontier expansion over the ELL adjacency: one hop per superstep; each
    hop is a scatter-or over neighbor slots (the dense-tile Pallas kernel
    `repro.kernels.frontier` implements the same hop as A @ f on the MXU).
    Returns (visited mask, number of supersteps).
    """
    eligible = (core == k) & g.node_mask
    visited0 = roots & eligible
    N = g.N

    def cond(c):
        visited, frontier, it = c
        return jnp.any(frontier) & (it < max_steps)

    def body(c):
        visited, frontier, it = c
        # scatter-or: every neighbor slot of a frontier node gets hit
        idx = jnp.where(g.nbr >= 0, g.nbr, N).reshape(-1)
        src = jnp.repeat(frontier, g.Cd)
        hit = jnp.zeros(N + 1, bool).at[idx].max(src)[:N]
        nxt = hit & eligible & ~visited
        return visited | nxt, nxt, it + 1

    visited, _, steps = jax.lax.while_loop(
        cond, body, (visited0, visited0, jnp.int32(0))
    )
    return visited, steps


def _restricted_recompute(
    g: GraphBlocks, est0: jax.Array, cand: jax.Array, max_steps: int = 10_000
) -> Tuple[jax.Array, jax.Array]:
    """Clamped min-H iteration: only `cand` nodes move; returns (core', steps)."""

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        h = hindex_rows(neighbor_estimates(g, est))
        new = jnp.where(cand & g.node_mask, jnp.minimum(est, h), est)
        return new, jnp.any(new != est), it + 1

    est, _, steps = jax.lax.while_loop(cond, body, (est0, jnp.bool_(True), jnp.int32(0)))
    return est, steps


def _stats(g: GraphBlocks, cand, bfs_steps, rec_steps, u, v) -> MaintenanceStats:
    blocks = jnp.zeros(g.P, bool).at[jnp.arange(g.N) // g.Cn].max(cand)
    return MaintenanceStats(
        candidates=jnp.sum(cand).astype(jnp.int32),
        bfs_steps=bfs_steps.astype(jnp.int32),
        recompute_steps=rec_steps.astype(jnp.int32),
        blocks_touched=jnp.sum(blocks).astype(jnp.int32),
        inter_partition=(u // g.Cn) != (v // g.Cn),
    )


@partial(jax.jit, donate_argnums=(0,))
def insert_edge_maintain(
    g: GraphBlocks, core: jax.Array, u: jax.Array, v: jax.Array
) -> Tuple[GraphBlocks, jax.Array, MaintenanceStats]:
    """Insert (u, v) and maintain coreness.  u, v are global padded ids."""
    k = jnp.minimum(core[u], core[v])
    roots = jnp.zeros(g.N, bool).at[u].set(True).at[v].set(True)
    cand, bfs_steps = k_reachable(g, core, roots, k)
    # the endpoints themselves are always candidates (their degree changed)
    cand = cand | roots

    g2 = insert_edge(g, u, v)
    ub = jnp.where(cand, jnp.minimum(core + 1, g2.deg), core)
    new_core, rec_steps = _restricted_recompute(g2, ub, cand)
    return g2, new_core, _stats(g2, cand, bfs_steps, rec_steps, u, v)


@partial(jax.jit, donate_argnums=(0,))
def delete_edge_maintain(
    g: GraphBlocks, core: jax.Array, u: jax.Array, v: jax.Array
) -> Tuple[GraphBlocks, jax.Array, MaintenanceStats]:
    """Delete (u, v) and maintain coreness."""
    k = jnp.minimum(core[u], core[v])
    roots = jnp.zeros(g.N, bool).at[u].set(True).at[v].set(True)
    cand, bfs_steps = k_reachable(g, core, roots, k)
    cand = cand | roots

    g2 = delete_edge(g, u, v)
    # deletion can only lower candidates, by at most 1; old core is a UB,
    # but degree may now be below it.
    ub = jnp.where(cand, jnp.minimum(core, g2.deg), core)
    new_core, rec_steps = _restricted_recompute(g2, ub, cand)
    return g2, new_core, _stats(g2, cand, bfs_steps, rec_steps, u, v)


def maintain_batch_host(g, core, updates):
    """Host loop applying a sequence of (u, v, op) updates (op: +1 ins, -1 del).

    Returns (g, core, list_of_stats).  This mirrors the paper's experiment:
    per-edge maintenance latency, not batched amortization.
    """
    stats = []
    for u, v, op in updates:
        fn = insert_edge_maintain if op > 0 else delete_edge_maintain
        g, core, s = fn(g, jnp.asarray(core), jnp.int32(u), jnp.int32(v))
        stats.append(jax.device_get(s))
    return g, core, stats
