"""Incremental k-core maintenance — the heart of BLADYG (paper §4.1).

On an edge update the coordinator does NOT recompute coreness from scratch.
Per Theorem 1 [Li, Yu, Mao, TKDE'14] only nodes *k-reachable* from the
lower-coreness endpoint can change, where k = min(core(u), core(v)):
a node w is k-reachable from r if there is a path r ~> w whose nodes all
have coreness exactly k.

BLADYG execution plan (paper fig. 5 generalized):
  1. M2W: master ships the update (u, v) to the blocks owning u and v.
  2. workerCompute: frontier search for the candidate set, propagating
     W2W whenever the frontier crosses a block boundary.
  3. W2M: candidate summary back to the master.
  4. masterCompute: restricted recomputation on the candidate set only
     (clamped min-H supersteps; see kcore.py for the exactness argument),
     candidates' new coreness is written back.

Bounds used (both from Li-Yu-Mao): insertion can only *raise* a candidate's
coreness, by at most 1; deletion can only *lower* it, by at most 1.  So the
restricted iteration starts from `core + 1` (insert) / `core` (delete) on
candidates — a valid pointwise upper bound — and clamps everyone else.

We take the union of the k-reachable sets from both endpoints (a superset of
the theorem's candidate set in the unequal-coreness cases; supersets only
cost work, never correctness).  The search runs in the *pre-update* graph
for insertions (the theorem's "original graph G") and in the pre-update
graph for deletions as well, then the edge is applied and the restricted
iteration runs on the post-update graph.

Both primitives (frontier hop, clamped h-index) are obtained only through
the kernel backend registry (`repro.kernels.ops`) — the frontier kernels
carry an R axis, which `maintain_batch` uses to run up to R updates'
candidate searches in ONE sequence of supersteps:

Batched maintenance (`maintain_batch`): R updates whose candidate sets are
pairwise disjoint are *independent* — each update's search and restricted
recompute never reads state the others write (the BFS only expands through
its own k-level set, and the recompute clamps everything outside its
candidates).  So the searches stack on the frontier R axis (supersteps =
max instead of sum), the accepted edges apply together, and ONE joint
clamped recompute finishes the chunk.  Conflicting updates (overlapping
candidate sets, detected after the batched search) fall back to the exact
sequential path.  The result is bit-identical to sequential maintenance;
only the superstep count drops.  See EXPERIMENTS.md §Batched maintenance.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .graph import GraphBlocks, insert_edge, delete_edge

#: backend name that routes maintenance supersteps through `repro.runtime`
#: (supported by `maintain_batch` / `runtime.run_stream`; the per-edge
#: jitted entry points reject it — the halo plan needs concrete arrays)
SPMD_BACKEND = "ell_spmd"


def _reject_spmd(backend: str, fn_name: str) -> None:
    if backend == SPMD_BACKEND:
        raise ValueError(
            f"{fn_name} does not support backend={SPMD_BACKEND!r}: it runs "
            "under jit, where the runtime's halo plan cannot be built from "
            "traced arrays. Use maintain_batch(..., backend='ell_spmd') or "
            "runtime.run_stream for mesh-executed maintenance."
        )


def _validate_updates_host(g: GraphBlocks, updates) -> None:
    """Host-boundary validation for a maintenance stream.

    Replays the whole stream through `updates.apply_updates_host` (which
    raises on self-loops, duplicate inserts, missing deletes, and degree-
    capacity overflow) and discards the result — the jitted maintenance
    path assumes validated input and would silently corrupt the ELL rows
    otherwise.
    """
    from .updates import apply_updates_host  # deferred: sibling module

    apply_updates_host(g, list(updates))


class MaintenanceStats(NamedTuple):
    candidates: jax.Array      # int32 — |candidate set|
    bfs_steps: jax.Array       # int32 — frontier supersteps (W2W rounds)
    recompute_steps: jax.Array # int32 — clamped min-H supersteps
    blocks_touched: jax.Array  # int32 — #blocks containing candidates
    inter_partition: jax.Array # bool  — update crossed a block boundary


class BatchMaintenanceStats(NamedTuple):
    """Aggregate accounting for one `maintain_batch` stream."""

    updates: int           # total updates processed
    batches: int           # number of batched chunks executed
    batched_updates: int   # updates that rode a batched chunk
    sequential_updates: int  # updates deferred to the sequential path
    bfs_steps: int         # total frontier supersteps (batched + sequential)
    recompute_steps: int   # total clamped min-H supersteps
    candidates: int        # total candidate-set size across updates


def k_reachable(
    g: GraphBlocks, core: jax.Array, roots: jax.Array, k: jax.Array,
    max_steps: int = 10_000, backend: str = "jnp",
) -> Tuple[jax.Array, jax.Array]:
    """Mask of nodes k-reachable from `roots` (incl. roots with core==k).

    Frontier expansion over the ELL adjacency, one hop per superstep, each
    hop dispatched through the kernel registry (`ops.frontier_blocks`).
    Returns (visited mask (N,), number of supersteps).
    """
    visited, steps = k_reachable_batch(
        g, core, roots[:, None], k[None], max_steps=max_steps, backend=backend
    )
    return visited[:, 0], steps


def k_reachable_batch(
    g: GraphBlocks, core: jax.Array, roots: jax.Array, ks: jax.Array,
    max_steps: int = 10_000, backend: str = "jnp",
) -> Tuple[jax.Array, jax.Array]:
    """R stacked k-reachability searches sharing one superstep sequence.

    roots: (N, R) bool — per-search root sets; ks: (R,) int32 — per-search
    k level.  Column r expands only through nodes with core == ks[r].
    Returns (visited (N, R) bool, supersteps int32 = max over searches).
    """
    eligible = (core[:, None] == ks[None, :]) & g.node_mask[:, None]
    visited0 = roots & eligible
    adj = ops.dense_adj(g, backend)  # densify once, not per hop

    def cond(c):
        visited, frontier, it = c
        return jnp.any(frontier) & (it < max_steps)

    def body(c):
        visited, frontier, it = c
        nxt = ops.frontier_blocks(
            g, frontier, eligible, visited, backend=backend, adj=adj
        )
        return visited | nxt, nxt, it + 1

    visited, _, steps = jax.lax.while_loop(
        cond, body, (visited0, visited0, jnp.int32(0))
    )
    return visited, steps


def _restricted_recompute(
    g: GraphBlocks, est0: jax.Array, cand: jax.Array,
    max_steps: int = 10_000, backend: str = "jnp",
) -> Tuple[jax.Array, jax.Array]:
    """Clamped min-H iteration: only `cand` nodes move; returns (core', steps)."""
    adj = ops.dense_adj(g, backend)  # densify once, not per superstep

    def cond(c):
        est, changed, it = c
        return changed & (it < max_steps)

    def body(c):
        est, _, it = c
        h = ops.hindex_blocks(g, est, backend=backend, adj=adj)
        new = jnp.where(cand & g.node_mask, jnp.minimum(est, h), est)
        return new, jnp.any(new != est), it + 1

    est, _, steps = jax.lax.while_loop(cond, body, (est0, jnp.bool_(True), jnp.int32(0)))
    return est, steps


def _stats(g: GraphBlocks, cand, bfs_steps, rec_steps, u, v) -> MaintenanceStats:
    blocks = jnp.zeros(g.P, bool).at[jnp.arange(g.N) // g.Cn].max(cand)
    return MaintenanceStats(
        candidates=jnp.sum(cand).astype(jnp.int32),
        bfs_steps=bfs_steps.astype(jnp.int32),
        recompute_steps=rec_steps.astype(jnp.int32),
        blocks_touched=jnp.sum(blocks).astype(jnp.int32),
        inter_partition=(u // g.Cn) != (v // g.Cn),
    )


@partial(jax.jit, donate_argnums=(0,), static_argnames=("backend",))
def insert_edge_maintain(
    g: GraphBlocks, core: jax.Array, u: jax.Array, v: jax.Array,
    backend: str = "jnp",
) -> Tuple[GraphBlocks, jax.Array, MaintenanceStats]:
    """Insert (u, v) and maintain coreness.  u, v are global padded ids."""
    _reject_spmd(backend, "insert_edge_maintain")
    k = jnp.minimum(core[u], core[v])
    roots = jnp.zeros(g.N, bool).at[u].set(True).at[v].set(True)
    cand, bfs_steps = k_reachable(g, core, roots, k, backend=backend)
    # the endpoints themselves are always candidates (their degree changed)
    cand = cand | roots

    g2 = insert_edge(g, u, v)
    ub = jnp.where(cand, jnp.minimum(core + 1, g2.deg), core)
    new_core, rec_steps = _restricted_recompute(g2, ub, cand, backend=backend)
    return g2, new_core, _stats(g2, cand, bfs_steps, rec_steps, u, v)


@partial(jax.jit, donate_argnums=(0,), static_argnames=("backend",))
def delete_edge_maintain(
    g: GraphBlocks, core: jax.Array, u: jax.Array, v: jax.Array,
    backend: str = "jnp",
) -> Tuple[GraphBlocks, jax.Array, MaintenanceStats]:
    """Delete (u, v) and maintain coreness."""
    _reject_spmd(backend, "delete_edge_maintain")
    k = jnp.minimum(core[u], core[v])
    roots = jnp.zeros(g.N, bool).at[u].set(True).at[v].set(True)
    cand, bfs_steps = k_reachable(g, core, roots, k, backend=backend)
    cand = cand | roots

    g2 = delete_edge(g, u, v)
    # deletion can only lower candidates, by at most 1; old core is a UB,
    # but degree may now be below it.
    ub = jnp.where(cand, jnp.minimum(core, g2.deg), core)
    new_core, rec_steps = _restricted_recompute(g2, ub, cand, backend=backend)
    return g2, new_core, _stats(g2, cand, bfs_steps, rec_steps, u, v)


def maintain_batch_host(g, core, updates):
    """Host loop applying a sequence of (u, v, op) updates (op: +1 ins, -1 del).

    Returns (g, core, list_of_stats).  This mirrors the paper's experiment —
    per-edge maintenance latency, not batched amortization; `maintain_batch`
    is the amortized path.

    The stream is validated here (self-loops, duplicates, missing deletes,
    capacity) — this is a host boundary; the jitted maintain functions
    assume validated input and would corrupt the ELL rows otherwise.

    NOTE: consumes `g` via jit buffer donation (a no-op on CPU, enforced
    on TPU/GPU) — do not reuse the argument afterwards.
    """
    _validate_updates_host(g, updates)
    stats = []
    for u, v, op in updates:
        fn = insert_edge_maintain if op > 0 else delete_edge_maintain
        g, core, s = fn(g, jnp.asarray(core), jnp.int32(u), jnp.int32(v))
        stats.append(jax.device_get(s))
    return g, core, stats


# ---------------------------------------------------------------------------
# Batched maintenance: amortize supersteps over independent updates.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend",))
def _batch_candidates(
    g: GraphBlocks, core: jax.Array, us: jax.Array, vs: jax.Array,
    valid: jax.Array, backend: str = "jnp",
):
    """Candidate sets for up to R updates via one batched frontier search.

    us, vs: (R,) int32 endpoint ids (arbitrary on invalid columns);
    valid: (R,) bool.  The per-update k levels are derived on device
    (-1 on invalid columns keeps them empty).
    Returns (cand (N, R) bool, supersteps).
    """
    R = us.shape[0]
    cols = jnp.arange(R)
    ks = jnp.where(valid, jnp.minimum(core[us], core[vs]), -1)
    roots = (
        jnp.zeros((g.N, R), bool)
        .at[us, cols].max(valid)
        .at[vs, cols].max(valid)
    )
    visited, steps = k_reachable_batch(g, core, roots, ks, backend=backend)
    # endpoints are always candidates (their degree changes)
    return (visited | roots) & valid[None, :], steps


def _independent_prefix(cand: np.ndarray, valid: int) -> Tuple[List[int], List[int]]:
    """Greedily split update columns into (accepted, deferred).

    A column is accepted iff its candidate set is disjoint from every
    earlier column that was accepted — AND every earlier column that was
    deferred.  Disjointness covers shared endpoints too (endpoints are
    always in their own candidate set).

    The deferred check is what keeps the reordering sound: deferred
    updates are applied *after* the accepted batch, so accepting a column
    that conflicts with an earlier deferred one would swap the order of
    two dependent updates (e.g. an insert into a full row hoisted above
    the delete that frees the slot).  Conflict-free pairs commute — their
    candidate sets (which contain the endpoints) are disjoint, so they
    touch disjoint adjacency rows.
    """
    overlap = cand.T.astype(np.int64) @ cand.astype(np.int64)  # (R, R)
    accepted: List[int] = []
    deferred: List[int] = []
    for r in range(valid):
        # accepted + deferred == all earlier columns, so the rule reduces
        # to "disjoint from every earlier column"
        if not overlap[r, :r].any():
            accepted.append(r)
        else:
            deferred.append(r)
    return accepted, deferred


def _apply_edges(
    g: GraphBlocks, us: jax.Array, vs: jax.Array, ops_: jax.Array
) -> GraphBlocks:
    """Apply (R,) fixed-width updates: op = +1 insert / -1 delete / 0 no-op."""

    def apply_one(i, gg):
        u, v, op = us[i], vs[i], ops_[i]
        return jax.lax.switch(
            jnp.clip(op + 1, 0, 2),
            [
                lambda q: delete_edge(q, u, v),  # op == -1
                lambda q: q,                     # op ==  0 (padding)
                lambda q: insert_edge(q, u, v),  # op == +1
            ],
            gg,
        )

    return jax.lax.fori_loop(0, us.shape[0], apply_one, g)


@partial(jax.jit, donate_argnums=(0,), static_argnames=("backend",))
def _apply_and_recompute(
    g: GraphBlocks, core: jax.Array, us: jax.Array, vs: jax.Array,
    ops_: jax.Array, cand_ins: jax.Array, cand_del: jax.Array,
    backend: str = "jnp",
):
    """Apply accepted edges and run ONE joint clamped recompute.

    us, vs, ops_: (R,) fixed-width accepted updates, op = +1 insert /
    -1 delete / 0 padding no-op — fixed R keeps the jit cache to one entry
    regardless of how many updates each chunk accepts.
    cand_ins / cand_del: (N,) union masks of the accepted insert / delete
    candidate sets (disjoint by construction).
    """
    g2 = _apply_edges(g, us, vs, ops_)
    # per-update upper bounds (valid because the candidate sets are disjoint:
    # no node gets both an insert and a delete bound)
    ub = jnp.where(cand_ins, jnp.minimum(core + 1, g2.deg), core)
    ub = jnp.where(cand_del, jnp.minimum(core, g2.deg), ub)
    union = cand_ins | cand_del
    new_core, rec_steps = _restricted_recompute(g2, ub, union, backend=backend)
    return g2, new_core, rec_steps


# ---------------------------------------------------------------------------
# ell_spmd routing: the identical maintenance protocol with every superstep
# (k-reachability hops, clamped min-H recompute) executed on the worker mesh
# through the runtime subsystem's halo exchange.
# ---------------------------------------------------------------------------


def _spmd_executor(g: GraphBlocks, W=None, ex=None):
    """Host-boundary construction of the mesh executor (deferred import —
    `runtime` lazily dispatches back into `kernels.ops`).  When a live
    executor `ex` is threaded through, it is returned as-is: the caller
    owns keeping its plan in sync via `ex.apply_updates`."""
    if ex is not None:
        return ex
    from ..runtime.spmd import SpmdExecutor

    return SpmdExecutor(g, W=W)


def _batch_candidates_spmd(ex, g: GraphBlocks, core, us, vs, valid):
    """`_batch_candidates` with the frontier supersteps run on the mesh."""
    R = len(us)
    cols = jnp.arange(R)
    usj, vsj = jnp.asarray(us), jnp.asarray(vs)
    validj = jnp.asarray(valid)
    ks = jnp.where(validj, jnp.minimum(core[usj], core[vsj]), -1)
    roots = (
        jnp.zeros((g.N, R), bool)
        .at[usj, cols].max(validj)
        .at[vsj, cols].max(validj)
    )
    visited, steps = ex.k_reachable_batch(core, roots, ks)
    return (visited | roots) & validj[None, :], steps


def _apply_and_recompute_spmd(
    g: GraphBlocks, core, us, vs, ops_, cand_ins, cand_del, W=None, ex=None
):
    """`_apply_and_recompute` with the joint clamped recompute on the mesh.

    The halo plan depends on the adjacency: with a threaded executor `ex`
    the plan is maintained *incrementally* on the post-update graph
    (`ex.apply_updates` — dirty workers only, zero full rebuilds);
    without one, a fresh executor is built per call (the legacy path).
    Either way the compiled mesh steps are reused from the per-(mesh, H)
    cache whenever the halo capacity holds.
    """
    g2 = _apply_edges(g, jnp.asarray(us), jnp.asarray(vs), jnp.asarray(ops_))
    ub = jnp.where(cand_ins, jnp.minimum(core + 1, g2.deg), core)
    ub = jnp.where(cand_del, jnp.minimum(core, g2.deg), ub)
    union = cand_ins | cand_del
    if ex is None:
        ex = _spmd_executor(g2, W)
    else:
        ex.apply_updates(g2, list(zip(us, vs, ops_)))
    new_core, rec_steps = ex.restricted_recompute(ub, union)
    return g2, new_core, rec_steps


def _maintain_one_spmd(g: GraphBlocks, core, update, tot, W=None, ex=None):
    """Sequential (coordinator-path) maintenance of one update on the mesh.

    With a threaded executor `ex` the halo plan rides along incrementally
    (the edit touches at most two blocks); without one, executors are
    built per call as before.
    """
    u, v, op = update
    uj, vj = jnp.int32(u), jnp.int32(v)
    shared = ex is not None
    ex = _spmd_executor(g, W, ex)
    k = jnp.minimum(core[uj], core[vj])
    roots = jnp.zeros(g.N, bool).at[uj].set(True).at[vj].set(True)
    cand, bfs_steps = ex.k_reachable_batch(core, roots[:, None], k[None])
    cand = cand[:, 0] | roots

    g2 = insert_edge(g, uj, vj) if op > 0 else delete_edge(g, uj, vj)
    bump = core + 1 if op > 0 else core
    ub = jnp.where(cand, jnp.minimum(bump, g2.deg), core)
    if shared:
        ex.apply_updates(g2, [update])
        ex2 = ex
    else:
        ex2 = _spmd_executor(g2, W)
    new_core, rec_steps = ex2.restricted_recompute(ub, cand)
    # ONE bundled transfer for the three counters (three bare int() casts
    # would block the dispatch queue once each)
    bfs_h, rec_h, cand_h = jax.device_get(
        (bfs_steps, rec_steps, jnp.sum(cand)))
    tot["bfs"] += int(bfs_h)
    tot["rec"] += int(rec_h)
    tot["cand"] += int(cand_h)
    tot["seq"] += 1
    return g2, new_core


def maintain_batch(
    g: GraphBlocks,
    core: jax.Array,
    updates: Sequence[Tuple[int, int, int]],
    R: int = 8,
    backend: str = "jnp",
    W=None,
) -> Tuple[GraphBlocks, jax.Array, BatchMaintenanceStats]:
    """Maintain coreness over a stream of updates, R at a time.

    g: GraphBlocks (nbr (N, Cd), N = P*Cn); core: (N,) int32 coreness of
    `g`; updates: sequence of (u, v, op) with op = +1 insert / -1 delete
    and u, v global padded ids.  Returns (g', (N,) int32 core',
    BatchMaintenanceStats).

    Chunks of up to R (u, v, op) updates share one batched k-reachability
    search on the frontier kernels' R axis.  Updates whose candidate sets
    are pairwise disjoint are applied together with a single joint clamped
    recompute; the rest fall back to exact sequential maintenance within
    the chunk.  Final coreness is identical to sequential processing; the
    frontier superstep count is the batch maximum instead of the sum.

    The stream is validated here (self-loops, duplicates, missing deletes,
    capacity) — this is a host boundary (the jitted update path never
    re-validates).

    With `backend="ell_spmd"` every superstep (the batched k-reachability
    search and the joint clamped recompute) executes on the worker mesh
    via the runtime subsystem's halo exchange; `W` forces the worker
    count (default: as many devices as divide P).  ONE executor threads
    through the whole stream, its halo plan maintained incrementally
    after every applied edit (zero full plan rebuilds).  Results are
    identical to every other backend.

    NOTE: like the single-edge maintain functions, this CONSUMES `g` via
    jit buffer donation (a no-op on CPU, enforced on TPU/GPU) — do not
    reuse the argument afterwards; use the returned graph.
    """
    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    _validate_updates_host(g, updates)
    spmd = backend == SPMD_BACKEND
    # ONE executor threads through the whole stream on the mesh path; its
    # halo plan is maintained incrementally after every applied edit
    ex = _spmd_executor(g, W) if spmd else None

    core = jnp.asarray(core)
    tot = dict(bfs=0, rec=0, cand=0, batched=0, seq=0, batches=0)
    # batched-path recompute supersteps accumulate on device; pulled once
    # when the final stats are assembled
    rec_dev = jnp.int32(0)
    for start in range(0, len(updates), R):
        chunk = list(updates[start:start + R])
        if len(chunk) == 1:
            g, core = _maintain_one(g, core, chunk[0], tot, backend, W=W,
                                    ex=ex)
            continue
        n = len(chunk)
        us = np.zeros(R, np.int32)
        vs = np.zeros(R, np.int32)
        ops_ = np.zeros(R, np.int32)
        us[:n] = [u for u, _, _ in chunk]
        vs[:n] = [v for _, v, _ in chunk]
        ops_[:n] = [op for _, _, op in chunk]
        valid = np.zeros(R, bool)
        valid[:n] = True

        if spmd:
            cand, steps = _batch_candidates_spmd(ex, g, core, us, vs, valid)
        else:
            cand, steps = _batch_candidates(
                g, core, jnp.asarray(us), jnp.asarray(vs),
                jnp.asarray(valid), backend=backend,
            )
        # ONE bundled transfer pulls the candidate matrix together with
        # the superstep counter (int(steps) alone would sync separately)
        steps_h, cand_np = jax.device_get((steps, cand))
        tot["bfs"] += int(steps_h)
        tot["batches"] += 1
        cand_np = np.asarray(cand_np)
        accepted, deferred = _independent_prefix(cand_np, n)

        if accepted:
            acc = np.asarray(accepted)
            ins_cols = acc[ops_[acc] > 0]
            del_cols = acc[ops_[acc] < 0]
            cand_ins = jnp.asarray(cand_np[:, ins_cols].any(axis=1))
            cand_del = jnp.asarray(cand_np[:, del_cols].any(axis=1))
            # pad accepted updates to fixed width R (op=0 no-ops) so
            # _apply_and_recompute compiles once per R, not per |accepted|
            us_a = np.zeros(R, np.int32)
            vs_a = np.zeros(R, np.int32)
            ops_a = np.zeros(R, np.int32)
            us_a[:len(acc)] = us[acc]
            vs_a[:len(acc)] = vs[acc]
            ops_a[:len(acc)] = ops_[acc]
            if spmd:
                g, core, rec_steps = _apply_and_recompute_spmd(
                    g, core, us_a, vs_a, ops_a, cand_ins, cand_del, W=W,
                    ex=ex)
            else:
                g, core, rec_steps = _apply_and_recompute(
                    g, core,
                    jnp.asarray(us_a), jnp.asarray(vs_a), jnp.asarray(ops_a),
                    cand_ins, cand_del, backend=backend,
                )
            rec_dev = rec_dev + rec_steps  # async accumulate, no host sync
            tot["cand"] += int(cand_np[:, acc].sum())
            tot["batched"] += len(accepted)

        for r in deferred:
            g, core = _maintain_one(g, core, chunk[r], tot, backend, W=W,
                                    ex=ex)

    stats = BatchMaintenanceStats(
        updates=len(updates),
        batches=tot["batches"],
        batched_updates=tot["batched"],
        sequential_updates=tot["seq"],
        bfs_steps=tot["bfs"],
        recompute_steps=tot["rec"] + int(jax.device_get(rec_dev)),
        candidates=tot["cand"],
    )
    return g, core, stats


def _maintain_one(g, core, update, tot, backend, W=None, ex=None):
    """Sequential fallback for one update; accumulates into `tot`."""
    if backend == SPMD_BACKEND:
        return _maintain_one_spmd(g, core, update, tot, W=W, ex=ex)
    u, v, op = update
    fn = insert_edge_maintain if op > 0 else delete_edge_maintain
    g, core, s = fn(g, core, jnp.int32(u), jnp.int32(v), backend=backend)
    s = jax.device_get(s)  # ONE bundled pull of the whole stats tuple
    tot["bfs"] += int(s.bfs_steps)
    tot["rec"] += int(s.recompute_steps)
    tot["cand"] += int(s.candidates)
    tot["seq"] += 1
    return g, core
