"""Degree computation + incremental maintenance — the paper's running example
(§3.2, Figs. 4-6), expressed as a BladygProgram.

Step 1 (static): every worker computes the degree of its block's nodes in
parallel (Local mode) and reports completion (W2M).
Step 2 (dynamic): for an inserted/deleted edge (u, v) the master sends M2W
directives to the blocks of u and v, which bump the two degrees and notify
back (the MSG1/MSG2 exchange of Fig. 5).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .engine import BladygProgram, Mode
from .graph import GraphBlocks


class DegreeProgram(BladygProgram):
    modes = Mode.LOCAL | Mode.W2M

    def worker_compute(self, g: GraphBlocks, wstate, directive) -> Tuple[Any, Any]:
        # Local: degree = #valid neighbor slots (deg array is authoritative,
        # but we recompute from adjacency to exercise the data path).
        deg = jnp.sum(g.nbr >= 0, axis=1).astype(jnp.int32)
        per_block_done = jnp.ones((g.P,), bool)
        return deg, per_block_done

    def master_compute(self, mstate, summary):
        halt = jnp.all(summary)
        return mstate, None, halt


# Module-level jit: one trace cache shared by every compute_degrees call
# (a per-call `jax.jit(...)` would retrace each time).
_degree_step = jax.jit(DegreeProgram().worker_compute)


def compute_degrees(g: GraphBlocks) -> jax.Array:
    """Static degree of every node (padding rows -> 0)."""
    deg, _ = _degree_step(g, None, None)
    return jnp.where(g.node_mask, deg, 0)


@jax.jit
def maintain_degrees_insert(deg: jax.Array, u, v) -> jax.Array:
    """The master's M2W directive for an inserted edge: bump deg[u], deg[v]."""
    return deg.at[u].add(1).at[v].add(1)


@jax.jit
def maintain_degrees_delete(deg: jax.Array, u, v) -> jax.Array:
    return deg.at[u].add(-1).at[v].add(-1)
