"""Dynamic edge-partitioning maintenance — BLADYG application #2 (paper §4.2).

Two update strategies, exactly the paper's §5.2.2 experiment:

  * IncrementalPart — apply the partitioning technique only to the
    incremental changes (hash/random: stateless per-edge assignment;
    DFEP: the UB-UPDATE neighbor-funding rule).
  * NaivePart — destroy the old partitioning and restart from scratch.

Deletions trigger the repartition-threshold protocol of §4.2: every worker
computes a local balance summary (workerCompute, W2M), the coordinator
decides whether a full repartition is needed (masterCompute).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

from . import partition as P_


@dataclass
class PartitionState:
    edges: np.ndarray   # (m, 2) original ids
    owner: np.ndarray   # (m,) block of each edge
    n: int
    P: int
    method: str         # 'hash' | 'random' | 'dfep' | 'vertex_cut'
    seed: int = 0


_STATIC = {
    "hash": lambda e, n, P, seed: P_.edge_hash_partition(e, P, seed),
    "random": lambda e, n, P, seed: P_.edge_random_partition(e, P, seed),
    "dfep": lambda e, n, P, seed: P_.dfep(e, n, P, seed),
    "vertex_cut": lambda e, n, P, seed: P_.vertex_cut_greedy(e, n, P),
}


def initial_partition(
    edges: np.ndarray, n: int, P: int, method: str, seed: int = 0
) -> Tuple[PartitionState, float]:
    """Run the static partitioner; returns (state, partitioning-time seconds)."""
    t0 = time.perf_counter()
    owner = _STATIC[method](np.asarray(edges), n, P, seed)
    pt = time.perf_counter() - t0
    return PartitionState(np.asarray(edges), owner, n, P, method, seed), pt


def incremental_part(
    st: PartitionState, new_edges: np.ndarray
) -> Tuple[PartitionState, float]:
    """IncrementalPart: assign only the new edges, keep everything else."""
    new_edges = np.asarray(new_edges)
    t0 = time.perf_counter()
    if st.method in ("hash", "random"):
        new_owner = _STATIC[st.method](new_edges, st.n, st.P, st.seed)
    elif st.method == "dfep":
        new_owner = P_.ub_update(st.edges, st.owner, new_edges, st.n, st.P)
    elif st.method == "vertex_cut":
        # greedy continues from current per-node partition sets
        new_owner = P_.ub_update(st.edges, st.owner, new_edges, st.n, st.P)
    else:
        raise ValueError(st.method)
    ut = time.perf_counter() - t0
    st2 = PartitionState(
        np.concatenate([st.edges, new_edges]),
        np.concatenate([st.owner, new_owner]),
        st.n, st.P, st.method, st.seed,
    )
    return st2, ut


def naive_part(
    st: PartitionState, new_edges: np.ndarray
) -> Tuple[PartitionState, float]:
    """NaivePart: throw the assignment away and repartition everything."""
    all_edges = np.concatenate([st.edges, np.asarray(new_edges)])
    t0 = time.perf_counter()
    owner = _STATIC[st.method](all_edges, st.n, st.P, st.seed)
    ut = time.perf_counter() - t0
    return PartitionState(all_edges, owner, st.n, st.P, st.method, st.seed), ut


def delete_edges(
    st: PartitionState,
    idx: np.ndarray,
    threshold: float = 1.5,
) -> Tuple[PartitionState, bool, float]:
    """Deletion protocol (§4.2): drop edges, workers report balance, the
    coordinator repartitions iff imbalance exceeds `threshold`.

    Returns (state', repartitioned?, update-time seconds).
    """
    t0 = time.perf_counter()
    keep = np.ones(len(st.edges), bool)
    keep[np.asarray(idx)] = False
    edges = st.edges[keep]
    owner = st.owner[keep]
    # workerCompute: per-block sizes (W2M); masterCompute: threshold test
    bal = P_.edge_balance(owner, st.P)
    repart = bal > threshold
    if repart:
        owner = _STATIC[st.method](edges, st.n, st.P, st.seed)
    ut = time.perf_counter() - t0
    return (
        PartitionState(edges, owner, st.n, st.P, st.method, st.seed),
        bool(repart),
        ut,
    )
