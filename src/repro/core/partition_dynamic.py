"""Dynamic edge-partitioning maintenance — BLADYG application #2 (paper §4.2).

Two update strategies, exactly the paper's §5.2.2 experiment:

  * IncrementalPart — apply the partitioning technique only to the
    incremental changes (hash/random: stateless per-edge assignment;
    DFEP: the UB-UPDATE neighbor-funding rule).
  * NaivePart — destroy the old partitioning and restart from scratch.

Deletions trigger the repartition-threshold protocol of §4.2: every worker
computes a local balance summary (workerCompute, W2M), the coordinator
decides whether a full repartition is needed (masterCompute).

The same protocol also runs *live* against the block runtime:
`block_loads`/`block_balance` are the workerCompute summaries over a
`GraphBlocks` instance and `choose_node_moves` is the masterCompute move
selection that `runtime.run_stream` feeds into `graph.migrate_vertices`
when the streaming balance trips its threshold — this is how the numpy
protocol reaches the live mesh instead of staying an offline experiment.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

from . import partition as P_


@dataclass
class PartitionState:
    edges: np.ndarray   # (m, 2) original ids
    owner: np.ndarray   # (m,) block of each edge
    n: int
    P: int
    method: str         # 'hash' | 'random' | 'dfep' | 'vertex_cut'
    seed: int = 0


_STATIC = {
    "hash": lambda e, n, P, seed: P_.edge_hash_partition(e, P, seed),
    "random": lambda e, n, P, seed: P_.edge_random_partition(e, P, seed),
    "dfep": lambda e, n, P, seed: P_.dfep(e, n, P, seed),
    "vertex_cut": lambda e, n, P, seed: P_.vertex_cut_greedy(e, n, P),
}


def initial_partition(
    edges: np.ndarray, n: int, P: int, method: str, seed: int = 0
) -> Tuple[PartitionState, float]:
    """Run the static partitioner; returns (state, partitioning-time seconds)."""
    t0 = time.perf_counter()
    owner = _STATIC[method](np.asarray(edges), n, P, seed)
    pt = time.perf_counter() - t0
    return PartitionState(np.asarray(edges), owner, n, P, method, seed), pt


def incremental_part(
    st: PartitionState, new_edges: np.ndarray
) -> Tuple[PartitionState, float]:
    """IncrementalPart: assign only the new edges, keep everything else."""
    new_edges = np.asarray(new_edges)
    t0 = time.perf_counter()
    if st.method in ("hash", "random"):
        new_owner = _STATIC[st.method](new_edges, st.n, st.P, st.seed)
    elif st.method == "dfep":
        new_owner = P_.ub_update(st.edges, st.owner, new_edges, st.n, st.P)
    elif st.method == "vertex_cut":
        # true greedy continuation from the current per-node partition
        # sets (NOT DFEP's ub_update, which scores by owned-edge counts
        # and silently changes the heuristic mid-stream)
        new_owner = P_.vertex_cut_update(
            st.edges, st.owner, new_edges, st.n, st.P)
    else:
        raise ValueError(st.method)
    ut = time.perf_counter() - t0
    st2 = PartitionState(
        np.concatenate([st.edges, new_edges]),
        np.concatenate([st.owner, new_owner]),
        st.n, st.P, st.method, st.seed,
    )
    return st2, ut


def naive_part(
    st: PartitionState, new_edges: np.ndarray
) -> Tuple[PartitionState, float]:
    """NaivePart: throw the assignment away and repartition everything."""
    all_edges = np.concatenate([st.edges, np.asarray(new_edges)])
    t0 = time.perf_counter()
    owner = _STATIC[st.method](all_edges, st.n, st.P, st.seed)
    ut = time.perf_counter() - t0
    return PartitionState(all_edges, owner, st.n, st.P, st.method, st.seed), ut


def block_loads(g) -> np.ndarray:
    """workerCompute load summary (W2M): valid neighbor slots per block.

    Degree-sum is the superstep cost model of the block runtime — every
    valid slot is one gathered value per superstep — so it is the balance
    the §4.2 threshold protocol should act on (node counts would miss
    hub skew)."""
    return np.asarray(g.deg, dtype=np.int64).reshape(g.P, g.Cn).sum(axis=1)


def block_balance(g) -> float:
    """Imbalance summary the §4.2 masterCompute thresholds: max/mean load."""
    load = block_loads(g)
    return float(load.max() / max(1.0, load.mean()))


def choose_node_moves(
    g,
    max_moves: int = 8,
    balance_slack: float = 1.05,
    pair_counts: Optional[np.ndarray] = None,
    objective: str = "halo",
) -> list:
    """masterCompute move selection for live rebalancing (§4.2).

    Greedy, deterministic: while some block's load exceeds
    `balance_slack x mean`, move one of its real nodes to an underloaded
    block with free node capacity.  Two candidate objectives:

      * ``"halo"`` (default) — degree-aware halo-volume minimization.
        Moving u from b to b2 changes the per-superstep W2W payload by
        2*(aff[u][b] - aff[u][b2]) slots (u's outgoing slots plus the
        mirror-image slots of its neighbors), so the primary score is
        the volume reduction aff[b2] - aff[b]; ties break toward the
        smallest *residual* halo degree deg[u] - aff[b2] — the slots
        the move cannot internalize and that keep paying W2W every
        superstep — then toward destinations with the most existing
        pair traffic (`pair_counts` weighted by that residual: heavy
        boundary nodes go where their remaining halo overlaps traffic
        that already flows).
      * ``"load"`` — the original greedy (edge-cut gain, then heaviest
        node, the node-level analogue of `ub_update`'s "partition
        owning the most incident edges" rule), kept for the §4.2
        experiments and tests that pin its move trajectories.

    `pair_counts` (`graph.halo_pair_counts`) orders destination
    candidates by existing W2W traffic, so ties resolve toward the
    blocks the overloaded block already talks to.

    Only *pre-existing* padding slots count as capacity (slots vacated
    by the chosen moves do not), matching `migrate_vertices`' contract.
    Returns a list of (node_id, dest_block) — possibly empty when no
    admissible move helps.
    """
    if objective not in ("halo", "load"):
        raise ValueError(f"objective must be 'halo' or 'load', "
                         f"got {objective!r}")
    nbr = np.asarray(g.nbr)
    mask = np.asarray(g.node_mask)
    deg = np.asarray(g.deg, dtype=np.int64)
    P, Cn = g.P, g.Cn
    load = block_loads(g)
    mean = max(1.0, float(load.mean()))
    free = np.array([
        int((~mask[b * Cn:(b + 1) * Cn]).sum()) for b in range(P)
    ])
    moves: list = []
    moved: set = set()
    while len(moves) < max_moves:
        b = int(np.argmax(load))
        if load[b] <= balance_slack * mean:
            break
        dests = [b2 for b2 in range(P)
                 if b2 != b and free[b2] > 0 and load[b2] < mean]
        if not dests:
            break
        if pair_counts is not None:
            dests.sort(key=lambda b2: (-int(pair_counts[b, b2]), b2))
        rows = np.arange(b * Cn, (b + 1) * Cn)
        real = rows[mask[rows]]
        # per-node destination-block affinities, one bincount for the
        # whole block (aff[i, p] = neighbors of real[i] living in p)
        nb = nbr[real]
        valid = nb >= 0
        ri, si = np.nonzero(valid)
        aff = np.zeros((len(real), P), np.int64)
        np.add.at(aff, (ri, nb[ri, si] // Cn), 1)
        best = None
        for i, u in enumerate(real):
            u = int(u)
            if u in moved or deg[u] == 0:
                continue
            for j, b2 in enumerate(dests):
                # post-move bound: never push the destination past the
                # slack line, or a hub ping-pongs between blocks (each
                # bounce is a migration — and a full plan rebuild on the
                # mesh path)
                if load[b2] + deg[u] > balance_slack * mean:
                    continue
                gain = int(aff[i, b2]) - int(aff[i, b])
                if objective == "halo":
                    # key maximized lexicographically: W2W volume cut,
                    # then least residual halo degree, then heaviest
                    # node, lowest id, traffic-ordered destination
                    residual = int(deg[u]) - int(aff[i, b2])
                    cand = (gain, -residual, int(deg[u]), -u, -j)
                else:
                    # key: best cut gain, then heaviest node (most load
                    # shed per move), lowest id, traffic-ordered dest
                    cand = (gain, int(deg[u]), -u, -j)
                if best is None or cand > best[0]:
                    best = (cand, u, b2)
        if best is None:
            break
        _, u, b2 = best
        moves.append((u, b2))
        moved.add(u)
        load[b] -= deg[u]
        load[b2] += deg[u]
        free[b2] -= 1
    return moves


def delete_edges(
    st: PartitionState,
    idx: np.ndarray,
    threshold: float = 1.5,
) -> Tuple[PartitionState, bool, float]:
    """Deletion protocol (§4.2): drop edges, workers report balance, the
    coordinator repartitions iff imbalance exceeds `threshold`.

    Returns (state', repartitioned?, update-time seconds).
    """
    t0 = time.perf_counter()
    keep = np.ones(len(st.edges), bool)
    keep[np.asarray(idx)] = False
    edges = st.edges[keep]
    owner = st.owner[keep]
    # workerCompute: per-block sizes (W2M); masterCompute: threshold test
    bal = P_.edge_balance(owner, st.P)
    repart = bal > threshold
    if repart:
        owner = _STATIC[st.method](edges, st.n, st.P, st.seed)
    ut = time.perf_counter() - t0
    return (
        PartitionState(edges, owner, st.n, st.P, st.method, st.seed),
        bool(repart),
        ut,
    )
