"""Skew-aware hub mirroring: vertex-cut replicas inside the block runtime.

Power-law graphs break the ELL layout's economics: ONE celebrity vertex
sets ``Cd`` for every row of ``GraphBlocks.nbr``, inflating memory,
gather work, and W2W halo payload for the whole mesh.  This module adds
the vertex-cut answer (PowerGraph-style, per the distributed-graph
analysis in PAPERS.md) *without* changing the block-centric runtime:

  * `split_hubs(g, threshold)` rewrites the graph so every vertex with
    ``deg > threshold`` becomes a **primary** row (its original row id)
    plus **mirror replica** rows, each holding one slice of at most
    ``threshold`` neighbors — so the split graph's ``Cd`` is the
    threshold, not the max degree.  Replicas occupy *existing padding
    rows*, preferentially in the block of the slice's readers (that
    locality is the halo-payload win), so every real row keeps its
    original index: CC label space, `orig_id` semantics, and the
    `to_networkx_edges` oracle are untouched.
  * The split graph is a **plain valid GraphBlocks** — sorted-ELL rows,
    exact degrees, nothing above `GraphBlocks` needs to know.  All
    kernels, `HaloPlan` tables, and the SPMD executor run it unchanged.
  * The `MirrorPlan` carries the replica bookkeeping the runner needs:
    which rows form a group, each row's primary, and the *logical*
    degree.  `kernels.ops.run_block_program(..., mirror=plan)` inserts a
    **combine-then-broadcast merge** between the neighbor combine and
    `BlockProgram.update`: per-slice partial aggregates are merged per
    group (min/sum exactly associative; hindex via count-histogram
    partials, the ``variant="count"`` formulation) and the merged value
    is written back to every group row.  Because program state is
    replicated onto mirror rows (`BlockProgram.mirror_state`), replicas
    advance in lockstep with their primary and every *reader* of a
    replica row sees the primary's value — results are exact vs the
    unsplit graph on all backends (bit-exact for the integer combines,
    float-reassociation-tolerant for "sum").
  * "count_common" (triangles) exchanges whole neighbor rows, which a
    slice cannot serve locally; `run_common_mirror` runs it exactly via
    a canonicalized-row kernel pass plus per-slice pairwise corrections
    (see the function docstring).
  * `apply_mirrored_edits` is the host mutation path: capacity-routed
    inserts, ON-LINE splits when an insert would push a vertex over the
    threshold (the new edge lands in the freshly-allocated replica, so
    no existing row is rewired), and mirrored deletes that locate and
    splice the one (row_u, row_v) pair holding the edge.

Host-boundary module: construction, mutation, and the triangle
corrections are numpy preprocessing, same contract as `build_blocks` /
`migrate_vertices`.  The merge stage itself is pure device code in
`kernels.ops._mirror_merge` / `runtime.spmd.SpmdBlockProgram`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import (PAD, CapacityError, GraphBlocks, _occurrence_ranks,
                    halo_slot_counts, relocate_rows, sort_nbr_rows)

#: monotonic MirrorPlan identity counter — the SPMD fused loop closes over
#: the plan arrays (they are compile-time constants of the shard_map'd
#: step), so every plan with distinct array *content* must carry a distinct
#: `uid` for the compiled-step caches to key on (see CACHE_SCHEMAS).
_UID_COUNTER = [0]


def _next_uid() -> int:
    _UID_COUNTER[0] += 1
    return _UID_COUNTER[0]


def _pow2(x: int, floor: int = 8) -> int:
    """Smallest power of two >= x, floored (compile-cache-stable sizing)."""
    k = floor
    while k < x:
        k *= 2
    return k


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MirrorPlan:
    """Replica bookkeeping for a hub-split graph (see module docstring).

    Attributes
    ----------
    primary_row:  (N,) int32 — primary row of each row's logical vertex
                  (self for non-replica rows, including padding).
    ldeg:         (N,) int32 — *logical* degree of the row's vertex (the
                  unsplit degree; 0 on padding rows).  This is what
                  `BlockCtx.deg` must carry under a mirrored run.
    primary_mask: (N,) bool — True for real non-replica rows; one True
                  per logical vertex (the frame init/queries reason in).
    grp_rows:     (Rp,) int32 — rows belonging to split groups, padded
                  with 0 (pad entries carry gid == Gmax and are inert).
    grp_gid:      (Rp,) int32 — group id per entry; Gmax on padding.
    row_gid:      (N,) int32 — group id of each row; Gmax off-group.
    Gmax, Km:     static ints — pow2-bucketed group count / max logical
                  hub degree (the hindex histogram width; exact because
                  a merged h-index never exceeds the logical degree).
    threshold:    static int — the split threshold == per-slice capacity.
    n_logical:    static int — real *logical* vertex count (what
                  `BlockCtx.n_real` must carry under a mirrored run).
    uid:          static int — plan identity token (see `_UID_COUNTER`).
    """

    primary_row: jax.Array
    ldeg: jax.Array
    primary_mask: jax.Array
    grp_rows: jax.Array
    grp_gid: jax.Array
    row_gid: jax.Array
    Gmax: int = dataclasses.field(metadata=dict(static=True))
    Km: int = dataclasses.field(metadata=dict(static=True))
    threshold: int = dataclasses.field(metadata=dict(static=True))
    n_logical: int = dataclasses.field(metadata=dict(static=True))
    uid: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_groups(self) -> int:
        gid = np.asarray(self.grp_gid)
        return len(np.unique(gid[gid < self.Gmax]))


def groups_of(plan: MirrorPlan) -> Dict[int, List[int]]:
    """Host view of the split groups: {primary row: [rows, primary first]}."""
    rows = np.asarray(plan.grp_rows)
    gid = np.asarray(plan.grp_gid)
    prow = np.asarray(plan.primary_row)
    out: Dict[int, List[int]] = {}
    for r, gx in zip(rows, gid):
        if gx >= plan.Gmax:
            continue
        out.setdefault(int(prow[r]), []).append(int(r))
    # primary first, replicas in allocation order (ascending is canonical)
    return {h: sorted(rs, key=lambda r: (r != h, r)) for h, rs in out.items()}


def _free_rows(mask: np.ndarray, Cn: int, P: int) -> Dict[int, List[int]]:
    """Free (padding) rows per block, ascending — replica allocation pool."""
    return {
        b: list(np.flatnonzero(~mask[b * Cn:(b + 1) * Cn]) + b * Cn)
        for b in range(P)
    }


def _alloc_replica(free: Dict[int, List[int]], pref: int, own: int) -> int:
    """Pop a free row: reader's block first, then the hub's, then any."""
    for b in (pref, own):
        if free.get(b):
            return free[b].pop(0)
    for b in sorted(free):
        if free[b]:
            return free[b].pop(0)
    raise CapacityError(
        "no free padding rows left for hub mirror replicas; rebuild the "
        "graph with node capacity headroom (build_blocks(node_slack=...)) "
        "or grow Cn (graph.grow_blocks / MirrorStream auto_grow)")


# ---------------------------------------------------------------------------
# Sorted-slice splice helpers (host-side numpy): the slice analogues of
# graph._sorted_insert_row/_sorted_delete_row.  Registered with tracelint's
# sorted-ELL rule — every mirror-path nbr write routes through these or
# through sort_nbr_rows.
# ---------------------------------------------------------------------------


def _sorted_slice_insert(row: np.ndarray, fill: int, val: int) -> None:
    """Insert `val` into a sorted ELL row slice in place (fill = old count).

    Shifts the tail right by one; caller guarantees fill < len(row) and
    `val` absent.  Keeps valid slots ascending with pads on the right.
    """
    pos = int(np.searchsorted(row[:fill], val))
    row[pos + 1:fill + 1] = row[pos:fill]
    row[pos] = val


def _sorted_slice_delete(row: np.ndarray, fill: int, val: int) -> None:
    """Remove `val` from a sorted ELL row slice in place (fill = old count).

    Shifts the tail left over the hole and re-pads the vacated slot.
    """
    pos = int(np.searchsorted(row[:fill], val))
    row[pos:fill - 1] = row[pos + 1:fill]
    row[fill - 1] = PAD


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def split_hubs(g: GraphBlocks, threshold: int) -> Tuple[GraphBlocks,
                                                        MirrorPlan]:
    """Split every vertex with deg > threshold into primary + mirror rows.

    Returns ``(g2, plan)`` where ``g2`` is a plain valid GraphBlocks with
    ``Cd == threshold`` and the same (P, Cn): hubs keep their original
    row as the primary (holding the first slice) and each further slice
    of at most `threshold` neighbors lands in an existing padding row —
    preferentially in the block its slice members live in, so slice
    reads stay block-local.  Non-hub rows are byte-identical up to the
    column truncation.  Raises when a block runs out of padding rows
    (build with `build_blocks(node_slack=...)` headroom).

    Both endpoint sides of an edge re-point at the serving row of the
    other side, so ``g2`` is a consistent undirected ELL graph and every
    row obeys the sorted-ELL invariant (established by `sort_nbr_rows`).
    Host-side preprocessing; raises under a trace.
    """
    if isinstance(g.nbr, jax.core.Tracer):
        raise TypeError("split_hubs is host-side preprocessing; it cannot "
                        "run under jit/vmap tracing.")
    t = int(threshold)
    if t < 1:
        raise ValueError(f"threshold must be >= 1, got {t}")
    nbr = np.asarray(g.nbr, np.int64)
    deg = np.asarray(g.deg, np.int64)
    mask = np.asarray(g.node_mask).copy()
    orig = np.asarray(g.orig_id, np.int64).copy()
    N, Cn, Cd = g.N, g.Cn, g.Cd

    hubs = np.flatnonzero(mask & (deg > t))
    free = _free_rows(mask, Cn, g.P)

    # serving-row maps, per directed slot of the ORIGINAL graph:
    #   rew[u, j]  — the row that holds u's slot j after the split
    #   rew2[u, j] — the row the slot's content re-points to (the partner
    #                endpoint's serving row for this edge)
    rew = np.repeat(np.arange(N, dtype=np.int64), Cd).reshape(N, Cd)
    rew2 = nbr.copy()
    groups: List[Tuple[int, List[int]]] = []
    for h in hubs:
        d = int(deg[h])
        nb = nbr[h, :d]  # sorted (ELL invariant)
        own = h // Cn
        blk = nb // Cn
        # own-block members first, then grouped by reader block: consecutive
        # chunks of <= t then cut along block boundaries where possible
        order = np.lexsort((nb, np.where(blk == own, -1, blk)))
        nb_o = nb[order]
        n_chunks = -(-d // t)
        rows_h = [int(h)]
        for ci in range(1, n_chunks):
            chunk = nb_o[ci * t:(ci + 1) * t]
            r = _alloc_replica(free, int(chunk[0] // Cn), int(own))
            rows_h.append(r)
            mask[r] = True
            orig[r] = orig[h]
        groups.append((int(h), rows_h))
        for ci, r in enumerate(rows_h):
            chunk = nb_o[ci * t:(ci + 1) * t]
            # u-side: these slots are served by row r
            rew[h, np.searchsorted(nb, chunk)] = r
            # partner side: w's slot pointing at h re-points to r
            for w in chunk:
                pos = np.searchsorted(nbr[w, :deg[w]], h)
                rew2[w, pos] = r

    valid = nbr >= 0
    src = rew[valid]
    dst = rew2[valid]
    nbr2 = np.full((N, t), PAD, np.int64)
    ranks = _occurrence_ranks(src)
    if ranks.size and ranks.max() >= t:
        raise AssertionError("slice overflow — split_hubs chunking bug")
    nbr2[src, ranks] = dst
    deg2 = np.bincount(src, minlength=N)
    nbr2 = sort_nbr_rows(nbr2)  # establish the sorted-ELL invariant

    g2 = GraphBlocks(
        nbr=jnp.asarray(nbr2, jnp.int32),
        deg=jnp.asarray(deg2, jnp.int32),
        node_mask=jnp.asarray(mask),
        orig_id=jnp.asarray(orig, jnp.int32),
        P=g.P, Cn=Cn, Cd=t,
    )
    plan = _plan_from_groups(
        N=N, deg_logical_of_row=deg, mask=mask,
        groups={h: rs for h, rs in groups}, threshold=t,
        n_logical=int(np.asarray(g.node_mask).sum()))
    return g2, plan


def _plan_from_groups(N: int, deg_logical_of_row: np.ndarray,
                      mask: np.ndarray, groups: Dict[int, List[int]],
                      threshold: int, n_logical: int) -> MirrorPlan:
    """Assemble a MirrorPlan from {primary: [rows]} (host bookkeeping)."""
    prow = np.arange(N, dtype=np.int64)
    for h, rows_h in groups.items():
        prow[rows_h] = h
    ldeg = np.where(mask, deg_logical_of_row[prow], 0)
    primary_mask = mask & (prow == np.arange(N))

    n_rows = sum(len(rs) for rs in groups.values())
    Gmax = _pow2(max(1, len(groups)))
    Rp = _pow2(max(1, n_rows))
    grp_rows = np.zeros(Rp, np.int64)
    grp_gid = np.full(Rp, Gmax, np.int64)
    row_gid = np.full(N, Gmax, np.int64)
    i = 0
    for gx, (h, rows_h) in enumerate(sorted(groups.items())):
        for r in rows_h:
            grp_rows[i] = r
            grp_gid[i] = gx
            row_gid[r] = gx
            i += 1
    Km = _pow2(int(ldeg[list(groups)].max()) if groups else 1)
    return MirrorPlan(
        primary_row=jnp.asarray(prow, jnp.int32),
        ldeg=jnp.asarray(ldeg, jnp.int32),
        primary_mask=jnp.asarray(primary_mask),
        grp_rows=jnp.asarray(grp_rows, jnp.int32),
        grp_gid=jnp.asarray(grp_gid, jnp.int32),
        row_gid=jnp.asarray(row_gid, jnp.int32),
        Gmax=Gmax, Km=Km, threshold=int(threshold),
        n_logical=int(n_logical), uid=_next_uid(),
    )


def grow_plan(plan: MirrorPlan, rekey: np.ndarray, g2: GraphBlocks
              ) -> MirrorPlan:
    """Relocate a MirrorPlan onto the post-`graph.grow_blocks` node axis.

    `rekey` is the (N_old,) old-id -> new-id map grow_blocks returned and
    `g2` the grown graph.  The rekey is monotone, so group ordering and
    the canonical within-group row order survive; the rebuilt plan is the
    relocated original with a fresh `uid` (the mirrored compiled step
    re-keys exactly once per grow).  Host-side.
    """
    groups = {int(rekey[h]): [int(rekey[r]) for r in rs]
              for h, rs in groups_of(plan).items()}
    ldeg = relocate_rows(np.asarray(plan.ldeg), rekey, g2.N, 0)
    return _plan_from_groups(
        N=g2.N, deg_logical_of_row=ldeg, mask=np.asarray(g2.node_mask),
        groups=groups, threshold=plan.threshold,
        n_logical=plan.n_logical)


# ---------------------------------------------------------------------------
# On-line mutation: capacity-routed inserts, threshold-triggered splits,
# mirrored deletes.
# ---------------------------------------------------------------------------


def apply_mirrored_edits(
    g2: GraphBlocks, plan: MirrorPlan,
    edits: Iterable[Tuple[int, int, int]],
) -> Tuple[GraphBlocks, MirrorPlan]:
    """Apply (u, v, op) edits to a split graph; ids are PRIMARY row ids.

    op = +1 insert / -1 delete, sequential in order, exact:

      * an insert routes each endpoint to its first row with slice
        capacity left; a vertex whose every row is full gets a fresh
        replica (an **on-line split** when it was single-row: crossing
        the threshold is what filled it) — the new edge lands in the new
        replica, so no existing row is rewired;
      * a delete locates the ONE (row_u, row_v) pair holding the edge
        (slices partition the neighborhood) and splices both sides.

    Returns ``(g2', plan')``; the plan always carries a fresh `uid`
    (array content changed), so mirrored SPMD runs recompile per edit
    batch — batch edits per window, like the stream does.  Empty
    replicas left behind by deletes are retained: they are inert under
    every merge.  Host-side preprocessing; raises under a trace.
    """
    if isinstance(g2.nbr, jax.core.Tracer):
        raise TypeError("apply_mirrored_edits is host-side preprocessing; "
                        "it cannot run under jit/vmap tracing.")
    nbr = np.asarray(g2.nbr, np.int64).copy()
    deg = np.asarray(g2.deg, np.int64).copy()
    mask = np.asarray(g2.node_mask).copy()
    orig = np.asarray(g2.orig_id, np.int64).copy()
    prow = np.asarray(plan.primary_row, np.int64).copy()
    ldeg = np.asarray(plan.ldeg, np.int64).copy()
    N, Cn, Cd2 = g2.N, g2.Cn, g2.Cd
    t = plan.threshold
    groups = groups_of(plan)
    free = _free_rows(mask, Cn, g2.P)

    def rows_of(u: int) -> List[int]:
        return groups.get(u, [u])

    def edge_pair(u: int, v: int) -> Optional[Tuple[int, int]]:
        """The (row_u, row_v) holding edge (u, v), or None if absent."""
        rv_set = set(rows_of(v))
        for ru in rows_of(u):
            for x in nbr[ru, :deg[ru]]:
                if int(x) in rv_set:
                    return ru, int(x)
        return None

    def route(u: int, pref_block: int) -> int:
        """Row of u taking one more neighbor; allocates a replica if full."""
        for r in rows_of(u):
            if deg[r] < Cd2:
                return r
        r = _alloc_replica(free, pref_block, u // Cn)
        mask[r] = True
        orig[r] = orig[u]
        prow[r] = u
        groups[u] = rows_of(u) + [r]
        return r

    for u, v, op in edits:
        u, v, op = int(u), int(v), int(op)
        for x in (u, v):
            if not (0 <= x < N) or not mask[x] or prow[x] != x:
                raise ValueError(f"{x} is not a primary row of a real node")
        if u == v:
            raise ValueError(f"self-loop on {u}")
        pair = edge_pair(u, v)
        if op > 0:
            if pair is not None:
                raise ValueError(f"edge ({u}, {v}) already present")
            ru = route(u, v // Cn)
            rv = route(v, ru // Cn)
            _sorted_slice_insert(nbr[ru], int(deg[ru]), rv)
            _sorted_slice_insert(nbr[rv], int(deg[rv]), ru)
            deg[ru] += 1
            deg[rv] += 1
            ldeg[rows_of(u)] += 1
            ldeg[rows_of(v)] += 1
        elif op < 0:
            if pair is None:
                raise ValueError(f"edge ({u}, {v}) not present")
            ru, rv = pair
            _sorted_slice_delete(nbr[ru], int(deg[ru]), rv)
            _sorted_slice_delete(nbr[rv], int(deg[rv]), ru)
            deg[ru] -= 1
            deg[rv] -= 1
            ldeg[rows_of(u)] -= 1
            ldeg[rows_of(v)] -= 1
        else:
            raise ValueError(f"op must be +1/-1, got {op}")

    g3 = dataclasses.replace(
        g2,
        nbr=jnp.asarray(nbr, jnp.int32),
        deg=jnp.asarray(deg, jnp.int32),
        node_mask=jnp.asarray(mask),
        orig_id=jnp.asarray(orig, jnp.int32),
    )
    plan2 = _plan_from_groups(
        N=N, deg_logical_of_row=ldeg, mask=mask,
        groups=groups, threshold=t, n_logical=plan.n_logical)
    return g3, plan2


# ---------------------------------------------------------------------------
# Exact triangle counting on a split graph ("count_common" route).
# ---------------------------------------------------------------------------


class _RawCommonProgram:
    """Internal one-superstep program: raw count_common reduction.

    Mirrors TriangleCountProgram's shape but stores the raw reduction so
    `run_common_mirror` can correct + merge before the real program's
    single `update`.  Duck-types the BlockProgram contract (hashable
    static; `kernels.ops.run_block_program` is the runner).
    """

    combine = "count_common"
    halo_fill = -1
    max_steps = 1

    def __hash__(self):
        return hash(type(self))

    def __eq__(self, other):
        return type(other) is type(self)

    def init(self, g):
        return (jnp.zeros(g.N, jnp.int32), jnp.asarray(g.nbr, jnp.int32))

    def halo_field(self, state):
        return state[1]

    def update(self, ctx, state, red):
        return (red.astype(jnp.int32), state[1])

    def changed(self, old, new):
        return jnp.bool_(True)


def _slice_sets(nbr: np.ndarray, deg: np.ndarray, rows: List[int]):
    """Canonical (primary-id, sorted, unique) member sets of given rows."""
    return [nbr[r, :deg[r]] for r in rows]


def run_common_mirror(g2: GraphBlocks, plan: MirrorPlan, program,
                      backend: str = "jnp",
                      interpret: Optional[bool] = None,
                      with_steps: bool = False,
                      state0=None):
    """Exact "count_common" (triangles) on a split graph, any backend.

    The slice rows make the naive kernel wrong twice over: row contents
    are *serving-row* ids (a hub appears under several ids), and a slot
    (u → v) only intersects u's own slice with ONE slice of v.  The
    exact route:

      1. **canonicalize** — map every stored id to its primary
         (`primary_row[nbr]`) and re-sort; the kernel then counts, per
         directed slot held by row a pointing at logical B,
         ``|C(a) ∩ C(primary_B)|`` where C(x) is row x's canonical
         member set (slices partition neighborhoods, so member sets are
         duplicate-free and the sorted-merge kernels stay exact);
      2. **correct** (host numpy) — each such slot needs the full grid
         ``Σ_{a'∈rows(A), b'∈rows(B)} |C(a') ∩ C(b')|``; the per-slot
         shortfall is credited to the row holding the slot.  Only slots
         with a hub endpoint need corrections, so the work is
         O(Σ_hub deg · slices);
      3. **merge + update** — group-sum the corrected reduction (every
         logical count lands on all of its rows) and run the real
         program's single `update` with the logical ctx.

    Returns like `run_block_program` (state, plus a superstep count of 1
    when `with_steps=True`).  `state0` is accepted for signature parity
    with the runner; count_common programs are single-step, so it only
    seeds non-counter state fields.
    """
    from ..kernels.ops import BlockCtx, run_block_program  # loaded by now

    nbr = np.asarray(g2.nbr, np.int64)
    deg = np.asarray(g2.deg, np.int64)
    prow_np = np.asarray(plan.primary_row, np.int64)
    canon = np.where(nbr >= 0, prow_np[np.maximum(nbr, 0)], PAD)
    canon = sort_nbr_rows(canon)
    gc = dataclasses.replace(g2, nbr=jnp.asarray(canon, jnp.int32))

    # 1. kernel pass on the canonical rows (fresh executor on the spmd
    #    backend: the halo plan must derive from gc's adjacency)
    raw_state = run_block_program(gc, _RawCommonProgram(), backend=backend,
                                  interpret=interpret)
    red = np.asarray(raw_state[0], np.int64)

    # 2. per-slot corrections for hub-incident edges
    groups = groups_of(plan)
    corr = np.zeros(g2.N, np.int64)
    for h, rows_h in groups.items():
        sets_h = _slice_sets(canon, deg, rows_h)
        union_pos = {r: i for i, r in enumerate(rows_h)}
        for r in rows_h:
            for xrow in nbr[r, :deg[r]]:
                xrow = int(xrow)
                W = int(prow_np[xrow])
                cx = canon[xrow, :deg[xrow]]
                inter = [len(np.intersect1d(cx, s, assume_unique=True))
                         for s in sets_h]
                if W in groups:
                    # hub–hub edge: handle only the (xrow -> h) direction
                    # here; the reverse appears when W's group is walked.
                    grid = sum(
                        len(np.intersect1d(
                            canon[y, :deg[y]], s, assume_unique=True))
                        for y in groups[W] for s in sets_h)
                    corr[xrow] += grid - inter[0]
                else:
                    # hub–nonhub edge: both directed slots settled here.
                    corr[xrow] += sum(inter) - inter[0]
                    corr[r] += sum(inter) - inter[union_pos[r]]
    red = red + corr

    # 3. group-sum merge: every row of a group carries the logical count
    for h, rows_h in groups.items():
        red[rows_h] = red[rows_h].sum()

    ctx = BlockCtx(deg=jnp.asarray(plan.ldeg, jnp.int32),
                   node_mask=g2.node_mask, n_real=plan.n_logical)
    if state0 is None:
        state0 = program.init(gc)
    state = program.update(ctx, state0, jnp.asarray(red, jnp.int32))
    return (state, jnp.int32(1)) if with_steps else state


# ---------------------------------------------------------------------------
# Accounting: the allocation + halo-payload story the benchmarks assert.
# ---------------------------------------------------------------------------


def mirror_report(g: GraphBlocks, g2: GraphBlocks,
                  plan: MirrorPlan) -> Dict[str, float]:
    """Allocation + per-superstep W2W payload, unsplit vs split.

    `slots_*` are the N·Cd ELL allocations (the memory the gather kernels
    sweep); `inter_*` the cross-block valid neighbor slots (the W2W halo
    payload of a one-value-per-slot superstep, `halo_slot_counts`);
    `merge_payload` the extra per-superstep elements the mirror merge
    moves (see `runtime.halo.mirror_merge_payload`).
    """
    from ..runtime.halo import mirror_merge_payload  # lazy: no cycle
    intra_u, inter_u = halo_slot_counts(g)
    intra_s, inter_s = halo_slot_counts(g2)
    return dict(
        slots_unsplit=g.N * g.Cd,
        slots_split=g2.N * g2.Cd,
        alloc_ratio=(g.N * g.Cd) / max(1, g2.N * g2.Cd),
        inter_unsplit=inter_u,
        inter_split=inter_s,
        intra_unsplit=intra_u,
        intra_split=intra_s,
        merge_payload=mirror_merge_payload(plan),
        n_groups=len(groups_of(plan)),
    )
