"""Workload library on the `BlockProgram` contract (BLADYG as a framework).

BLADYG's central claim is that the block-centric abstraction — partition,
block-local compute, W2W/W2M exchange, coordinator convergence — is
workload-generic, not a k-core implementation detail.  This module is the
proof: each workload below is a ~20-line `BlockProgram` (state + halo
field + named neighbor combine + update + halt), and the SAME program
object runs unchanged on every backend of the kernel registry through
`kernels.ops.run_block_program` — pure-jnp, dense-tile, ELL Pallas, or
sharded over the worker mesh with a real halo exchange.

Shipped workloads (the canonical kernel set of the "Thinking Like a
Vertex" survey):

  `ConnectedComponentsProgram` — min-label propagation: every node starts
      labeled with its own padded id and repeatedly keeps the minimum
      label among itself and its neighbors, so each component converges
      to the minimum padded id of its members (the *canonical* labeling;
      supersteps ~ component diameter).  Edge insertions merge two
      components and preserve canonicality in O(1) supersteps
      (`merge_labels`) — the natural dynamic workload of the stream loop.
  `PageRankProgram` — push-style PageRank on the undirected graph: the
      exchanged field is each node's outgoing contribution rank/deg, the
      combine is "sum", and the update applies teleport + damping.
      `tol=None` gives the fixed-iteration variant (`max_steps`
      supersteps exactly); a float tol halts when no node moved more
      than tol.  Mass at dangling (degree-0) real nodes is NOT
      redistributed — it decays into the teleport term; the test oracle
      implements the same convention.
  `TriangleCountProgram` — one "count_common" superstep over halo'd
      neighbor rows: red[u] counts ordered common-neighbor pairs, i.e.
      2 × triangles through u.  Per-node counts; sum/3 is the global
      total.
  `CorenessBlockProgram` — the §4.1 min-H iteration re-expressed on the
      contract (combine "hindex"): the program whose implicit structure
      this abstraction was extracted from.  The dedicated
      `ops.coreness_blocks` fixpoints remain the tuned production path
      (degree-bounded K, pad-once); this program is the parity witness
      that the contract subsumes them.

Doctest (the quickstart in 5 lines — swap the program, keep the runner):

    >>> import numpy as np
    >>> from repro.core import build_blocks
    >>> from repro.core.algorithms import (
    ...     connected_components, triangle_counts)
    >>> edges = np.array([[0, 1], [1, 2], [0, 2], [3, 4]])
    >>> g = build_blocks(edges, 5, np.array([0, 0, 0, 1, 1]), P=2)
    >>> mask = np.asarray(g.node_mask)
    >>> np.asarray(connected_components(g))[mask]        # min-id labels
    array([0, 0, 0, 8, 8], dtype=int32)
    >>> np.asarray(triangle_counts(g))[mask]             # one triangle
    array([1, 1, 1, 0, 0], dtype=int32)
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .engine import BlockCtx, BlockProgram, MultiProgram
from .graph import GraphBlocks

#: the CC label of padding rows and the min-combine's absorbing fill
INT32_MAX = int(np.iinfo(np.int32).max)


class ConnectedComponentsProgram(BlockProgram):
    """Min-label propagation; converges to each component's min padded id."""

    combine = "min"
    halo_fill = INT32_MAX
    max_steps = 10_000

    def init(self, g: GraphBlocks) -> jax.Array:
        return jnp.where(g.node_mask, jnp.arange(g.N, dtype=jnp.int32),
                         INT32_MAX)

    def halo_field(self, state: jax.Array) -> jax.Array:
        return state

    def update(self, ctx: BlockCtx, state: jax.Array,
               red: jax.Array) -> jax.Array:
        return jnp.where(ctx.node_mask, jnp.minimum(state, red), state)


class PageRankProgram(BlockProgram):
    """Push-style PageRank; state = (rank, contribution), field = contrib.

    rank'[u] = (1 - alpha)/n_real + alpha * sum_{v ~ u} rank[v]/deg[v]
    on real nodes (0 on padding).  `tol` is the per-node halt tolerance
    on |rank' - rank| (None = fixed-iteration: exactly `max_steps`
    supersteps); ranks are float32 throughout, so cross-backend parity is
    allclose, not bit equality.
    """

    combine = "sum"
    halo_fill = 0.0

    def __init__(self, alpha: float = 0.85, tol: Optional[float] = 1e-6,
                 max_steps: int = 100):
        self.alpha = float(alpha)
        self.tol = None if tol is None else float(tol)
        self.max_steps = int(max_steps)

    def _key(self):
        return (self.alpha, self.tol, self.max_steps)

    def _contrib(self, deg: jax.Array, rank: jax.Array) -> jax.Array:
        return jnp.where(deg > 0, rank / jnp.maximum(deg, 1),
                         0.0).astype(jnp.float32)

    def init(self, g: GraphBlocks) -> Tuple[jax.Array, jax.Array]:
        n = jnp.maximum(jnp.sum(g.node_mask.astype(jnp.float32)), 1.0)
        rank = jnp.where(g.node_mask, 1.0 / n, 0.0).astype(jnp.float32)
        return rank, self._contrib(g.deg, rank)

    def halo_field(self, state) -> jax.Array:
        return state[1]

    def update(self, ctx: BlockCtx, state, red: jax.Array):
        base = (1.0 - self.alpha) / ctx.n_real
        rank = jnp.where(ctx.node_mask, base + self.alpha * red,
                         0.0).astype(jnp.float32)
        return rank, self._contrib(ctx.deg, rank)

    def changed(self, old, new) -> jax.Array:
        if self.tol is None:
            return jnp.bool_(True)  # fixed-iteration: max_steps bounds it
        return jnp.any(jnp.abs(new[0] - old[0]) > self.tol)


class TriangleCountProgram(BlockProgram):
    """One "count_common" superstep; state = (per-node counts, nbr rows)."""

    combine = "count_common"
    halo_fill = -1
    max_steps = 1  # a single exchange computes every count

    def init(self, g: GraphBlocks):
        return jnp.zeros(g.N, jnp.int32), jnp.asarray(g.nbr, jnp.int32)

    def halo_field(self, state) -> jax.Array:
        return state[1]

    def update(self, ctx: BlockCtx, state, red: jax.Array):
        # red[u] = ordered common-neighbor pairs = 2 * triangles at u
        return red // 2, state[1]

    def mirror_state(self, state, primary_row: jax.Array):
        # counts are per-vertex (replicate); neighbor rows are per-ROW
        # slices — gathering them through primaries would duplicate the
        # primary's slice onto every mirror.
        return state[0][primary_row], state[1]


class CorenessBlockProgram(BlockProgram):
    """§4.1 min-H coreness on the generic contract (parity witness)."""

    combine = "hindex"
    halo_fill = -1
    max_steps = 10_000

    def init(self, g: GraphBlocks) -> jax.Array:
        return jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)

    def halo_field(self, state: jax.Array) -> jax.Array:
        return state

    def update(self, ctx: BlockCtx, state: jax.Array,
               red: jax.Array) -> jax.Array:
        return jnp.where(ctx.node_mask, jnp.minimum(state, red), state)


# ---------------------------------------------------------------------------
# Friendly entry points (thin wrappers over `ops.run_block_program`).
# ---------------------------------------------------------------------------


def connected_components(
    g: GraphBlocks,
    backend: str = "auto",
    executor=None,
    max_steps: Optional[int] = None,
    with_steps: bool = False,
    mirror=None,
) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Canonical component labels: label[u] = min padded id of u's component.

    Returns (N,) int32 with -1 on padding rows (plus the superstep count
    as a device scalar when `with_steps=True`).  Identical integers on
    every backend; supersteps scale with the largest component diameter.

    `mirror` (a `core.hub_split.MirrorPlan`) runs the hub-split dataflow;
    mirror rows only ever carry their primary's id, so labels stay in the
    unsplit id space and primaries match the unsplit run bit-exactly.
    """
    out = ops.run_block_program(
        g, ConnectedComponentsProgram(), backend=backend, executor=executor,
        max_steps=max_steps, with_steps=with_steps, mirror=mirror)
    state, steps = out if with_steps else (out, None)
    labels = jnp.where(g.node_mask, state, -1)
    return (labels, steps) if with_steps else labels


def pagerank(
    g: GraphBlocks,
    alpha: float = 0.85,
    tol: Optional[float] = 1e-6,
    max_steps: int = 100,
    backend: str = "auto",
    executor=None,
    with_steps: bool = False,
    mirror=None,
) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Push-style PageRank over the undirected graph; (N,) float32 ranks.

    `tol=None` runs exactly `max_steps` supersteps (the fixed-iteration
    variant); otherwise the fused loop halts once no node moves more than
    `tol`.  Padding rows hold 0.0.  Under `mirror` (hub split) the slice
    partials re-associate the float sums — allclose to the unsplit run,
    not bit-equal (the integer workloads ARE bit-equal).
    """
    prog = PageRankProgram(alpha=alpha, tol=tol, max_steps=max_steps)
    out = ops.run_block_program(
        g, prog, backend=backend, executor=executor, with_steps=with_steps,
        mirror=mirror)
    if with_steps:
        (rank, _), steps = out
        return rank, steps
    return out[0]


def triangle_counts(
    g: GraphBlocks,
    backend: str = "auto",
    executor=None,
    with_steps: bool = False,
    mirror=None,
) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Per-node triangle counts ((N,) int32, 0 on padding rows).

    tri[u] = number of triangles containing u; the global total is
    `triangle_total(counts)` = sum / 3 (each triangle has 3 corners).
    One superstep on every backend.  Under `mirror` the runner routes
    through the exact `hub_split.run_common_mirror` pass (canonicalized
    rows + per-slice corrections) — counts at primaries are bit-exact.
    """
    out = ops.run_block_program(
        g, TriangleCountProgram(), backend=backend, executor=executor,
        with_steps=with_steps, mirror=mirror)
    if with_steps:
        (counts, _), steps = out
        return counts, steps
    return out[0]


def fused_analytics(
    g: GraphBlocks,
    alpha: float = 0.85,
    steps: int = 30,
    backend: str = "auto",
    executor=None,
    with_steps: bool = False,
    init: Optional[Tuple[jax.Array, jax.Array]] = None,
    mirror=None,
) -> Union[Tuple[jax.Array, jax.Array, jax.Array],
           Tuple[Tuple[jax.Array, jax.Array, jax.Array], jax.Array]]:
    """Coreness + CC labels + PageRank from ONE fused superstep loop.

    Builds a `MultiProgram` over `CorenessBlockProgram`,
    `ConnectedComponentsProgram`, and fixed-iteration
    `PageRankProgram(alpha, tol=None)` and runs exactly `steps` fused
    supersteps: each superstep reads the neighbor slots once and serves
    all three reduces off the shared gather.  Returns
    ``(coreness, labels, rank)`` — coreness (N,) int32 (0 on padding),
    labels (N,) int32 (-1 on padding), rank (N,) float32 (0.0 on
    padding) — each bit-identical to its standalone program run for the
    same superstep count, provided `steps` covers the min/hindex
    programs' convergence (their updates idle at the fixpoint).

    `init=(core, labels)` warm-starts the two monotone sub-programs from
    maintained values (labels in the `connected_components` convention:
    -1 on padding, unmasked here to the internal `INT32_MAX`).  Both are
    fixpoints of their own updates — min-H of true coreness returns the
    coreness, min-label of canonical labels returns the labels — so when
    the inputs are exact (as the stream loop keeps them) they ride
    through the fused loop bit-unchanged while PageRank, always reset to
    its uniform init here, still runs its `steps` fixed iterations.
    This is the serving path's snapshot refresh: one fused loop, three
    fields, no standalone convergence budget for coreness/CC needed.

    `mirror` (a `core.hub_split.MirrorPlan`) runs the whole fused loop
    under the vertex-cut dataflow: one merge stage per field per
    superstep, coreness/CC bit-exact vs the unsplit run, PageRank
    allclose (float slice sums re-associate).
    """
    pr = PageRankProgram(alpha=alpha, tol=None, max_steps=steps)
    prog = MultiProgram(
        (CorenessBlockProgram(), ConnectedComponentsProgram(), pr),
        max_steps=steps)
    state0 = None
    if init is not None:
        core0, labels0 = init
        gi = g if mirror is None else ops._mirror_init_view(g, mirror)
        state0 = (
            jnp.asarray(core0, jnp.int32),
            jnp.where(g.node_mask, jnp.asarray(labels0, jnp.int32),
                      INT32_MAX),
            pr.init(gi),
        )
    out = ops.run_block_program(
        g, prog, backend=backend, executor=executor, with_steps=with_steps,
        state0=state0, mirror=mirror)
    state, n = out if with_steps else (out, None)
    core, lab, (rank, _) = state
    results = (core, jnp.where(g.node_mask, lab, -1), rank)
    return (results, n) if with_steps else results


def triangle_total(counts: jax.Array) -> jax.Array:
    """Global triangle count from per-node counts (device int scalar)."""
    return jnp.sum(counts) // 3


@jax.jit
def merge_labels(labels: jax.Array, us: jax.Array, vs: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Exact CC maintenance for a fixed-width batch of edge INSERTIONS.

    labels: (N,) canonical component labels (min member padded id, as
    `connected_components` returns on real rows); us, vs: (R,) int32
    endpoint ids; valid: (R,) bool (False columns are no-ops).  Each
    insertion replaces the larger of the two endpoint labels with the
    smaller everywhere — the merged component keeps its minimum member
    id, so canonicality is preserved and the result is bit-identical to
    recomputation from scratch.  Deletions cannot be maintained this way
    (a split needs a fresh propagation); the stream loop recomputes on
    delete windows.
    """

    def body(i, lab):
        la, lb = lab[us[i]], lab[vs[i]]
        lo, hi = jnp.minimum(la, lb), jnp.maximum(la, lb)
        return jnp.where(valid[i] & (lab == hi), lo, lab)

    return jax.lax.fori_loop(0, us.shape[0], body, labels)
