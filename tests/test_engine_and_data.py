"""BladygEngine programs, degree example, data pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BladygEngine, compute_degrees, maintain_degrees_insert,
    maintain_degrees_delete, insert_edge, coreness, coreness_via_engine,
    halo_slot_counts)
from repro.core.degree import DegreeProgram
from repro.data.pipeline import SyntheticTokens, ByteCorpus


def test_degree_program_runs_one_superstep(blocks_ba):
    eng = BladygEngine(blocks_ba)
    prog = DegreeProgram()
    deg, _ = eng.run(prog, None, None, max_supersteps=10)
    assert len(eng.traces) == 1  # halts after one superstep
    np.testing.assert_array_equal(
        np.asarray(jnp.where(blocks_ba.node_mask, deg, 0)),
        np.asarray(compute_degrees(blocks_ba)))


def test_degree_incremental_matches_paper_example(blocks_ba):
    """Paper §3.2: insert (u, v) -> only deg[u], deg[v] bumped via M2W."""
    g = blocks_ba
    deg = compute_degrees(g)
    from repro.core.updates import sample_insertions
    (u, v, _), = sample_insertions(g, 1, "inter", seed=0)
    g2 = insert_edge(g, jnp.int32(u), jnp.int32(v))
    deg2 = maintain_degrees_insert(deg, u, v)
    np.testing.assert_array_equal(np.asarray(deg2),
                                  np.asarray(compute_degrees(g2)))
    deg3 = maintain_degrees_delete(deg2, u, v)
    np.testing.assert_array_equal(np.asarray(deg3), np.asarray(deg))


def test_engine_message_stats(blocks_ba):
    eng = BladygEngine(blocks_ba)
    eng.run(DegreeProgram(), None, None)
    tot = eng.message_totals()
    assert tot.w2m > 0  # per-block summaries flowed to the master


def test_coreness_program_meters_w2w(blocks_ba):
    """The halo exchange is metered per superstep, split intra/inter."""
    core, eng = coreness_via_engine(blocks_ba)
    np.testing.assert_array_equal(
        np.asarray(core), np.asarray(coreness(blocks_ba)))
    intra, inter = halo_slot_counts(blocks_ba)
    assert inter > 0  # random 4-way partition always cuts edges
    assert intra + inter == int(np.asarray(blocks_ba.deg).sum())
    n = len(eng.traces)
    assert n >= 1
    tot = eng.message_totals()
    assert tot.w2w_intra == intra * n
    assert tot.w2w_inter == inter * n
    assert tot.w2m == n  # one changed-flag per superstep


def test_run_jit_records_traces(blocks_ba):
    """run_jit reconstructs the trace from static shapes + superstep count."""
    from repro.core.kcore import CorenessProgram
    g = blocks_ba
    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    eng = BladygEngine(g)
    est, _ = eng.run_jit(CorenessProgram(), est0, None, None)
    np.testing.assert_array_equal(
        np.asarray(jnp.where(g.node_mask, est, 0)),
        np.asarray(coreness(g)))
    assert len(eng.traces) >= 1
    intra, inter = halo_slot_counts(g)
    t = eng.traces[0].stats
    assert (t.w2w_intra, t.w2w_inter) == (intra, inter)
    assert t.w2m == 1


def test_synthetic_tokens_deterministic_and_sharded():
    a = SyntheticTokens(1000, 16, 8, seed=1).batch(5)
    b = SyntheticTokens(1000, 16, 8, seed=1).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(1000, 16, 8, seed=1).batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding: different hosts, different rows; label shift consistent
    h0 = SyntheticTokens(1000, 16, 8, seed=1, host_index=0, host_count=2)
    h1 = SyntheticTokens(1000, 16, 8, seed=1, host_index=1, host_count=2)
    assert h0.local_batch == 4
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"the quick brown fox jumps over the lazy dog " * 50)
    ds = ByteCorpus(str(p), seq_len=32, global_batch=4)
    b0 = ds.batch(0)
    assert b0["tokens"].shape == (4, 32)
    assert b0["tokens"].max() < 256
    np.testing.assert_array_equal(ds.batch(3)["tokens"],
                                  ds.batch(3)["tokens"])


def test_vocab_bounds():
    ds = SyntheticTokens(50, 8, 4, seed=0)
    for s in range(5):
        assert ds.batch(s)["tokens"].max() < 50
