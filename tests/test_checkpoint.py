"""Checkpointing: atomicity, keep-N, async, restore, elastic remesh."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_test_mesh
from repro.distributed import sharding as SH


@pytest.fixture
def tree():
    k = jax.random.PRNGKey(0)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    mgr.save(3, tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = mgr.restore(3, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    # fake a crashed save
    bad = tmp_path / "step_00000002"
    shutil.copytree(tmp_path / "step_00000001", bad)
    (bad / "COMMIT").unlink()
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1


def test_keep_n_garbage_collection(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7
    out = mgr.restore(7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_structure_mismatch_raises(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    with pytest.raises(ValueError, match="leaves"):
        mgr.restore(1, {"a": tree["a"]})


def test_elastic_restore_onto_new_mesh(tmp_path, tree):
    """Same checkpoint restores under different mesh shardings (the
    node-failure / scale-up path)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree)
    mesh = make_test_mesh(dp=1, tp=jax.device_count())
    sh = SH.param_shardings(tree, mesh)
    out = mgr.restore(5, tree, sh)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    # leaves actually carry the new sharding
    assert out["a"].sharding.mesh.shape == mesh.shape


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,), jnp.float32)})
    out = mgr.restore(1, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16
