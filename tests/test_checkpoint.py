"""Checkpointing: atomicity, keep-N, async, restore, elastic sessions.

The manager is exercised against the objects it actually checkpoints in
this codebase — `GraphBlocks` pytrees and live stream sessions — not
synthetic parameter trees: the graph path is what crash recovery
(`runtime.recovery`) depends on.
"""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, restore_session,
                              save_session)
from repro.core import build_blocks, coreness
from repro.core.algorithms import connected_components
from repro.core.partition import node_random_partition
from repro.core.updates import sample_deletions, sample_insertions
from repro.graphgen import barabasi_albert
from repro.runtime.stream import StreamSession


@pytest.fixture(scope="module")
def g0():
    edges = barabasi_albert(120, 3, seed=3)
    n = int(edges.max()) + 1
    assign = node_random_partition(n, 4, seed=1)
    return build_blocks(edges, n, assign, P=4, deg_slack=24)


@pytest.fixture
def tree(g0):
    # a GraphBlocks IS a pytree (registered dataclass): the checkpoint
    # manager must handle it as-is, plus nested analytics alongside
    return {"g": g0, "analytics": {"core": coreness(g0, backend="jnp")}}


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    mgr.save(3, tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    _assert_tree_equal(tree, mgr.restore(3, like))


def test_uncommitted_checkpoint_ignored(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    # fake a crashed save: directory present, COMMIT missing
    bad = tmp_path / "step_00000002"
    shutil.copytree(tmp_path / "step_00000001", bad)
    (bad / "COMMIT").unlink()
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1


def test_torn_tmp_dir_ignored(tmp_path, tree):
    """A crash mid-write leaves step_XXXX.tmp — never listed, never
    restorable, and a later save of the same step replaces it."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    torn = tmp_path / "step_00000002.tmp"
    torn.mkdir()
    (torn / "leaf_00000.npy").write_bytes(b"\x93NUMPY garbage")
    assert mgr.all_steps() == [1]
    with pytest.raises(FileNotFoundError):
        mgr.restore_dict(2)
    mgr.save(2, tree)  # overwrites the torn tmp on its way through
    assert mgr.all_steps() == [1, 2]


def test_keep_n_garbage_collection(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7
    _assert_tree_equal(tree, mgr.restore(7, tree))


def test_structure_mismatch_raises(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    with pytest.raises(ValueError, match="leaves"):
        mgr.restore(1, {"g": tree["g"]})


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((4,), jnp.float32)})
    out = mgr.restore(1, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


def test_flat_dict_self_describing(tmp_path, g0):
    """Flat-dict checkpoints restore with NO template: the manifest
    carries key order and meta — crash recovery cannot know what
    capacities the stream had grown to."""
    mgr = CheckpointManager(str(tmp_path))
    arrays = {"g.nbr": g0.nbr, "g.deg": g0.deg, "core": coreness(g0)}
    meta = {"kind": "unit", "Cn": g0.Cn, "Cd": g0.Cd}
    mgr.save(5, arrays, meta=meta)
    assert mgr.load_meta(5) == meta
    out = mgr.restore_dict(5)
    assert set(out) == set(arrays)
    for k in arrays:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(arrays[k]))


def test_restore_dict_needs_flat_dict(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)  # nested — not key-addressable
    with pytest.raises(ValueError, match="flat dict"):
        mgr.restore_dict(1)


def _open(g0):
    core = coreness(g0, backend="jnp")
    labels = connected_components(g0, backend="jnp")
    return StreamSession(jax.tree.map(jnp.copy, g0), core, R=4,
                         cc_labels=labels)


def _windows(g, k=6, seed=9):
    ups = (sample_insertions(g, 2 * k, "inter", seed=seed)
           + sample_deletions(g, 2 * k, "intra", seed=seed + 1))
    return [ups[i::k] for i in range(k)]


def test_session_snapshot_roundtrip(tmp_path, g0):
    """save_session/restore_session: the restored session continues the
    stream bit-identically to one that was never interrupted."""
    ws = _windows(g0)
    a, b = _open(g0), _open(g0)
    for w in ws[:3]:
        a.apply_window(w)
        b.apply_window(w)
    mgr = CheckpointManager(str(tmp_path))
    step = save_session(mgr, a, extra_meta={"note": 1})
    assert step == 3
    step2, c, meta = restore_session(mgr)
    assert step2 == 3 and meta["extra"] == {"note": 1}
    assert c.windows_applied == a.windows_applied
    for w in ws[3:]:
        b.apply_window(w)
        c.apply_window(w)
    np.testing.assert_array_equal(np.asarray(b.core), np.asarray(c.core))
    np.testing.assert_array_equal(np.asarray(b.labels),
                                  np.asarray(c.labels))
    np.testing.assert_array_equal(np.asarray(b.g.nbr), np.asarray(c.g.nbr))
    sa, sc = b.stats(), c.stats()
    assert sc.updates == sa.updates
    assert sc.batches == sa.batches
    assert sc.per_block == sa.per_block


def test_restore_session_requires_meta(tmp_path, g0):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": g0.deg})
    with pytest.raises(ValueError, match="session meta"):
        restore_session(mgr, step=1)


def test_restore_session_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        restore_session(mgr)


def test_snapshot_survives_buffer_donation(tmp_path, g0):
    """The apply path donates the live graph buffers: a snapshot taken
    BEFORE further windows must hold copies, not references."""
    ws = _windows(g0)
    sess = _open(g0)
    sess.apply_window(ws[0])
    mgr = CheckpointManager(str(tmp_path))
    arrays, _ = sess.state_dict()
    for w in ws[1:]:
        sess.apply_window(w)  # donates / recycles the old buffers
    for k, arr in arrays.items():
        np.asarray(arr)  # raises if the snapshot aliased a donated buffer
