"""tracelint (`repro.analysis`) — trigger/non-trigger fixtures per rule,
pragma + baseline round-trips, the dead-seed and entry-point audits, and
the CLI gate end to end.

Every AST rule gets a pair: a snippet that MUST produce a finding and a
minimally-different snippet that MUST NOT (the escape hatch the rule
documents — bucket helper, approved splice, registered schema, static
width).  The self-scan test then pins the repo itself clean against the
committed (empty) baseline, and the audit tests keep the declarative
transfer budgets in parity with the counter tests in
`test_device_fixpoints.py` / `test_service.py`.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis import (
    MANIFEST,
    audit_dead_seed,
    load_baseline,
    partition_findings,
    run_audit,
    scan_source,
    scan_tree,
    write_baseline,
)
from repro.analysis.__main__ import main as tracelint_main
from repro.analysis.engine import Finding
from repro.analysis.entrypoints import EntryPoint, forbidden_primitives

REPO = Path(__file__).resolve().parents[1]
SRC_ROOT = REPO / "src"
BASELINE = REPO / "tracelint_baseline.json"


def _rules(findings):
    return sorted({f.rule for f in findings})


def _write(path: Path, text: str = "") -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

_SYNC_BAD = """\
import jax
import jax.numpy as jnp

def superstep(x):
    total = int(jnp.sum(x))
    y = jax.device_get(x)
    return total, y
"""


def test_host_sync_trigger():
    fs = scan_source(_SYNC_BAD, "repro/runtime/fake.py", rules=["host-sync"])
    assert len(fs) == 2
    assert _rules(fs) == ["host-sync"]
    assert [f.line for f in fs] == [5, 6]


def test_host_sync_non_trigger_out_of_scope():
    # graphgen is host-side generator code, outside SYNC_SCOPE
    fs = scan_source(_SYNC_BAD, "repro/graphgen/fake.py",
                     rules=["host-sync"])
    assert fs == []


def test_host_sync_non_trigger_boundary_pragma():
    marked = _SYNC_BAD.replace(
        "def superstep(x):", "def superstep(x):  # tracelint: boundary")
    fs = scan_source(marked, "repro/runtime/fake.py", rules=["host-sync"])
    assert fs == []


def test_host_sync_non_trigger_whitelisted_boundary():
    # build_blocks is a registered host boundary for repro/core/graph.py
    text = "import jax\n\ndef build_blocks(e):\n    return jax.device_get(e)\n"
    assert scan_source(text, "repro/core/graph.py",
                       rules=["host-sync"]) == []
    # the same code under a non-boundary name is a finding
    rogue = text.replace("build_blocks", "sneaky_pull")
    assert len(scan_source(rogue, "repro/core/graph.py",
                           rules=["host-sync"])) == 1


# ---------------------------------------------------------------------------
# retrace-hazard
# ---------------------------------------------------------------------------

_RETRACE_BAD = """\
def compile_step(x):
    width = int(x.shape[1])
    return width
"""

_RETRACE_OK = """\
from repro.kernels.ops import _pow2_bucket

def compile_step(x):
    width = _pow2_bucket(int(x.shape[1]))
    return width
"""


def test_retrace_shape_derived_trigger_and_bucketed_escape():
    bad = scan_source(_RETRACE_BAD, "repro/runtime/fake.py",
                      rules=["retrace-hazard"])
    assert len(bad) == 1 and bad[0].rule == "retrace-hazard"
    ok = scan_source(_RETRACE_OK, "repro/runtime/fake.py",
                     rules=["retrace-hazard"])
    assert ok == []


def test_retrace_nested_jit_trigger_and_memoized_escape():
    bad = "import jax\n\ndef make_step(fn):\n    return jax.jit(fn)\n"
    assert len(scan_source(bad, "repro/runtime/fake.py",
                           rules=["retrace-hazard"])) == 1
    ok = ("import functools\nimport jax\n\n"
          "@functools.lru_cache(maxsize=4)\n"
          "def make_step(fn):\n    return jax.jit(fn)\n")
    assert scan_source(ok, "repro/runtime/fake.py",
                       rules=["retrace-hazard"]) == []


def test_retrace_mutable_default_on_jitted_def():
    bad = ("import jax\n\n@jax.jit\ndef f(x, hist=[]):\n    return x\n")
    fs = scan_source(bad, "repro/runtime/fake.py", rules=["retrace-hazard"])
    assert len(fs) == 1
    ok = bad.replace("hist=[]", "hist=()")
    assert scan_source(ok, "repro/runtime/fake.py",
                       rules=["retrace-hazard"]) == []


# ---------------------------------------------------------------------------
# sorted-ell
# ---------------------------------------------------------------------------

_ELL_BAD = """\
from dataclasses import replace

def corrupt(g, u, v):
    nbr = g.nbr.at[u, 0].set(v)
    return replace(g, nbr=nbr)
"""

_ELL_OK = """\
from dataclasses import replace

from repro.core.graph import _sorted_insert_row

def splice(g, u, v):
    nbr = g.nbr.at[u].set(_sorted_insert_row(g.nbr[u], v))
    return replace(g, nbr=nbr)
"""


def test_sorted_ell_trigger():
    fs = scan_source(_ELL_BAD, "repro/runtime/fake.py", rules=["sorted-ell"])
    # the raw .at[].set AND the replace(nbr=...) kwarg both flag
    assert len(fs) == 2
    assert _rules(fs) == ["sorted-ell"]


def test_sorted_ell_non_trigger_one_deep_local_resolution():
    # `nbr = ....set(_sorted_insert_row(...))` approves BOTH the write
    # and the later `replace(g, nbr=nbr)` that names the local
    assert scan_source(_ELL_OK, "repro/runtime/fake.py",
                       rules=["sorted-ell"]) == []


def test_sorted_ell_ignores_other_names():
    text = "def f(tbl, u, v):\n    halo = tbl.halo.at[u].set(v)\n    return halo\n"
    assert scan_source(text, "repro/runtime/fake.py",
                       rules=["sorted-ell"]) == []


# ---------------------------------------------------------------------------
# cache-key
# ---------------------------------------------------------------------------


def test_cache_key_unregistered_lru_site_triggers():
    bad = ("import functools\n\n"
           "@functools.lru_cache(maxsize=8)\n"
           "def _compiled_step(mesh, H):\n    return None\n")
    fs = scan_source(bad, "repro/runtime/fake.py", rules=["cache-key"])
    assert len(fs) == 1 and "register" in fs[0].message.lower()


def test_cache_key_registered_covering_site_passes():
    # the registered spmd.py::_compiled_hindex schema is (mesh, H, overlap)
    ok = ("import functools\n\n"
          "@functools.lru_cache(maxsize=8)\n"
          "def _compiled_hindex(mesh, H, overlap):\n    return None\n")
    assert scan_source(ok, "repro/runtime/spmd.py",
                       rules=["cache-key"]) == []
    # dropping a schema axis from the parameter list is a finding
    under = ok.replace("(mesh, H, overlap)", "(mesh, H)")
    assert len(scan_source(under, "repro/runtime/spmd.py",
                           rules=["cache-key"])) == 1


def test_cache_key_unregistered_dict_cache_triggers():
    bad = "class Engine:\n    _plan_cache: dict = {}\n"
    fs = scan_source(bad, "repro/runtime/fake.py", rules=["cache-key"])
    assert len(fs) == 1


# ---------------------------------------------------------------------------
# pallas-kernel
# ---------------------------------------------------------------------------

_PALLAS_BAD = """\
def scan_kernel(x_ref, o_ref, n):
    for i in range(n):
        o_ref[i] = x_ref[i]
"""

_PALLAS_OK = """\
CHUNK = 8

def scan_kernel(x_ref, o_ref):
    for i in range(CHUNK):
        o_ref[i] = x_ref[i]
"""


def test_pallas_python_loop_over_traced_dim_triggers():
    fs = scan_source(_PALLAS_BAD, "repro/kernels/ell_fake.py",
                     rules=["pallas-kernel"])
    assert len(fs) == 1 and fs[0].rule == "pallas-kernel"


def test_pallas_static_unroll_and_out_of_scope_pass():
    assert scan_source(_PALLAS_OK, "repro/kernels/ell_fake.py",
                       rules=["pallas-kernel"]) == []
    # the rule only scopes the pallas kernel modules
    assert scan_source(_PALLAS_BAD, "repro/kernels/ops.py",
                       rules=["pallas-kernel"]) == []


# ---------------------------------------------------------------------------
# pragmas + baseline
# ---------------------------------------------------------------------------


def test_pragma_disable_suppresses_only_named_rule():
    sup = _SYNC_BAD.replace(
        "int(jnp.sum(x))",
        "int(jnp.sum(x))  # tracelint: disable=host-sync")
    fs = scan_source(sup, "repro/runtime/fake.py", rules=["host-sync"])
    assert [f.line for f in fs] == [6]  # only the un-pragmaed line remains
    wrong = _SYNC_BAD.replace(
        "int(jnp.sum(x))",
        "int(jnp.sum(x))  # tracelint: disable=sorted-ell")
    assert len(scan_source(wrong, "repro/runtime/fake.py",
                           rules=["host-sync"])) == 2


def test_baseline_roundtrip(tmp_path):
    fs = scan_source(_SYNC_BAD, "repro/runtime/fake.py", rules=["host-sync"])
    bp = tmp_path / "baseline.json"
    write_baseline(bp, fs)
    new, grandfathered = partition_findings(fs, load_baseline(bp))
    assert new == [] and len(grandfathered) == len(fs)
    # a finding NOT in the baseline partitions as new
    extra = fs + [Finding(path="repro/runtime/fake.py", line=99,
                          rule="host-sync", message="m",
                          snippet="int(jnp.prod(x))")]
    new2, _ = partition_findings(extra, load_baseline(bp))
    assert len(new2) == 1 and new2[0].line == 99


# ---------------------------------------------------------------------------
# dead-seed audit
# ---------------------------------------------------------------------------


def test_dead_seed_trigger_and_quarantine_marker(tmp_path):
    _write(tmp_path / "repro/core/graph.py", "")
    _write(tmp_path / "repro/models/__init__.py", '"""Model zoo."""\n')
    _write(tmp_path / "repro/models/zoo.py", "")
    fs = audit_dead_seed(tmp_path)
    assert {f.snippet for f in fs} == {"repro.models", "repro.models.zoo"}
    assert _rules(fs) == ["dead-seed"]
    assert fs[0].path == "repro/models/__init__.py"
    # the documented seed_fixtures note quarantines the whole subtree
    _write(tmp_path / "repro/models/__init__.py",
           '"""seed_fixtures: quarantined seed substrate."""\n')
    assert audit_dead_seed(tmp_path) == []


def test_dead_seed_reachable_module_not_flagged(tmp_path):
    _write(tmp_path / "repro/core/graph.py",
           "from ..models.zoo import build\n")
    _write(tmp_path / "repro/models/__init__.py", '"""Model zoo."""\n')
    _write(tmp_path / "repro/models/zoo.py", "")
    # graph.py names repro.models.zoo -> zoo is live; only the package
    # __init__ (never named by graph code) remains dead
    assert {f.snippet for f in audit_dead_seed(tmp_path)} == {"repro.models"}


# ---------------------------------------------------------------------------
# self-scan: the repo itself is clean against the committed baseline
# ---------------------------------------------------------------------------


def test_self_scan_is_clean_against_committed_baseline():
    findings = scan_tree(SRC_ROOT) + audit_dead_seed(SRC_ROOT)
    new, _ = partition_findings(findings, load_baseline(BASELINE))
    assert new == [], "\n".join(str(f) for f in new)


def test_committed_baseline_is_near_empty():
    data = json.loads(BASELINE.read_text())
    assert data["count"] == len(data["fingerprints"]) <= 2


# ---------------------------------------------------------------------------
# entry-point audit (parity with the device_get counter tests)
# ---------------------------------------------------------------------------


def test_entry_point_audit_is_clean():
    assert run_audit() == []


def test_manifest_budgets_match_counter_tests():
    budgets = {ep.name: ep.max_device_gets for ep in MANIFEST}
    # parity with tests/test_device_fixpoints.py:
    assert budgets["ops.coreness_blocks[jnp]"] == 0    # fused while_loop
    assert budgets["ops.coreness_blocks[ell]"] == 1    # ONE degree bound
    assert budgets["stream._route_window"] == 0        # pure device code
    assert budgets["StreamSession.apply_window[clean]"] == 1  # verdict pull
    # parity with tests/test_service.py (one get per answered batch):
    assert budgets["queries.run_batch[core]"] == 1
    assert budgets["queries.run_batch[topk_pagerank]"] == 1


def test_audit_flags_extra_device_get():
    # the "extra device_get in _route_window" scenario, in miniature
    def leaky_route(x):
        jax.device_get(x)
        return x + 1

    ep = EntryPoint(
        name="leaky", invariant="routing is pure device code",
        max_device_gets=0,
        prepare=lambda: (leaky_route, (jnp.arange(4),)))
    fs = run_audit([ep])
    assert len(fs) == 1 and "1 device_get" in fs[0].message


def test_audit_probe_flags_callback_primitives():
    def hidden_host_dep(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    ep = EntryPoint(
        name="cb", invariant="jaxpr is transfer-free",
        max_device_gets=99,
        prepare=lambda: (hidden_host_dep, (jnp.arange(4.0),)), probe=True)
    fs = run_audit([ep])
    assert len(fs) == 1 and "callback" in fs[0].message


def test_forbidden_primitives_clean_on_pure_fn():
    jaxpr = jax.make_jaxpr(lambda x: x * 2 + 1)(jnp.arange(3))
    assert forbidden_primitives(jaxpr) == []


# ---------------------------------------------------------------------------
# CLI: deliberately breaking an invariant fails the gate
# ---------------------------------------------------------------------------


def test_cli_deliberate_violation_fails_then_baselines(tmp_path):
    _write(tmp_path / "repro/runtime/bad.py", _ELL_BAD)  # unsorted splice
    baseline = tmp_path / "baseline.json"
    check = ["--root", str(tmp_path), "--baseline", str(baseline),
             "--no-audit", "--check"]
    assert tracelint_main(check) == 1
    # grandfathering the findings turns the same gate green
    assert tracelint_main(["--root", str(tmp_path), "--baseline",
                           str(baseline), "--no-audit",
                           "--write-baseline"]) == 0
    assert tracelint_main(check) == 0


def test_cli_report_artifact(tmp_path):
    _write(tmp_path / "repro/runtime/bad.py", _ELL_BAD)
    report = tmp_path / "findings.json"
    rc = tracelint_main(["--root", str(tmp_path),
                         "--baseline", str(tmp_path / "baseline.json"),
                         "--no-audit", "--report", str(report)])
    assert rc == 0  # informational run (no --check) always exits 0
    data = json.loads(report.read_text())
    assert data["total"] == len(data["new"]) == 2
    assert all(f["rule"] == "sorted-ell" for f in data["new"])


def test_cli_rejects_root_without_repro(tmp_path):
    assert tracelint_main(["--root", str(tmp_path)]) == 2
