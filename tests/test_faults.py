"""Fault injection: worker loss, torn checkpoints, chaotic recovery.

The crash-recovery contract (`runtime.recovery` + `checkpoint`):

  * a torn save (crash mid-write: ``step_XXXX.tmp``, or a step directory
    missing its COMMIT marker) is NEVER listed or loaded — recovery
    always starts from the last atomically committed snapshot;
  * killing a worker at an arbitrary window and recovering (restore →
    evacuate its blocks onto the survivors → replay the window-log tail)
    lands on EXACTLY the logical state of a run that never crashed:
    per-vertex coreness, component structure, and topology all match the
    never-crashed oracle, and the maintained analytics are bit-identical
    to a from-scratch recompute on the recovered topology;
  * restore may target a DIFFERENT worker count (W' | P) — the single
    1-CPU tier-1 run exercises W'=1; the forced-8-device CI job re-runs
    this file so the same snapshots restore across 1<->8 device meshes.

This file doubles as the e2e elasticity acceptance drill: a stream that
starts at tight capacities, triples its edge count through automatic
escalation, survives a mid-stream worker loss, and finishes with
(core, labels, pagerank) bit-identical to a from-scratch recompute —
with compiled-cache re-specialization counter-bounded at one per grow
and zero in steady state.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.checkpoint import CheckpointManager, restore_session, save_session
from repro.core import build_blocks, coreness
from repro.core.algorithms import connected_components, pagerank
from repro.core.partition import node_random_partition
from repro.graphgen import erdos_renyi
from repro.kernels import ops
from repro.runtime import spmd as spmd_mod
from repro.runtime.mesh import best_worker_count
from repro.runtime.recovery import (ElasticCoordinator, WindowLog,
                                    blocks_of_worker, kill_session,
                                    plan_evacuation)
from repro.runtime.stream import StreamSession
from repro.service import AnalyticsState

P = 8
N_NODES = 96
PR_STEPS = 10


def _graph(seed=2, deg_slack=1, node_slack=2):
    edges = erdos_renyi(N_NODES, 200, seed=seed)
    assign = node_random_partition(N_NODES, P, seed=seed + 1)
    g = build_blocks(edges, N_NODES, assign, P=P, deg_slack=deg_slack,
                     node_slack=node_slack)
    return g, edges


def _session(g, backend="jnp", W=None):
    return StreamSession(
        jax.tree.map(jnp.copy, g), coreness(g, backend="jnp"), R=8,
        backend=backend, W=W, cc_labels=connected_components(g),
        auto_grow=True)


def _windows(g, n_w, seed, insert_bias=0.7):
    """Random edit windows in the OPEN-TIME padded id space."""
    rng = np.random.default_rng(seed)
    mask = np.asarray(g.node_mask).astype(bool)
    real = np.flatnonzero(mask)
    nbr = np.asarray(g.nbr)
    cur = set()
    for i in real:
        for j in nbr[i]:
            if j >= 0:
                cur.add((min(int(i), int(j)), max(int(i), int(j))))
    out = []
    for _ in range(n_w):
        w = []
        while len(w) < 6:
            u = int(real[rng.integers(0, len(real))])
            v = int(real[rng.integers(0, len(real))])
            key = (min(u, v), max(u, v))
            if u == v:
                continue
            if key in cur and rng.random() > insert_bias:
                cur.discard(key)
                w.append((u, v, -1))
            elif key not in cur:
                cur.add(key)
                w.append((u, v, +1))
        out.append(w)
    return out


class _EditStream:
    """Stateful window generator in the session's OPEN-TIME id space.

    `apply_window` names vertices as of session open (grows/migrations
    remap internally), so a generator that spans capacity escalations
    must keep issuing open-time ids — regenerating from the CURRENT
    graph would double-remap."""

    def __init__(self, g, seed):
        mask = np.asarray(g.node_mask).astype(bool)
        self.real = np.flatnonzero(mask)
        nbr = np.asarray(g.nbr)
        self.cur = set()
        for i in self.real:
            for j in nbr[i]:
                if j >= 0:
                    self.cur.add((min(int(i), int(j)), max(int(i), int(j))))
        self.rng = np.random.default_rng(seed)

    def window(self, size=6, insert_bias=0.7):
        w = []
        while len(w) < size:
            u = int(self.real[self.rng.integers(0, len(self.real))])
            v = int(self.real[self.rng.integers(0, len(self.real))])
            key = (min(u, v), max(u, v))
            if u == v:
                continue
            if key in self.cur and self.rng.random() > insert_bias:
                self.cur.discard(key)
                w.append((u, v, -1))
            elif key not in self.cur:
                self.cur.add(key)
                w.append((u, v, +1))
        return w


def _logical_state(sess):
    """Per-orig-id analytics + topology: the permutation-free view two
    differently-migrated sessions can be compared in."""
    g = sess.g
    mask = np.asarray(g.node_mask).astype(bool)
    oid = np.asarray(g.orig_id)
    core = dict(zip(oid[mask].tolist(),
                    np.asarray(sess.core)[mask].tolist()))
    comps = {}
    for i in np.flatnonzero(mask):
        comps.setdefault(int(np.asarray(sess.labels)[i]), set()).add(
            int(oid[i]))
    parts = sorted(tuple(sorted(s)) for s in comps.values())
    nbr = np.asarray(g.nbr)
    edges = set()
    for i in np.flatnonzero(mask):
        for j in nbr[i]:
            if j >= 0:
                edges.add((min(int(oid[i]), int(oid[j])),
                           max(int(oid[i]), int(oid[j]))))
    return core, parts, edges


def _assert_exact_vs_recompute(sess):
    """Maintained analytics == from-scratch recompute, bit for bit."""
    np.testing.assert_array_equal(
        np.asarray(sess.core), np.asarray(coreness(sess.g, backend="jnp")))
    np.testing.assert_array_equal(
        np.asarray(sess.labels),
        np.asarray(connected_components(sess.g, backend="jnp")))


# ---------------------------------------------------------------------------
# torn checkpoints
# ---------------------------------------------------------------------------


def test_torn_checkpoint_never_loaded(tmp_path):
    """Crash injections at every stage of a save — tmp dir with partial
    leaves, step dir missing COMMIT — are invisible to recovery."""
    g, _ = _graph()
    sess = _session(g)
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    save_session(mgr, sess, step=1)

    # crash A: mid-write, only the tmp dir exists
    torn_tmp = tmp_path / "step_00000007.tmp"
    torn_tmp.mkdir()
    (torn_tmp / "leaf_00000.npy").write_bytes(b"partial garbage")
    # crash B: leaves + manifest written, COMMIT never landed
    torn_dir = tmp_path / "step_00000008"
    torn_dir.mkdir()
    (torn_dir / "leaf_00000.npy").write_bytes(b"also garbage")
    (torn_dir / "manifest.json").write_text("{}")

    assert mgr.all_steps() == [1]
    step, restored, _ = restore_session(mgr)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored.g.nbr),
                                  np.asarray(sess.g.nbr))
    with pytest.raises(FileNotFoundError):
        restore_session(mgr, step=8)


def test_kill_session_buffers_unusable(tmp_path):
    """After the loss drill, the dead session's device buffers are gone:
    serving from the corpse raises instead of silently reading stale
    pre-crash state."""
    g, _ = _graph()
    sess = _session(g)
    kill_session(sess)
    with pytest.raises(RuntimeError):
        np.asarray(sess.core) + 0


# ---------------------------------------------------------------------------
# evacuation planning
# ---------------------------------------------------------------------------


def test_plan_evacuation_balanced_and_complete():
    g, _ = _graph(node_slack=24)
    dead = blocks_of_worker(0, P, P)  # one block per worker
    moves = plan_evacuation(g, dead)
    mask = np.asarray(g.node_mask)
    assert len(moves) == int(mask[: g.Cn].sum())
    assert all(d not in dead for _, d in moves)
    # balanced: most-free-first keeps destination loads within one
    loads = {}
    for _, d in moves:
        loads[d] = loads.get(d, 0) + 1
    free = {b: int(g.Cn - mask[b * g.Cn:(b + 1) * g.Cn].sum())
            for b in range(P) if b not in dead}
    slack_after = [free[b] - loads.get(b, 0) for b in free]
    assert max(slack_after) - min(slack_after) <= 1


def test_plan_evacuation_raises_when_survivors_full():
    # every block exactly full (Cn == per-block occupancy): the planner
    # must refuse and tell the caller to grow Cn
    edges = erdos_renyi(N_NODES, 200, seed=2)
    assign = np.arange(N_NODES) % P
    g = build_blocks(edges, N_NODES, assign, P=P, Cn=N_NODES // P,
                     deg_slack=4)
    with pytest.raises(Exception) as ei:
        plan_evacuation(g, [0])
    assert "grow Cn" in str(ei.value)


# ---------------------------------------------------------------------------
# chaos: kill a worker at a random window, recover, compare to oracle
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_chaos_worker_loss_recovery(seed):
    """Property drill: random edit stream, checkpoint at a random
    window, worker killed at a random later window, torn-save debris
    injected — recovery replays to the never-crashed oracle's logical
    state and its analytics are bit-exact vs recompute."""
    rng = np.random.default_rng(seed)
    g, _ = _graph(seed=int(rng.integers(0, 100)), node_slack=4)
    ws = _windows(g, 8, seed=seed + 1)
    ckpt_at = int(rng.integers(1, 7))
    kill_at = int(rng.integers(ckpt_at, 9))
    dead_w = int(rng.integers(0, P))

    import tempfile, shutil
    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp, keep_n=2)
        coord = ElasticCoordinator(_session(g), mgr)
        oracle = _session(g)
        for i, w in enumerate(ws):
            if i == ckpt_at:
                coord.checkpoint()
            if i == kill_at:
                # torn-save debris right where recovery will look
                torn = mgr.dir / f"step_{90 + i:08d}.tmp"
                torn.mkdir()
                (torn / "leaf_00000.npy").write_bytes(b"x")
                coord.recover_worker(dead_w)
            coord.apply_window(w)
            oracle.apply_window(w)
        if kill_at >= len(ws):  # kill after the stream drained
            coord.recover_worker(dead_w)
        got = _logical_state(coord.session)
        want = _logical_state(oracle)
        assert got[0] == want[0], "coreness diverged"
        assert got[1] == want[1], "components diverged"
        assert got[2] == want[2], "topology diverged"
        _assert_exact_vs_recompute(coord.session)
        # the torn step never surfaced
        assert all(s < 90 for s in mgr.all_steps())
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


# ---------------------------------------------------------------------------
# remesh restore: snapshots cross device topologies
# ---------------------------------------------------------------------------


def test_restore_across_mesh_shapes(tmp_path):
    """A snapshot saved from one worker mesh restores onto every W' | P
    the current device pool supports (the 8-forced-device CI job crosses
    real 1<->8 boundaries; 1 device exercises the W'=1 fold)."""
    nd = jax.device_count()
    W0 = best_worker_count(P, nd)
    g, _ = _graph()
    sess = _session(g, backend="ell_spmd", W=W0)
    for w in _windows(g, 3, seed=5):
        sess.apply_window(w)
    mgr = CheckpointManager(str(tmp_path))
    save_session(mgr, sess)
    want_core = np.asarray(sess.core)
    want_nbr = np.asarray(sess.g.nbr)
    candidates = sorted({w for w in (1, 2, 4, 8)
                         if P % w == 0 and w <= nd and nd % w == 0})
    for W in candidates:
        _, restored, _ = restore_session(mgr, W=W, backend="ell_spmd")
        np.testing.assert_array_equal(np.asarray(restored.core), want_core)
        np.testing.assert_array_equal(np.asarray(restored.g.nbr), want_nbr)
        # and the restored session still ingests
        restored.apply_window(_windows(restored.g, 1, seed=9)[0])
        _assert_exact_vs_recompute(restored)


# ---------------------------------------------------------------------------
# the e2e elasticity acceptance drill
# ---------------------------------------------------------------------------


def test_e2e_elastic_acceptance(tmp_path):
    """Start at tight capacities; TRIPLE the edge count via automatic
    escalation; checkpoint; lose a worker mid-stream; recover onto the
    surviving mesh; keep streaming.  Final (core, labels, pagerank) are
    bit-identical to a from-scratch recompute, and compiled-cache
    re-specialization is counter-bounded: at most one per grow, zero in
    steady state."""
    nd = jax.device_count()
    W0 = best_worker_count(P, nd)
    backend = "ell_spmd"
    g, edges = _graph(deg_slack=1, node_slack=2)
    m0 = g.m_real
    sess = _session(g, backend=backend, W=W0)
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    coord = ElasticCoordinator(sess, mgr)

    # all windows speak OPEN-TIME ids (grows remap internally)
    stream = _EditStream(g, seed=0)

    # phase 1: insert-heavy windows until the edge count triples —
    # tight Cd=deg-slack-1 capacities force automatic escalation
    while coord.session.g.m_real < 3 * m0:
        coord.apply_window(stream.window(insert_bias=1.0))
    grows_p1 = coord.session._grows
    assert grows_p1 >= 1, "tripling never hit a capacity wall"
    assert coord.session.g.m_real >= 3 * m0
    _assert_exact_vs_recompute(coord.session)

    # phase 2: checkpoint, stream on, then lose a worker
    coord.checkpoint()
    for _ in range(2):
        coord.apply_window(stream.window())
    W_new = W0 if W0 == 1 else W0 // 2  # recover onto fewer workers
    # the lost worker is one of the paper's logical block-workers: under
    # a single-device fold (W0=1) that is one block, not the whole mesh
    coord.recover_worker(0, W_old=(W0 if W0 > 1 else P), W=W_new,
                         backend=backend)
    # the dead worker's blocks were evacuated
    g2 = coord.session.g
    mask = np.asarray(g2.node_mask)
    for b in blocks_of_worker(0, P, W0 if W0 > 1 else P):
        assert mask[b * g2.Cn:(b + 1) * g2.Cn].sum() == 0

    # phase 3: steady-state streaming on the recovered session — zero
    # fresh compiled-step builds unless a further grow happens
    coord.apply_window(stream.window())  # warm the new mesh
    grows0 = coord.session._grows
    builds0 = spmd_mod.step_build_count()
    traces0 = ops.gather_trace_count()
    for _ in range(3):
        coord.apply_window(stream.window())
    grew = coord.session._grows - grows0
    assert spmd_mod.step_build_count() - builds0 <= grew
    if grew == 0:
        assert ops.gather_trace_count() == traces0

    # final: analytics bit-identical to from-scratch recompute
    final = coord.session
    _assert_exact_vs_recompute(final)
    state = AnalyticsState(final, pr_steps=PR_STEPS)
    snap = state.snapshot
    np.testing.assert_array_equal(
        np.asarray(snap.rank),
        np.asarray(pagerank(final.g, tol=None, max_steps=PR_STEPS)))
    assert snap.grows == final._grows >= grows_p1
