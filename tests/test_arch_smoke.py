"""Per-architecture smoke tests (assignment requirement): REDUCED config of
the same family, one forward + one train step on CPU, asserting output
shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS
from repro.models import build, param_count
from repro.models.layers import _dtype


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(0)
    b = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.n_prefix_tokens:
        b["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.prefix_dim)) * 0.1
    if cfg.is_encdec:
        b["src_embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
    return b


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    assert param_count(params) > 0
    batch = _batch(cfg)
    ocfg = optim.AdamWConfig(total_steps=10, warmup_steps=2)
    opt_state = optim.init(params, ocfg)

    @jax.jit
    def step(p, s, b):
        def loss_of(pp):
            loss, aux = bundle.loss_fn(pp, b, remat=True)
            return loss
        loss, grads = jax.value_and_grad(loss_of)(p)
        p2, s2 = optim.update(grads, s, ocfg, _dtype(cfg.dtype))
        return p2, s2, loss

    p1, s1, loss1 = step(params, opt_state, batch)
    assert np.isfinite(float(loss1)), name
    p2, s2, loss2 = step(p1, s1, batch)
    assert np.isfinite(float(loss2)), name
    # same batch twice -> the optimizer should make progress on it
    assert float(loss2) < float(loss1) + 0.05, (name, float(loss1), float(loss2))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step_shapes(name):
    cfg = ARCHS[name].reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    caches = bundle.cache_init(B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches2 = jax.jit(bundle.decode_fn)(params, tok, caches,
                                                jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab), name
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), name
    # cache pytree structure preserved
    assert (jax.tree_util.tree_structure(caches)
            == jax.tree_util.tree_structure(caches2))


def test_all_archs_present():
    assert len(ARCHS) == 10
    fams = {c.family for c in ARCHS.values()}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}
