"""Numerical consistency across execution paths (the serving-correctness
tests): decode == forward, chunked == sequential, absorbed == naive,
capacity == dense when capacity is ample.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build
from repro.models import transformer as T
from repro.models import encdec as ED
from repro.models.ssm import init_mamba, mamba_chunked, mamba_sequential_ref
from repro.models.moe import init_moe, moe_dense, moe_capacity

DEC_ARCHS = ["gemma3-1b", "mamba2-370m", "zamba2-7b", "deepseek-v3-671b",
             "internlm2-1.8b", "granite-34b", "codeqwen1.5-7b",
             "llama4-scout-17b-a16e"]


@pytest.mark.parametrize("name", DEC_ARCHS)
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced()
    b = build(cfg)
    key = jax.random.PRNGKey(3)
    params = b.init(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits_fwd, _ = T.lm_forward(params, cfg, toks, moe_path="dense",
                                 remat=False)
    caches = b.cache_init(B, S)
    dec = jax.jit(lambda p, t, c, pos: b.decode_fn(p, t, c, pos,
                                                   moe_path="dense"))
    outs = []
    for t in range(S):
        lg, caches = dec(params, toks[:, t:t + 1], caches, jnp.int32(t))
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(logits_fwd - jnp.stack(outs, 1))))
    scale = float(jnp.max(jnp.abs(logits_fwd))) + 1e-9
    assert err / scale < 5e-5, (name, err, scale)


def test_prefix_lm_prefill_then_decode():
    cfg = ARCHS["paligemma-3b"].reduced()
    b = build(cfg)
    key = jax.random.PRNGKey(4)
    params = b.init(key)
    B, S, P = 2, 10, cfg.n_prefix_tokens
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    pfx = jax.random.normal(key, (B, P, cfg.prefix_dim)) * 0.1
    want, _ = T.lm_forward(params, cfg, toks, prefix_embeds=pfx,
                           moe_path="dense", remat=False)
    want = want[:, P:]
    caches = b.cache_init(B, P + S)
    half = S // 2
    lg, caches = b.decode_fn(params, toks[:, :half], caches, jnp.int32(0),
                             prefix_embeds=pfx)
    outs = [lg[:, P + t] for t in range(half)]
    for t in range(half, S):
        lg, caches = b.decode_fn(params, toks[:, t:t + 1], caches,
                                 jnp.int32(P + t))
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(want - jnp.stack(outs, 1))))
    assert err < 1e-4


def test_encdec_decode_matches_forward():
    cfg = ARCHS["seamless-m4t-large-v2"].reduced()
    b = build(cfg)
    key = jax.random.PRNGKey(5)
    params = b.init(key)
    B, S, Sm = 2, 10, 6
    src = jax.random.normal(key, (B, Sm, cfg.d_model)) * 0.3
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    want, _ = ED.encdec_forward(params, cfg, src, toks)
    memory = ED.encode(params, cfg, src)
    caches = ED.init_encdec_cache(cfg, B, S, Sm)
    caches = ED.encdec_prime_cross(params, cfg, memory, caches)
    outs = []
    for t in range(S):
        lg, caches = ED.encdec_decode_step(params, cfg, toks[:, t:t + 1],
                                           caches, jnp.int32(t))
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(want - jnp.stack(outs, 1))))
    assert err < 1e-4


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mamba_chunked_matches_sequential(chunk):
    cfg = ARCHS["mamba2-370m"].reduced()
    key = jax.random.PRNGKey(6)
    p = init_mamba(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model)) * 0.5
    yc = mamba_chunked(p, cfg, x, chunk=chunk)
    ys = mamba_sequential_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               atol=2e-5, rtol=2e-5)


def test_mamba_prefill_state_handoff():
    """chunked(return_state) -> decode continues exactly."""
    cfg = ARCHS["mamba2-370m"].reduced()
    key = jax.random.PRNGKey(7)
    p = init_mamba(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 24, cfg.d_model)) * 0.5
    full = mamba_sequential_ref(p, cfg, x)
    pre, state = mamba_chunked(p, cfg, x[:, :16], chunk=8, return_state=True)
    from repro.models.ssm import init_mamba_cache, mamba_step
    cache = init_mamba_cache(cfg, 1, jnp.float32)
    cache = {"conv": cache["conv"], "state": state}
    # conv state needs the last (W-1) conv inputs; rebuild by stepping the
    # last W-1 prefix tokens through a fresh cache is incorrect — instead we
    # verify the SSM state by re-running steps 16.. with conv warmed from
    # scratch over the full stream:
    cache_seq = init_mamba_cache(cfg, 1, jnp.float32)
    for t in range(16):
        _, cache_seq = mamba_step(p, cfg, x[:, t:t + 1], cache_seq)
    np.testing.assert_allclose(np.asarray(cache_seq["state"]),
                               np.asarray(state), atol=3e-5, rtol=3e-5)
    outs = []
    for t in range(16, 24):
        y, cache_seq = mamba_step(p, cfg, x[:, t:t + 1], cache_seq)
        outs.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full[:, 16:]), atol=3e-5, rtol=3e-5)


def test_mla_absorbed_equals_naive():
    cfg = ARCHS["deepseek-v3-671b"].reduced()
    b = build(cfg)
    key = jax.random.PRNGKey(8)
    params = b.init(key)
    B, S = 2, 10
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    res = {}
    for absorbed in (False, True):
        caches = b.cache_init(B, S)
        outs = []
        for t in range(S):
            lg, caches = b.decode_fn(params, toks[:, t:t + 1], caches,
                                     jnp.int32(t), moe_path="dense",
                                     mla_absorbed=absorbed)
            outs.append(lg[:, 0])
        res[absorbed] = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(res[True]), np.asarray(res[False]),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_equals_dense_when_ample():
    cfg = ARCHS["llama4-scout-17b-a16e"].reduced()
    key = jax.random.PRNGKey(9)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    yd, aux_d = moe_dense(p, cfg, x)
    yc, aux_c = moe_capacity(p, cfg, x, capacity=2 * 16 * cfg.top_k)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_c), rtol=1e-5)


def test_moe_capacity_drops_overflow_deterministically():
    cfg = ARCHS["llama4-scout-17b-a16e"].reduced()
    key = jax.random.PRNGKey(10)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    y1, _ = moe_capacity(p, cfg, x, capacity=1)
    y2, _ = moe_capacity(p, cfg, x, capacity=1)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_ring_cache_decode_matches_forward_beyond_window():
    """Sliding-window ring caches (the gemma3 §Perf optimization) are exact
    past the window boundary and strictly smaller."""
    cfg = ARCHS["gemma3-1b"].reduced()
    b = build(cfg)
    key = jax.random.PRNGKey(11)
    params = b.init(key)
    B, S = 2, 48  # window is 32 in the reduced config
    assert S > cfg.sliding_window
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    want, _ = T.lm_forward(params, cfg, toks, moe_path="dense", remat=False)
    sizes = {}
    for ring in (False, True):
        caches = b.cache_init(B, S, ring=ring)
        sizes[ring] = sum(x.size for x in jax.tree_util.tree_leaves(caches))
        dec = jax.jit(b.decode_fn)
        outs = []
        for t in range(S):
            lg, caches = dec(params, toks[:, t:t + 1], caches, jnp.int32(t))
            outs.append(lg[:, 0])
        got = jnp.stack(outs, 1)
        err = float(jnp.max(jnp.abs(want - got)))
        assert err < 1e-4, (ring, err)
    assert sizes[True] < sizes[False]


def test_banded_sliding_window_equals_masked_full():
    """Banded local attention (the §Perf prefill optimization) is exact."""
    from repro.models.layers import sdpa, sdpa_banded
    key = jax.random.PRNGKey(12)
    for (B, S, H, Hkv, D, W) in [(2, 64, 4, 1, 16, 16), (1, 128, 4, 2, 32, 32)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        full = sdpa(q, k, v, causal=True, window=W)
        band = sdpa_banded(q, k, v, W)
        np.testing.assert_allclose(np.asarray(full), np.asarray(band),
                                   atol=2e-5, rtol=2e-5)


def test_gemma3_forward_same_with_and_without_banded(monkeypatch):
    """End-to-end: the banded path changes nothing numerically."""
    import os
    cfg = ARCHS["gemma3-1b"].reduced()
    b = build(cfg)
    key = jax.random.PRNGKey(13)
    params = b.init(key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)  # 64 = 2x window
    monkeypatch.setenv("REPRO_NO_BANDED", "1")
    base, _ = T.lm_forward(params, cfg, toks, remat=False)
    monkeypatch.delenv("REPRO_NO_BANDED")
    opt, _ = T.lm_forward(params, cfg, toks, remat=False)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               atol=2e-5, rtol=2e-5)
