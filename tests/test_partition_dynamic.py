"""Deletion-triggered repartition-threshold protocol (paper §4.2).

On deletions every worker reports its block's edge count (workerCompute,
W2M) and the coordinator repartitions iff the imbalance summary exceeds
the threshold (masterCompute).  `partition_dynamic.delete_edges` is that
protocol; these tests pin its three contractual behaviors:

  * below threshold — edge owners of the surviving edges are untouched
    (no data movement, the paper's cheap path);
  * above threshold — a full repartition runs and restores balance;
  * the balance summary the decision is made on equals the NumPy oracle
    max(block size) / mean(block size).
"""
import numpy as np
import pytest

from repro.core.partition import edge_balance
from repro.core.partition_dynamic import (
    PartitionState, delete_edges, initial_partition)
from repro.graphgen import barabasi_albert

P = 4


@pytest.fixture()
def skewed_state():
    """Hand-built owner assignment: block 0 holds half of all edges, so
    targeted deletions can push the imbalance over any threshold."""
    edges = barabasi_albert(200, 4, seed=9)
    m = len(edges)
    owner = np.zeros(m, np.int64)
    owner[: m // 2] = 0
    owner[m // 2:] = 1 + np.arange(m - m // 2) % (P - 1)
    return PartitionState(edges, owner, int(edges.max()) + 1, P, "hash")


def _balance_oracle(owner: np.ndarray) -> float:
    size = np.bincount(owner, minlength=P)
    return float(size.max() / size.mean())


def test_balance_summary_matches_numpy_oracle(skewed_state):
    st = skewed_state
    assert edge_balance(st.owner, P) == pytest.approx(
        _balance_oracle(st.owner))
    # and for the post-deletion state the decision is actually made on
    keep = np.ones(len(st.edges), bool)
    keep[:10] = False
    assert edge_balance(st.owner[keep], P) == pytest.approx(
        _balance_oracle(st.owner[keep]))


def test_below_threshold_keeps_owners_stable(skewed_state):
    st = skewed_state
    # delete a few block-0 edges: block 0 stays the biggest but the
    # imbalance stays under a generous threshold
    idx = np.arange(5)
    bal_after = _balance_oracle(np.delete(st.owner, idx))
    st2, repartitioned, ut = delete_edges(st, idx, threshold=bal_after + 0.5)
    assert not repartitioned
    assert ut >= 0.0
    keep = np.ones(len(st.edges), bool)
    keep[idx] = False
    np.testing.assert_array_equal(st2.edges, st.edges[keep])
    np.testing.assert_array_equal(st2.owner, st.owner[keep])  # stable owners


def test_above_threshold_triggers_full_repartition(skewed_state):
    st = skewed_state
    # deleting every non-block-0 edge leaves all survivors on one block:
    # imbalance == P, above any sane threshold
    idx = np.flatnonzero(st.owner != 0)
    survivors = np.delete(st.owner, idx)
    assert _balance_oracle(survivors) == pytest.approx(P)
    st2, repartitioned, _ = delete_edges(st, idx, threshold=1.5)
    assert repartitioned
    assert len(st2.owner) == len(st.edges) - len(idx)
    # the repartition restored balance below the trigger level
    assert _balance_oracle(st2.owner) < P / 2
    # and owners were genuinely recomputed (hash spreads over blocks)
    assert len(np.unique(st2.owner)) > 1


def test_threshold_boundary_is_strict(skewed_state):
    """Repartition fires only strictly above the threshold."""
    st = skewed_state
    idx = np.arange(3)
    bal = edge_balance(np.delete(st.owner, idx), P)
    _, at_threshold, _ = delete_edges(st, idx, threshold=bal)
    assert not at_threshold  # bal > bal is False
    _, above, _ = delete_edges(st, idx, threshold=bal - 1e-6)
    assert above


def test_initial_partition_then_delete_roundtrip():
    """End-to-end §4.2 flow: partition, delete, re-balance decision."""
    edges = barabasi_albert(150, 3, seed=4)
    n = int(edges.max()) + 1
    st, _ = initial_partition(edges, n, P, "hash", seed=0)
    rng = np.random.default_rng(0)
    idx = rng.choice(len(edges), size=len(edges) // 10, replace=False)
    st2, repartitioned, _ = delete_edges(st, idx, threshold=1.5)
    assert len(st2.edges) == len(edges) - len(idx)
    # hash partitioning is balanced; random 10% deletions keep it that way
    assert not repartitioned
