"""Device-resident fixpoints: kernel-variant parity, fused-loop equivalence,
and the zero-per-superstep-host-transfer contract.

Three acceptance surfaces of the device-residency work:

  * hypothesis parity of the sort-based h-index kernel and the chunked
    frontier kernel vs the `ref.py` oracles at ragged N/Cd — including Cd
    not a multiple of 128, all-padding rows, and the max-degree column
    bound K < Cd (left-filled rows);
  * fused `lax.while_loop` fixpoints == the pre-refactor host-driven loop,
    bit-exact coreness AND identical superstep counts, on every backend;
  * `jax.device_get` call counting: a fixpoint performs O(1) host
    transfers regardless of its superstep count, and `run_stream`'s window
    routing performs one transfer per window, never per superstep.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.core import build_blocks, coreness
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert, erdos_renyi
from repro.kernels import ops, ref
from repro.runtime import run_stream

ALL_BACKENDS = ("jnp", "dense", "ell")


# ---------------------------------------------------------------------------
# ragged-shape construction helpers
# ---------------------------------------------------------------------------


def _ragged_ell(n, cd, seed):
    """Left-filled ELL rows with ragged degrees (some rows all padding)."""
    rng = np.random.default_rng(seed)
    nbr = np.full((n, cd), -1, np.int32)
    degs = rng.integers(0, cd + 1, n)
    degs[rng.random(n) < 0.2] = 0  # force all-padding rows
    for i in range(n):
        nbr[i, : degs[i]] = rng.integers(0, n, degs[i])
    est = rng.integers(0, n + 2, n).astype(np.int32)
    return jnp.asarray(nbr), jnp.asarray(est), int(degs.max(initial=0))


# ---------------------------------------------------------------------------
# kernel parity at ragged shapes (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 150), st.integers(1, 170), st.integers(0, 10_000),
       st.sampled_from(["sort", "count"]))
def test_hindex_ell_variants_match_oracle_ragged(n, cd, seed, variant):
    """Cd deliberately spans non-multiples of 128 (wrapper pads)."""
    nbr, est, _ = _ragged_ell(n, cd, seed)
    got = np.asarray(ops.hindex_ell(nbr, est, variant=variant, interpret=True))
    want = np.asarray(ref.ell_hindex_ref(nbr, est))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 120), st.integers(1, 300), st.integers(0, 10_000))
def test_hindex_ell_degree_bound_K_exact_on_left_filled(n, cd, seed):
    """K from the pow2-bucketed max degree (possibly < padded Cd) is exact
    because GraphBlocks rows are left-filled — the `degree_bound` policy."""
    nbr, est, max_deg = _ragged_ell(n, cd, seed)
    K = ops._pow2_bucket(max(1, max_deg))
    got = np.asarray(ops.hindex_ell(nbr, est, K=K, interpret=True))
    want = np.asarray(ref.ell_hindex_ref(nbr, est))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 120), st.integers(1, 150), st.integers(1, 6),
       st.integers(0, 10_000))
def test_frontier_ell_chunked_matches_oracle_ragged(n, cd, R, seed):
    nbr, _, max_deg = _ragged_ell(n, cd, seed)
    rng = np.random.default_rng(seed + 1)
    f = jnp.asarray(rng.random((n, R)) < 0.15)
    elig = jnp.asarray(rng.random((n, R)) < 0.7)
    vis = jnp.asarray(rng.random((n, R)) < 0.1)
    want = np.asarray(ref.ell_frontier_hop_ref(nbr, f, elig, vis))
    got = np.asarray(
        ops.frontier_step_ell(nbr, f, elig, vis, interpret=True)) > 0
    np.testing.assert_array_equal(got, want)
    # degree-bounded column sweep (left-filled rows)
    K = ops._pow2_bucket(max(1, max_deg))
    got_k = np.asarray(
        ops.frontier_step_ell(nbr, f, elig, vis, interpret=True, K=K)) > 0
    np.testing.assert_array_equal(got_k, want)


def test_hindex_ell_rejects_unknown_variant():
    nbr, est, _ = _ragged_ell(8, 4, 0)
    with pytest.raises(ValueError, match="variant"):
        ops.hindex_ell(nbr, est, variant="bogus", interpret=True)


# ---------------------------------------------------------------------------
# fused fixpoint == host-driven loop (coreness AND superstep counts)
# ---------------------------------------------------------------------------


def _hostloop_coreness(g, backend):
    """The pre-refactor fixpoint: one host round-trip per superstep."""
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    adj = ops.dense_adj(g, backend)
    steps = 0
    while True:
        h = ops.hindex_blocks(g, est, backend=backend, adj=adj,
                              interpret=True)
        new = jnp.where(g.node_mask, jnp.minimum(est, h), est)
        steps += 1
        if bool(jax.device_get(jnp.all(new == est))):
            break
        est = new
    return np.asarray(est), steps


def _graphs():
    ba = barabasi_albert(140, 4, seed=3)
    er = erdos_renyi(120, 380, seed=8)
    out = []
    for name, edges in (("ba", ba), ("er", er)):
        n = int(edges.max()) + 1
        out.append((name, build_blocks(
            edges, n, node_random_partition(n, 4, seed=1), P=4,
            deg_slack=24)))
    return out


def test_fused_fixpoint_matches_hostloop_all_backends():
    for name, g in _graphs():
        for b in ALL_BACKENDS:
            want, want_steps = _hostloop_coreness(g, b)
            est, steps = ops.coreness_blocks(
                g, backend=b, interpret=True, with_steps=True)
            # step counts come back as device scalars, not host ints
            assert hasattr(steps, "dtype"), type(steps)
            np.testing.assert_array_equal(np.asarray(est), want)
            assert int(steps) == want_steps, (name, b, int(steps), want_steps)


def test_fused_fixpoint_spmd_step_count_matches_jnp():
    from repro.runtime import SpmdExecutor

    _, g = _graphs()[0]
    _, steps_jnp = ops.coreness_blocks(g, backend="jnp", with_steps=True)
    est, steps_mesh = ops.coreness_blocks(
        g, backend="ell_spmd", with_steps=True)
    assert int(steps_mesh) == int(steps_jnp)
    np.testing.assert_array_equal(
        np.asarray(est), np.asarray(ops.coreness_blocks(g, backend="jnp")))
    # executor threading: no fresh executor per call
    ex = SpmdExecutor(g)
    est2 = ops.coreness_blocks(g, backend="ell_spmd", executor=ex)
    np.testing.assert_array_equal(np.asarray(est2), np.asarray(est))


def test_coreness_blocks_threads_executor_without_rebuilding(monkeypatch):
    from repro.runtime import SpmdExecutor
    from repro.runtime import spmd as spmd_mod

    _, g = _graphs()[1]
    ex = SpmdExecutor(g)
    built = {"n": 0}
    orig_init = spmd_mod.SpmdExecutor.__init__

    def counting_init(self, *a, **kw):
        built["n"] += 1
        return orig_init(self, *a, **kw)

    monkeypatch.setattr(spmd_mod.SpmdExecutor, "__init__", counting_init)
    core = ops.coreness_blocks(g, backend="ell_spmd", executor=ex)
    h = ops.hindex_blocks(g, jnp.asarray(core), backend="ell_spmd",
                          executor=ex)
    f = jnp.zeros((g.N, 1), bool).at[0, 0].set(True)
    ops.frontier_blocks(g, f, g.node_mask, jnp.zeros((g.N, 1), bool),
                        backend="ell_spmd", executor=ex)
    assert built["n"] == 0, "dispatch built a fresh SpmdExecutor per call"
    np.testing.assert_array_equal(
        np.asarray(h), np.asarray(ref.ell_hindex_ref(g.nbr, jnp.asarray(core))))


# ---------------------------------------------------------------------------
# zero per-superstep host transfers (device_get call counting)
# ---------------------------------------------------------------------------


def _slow_cascade_graph(n=96):
    """A chain of triangles: the min-H cascade walks the chain, so the
    fixpoint takes O(n) supersteps — enough to separate per-superstep from
    per-fixpoint transfer counts."""
    edges = []
    for i in range(n - 2):
        edges.append((i, i + 1))
    edges.append((n - 2, n - 1))
    edges.append((n - 3, n - 1))  # one triangle at the far end
    edges = np.asarray(edges)
    return build_blocks(edges, n, np.zeros(n, int), P=1, deg_slack=16)


@pytest.fixture
def count_device_get(monkeypatch):
    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    return calls


def test_coreness_fixpoint_transfer_count_is_o1(count_device_get):
    g = _slow_cascade_graph()
    for b in ALL_BACKENDS:
        count_device_get["n"] = 0
        est, steps = ops.coreness_blocks(
            g, backend=b, interpret=True, with_steps=True)
        n_gets = count_device_get["n"]
        assert int(steps) > 20, (b, int(steps))  # a genuinely long fixpoint
        # at most the one degree_bound read — NEVER one per superstep
        assert n_gets <= 1, (b, n_gets, int(steps))


def test_clamped_recompute_has_no_per_superstep_transfers(count_device_get):
    from repro.core import insert_edge_maintain

    g = _slow_cascade_graph()
    core = coreness(g, backend="jnp")
    count_device_get["n"] = 0
    g2, core2, st = insert_edge_maintain(
        g, jnp.asarray(core), jnp.int32(0), jnp.int32(4))
    assert count_device_get["n"] == 0  # fully jitted: nothing crosses
    assert int(st.recompute_steps) >= 1


def test_run_stream_routing_transfers_per_window_not_per_superstep(
        count_device_get):
    """One routed window = one device_get (the compact verdict bundle),
    independent of how many BFS/recompute supersteps the window costs."""
    g = _slow_cascade_graph(64)
    core = coreness(g, backend="jnp")
    ups = [(0, 8, +1), (20, 30, +1), (40, 50, +1), (2, 10, +1)]
    count_device_get["n"] = 0
    res = run_stream(
        jax.tree.map(lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, g),
        core, list(ups), R=2)
    g2, core2, stats = res.g, res.core, res.stats
    n_gets = count_device_get["n"]
    assert stats.batches == 2
    assert stats.bfs_steps + stats.recompute_steps > stats.batches
    # window routing: ONE bundled transfer per window; escalated updates
    # (the sequential coordinator path) may add a bounded constant each;
    # +1: stats() pulls the device-resident recompute-superstep counter
    # once at close-out (apply_window itself never blocks on it)
    assert n_gets <= stats.batches + 1 + 2 * stats.escalated, (
        n_gets, stats.batches, stats.escalated)
    # exactness unchanged
    np.testing.assert_array_equal(
        np.asarray(coreness(g2, backend="jnp")), np.asarray(core2))


def test_run_stream_routing_bit_identical_to_host_reference():
    """The device-side `_route_window` verdict reproduces the host rule:
    cross-block > spill > conflict, conflicts vs ANY earlier column."""
    from repro.runtime.stream import _route_window

    rng = np.random.default_rng(0)
    N, R, Cn = 48, 6, 12
    for trial in range(25):
        cand = rng.random((N, R)) < 0.25
        us = rng.integers(0, N, R)
        vs = rng.integers(0, N, R)
        ops_ = rng.choice([-1, 1], R)
        n = int(rng.integers(1, R + 1))
        valid = np.arange(R) < n
        cand = cand & valid[None, :]
        for r in range(R):  # endpoints are always candidates
            if valid[r]:
                cand[us[r], r] = cand[vs[r], r] = True

        # host reference (the pre-refactor routing pass)
        block_of = np.arange(N) // Cn
        owner = us[:n] // Cn
        intra = owner == vs[:n] // Cn
        spill = (cand[:, :n] & (block_of[:, None] != owner[None, :])).any(0)
        overlap = cand.T.astype(np.int64) @ cand.astype(np.int64)
        acc_ref, cross_ref, spill_ref, conf_ref = [], [], [], []
        for r in range(n):
            conflicts = bool(overlap[r, :r].any())
            if intra[r] and not spill[r] and not conflicts:
                acc_ref.append(r)
            elif not intra[r]:
                cross_ref.append(r)
            elif spill[r]:
                spill_ref.append(r)
            else:
                conf_ref.append(r)

        route = _route_window(
            jnp.asarray(cand), jnp.asarray(us.astype(np.int32)),
            jnp.asarray(vs.astype(np.int32)),
            jnp.asarray(ops_.astype(np.int32)), jnp.asarray(valid), Cn=Cn)
        assert list(np.flatnonzero(np.asarray(route.accept))) == acc_ref
        assert list(np.flatnonzero(np.asarray(route.cross))) == cross_ref
        assert list(np.flatnonzero(np.asarray(route.spill))) == spill_ref
        assert list(np.flatnonzero(np.asarray(route.conflict))) == conf_ref
        acc = np.asarray(route.accept)
        ins = cand[:, np.flatnonzero(acc & (ops_ > 0))].any(1)
        dele = cand[:, np.flatnonzero(acc & (ops_ < 0))].any(1)
        np.testing.assert_array_equal(np.asarray(route.cand_ins), ins)
        np.testing.assert_array_equal(np.asarray(route.cand_del), dele)
        want_blocks = np.zeros(N // Cn, np.int32)
        np.add.at(want_blocks, us[np.flatnonzero(acc)] // Cn, 1)
        np.testing.assert_array_equal(np.asarray(route.per_block), want_blocks)


def test_run_spmd_fused_has_no_per_superstep_transfers(count_device_get):
    """The fused SPMD superstep loop pulls ONE scalar (the count) for the
    whole run; the halt decision stays on the mesh."""
    from repro.core import coreness_via_spmd

    g = _slow_cascade_graph()
    count_device_get["n"] = 0
    core, eng = coreness_via_spmd(g)
    supersteps = len(eng.traces)
    assert supersteps > 20
    assert count_device_get["n"] <= 2, (count_device_get["n"], supersteps)
    np.testing.assert_array_equal(
        np.asarray(core), np.asarray(coreness(g, backend="jnp")))
