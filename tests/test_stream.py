"""Streaming update router: block routing, escalation, exactness.

The contract of `runtime.run_stream`: final (graph, coreness) are
bit-identical to sequential per-update maintenance, while updates that
are block-local and independent ride the batched workerCompute-only path
and everything else escalates to the coordinator.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import build_blocks, coreness, maintain_batch_host
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert
from repro.runtime import route_updates, run_stream
from repro.runtime.stream import owner_block

P = 4
COMMUNITY = 12  # nodes per block in the community graph


def _clone(g):
    return jax.tree.map(
        lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, g)


def community_graph():
    """P disjoint communities, one per block: candidate sets can never
    leave the owner block, so intra-block updates are block-local."""
    edges = []
    for b in range(P):
        base = b * COMMUNITY
        for i in range(COMMUNITY):
            edges.append((base + i, base + (i + 1) % COMMUNITY))  # cycle
            edges.append((base + i, base + (i + 2) % COMMUNITY))  # chords
    edges = np.array(edges)
    n = P * COMMUNITY
    assign = np.arange(n) // COMMUNITY
    return build_blocks(edges, n, assign, P=P, deg_slack=16)


def ba_graph():
    edges = barabasi_albert(160, 4, seed=7)
    n = int(edges.max()) + 1
    assign = node_random_partition(n, P, seed=2)
    return build_blocks(edges, n, assign, P=P, deg_slack=48)


def _pad_id(g, b, i):
    """Global padded id of the i-th node of block b (community graph)."""
    orig = np.asarray(g.orig_id)
    return int(np.flatnonzero(orig == b * COMMUNITY + i)[0])


def test_route_updates_splits_by_owner_block():
    g = community_graph()
    ups = [
        (_pad_id(g, 0, 0), _pad_id(g, 0, 5), +1),   # block 0
        (_pad_id(g, 2, 1), _pad_id(g, 2, 7), +1),   # block 2
        (_pad_id(g, 0, 3), _pad_id(g, 3, 3), +1),   # cross 0-3
    ]
    per_block, cross = route_updates(g, ups)
    assert set(per_block) == {0, 2}
    assert per_block[0] == [ups[0]] and per_block[2] == [ups[1]]
    assert cross == [ups[2]]
    assert owner_block(g, ups[0][0]) == 0


def test_block_local_updates_ride_the_batched_path():
    g = community_graph()
    core0 = coreness(g, backend="jnp")
    # one independent intra-block insertion per block: all block-local
    ups = [(_pad_id(g, b, 0), _pad_id(g, b, 5), +1) for b in range(P)]
    res = run_stream(_clone(g), core0, ups, R=P)
    g2, core2, st = res.g, res.core, res.stats
    assert st.block_local == P and st.escalated == 0
    assert st.per_block == (1,) * P
    assert (np.asarray(coreness(g2, backend="jnp"))
            == np.asarray(core2)).all()


def test_cross_block_and_conflicts_escalate():
    g = community_graph()
    core0 = coreness(g, backend="jnp")
    u00, u05 = _pad_id(g, 0, 0), _pad_id(g, 0, 5)
    ups = [
        (u00, u05, +1),                         # block-local
        (u00, _pad_id(g, 0, 6), +1),            # shares u00 -> conflict
        (_pad_id(g, 1, 0), _pad_id(g, 2, 0), +1),  # cross-block
    ]
    res = run_stream(_clone(g), core0, ups, R=4)
    g2, core2, st = res.g, res.core, res.stats
    assert st.escalated_cross_block == 1
    assert st.escalated_conflict >= 1
    # exactness regardless of routing decisions
    ref_g, ref_core, _ = maintain_batch_host(_clone(g), core0, ups)
    assert (np.asarray(core2) == np.asarray(ref_core)).all()
    assert (np.asarray(g2.nbr) == np.asarray(ref_g.nbr)).all()


def test_stream_exact_vs_sequential_on_general_graph():
    from repro.core.updates import sample_deletions, sample_insertions

    g = ba_graph()
    core0 = coreness(g, backend="jnp")
    ups = (sample_insertions(g, 3, "inter", seed=2)
           + sample_insertions(g, 3, "intra", seed=3)
           + sample_deletions(g, 3, "inter", seed=4)
           + sample_deletions(g, 3, "intra", seed=5))
    ref_g, ref_core, _ = maintain_batch_host(_clone(g), core0, list(ups))
    res = run_stream(_clone(g), core0, ups, R=4)
    g2, core2, st = res.g, res.core, res.stats
    assert (np.asarray(core2) == np.asarray(ref_core)).all()
    assert (np.asarray(g2.nbr) == np.asarray(ref_g.nbr)).all()
    assert st.updates == len(ups)
    assert st.block_local + st.escalated == len(ups)


def test_stream_accepts_generators():
    g = community_graph()
    core0 = coreness(g, backend="jnp")
    ups = [(_pad_id(g, b, 1), _pad_id(g, b, 6), +1) for b in range(P)]
    res = run_stream(_clone(g), core0, iter(ups), R=2)
    g2, core2, st = res.g, res.core, res.stats
    assert st.batches == 2 and st.updates == P
    assert (np.asarray(coreness(g2, backend="jnp"))
            == np.asarray(core2)).all()


def test_stream_spmd_backend_parity():
    g = community_graph()
    core0 = coreness(g, backend="jnp")
    ups = [(_pad_id(g, 0, 0), _pad_id(g, 0, 5), +1),
           (_pad_id(g, 1, 0), _pad_id(g, 2, 0), +1)]
    res_a = run_stream(_clone(g), core0, ups, R=2, backend="jnp")
    res_b = run_stream(_clone(g), core0, ups, R=2, backend="ell_spmd")
    g_a, core_a = res_a.g, res_a.core
    g_b, core_b = res_b.g, res_b.core
    assert (np.asarray(core_a) == np.asarray(core_b)).all()
    assert (np.asarray(g_a.nbr) == np.asarray(g_b.nbr)).all()


def _core_by_orig(g, core):
    """Coreness indexed by original node id — the migration-invariant view."""
    orig = np.asarray(g.orig_id)
    core = np.asarray(core)
    out = np.full(int(orig.max()) + 1, -1, core.dtype)
    m = orig >= 0
    out[orig[m]] = core[m]
    return out


def _skewed_graph():
    """Half the nodes (including the BA hubs) on block 0, with free node
    capacity everywhere: the §4.2 balance threshold has something to fix."""
    edges = barabasi_albert(160, 4, seed=7)
    n = int(edges.max()) + 1
    assign = np.where(np.arange(n) < n // 2, 0, 1 + np.arange(n) % 3)
    return build_blocks(edges, n, assign, P=4, Cn=96, deg_slack=48)


def _mixed_updates(g):
    from repro.core.updates import sample_deletions, sample_insertions

    return (sample_insertions(g, 4, "inter", seed=2)
            + sample_insertions(g, 4, "intra", seed=3)
            + sample_deletions(g, 4, "inter", seed=4)
            + sample_deletions(g, 4, "intra", seed=5))


def test_stream_spmd_zero_full_rebuilds_in_steady_state():
    """The tentpole counter assertion: one executor threads the whole
    stream, every window maintains the halo plan incrementally, and NO
    full plan rebuild happens without a migration."""
    g = ba_graph()
    core0 = coreness(g, backend="jnp")
    ups = _mixed_updates(g)
    res = run_stream(_clone(g), core0, ups, R=4, backend="ell_spmd")
    g2, core2, st = res.g, res.core, res.stats
    assert st.plan_rebuilds == 0
    assert st.plan_updates > 0
    assert st.migrations == 0
    ref_g, ref_core, _ = maintain_batch_host(_clone(g), core0, list(ups))
    assert (np.asarray(core2) == np.asarray(ref_core)).all()
    assert (np.asarray(g2.nbr) == np.asarray(ref_g.nbr)).all()


def test_stream_threads_a_caller_owned_executor():
    """Passing `executor=` reuses one executor ACROSS run_stream calls —
    the whole-stream analogue of the per-window threading."""
    from repro.runtime import SpmdExecutor

    g = community_graph()
    core0 = coreness(g, backend="jnp")
    ex = SpmdExecutor(g)
    ups1 = [(_pad_id(g, b, 0), _pad_id(g, b, 5), +1) for b in range(P)]
    res1 = run_stream(_clone(g), core0, ups1, R=P,
                      backend="ell_spmd", executor=ex)
    g1, core1, st1 = res1.g, res1.core, res1.stats
    ups2 = [(_pad_id(g, b, 1), _pad_id(g, b, 6), +1) for b in range(P)]
    res2 = run_stream(g1, core1, ups2, R=P,
                      backend="ell_spmd", executor=ex)
    g2, core2, st2 = res2.g, res2.core, res2.stats
    assert ex.full_rebuilds == 0
    assert ex.plan_updates == st1.plan_updates + st2.plan_updates
    assert (np.asarray(coreness(g2, backend="jnp"))
            == np.asarray(core2)).all()


@pytest.mark.parametrize("backend", ("jnp", "ell_spmd"))
def test_stream_migration_keeps_coreness_bit_identical(backend):
    """The acceptance criterion: a triggered §4.2 migration leaves the
    final coreness bit-identical (through orig_id) to the unmigrated
    run, on the jnp path and on the mesh at any device count."""
    g = _skewed_graph()
    core0 = coreness(g, backend="jnp")
    ups = _mixed_updates(g)
    ref = run_stream(_clone(g), core0, list(ups), R=4, backend="jnp")
    ref_g, ref_core = ref.g, ref.core
    res = run_stream(_clone(g), core0, list(ups), R=4,
                     backend=backend,
                     rebalance_threshold=1.2,
                     rebalance_max_moves=6)
    g2, core2, st = res.g, res.core, res.stats
    assert st.migrations > 0 and st.migrated_vertices > 0
    assert (_core_by_orig(g2, core2) == _core_by_orig(ref_g, ref_core)).all()
    # the edge set is preserved too (in original ids)
    from repro.core import to_networkx_edges
    assert (to_networkx_edges(g2) == to_networkx_edges(ref_g)).all()
    if backend == "ell_spmd":
        # full rebuilds happen exactly at migrations, never in between
        assert st.plan_rebuilds == st.migrations
    # §4.2 did its job: the trigger balance is restored below threshold
    from repro.core.partition_dynamic import block_balance
    assert block_balance(g2) <= block_balance(ref_g)


def test_stream_rebalance_disabled_never_migrates():
    g = _skewed_graph()
    core0 = coreness(g, backend="jnp")
    ups = _mixed_updates(g)[:4]
    st = run_stream(_clone(g), core0, ups, R=4, backend="jnp").stats
    assert st.migrations == 0 and st.migrated_vertices == 0


def test_stream_rejects_executor_on_non_mesh_backend():
    """executor= without backend='ell_spmd' would silently leave the
    executor's halo plan stale — must be loud instead."""
    from repro.runtime import SpmdExecutor

    g = community_graph()
    core0 = coreness(g, backend="jnp")
    ex = SpmdExecutor(g)
    with pytest.raises(ValueError, match="executor"):
        run_stream(g, core0, [], R=2, backend="jnp", executor=ex)


def test_stream_rejects_bad_window():
    g = community_graph()
    core0 = coreness(g, backend="jnp")
    with pytest.raises(ValueError):
        run_stream(g, core0, [], R=0)
    # invalid update (self-loop) is caught at the host boundary
    u = _pad_id(g, 0, 0)
    with pytest.raises(ValueError):
        run_stream(g, core0, [(u, u, +1)], R=2)


def test_stream_result_tuple_shim_warns_and_matches_arity():
    """Legacy tuple unpacking still works — 3 fields without cc_labels,
    4 with — behind a DeprecationWarning; named access never warns."""
    import warnings

    from repro.core.algorithms import connected_components
    from repro.runtime import StreamResult

    g = community_graph()
    core0 = coreness(g, backend="jnp")
    ups = [(_pad_id(g, 0, 0), _pad_id(g, 0, 5), +1)]
    res = run_stream(_clone(g), core0, ups, R=2)
    assert isinstance(res, StreamResult) and res.labels is None
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        g2, core2, st = res
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert g2 is res.g and core2 is res.core and st is res.stats

    labels0 = connected_components(g, backend="jnp")
    res4 = run_stream(_clone(g), core0, ups, R=2, cc_labels=labels0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _, _, _, labels = res4
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert labels is res4.labels and res4.labels is not None
    # NamedTuple indexing/len see all 4 fields, warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert len(res) == 4 and res[3] is None and res4[3] is labels


def test_stream_session_stepper_matches_run_stream():
    """`run_stream` is a thin wrapper: hand-stepping the same windows
    through a StreamSession reproduces its result bit-for-bit."""
    from repro.runtime import StreamSession
    from repro.runtime.stream import _iter_windows

    g = ba_graph()
    core0 = coreness(g, backend="jnp")
    ups = _mixed_updates(g)
    ref = run_stream(_clone(g), core0, list(ups), R=4)

    sess = StreamSession(_clone(g), core0, R=4)
    for i, window in enumerate(_iter_windows(list(ups), 4)):
        sess.apply_window(window)
        assert sess.windows_applied == i + 1
    res = sess.result()
    assert (np.asarray(res.core) == np.asarray(ref.core)).all()
    assert (np.asarray(res.g.nbr) == np.asarray(ref.g.nbr)).all()
    assert res.stats == ref.stats
    # close is the documented alias and the session survives result()
    assert sess.close().stats == res.stats


def test_stream_session_rejects_oversized_window():
    from repro.runtime import StreamSession

    g = community_graph()
    core0 = coreness(g, backend="jnp")
    sess = StreamSession(_clone(g), core0, R=2)
    ups = [(_pad_id(g, b, 0), _pad_id(g, b, 5), +1) for b in range(3)]
    with pytest.raises(ValueError, match="exceeds R"):
        sess.apply_window(ups)
