"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real device count (1 CPU); only launch/dryrun.py forces 512 host devices.
"""
import numpy as np
import pytest


def nx_graph(edges, n):
    import networkx as nx
    G = nx.Graph()
    G.add_nodes_from(range(n))
    G.add_edges_from(map(tuple, np.asarray(edges)))
    return G


@pytest.fixture(scope="session")
def ba_graph():
    from repro.graphgen import barabasi_albert
    edges = barabasi_albert(200, 4, seed=11)
    return edges, int(edges.max()) + 1


@pytest.fixture(scope="session")
def er_graph():
    from repro.graphgen import erdos_renyi
    edges = erdos_renyi(150, 450, seed=5)
    return edges, 150


@pytest.fixture(scope="session")
def blocks_ba(ba_graph):
    from repro.core import build_blocks
    from repro.core.partition import node_random_partition
    edges, n = ba_graph
    assign = node_random_partition(n, 4, seed=2)
    return build_blocks(edges, n, assign, P=4, deg_slack=48)
