"""Compute/communication overlap in the mesh runtime (split-phase halo read).

`SpmdExecutor(overlap=True)` (the default) gathers local-slot neighbor
values straight from the field shard — no data dependence on the halo
all_to_all — and selects halo slots from the exchanged buffer.  Contracts:

  * bit-parity — every primitive and BlockProgram produces identical
    results under overlap and strict ordering, at any device count (CI
    runs this file at 1 and 8 forced host devices);
  * independence — local-slot outputs of `_overlap_select` cannot be
    affected by the halo buffer (poisoned-buffer check), which is the
    property that makes the local gather schedulable before the
    collective lands;
  * accounting — `SuperstepTrace.serialized_collectives` records 0
    phases per superstep under overlap, 1 under strict ordering.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_ell_random, fused_analytics
from repro.core.algorithms import (
    ConnectedComponentsProgram, PageRankProgram, TriangleCountProgram,
)
from repro.kernels import ops
from repro.runtime.spmd import SpmdEngine, SpmdExecutor, _overlap_select


@pytest.fixture(scope="module")
def g():
    return build_ell_random(192, Cd=16, seed=11)


@pytest.fixture(scope="module")
def executors(g):
    return {ov: SpmdExecutor(g, overlap=ov) for ov in (True, False)}


def test_overlap_select_poisoned_halo():
    """Local slots are a pure function of the field — poison the halo."""
    rng = np.random.default_rng(0)
    S, Cd, H = 16, 5, 8
    field = jnp.asarray(rng.integers(0, 100, (S,)), jnp.int32)
    nbrl = jnp.asarray(rng.integers(0, S + H + 2, (S, Cd)), jnp.int32)
    halo = jnp.asarray(rng.integers(0, 100, (H + 2,)), jnp.int32)
    poison = jnp.full((H + 2,), 999_999, jnp.int32)
    a = np.asarray(_overlap_select(field, halo, nbrl))
    b = np.asarray(_overlap_select(field, poison, nbrl))
    local = np.asarray(nbrl) < S
    np.testing.assert_array_equal(a[local], b[local])
    np.testing.assert_array_equal(
        b[local], np.asarray(field)[np.asarray(nbrl)[local]])
    # slot for slot, the select equals the strict concat-gather
    strict = np.concatenate([np.asarray(field), np.asarray(halo)])[
        np.asarray(nbrl)]
    np.testing.assert_array_equal(a, strict)


def test_overlap_select_tail_dims():
    """(S, R) fields (frontier batches, neighbor rows) broadcast the mask."""
    rng = np.random.default_rng(1)
    S, Cd, H, R = 8, 4, 6, 3
    field = jnp.asarray(rng.integers(0, 50, (S, R)), jnp.int32)
    nbrl = jnp.asarray(rng.integers(0, S + H + 2, (S, Cd)), jnp.int32)
    halo = jnp.asarray(rng.integers(0, 50, (H + 2, R)), jnp.int32)
    got = np.asarray(_overlap_select(field, halo, nbrl))
    strict = np.concatenate([np.asarray(field), np.asarray(halo)])[
        np.asarray(nbrl)]
    np.testing.assert_array_equal(got, strict)


def test_coreness_parity(g, executors):
    want = np.asarray(ops.coreness_blocks(g, backend="jnp"))
    for ov, ex in executors.items():
        est, _ = ex.coreness()
        np.testing.assert_array_equal(np.asarray(est), want,
                                      err_msg=f"overlap={ov}")


def test_hindex_superstep_parity(g, executors):
    est = jnp.asarray(g.deg, jnp.int32)
    want = np.asarray(ops.hindex_blocks(g, est, backend="jnp"))
    for ov, ex in executors.items():
        np.testing.assert_array_equal(np.asarray(ex.hindex(est)), want,
                                      err_msg=f"overlap={ov}")


@pytest.mark.parametrize("prog", [
    ConnectedComponentsProgram(),
    PageRankProgram(tol=None, max_steps=20),
    TriangleCountProgram(),
], ids=["cc", "pagerank", "triangles"])
def test_block_programs_parity(g, executors, prog):
    want = ops.run_block_program(g, prog, backend="jnp")
    want = [np.asarray(x) for x in
            (want if isinstance(want, tuple) else (want,))]
    for ov, ex in executors.items():
        got = ops.run_block_program(g, prog, backend="ell_spmd", executor=ex)
        got = [np.asarray(x) for x in
               (got if isinstance(got, tuple) else (got,))]
        for w, o in zip(want, got):
            np.testing.assert_array_equal(o, w, err_msg=f"overlap={ov}")


def test_fused_analytics_parity(g, executors):
    want = fused_analytics(g, steps=20, backend="jnp")
    for ov, ex in executors.items():
        got = fused_analytics(g, steps=20, backend="ell_spmd", executor=ex)
        for w, o in zip(want, got):
            np.testing.assert_array_equal(np.asarray(o), np.asarray(w),
                                          err_msg=f"overlap={ov}")


def test_serialized_collectives_accounting(g, executors):
    """Overlap supersteps wait on 0 collective phases; strict ones on 1."""
    from repro.runtime.spmd import SpmdBlockProgram

    totals = {}
    for ov, ex in executors.items():
        eng = SpmdEngine(g, executor=ex)
        prog = SpmdBlockProgram(ConnectedComponentsProgram(), int(g.n_real))
        state0 = ConnectedComponentsProgram().init(g)
        eng.run_spmd(prog, state0, None, max_supersteps=50)
        assert eng.traces, "no supersteps traced"
        want = 0 if ov else 1
        assert all(t.serialized_collectives == want for t in eng.traces), ov
        totals[ov] = sum(t.serialized_collectives for t in eng.traces)
    # identical results, strictly fewer serialized collective phases
    assert totals[True] < totals[False]
