"""Capacity escalation: pad-and-rekey growth, end to end.

The elastic contract (`core.graph.grow_blocks` and the session `grow`
surface built on it):

  * growth is PURE relocation — `grow_blocks(g, Cn2, Cd2)` produces the
    graph `build_blocks` would have produced at the larger capacities,
    bit for bit, because the rekey `b*Cn + r -> b*Cn2 + r` is globally
    monotone (sorted-ELL rows survive a value remap without a re-sort);
  * growth is reversible — grow-then-shrink round-trips bit-identically
    (orig_id is the witness: relocation never renames a vertex);
  * escalation is automatic and exact — a window that would overflow
    `Cd` (or a replica pool that would exhaust `Cn`) grows the graph and
    retries, and the stream's maintained analytics still match a
    from-scratch recompute on the final topology;
  * escalation is CHEAP afterwards — compiled caches re-specialize once
    per grow and steady state returns to zero retraces (counter-asserted
    below, the same counters the service tests pin).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CapacityError, add_vertices_host, build_blocks,
                        coreness, grow_blocks)
from repro.core.algorithms import connected_components
from repro.core.graph import migrate_vertices
from repro.core.hub_split import split_hubs
from repro.core.partition import node_random_partition
from repro.core.updates import (apply_updates_host, sample_deletions,
                                sample_insertions)
from repro.graphgen import barabasi_albert, erdos_renyi
from repro.kernels import ops
from repro.runtime import spmd as spmd_mod
from repro.runtime.stream import MirrorStream, StreamSession
from repro.service import AnalyticsState


def _graph(n=96, m=240, P=4, seed=2, deg_slack=2, node_slack=0):
    edges = erdos_renyi(n, m, seed=seed)
    assign = node_random_partition(n, P, seed=seed + 1)
    return build_blocks(edges, n, assign, P=P, deg_slack=deg_slack,
                        node_slack=node_slack), edges, assign


def _assert_graph_equal(a, b):
    for f in ("nbr", "deg", "node_mask", "orig_id"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


# ---------------------------------------------------------------------------
# grow_blocks: relocation == rebuild
# ---------------------------------------------------------------------------


def test_grow_equals_rebuild():
    """Growing capacities relocates to EXACTLY the graph a from-scratch
    build at those capacities produces — the strongest possible
    statement that no invariant (sorted-ELL, padding, ids) bends."""
    g, edges, assign = _graph()
    for Cn2, Cd2 in ((g.Cn * 2, g.Cd), (g.Cn, g.Cd * 2),
                     (g.Cn * 2, g.Cd * 4)):
        g2, rekey = grow_blocks(g, Cn=Cn2, Cd=Cd2)
        want = build_blocks(edges, g.N and int(np.asarray(g.node_mask).sum()),
                            assign, P=g.P, Cn=Cn2, Cd=Cd2)
        _assert_graph_equal(g2, want)
        # the rekey is monotone over real rows (sorted-ELL survives)
        real = rekey[rekey >= 0]
        assert np.all(np.diff(real) > 0)


def test_grow_then_shrink_roundtrip():
    """Shrinking back to the original capacities restores the original
    graph bit for bit — orig_id never changes across grow/shrink."""
    g, _, _ = _graph()
    g2, _ = grow_blocks(g, Cn=g.Cn * 4, Cd=g.Cd * 2)
    g3, _ = grow_blocks(g2, Cn=g.Cn, Cd=g.Cd)
    _assert_graph_equal(g, g3)


def test_shrink_below_contents_raises():
    g, _, _ = _graph()
    with pytest.raises(CapacityError):
        grow_blocks(g, Cd=1)  # max real degree exceeds 1
    full_rows = int(np.asarray(g.node_mask)[:g.Cn].sum())
    with pytest.raises(CapacityError):
        grow_blocks(g, Cn=max(1, full_rows - 1))


def test_grow_under_trace_raises():
    """Growth is a HOST boundary: calling it under jit must fail
    loudly, not silently trace a data-dependent shape."""
    g, _, _ = _graph()

    @jax.jit
    def f(gg):
        g2, _ = grow_blocks(gg, Cn=gg.Cn * 2)
        return g2.deg

    with pytest.raises(TypeError):
        f(g)


def test_add_vertices_deterministic_and_capped():
    g, _, _ = _graph(node_slack=3)
    g2, rows = add_vertices_host(g, 1, 2)
    g3, rows_again = add_vertices_host(g, 1, 2)
    rows, rows_again = list(map(int, rows)), list(map(int, rows_again))
    assert rows == rows_again  # lowest-free-rows-first: replayable
    assert all(g.Cn <= r < 2 * g.Cn for r in rows)
    with pytest.raises(CapacityError):
        add_vertices_host(g2, 1, g.Cn)  # block 1 cannot take Cn more


# ---------------------------------------------------------------------------
# streaming escalation: host/jit parity, counters, analytics exactness
# ---------------------------------------------------------------------------


def _overflow_windows(g, k=4, seed=5):
    """Insert-heavy windows guaranteed to overflow a tight Cd."""
    rng = np.random.default_rng(seed)
    mask = np.asarray(g.node_mask).astype(bool)
    real = np.flatnonzero(mask)
    nbr = np.asarray(g.nbr)
    present = set()
    for i in real:
        for j in nbr[i]:
            if j >= 0:
                present.add((min(int(i), int(j)), max(int(i), int(j))))
    hub = int(real[np.argmax(np.asarray(g.deg)[real])])
    out, cur = [], set(present)
    for _ in range(k):
        w = []
        while len(w) < 6:
            u = hub if rng.random() < 0.5 else int(
                real[rng.integers(0, len(real))])
            v = int(real[rng.integers(0, len(real))])
            key = (min(u, v), max(u, v))
            if u == v or key in cur:
                continue
            cur.add(key)
            w.append((u, v, +1))
        out.append(w)
    return out


def test_cd_escalation_matches_host_and_recompute():
    """auto_grow sessions ingest windows that overflow Cd; the final
    graph matches the host oracle applied to an ALREADY-grown graph, and
    maintained core/labels match a from-scratch recompute — on the jnp
    and the spmd backend alike (host/jit bit-parity across a grow)."""
    g, _, _ = _graph(deg_slack=1)
    ws = _overflow_windows(g)
    flat = [u for w in ws for u in w]
    for backend in ("jnp", "ell_spmd"):
        sess = StreamSession(
            jax.tree.map(jnp.copy, g), coreness(g, backend="jnp"), R=8,
            backend=backend, cc_labels=connected_components(g),
            auto_grow=True)
        for w in ws:
            sess.apply_window(w)
        assert sess._grows >= 1  # the windows genuinely overflowed
        # host oracle: pre-grow a copy to the session's final capacities,
        # splice the same edits host-side
        g_big, rekey = grow_blocks(g, Cn=sess.g.Cn, Cd=sess.g.Cd)
        host = apply_updates_host(
            g_big, [(int(rekey[u]), int(rekey[v]), op) for u, v, op in flat])
        _assert_graph_equal(sess.g, host)
        np.testing.assert_array_equal(
            np.asarray(sess.core),
            np.asarray(coreness(sess.g, backend="jnp")))
        np.testing.assert_array_equal(
            np.asarray(sess.labels),
            np.asarray(connected_components(sess.g, backend="jnp")))


def test_escalation_counters_one_retrace_per_grow():
    """Cache accounting across a grow: the compiled window step
    re-specializes exactly once (new (Cn, Cd) key), steady state returns
    to ZERO fresh traces, and the executor/session counters agree."""
    g, _, _ = _graph(deg_slack=1)
    ws = _overflow_windows(g, k=6)
    sess = StreamSession(
        jax.tree.map(jnp.copy, g), coreness(g, backend="jnp"), R=8,
        backend="ell_spmd", cc_labels=connected_components(g),
        auto_grow=True)
    sess.apply_window(ws[0])  # warm the caches at the open capacities
    grows0, builds0 = sess._grows, spmd_mod.step_build_count()
    traces0 = ops.gather_trace_count()
    for w in ws[1:]:
        sess.apply_window(w)
    grew = sess._grows - grows0
    assert grew >= 1
    assert sess.executor.grows == sess._grows
    assert sess.stats().grows == sess._grows
    # one compiled-step build per capacity change, not per window
    assert spmd_mod.step_build_count() - builds0 <= grew
    # steady state after the last grow: zero fresh traces / builds
    builds1, traces1 = spmd_mod.step_build_count(), ops.gather_trace_count()
    for w in _overflow_windows(sess.g, k=2, seed=11):
        sess.apply_window(w)
    if sess._grows == grows0 + grew:  # no further escalation happened
        assert spmd_mod.step_build_count() == builds1
        assert ops.gather_trace_count() == traces1


def test_snapshot_versions_across_grow():
    """EpochSnapshot carries (Cn, Cd, grows): readers can detect that a
    grow re-keyed the padded id space between two epochs."""
    g, _, _ = _graph(deg_slack=1)
    sess = StreamSession(
        jax.tree.map(jnp.copy, g), coreness(g, backend="jnp"), R=8,
        cc_labels=connected_components(g), auto_grow=True)
    state = AnalyticsState(sess, pr_steps=8)
    s0 = state.snapshot
    assert (s0.Cn, s0.Cd, s0.grows) == (g.Cn, g.Cd, 0)
    for w in _overflow_windows(g):
        sess.apply_window(w)
    assert sess._grows >= 1
    s1 = state.refresh()
    assert s1.epoch == s0.epoch + 1
    assert s1.grows == sess._grows
    assert s1.Cd == sess.g.Cd > s0.Cd


# ---------------------------------------------------------------------------
# migration + growth interplay (the recovery path's building blocks)
# ---------------------------------------------------------------------------


def test_migrate_after_grow_keeps_orig_ids():
    """A §4.2 migration on a grown graph still tracks vertices by
    orig_id — growth never confuses the permutation machinery."""
    g, _, _ = _graph()
    g2, _ = grow_blocks(g, Cn=g.Cn * 2)
    core2 = jnp.asarray(coreness(g2, backend="jnp"))
    mask = np.asarray(g2.node_mask).astype(bool)
    movers = np.flatnonzero(mask[: g2.Cn])[:3]  # 3 nodes out of block 0
    moves = [(int(u), 1 + int(u) % (g2.P - 1)) for u in movers]
    g3, perm, core3 = migrate_vertices(g2, moves, core2)
    want = dict(zip(np.asarray(g2.orig_id)[mask].tolist(),
                    np.asarray(core2)[mask].tolist()))
    mask3 = np.asarray(g3.node_mask).astype(bool)
    got = dict(zip(np.asarray(g3.orig_id)[mask3].tolist(),
                   np.asarray(core3)[mask3].tolist()))
    assert got == want


# ---------------------------------------------------------------------------
# in-flight MirrorStream growth (replica-pool exhaustion mid-window)
# ---------------------------------------------------------------------------


def _skewed(n=90, seed=4, P=4, threshold=6, node_slack=2):
    edges = {(0, v) for v in range(1, 1 + threshold * 3)}
    for u, v in barabasi_albert(n, 3, seed=seed):
        if u != v:
            edges.add((min(u, v), max(u, v)))
    edges = np.array(sorted(edges))
    assign = node_random_partition(n, P, seed=seed + 1)
    return build_blocks(edges, n, assign, P=P,
                        node_slack=node_slack), edges, assign


def test_mirror_inflight_grow():
    """A window whose threshold-crossing inserts exhaust the replica
    pool mid-window triggers an in-flight Cn grow and a clean retry:
    nothing half-applies, and the maintained coreness still equals the
    mirror-aware recompute on the final graph."""
    g, edges, assign = _skewed()
    g2, plan = split_hubs(g, threshold=6)
    sess = MirrorStream(g2, plan, backend="jnp", cc_labels=True,
                        auto_grow=True)
    pm = np.asarray(plan.primary_mask)
    row_of = {int(o): i for i, o in enumerate(np.asarray(g2.orig_id))
              if pm[i]}
    # push many new neighbors onto one vertex: each threshold crossing
    # wants a fresh replica row; a tiny node_slack runs out quickly
    tgt = 2
    cur = set(map(tuple, edges.tolist()))
    window = []
    for v in range(90):
        e = (min(tgt, v), max(tgt, v))
        if tgt != v and e not in cur:
            cur.add(e)
            window.append((row_of[tgt], row_of[v], +1))
        if len(window) == 24:
            break
    Cn0 = sess.g.Cn
    sess.apply_window(window)
    assert sess._grows >= 1 and sess.g.Cn > Cn0
    assert sess.result().stats.grows == sess._grows
    want = np.asarray(coreness(sess.g, backend="jnp", mirror=sess.mirror))
    np.testing.assert_array_equal(np.asarray(sess.core), want)
