"""Optimizer substrate: AdamW semantics, schedule, clipping, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.optim.compress import (
    quantize_int8, dequantize_int8, init_error_feedback)


def test_adamw_converges_on_quadratic():
    ocfg = optim.AdamWConfig(lr_peak=0.1, lr_min=0.01, warmup_steps=5,
                             total_steps=200, weight_decay=0.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = optim.init(params, ocfg)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = optim.update(grads, state, ocfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_bounds_update():
    ocfg = optim.AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=10,
                             clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = optim.init(params, ocfg)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _ = optim.update(huge, state, ocfg, jnp.float32)
    # effective |g| after clip is <= 1, so |delta| <= lr * O(1/sqrt eps-ish)
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0


def test_cosine_schedule_shape():
    ocfg = optim.AdamWConfig(lr_peak=1.0, lr_min=0.1, warmup_steps=10,
                             total_steps=100)
    lrs = [float(optim.cosine_lr(ocfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert abs(lrs[10] - 1.0) < 0.05
    assert lrs[-1] < 0.2
    assert all(b <= a + 1e-6 for a, b in zip(lrs[10:], lrs[11:]))


def test_bf16_moments_halve_memory():
    params = {"w": jnp.zeros((128, 128))}
    s32 = optim.init(params, optim.AdamWConfig(moments_dtype="float32"))
    s16 = optim.init(params, optim.AdamWConfig(moments_dtype="bfloat16"))
    assert s16.m["w"].dtype == jnp.bfloat16
    assert s32.m["w"].dtype == jnp.float32


def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-7


def test_error_feedback_removes_bias():
    """With EF, the *accumulated* applied signal tracks the true sum."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * 1e-3)
    ef = {"g": jnp.zeros(256)}
    applied = jnp.zeros(256)
    for _ in range(50):
        target = g + ef["g"]
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        ef = {"g": target - deq}
        applied = applied + deq
    np.testing.assert_allclose(np.asarray(applied), np.asarray(50 * g),
                               atol=float(s) * 1.5)


def test_compressed_psum_under_shard_map():
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim.compress import compressed_psum_mean
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(dp=jax.device_count(), tp=1)
    grads = {"w": jnp.arange(8, dtype=jnp.float32)}
    ef = init_error_feedback(grads)

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
             check_rep=False)
    def f(g, e):
        return compressed_psum_mean(g, e, "data")

    red, ef2 = f(grads, ef)
    np.testing.assert_allclose(np.asarray(red["w"]), np.arange(8), atol=0.05)
