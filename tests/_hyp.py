"""Hypothesis compatibility shim.

Uses the real ``hypothesis`` when installed (``pip install -e .[dev]``).
Otherwise provides a deterministic mini-driver so the property tests still
run (with a bounded number of seeded examples) instead of failing at
collection — the container image does not ship hypothesis.
"""
try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampler: rng -> example value."""

        def __init__(self, sample):
            self.sample = sample

    class st:  # noqa: N801 - mimics `hypothesis.strategies` namespace
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(0, len(options)))]
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(size)]

            return _Strategy(sample)

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    _FALLBACK_CAP = 10  # keep the no-hypothesis path fast in CI

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying fn's signature would make
            # pytest resolve the strategy-bound parameters as fixtures.
            def wrapper():
                # @settings may wrap *this* function afterwards; read at
                # call time so the decorator order in tests keeps working.
                n = getattr(wrapper, "_max_examples", None) or getattr(
                    fn, "_max_examples", _FALLBACK_CAP
                )
                rng = _np.random.default_rng(0)
                for _ in range(min(n, _FALLBACK_CAP)):
                    vals = [s.sample(rng) for s in strategies]
                    fn(*vals)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
