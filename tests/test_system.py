"""End-to-end system tests: the full BLADYG workflow (partition -> compute ->
dynamic maintenance -> verify) and the training launcher with fault drills.
"""
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.core import (
    build_blocks, coreness, maintain_batch_host, to_networkx_edges)
from repro.core.partition import node_bfs_partition
from repro.core.updates import sample_insertions, sample_deletions
from repro.graphgen import snap_like, nearest_neighbor_graph

REPO = Path(__file__).resolve().parents[1]


def test_end_to_end_dynamic_kcore_workflow():
    """The paper's full pipeline on a DS1-shaped synthetic graph."""
    edges = nearest_neighbor_graph(400, u=0.85, seed=42)
    n = int(edges.max()) + 1
    assign = node_bfs_partition(edges, n, 8, seed=1)
    g = build_blocks(edges, n, assign, P=8, deg_slack=40)
    core = coreness(g)

    ups = (sample_insertions(g, 10, "inter", seed=1)
           + sample_insertions(g, 10, "intra", seed=2)
           + sample_deletions(g, 10, "inter", seed=3)
           + sample_deletions(g, 10, "intra", seed=4))
    g, core, stats = maintain_batch_host(g, core, ups)

    G = nx.Graph()
    G.add_nodes_from(range(n))
    G.add_edges_from(map(tuple, to_networkx_edges(g)))
    ref = nx.core_number(G)
    orig = np.asarray(g.orig_id)
    c = np.asarray(core)
    for i in range(g.N):
        if orig[i] >= 0:
            assert c[i] == ref[orig[i]]
    # maintenance did bounded work: candidates << n on average
    avg_cand = np.mean([int(s.candidates) for s in stats])
    assert avg_cand < n


def test_snap_like_generators_have_paper_shape():
    e = snap_like("ego-Facebook", scale=0.25, seed=0)
    n = int(e.max()) + 1
    avg_deg = 2 * len(e) / n
    assert 900 <= n <= 1100
    assert avg_deg > 10  # dense social graph
    e2 = snap_like("roadNet-CA", scale=0.002, seed=0)
    n2 = int(e2.max()) + 1
    assert 2 * len(e2) / n2 < 6  # sparse road network


@pytest.mark.slow
def test_train_launcher_with_failure_and_resume(tmp_path):
    """Full fault drill through the CLI: train, inject failure (exit 42),
    restart with --resume auto, finish."""
    ck = tmp_path / "ck"
    base = [sys.executable, "-m", "repro.launch.train",
            "--arch", "internlm2-1.8b", "--reduced", "--batch", "2",
            "--seq", "32", "--ckpt-dir", str(ck), "--ckpt-every", "3"]
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r1 = subprocess.run(base + ["--steps", "9", "--simulate-failure", "6"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r1.returncode == 42, r1.stderr[-2000:]
    r2 = subprocess.run(base + ["--steps", "9", "--resume", "auto"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step 6" in r2.stdout


@pytest.mark.slow
def test_grad_compression_trains(tmp_path):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root", "JAX_PLATFORMS": "cpu"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "internlm2-1.8b", "--reduced", "--steps", "4", "--batch", "2",
         "--seq", "32", "--grad-compression"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done:" in r.stdout
