"""Maximal clique maintenance vs networkx (paper §4.3)."""
import numpy as np
import networkx as nx
import pytest
from _hyp import given, settings, st

from repro.core import MaximalCliques, bron_kerbosch
from repro.graphgen import erdos_renyi

from conftest import nx_graph


def _ref_cliques(G):
    return set(frozenset(c) for c in nx.find_cliques(G))


def test_static_enumeration_matches_networkx():
    edges = erdos_renyi(40, 160, seed=2)
    G = nx_graph(edges, 40)
    mc = MaximalCliques(40, map(tuple, edges))
    assert mc.cliques == _ref_cliques(G)


def test_insert_maintenance():
    edges = erdos_renyi(30, 80, seed=3)
    G = nx_graph(edges, 30)
    mc = MaximalCliques(30, map(tuple, edges))
    rng = np.random.default_rng(0)
    added = 0
    while added < 25:
        a, b = rng.integers(0, 30, 2)
        if a == b or G.has_edge(a, b):
            continue
        mc.insert_edge(int(a), int(b))
        G.add_edge(int(a), int(b))
        added += 1
    assert mc.cliques == _ref_cliques(G)
    assert mc.check()


def test_delete_maintenance():
    edges = erdos_renyi(30, 120, seed=4)
    G = nx_graph(edges, 30)
    mc = MaximalCliques(30, map(tuple, edges))
    rng = np.random.default_rng(1)
    eds = list(G.edges())
    for i in rng.choice(len(eds), size=25, replace=False):
        a, b = eds[i]
        mc.delete_edge(int(a), int(b))
        G.remove_edge(a, b)
    assert mc.cliques == _ref_cliques(G)
    assert mc.check()


def test_prefix_tree_index_consistent():
    edges = erdos_renyi(25, 70, seed=5)
    mc = MaximalCliques(25, map(tuple, edges))
    from_index = set()
    for root, cl in mc.by_root.items():
        for c in cl:
            assert min(c) == root
            from_index.add(c)
    assert from_index == mc.cliques


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_property_random_dynamics(seed):
    rng = np.random.default_rng(seed)
    n = 18
    edges = erdos_renyi(n, 30, seed=seed)
    G = nx_graph(edges, n)
    mc = MaximalCliques(n, map(tuple, edges))
    for _ in range(20):
        a, b = rng.integers(0, n, 2)
        if a == b:
            continue
        if G.has_edge(a, b):
            mc.delete_edge(int(a), int(b))
            G.remove_edge(a, b)
        else:
            mc.insert_edge(int(a), int(b))
            G.add_edge(int(a), int(b))
    assert mc.cliques == _ref_cliques(G)
