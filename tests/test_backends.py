"""Cross-backend parity + batched maintenance (the dispatch-layer contract).

Every backend of the kernel registry (jnp / dense Pallas / ELL Pallas, all
interpret mode on CPU) must produce bit-identical h-index, frontier, and
coreness results; the ELL path must run at sizes where the dense O(N^2)
adjacency is infeasible; `maintain_batch` must match sequential maintenance
exactly while spending fewer frontier supersteps on independent updates.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    build_blocks, build_ell_random, coreness, delete_edge_maintain,
    insert_edge_maintain, maintain_batch,
)
from repro.core.partition import node_random_partition
from repro.core.updates import sample_insertions, sample_deletions
from repro.graphgen import erdos_renyi, barabasi_albert
from repro.kernels import ops

ALL_BACKENDS = ("jnp", "dense", "ell")


def _blocks(seed, n=120, m=360, P=4):
    edges = erdos_renyi(n, m, seed=seed)
    n = int(edges.max()) + 1
    return build_blocks(edges, n, node_random_partition(n, P, seed=seed), P=P,
                        deg_slack=24)


# ------------------------------------------------------------- dispatch ----

def test_resolve_backend():
    assert ops.resolve_backend("ell", N=10) == "ell"
    assert ops.resolve_backend("auto", N=10) in ops.BACKENDS
    with pytest.raises(ValueError):
        ops.resolve_backend("cuda", N=10)


def test_auto_is_jnp_off_tpu():
    # CI runs on CPU: Pallas would execute interpreted, so auto -> jnp
    if jax.devices()[0].platform != "tpu":
        assert ops.resolve_backend("auto", N=10_000_000) == "jnp"


# --------------------------------------------------------------- parity ----

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_coreness_parity_across_backends(seed):
    """ELL-backend coreness matches the jnp oracle exactly (3 random graphs)."""
    g = _blocks(seed)
    cores = {
        b: np.asarray(coreness(g, backend=b)) for b in ALL_BACKENDS
    }
    np.testing.assert_array_equal(cores["jnp"], cores["dense"])
    np.testing.assert_array_equal(cores["jnp"], cores["ell"])


@pytest.mark.parametrize("seed", [3, 4])
def test_hindex_parity_across_backends(seed):
    g = _blocks(seed)
    rng = np.random.default_rng(seed)
    est = jnp.asarray(rng.integers(0, 15, g.N), jnp.int32)
    h = {b: np.asarray(ops.hindex_blocks(g, est, backend=b, interpret=True))
         for b in ALL_BACKENDS}
    np.testing.assert_array_equal(h["jnp"], h["dense"])
    np.testing.assert_array_equal(h["jnp"], h["ell"])


@pytest.mark.parametrize("R", [1, 5])
def test_frontier_parity_across_backends(R):
    g = _blocks(7, n=150, m=500)
    rng = np.random.default_rng(R)
    f = jnp.asarray(rng.random((g.N, R)) < 0.05)
    elig = jnp.asarray(rng.random((g.N, R)) < 0.6)  # per-column masks
    vis = jnp.asarray(rng.random((g.N, R)) < 0.1)
    nxt = {b: np.asarray(ops.frontier_blocks(g, f, elig, vis, backend=b,
                                             interpret=True))
           for b in ALL_BACKENDS}
    np.testing.assert_array_equal(nxt["jnp"], nxt["dense"])
    np.testing.assert_array_equal(nxt["jnp"], nxt["ell"])


def test_frontier_shared_eligibility_broadcasts():
    g = _blocks(9)
    rng = np.random.default_rng(9)
    f = jnp.asarray(rng.random((g.N, 3)) < 0.05)
    elig1 = jnp.asarray(rng.random(g.N) < 0.5)          # shared (N,)
    vis = jnp.zeros((g.N, 3), bool)
    a = ops.frontier_blocks(g, f, elig1, vis, backend="jnp")
    b = ops.frontier_blocks(g, f, jnp.broadcast_to(elig1[:, None], (g.N, 3)),
                            vis, backend="jnp")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------- ELL beyond dense scale ----

@pytest.mark.slow
def test_ell_runs_where_dense_is_infeasible():
    """The tentpole claim: O(N*Cd) kernels at an N whose dense (N, N)
    adjacency would exceed 4 GiB (same graph the backends benchmark times)."""
    g = build_ell_random(46848, Cd=8, seed=0)
    assert ops.dense_bytes(g.N) > 4 * 2**30  # dense bf16 adjacency > 4 GiB
    core_jnp = coreness(g, backend="jnp")
    core_ell = coreness(g, backend="ell")  # interpret mode on CPU
    np.testing.assert_array_equal(np.asarray(core_jnp), np.asarray(core_ell))


# ----------------------------------------------------- batched updates ----

def _community_graph(n_comm=8, sz=40, seed=0):
    """Disjoint communities -> naturally independent candidate sets."""
    edges = np.concatenate(
        [erdos_renyi(sz, 100, seed=seed + b) + b * sz for b in range(n_comm)]
    )
    n = n_comm * sz
    g = build_blocks(edges, n, np.arange(n) // sz, P=n_comm, deg_slack=32)
    return g


def _one_insert_per_community(g, seed=0):
    rng = np.random.default_rng(seed)
    nbr = np.asarray(g.nbr)
    mask = np.asarray(g.node_mask)
    ups = []
    for b in range(g.P):
        ids = np.flatnonzero(mask & (np.arange(g.N) // g.Cn == b))
        while True:
            u, v = rng.choice(ids, 2, replace=False)
            if not (nbr[u] == v).any():
                ups.append((int(u), int(v), +1))
                break
    return ups


def test_maintain_batch_matches_sequential_with_fewer_supersteps():
    """Acceptance: R=8 batch == 8 sequential inserts, fewer frontier steps."""
    g0 = _community_graph()
    core0 = coreness(g0)
    ups = _one_insert_per_community(g0, seed=1)
    assert len(ups) == 8

    gs = jax.tree.map(lambda x: x.copy(), g0)
    cs = core0.copy()
    seq_bfs = 0
    for u, v, _ in ups:
        gs, cs, st = insert_edge_maintain(gs, cs, jnp.int32(u), jnp.int32(v))
        seq_bfs += int(st.bfs_steps)

    gb, cb, bst = maintain_batch(
        jax.tree.map(lambda x: x.copy(), g0), core0.copy(), ups, R=8
    )
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(cb))
    assert bst.batched_updates == 8 and bst.sequential_updates == 0
    assert bst.bfs_steps < seq_bfs, (bst.bfs_steps, seq_bfs)
    # graphs end identical too
    np.testing.assert_array_equal(np.asarray(gs.deg), np.asarray(gb.deg))


def test_maintain_batch_conflicting_updates_fall_back_exactly():
    """On a hub graph candidate sets overlap: the batch must serialize those
    updates and still produce the exact sequential result."""
    import networkx as nx
    edges = barabasi_albert(150, 4, seed=3)
    n = int(edges.max()) + 1
    g0 = build_blocks(edges, n, node_random_partition(n, 4, seed=0), P=4,
                      deg_slack=40)
    core0 = coreness(g0)
    ups = sample_insertions(g0, 8, "inter", seed=5)

    gb, cb, bst = maintain_batch(
        jax.tree.map(lambda x: x.copy(), g0), core0.copy(), ups, R=8
    )
    assert bst.updates == 8
    assert bst.batched_updates + bst.sequential_updates == 8

    G = nx.Graph()
    orig = np.asarray(g0.orig_id)
    G.add_nodes_from(int(o) for o in orig if o >= 0)
    G.add_edges_from(map(tuple, edges))
    for u, v, _ in ups:
        G.add_edge(int(orig[u]), int(orig[v]))
    ref_core = nx.core_number(G)
    c = np.asarray(cb)
    for i in range(g0.N):
        if orig[i] >= 0:
            assert c[i] == ref_core[orig[i]]


def test_maintain_batch_mixed_ops_and_odd_chunks():
    """Insertions + deletions, chunk size not dividing the stream."""
    import networkx as nx
    g0 = _community_graph(n_comm=4, sz=30, seed=2)
    core0 = coreness(g0)
    ups = (_one_insert_per_community(g0, seed=3)
           + sample_deletions(g0, 5, "intra", seed=4))
    gb, cb, _ = maintain_batch(
        jax.tree.map(lambda x: x.copy(), g0), core0.copy(), ups, R=3
    )
    G = nx.Graph()
    orig = np.asarray(g0.orig_id)
    G.add_nodes_from(int(o) for o in orig if o >= 0)
    for u in range(g0.N):
        for v in np.asarray(g0.nbr)[u]:
            if v >= 0:
                G.add_edge(int(orig[u]), int(orig[v]))
    for u, v, op in ups:
        if op > 0:
            G.add_edge(int(orig[u]), int(orig[v]))
        else:
            G.remove_edge(int(orig[u]), int(orig[v]))
    ref_core = nx.core_number(G)
    c = np.asarray(cb)
    for i in range(g0.N):
        if orig[i] >= 0:
            assert c[i] == ref_core[orig[i]]


def test_independent_prefix_defers_conflicts_with_deferred_too():
    """A column overlapping an earlier *deferred* column must defer as well:
    accepting it would apply it before that earlier update, reordering two
    dependent updates."""
    from repro.core.kcore_dynamic import _independent_prefix
    cand = np.zeros((6, 3), bool)
    cand[[0, 1], 0] = True   # col0: accepted
    cand[[1, 2], 1] = True   # col1: overlaps col0 -> deferred
    cand[[2, 3], 2] = True   # col2: disjoint from col0, overlaps col1
    accepted, deferred = _independent_prefix(cand, 3)
    assert accepted == [0]
    assert deferred == [1, 2]


def test_maintain_batch_preserves_order_of_dependent_updates():
    """Regression: an insert into a full row must not be hoisted above the
    deferred delete that frees the slot (row-capacity dependence)."""
    from repro.core import to_networkx_edges
    # cycle 0-1-2-9, K4 on {3,10,11,12}, edge 3-9 (row 3 full at Cd=4),
    # isolated node 4; P=1 keeps padded ids == original ids
    edges = np.array(
        [[0, 1], [1, 2], [2, 9], [9, 0],
         [3, 10], [3, 11], [3, 12], [10, 11], [10, 12], [11, 12],
         [3, 9]]
    )
    g0 = build_blocks(edges, 13, np.zeros(13, int), P=1, Cd=4)
    core0 = coreness(g0)
    ups = [(0, 2, +1), (3, 9, -1), (3, 4, +1)]

    gs = jax.tree.map(lambda x: x.copy(), g0)
    cs = core0.copy()
    for u, v, op in ups:
        fn = insert_edge_maintain if op > 0 else delete_edge_maintain
        gs, cs, _ = fn(gs, cs, jnp.int32(u), jnp.int32(v))

    gb, cb, _ = maintain_batch(
        jax.tree.map(lambda x: x.copy(), g0), core0.copy(), ups, R=3
    )
    np.testing.assert_array_equal(np.asarray(cs), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(gs.deg), np.asarray(gb.deg))
    np.testing.assert_array_equal(to_networkx_edges(gs), to_networkx_edges(gb))


def test_maintain_batch_rejects_self_loops():
    g0 = _community_graph(n_comm=2, sz=20, seed=5)
    core0 = coreness(g0)
    with pytest.raises(ValueError, match="self-loop"):
        maintain_batch(g0, core0, [(3, 3, +1)], R=4)


def test_maintain_batch_rejects_duplicate_insert():
    """Host-boundary validation covers the whole stream, not just loops:
    inserting the same edge twice would corrupt the ELL row bookkeeping."""
    g0 = _community_graph(n_comm=2, sz=20, seed=6)
    core0 = coreness(g0)
    (u, v, _), = _one_insert_per_community(g0, seed=7)[:1]
    with pytest.raises(ValueError, match="already present"):
        maintain_batch(g0, core0, [(u, v, +1), (u, v, +1)], R=2)
    with pytest.raises(ValueError, match="not present"):
        maintain_batch(g0, core0, [(u, v, -1)], R=2)
