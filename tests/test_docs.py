"""Docs gate: doctest the shape-convention snippets, kill dead links.

Two rot-proofing checks over the markdown + docstring surface (the CI
`docs` job runs exactly this module):

  * every fenced ```python block containing `>>> ` in the repo's *.md
    files runs as a doctest (ARCHITECTURE.md's shape-convention snippets
    are the motivating case), and so do the docstring doctests of the
    public modules that carry them (`core.algorithms`);
  * every relative markdown link `[text](path)` must point at an
    existing file — external http(s)/mailto links and pure anchors are
    out of scope (no network in CI).
"""
import doctest
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
MD_FILES = sorted(REPO.glob("*.md"))

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

#: modules whose docstrings carry runnable `>>>` examples
DOCTEST_MODULES = ["repro.core.algorithms"]


def _doctest_blocks(path: Path):
    text = path.read_text()
    return [b for b in _FENCE.findall(text) if ">>> " in b]


@pytest.mark.parametrize(
    "md", [p for p in MD_FILES if _doctest_blocks(p)], ids=lambda p: p.name)
def test_markdown_doctest_snippets(md):
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
    for i, block in enumerate(_doctest_blocks(md)):
        test = parser.get_doctest(block, {}, f"{md.name}[{i}]", str(md), 0)
        runner.run(test)
    results = runner.summarize(verbose=False)
    assert results.failed == 0, (
        f"{results.failed} doctest failure(s) in {md.name}")


def test_architecture_md_exists_and_has_snippets():
    arch = REPO / "ARCHITECTURE.md"
    assert arch.exists(), "ARCHITECTURE.md missing"
    assert _doctest_blocks(arch), "ARCHITECTURE.md lost its doctest snippets"
    readme = (REPO / "README.md").read_text()
    assert "ARCHITECTURE.md" in readme, "README no longer links ARCHITECTURE"


@pytest.mark.parametrize("mod", DOCTEST_MODULES)
def test_module_docstring_doctests(mod):
    module = __import__(mod, fromlist=["_"])
    results = doctest.testmod(module, optionflags=doctest.ELLIPSIS)
    assert results.attempted > 0, f"{mod} lost its docstring doctests"
    assert results.failed == 0


@pytest.mark.parametrize("md", MD_FILES, ids=lambda p: p.name)
def test_markdown_relative_links_resolve(md):
    dead = []
    for target in _LINK.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if path and not (md.parent / path).exists():
            dead.append(target)
    assert not dead, f"dead relative link(s) in {md.name}: {dead}"
