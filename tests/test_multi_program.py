"""MultiProgram: fused multi-field supersteps off one adjacency gather.

Three contracts under test:

  * parity — a fused coreness+CC+PageRank run is bit-identical, per
    field, to the standalone programs run for the same superstep count,
    on every backend (jnp / ell / dense / ell_spmd);
  * one gather — tracing the fused superstep loop dispatches exactly ONE
    adjacency gather where k standalone programs dispatch k
    (`ops.gather_trace_count`, bumped per `red_of` trace; asserted via
    explicit `.lower()` calls since jit cache hits never retrace);
  * validation — non-fusable sub-combines ("count_common") and unknown
    combines are rejected at construction/dispatch.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import MultiProgram, build_ell_random, fused_analytics
from repro.core.algorithms import (
    ConnectedComponentsProgram, CorenessBlockProgram, PageRankProgram,
    TriangleCountProgram, connected_components, pagerank,
)
from repro.kernels import ops

STEPS = 30


def _programs():
    return (CorenessBlockProgram(), ConnectedComponentsProgram(),
            PageRankProgram(tol=None, max_steps=STEPS))


@pytest.fixture(scope="module")
def g():
    return build_ell_random(192, Cd=16, seed=5)


@pytest.mark.parametrize("backend", ["jnp", "ell", "dense", "ell_spmd"])
def test_fused_matches_standalone(g, backend):
    core, lab, rank = fused_analytics(g, steps=STEPS, backend=backend)
    core_ref = ops.run_block_program(
        g, CorenessBlockProgram(), backend=backend)
    lab_ref = connected_components(g, backend=backend)
    rank_ref = pagerank(g, tol=None, max_steps=STEPS, backend=backend)
    np.testing.assert_array_equal(np.asarray(core), np.asarray(core_ref))
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_ref))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rank_ref))


def test_fused_runs_exactly_steps_supersteps(g):
    (_, _, _), n = fused_analytics(g, steps=STEPS, backend="jnp",
                                   with_steps=True)
    assert int(n) == STEPS  # fixed-iteration PageRank pins the loop length


def _lower(g, program, b):
    """Force a fresh trace of the fused superstep loop (no jit cache)."""
    state0 = program.init(g)
    ops._block_program_fused.lower(
        g, state0, None, None, program=program, b=b, interpret=True,
        max_steps=5, n_real=int(g.n_real))


@pytest.mark.parametrize("b", ["jnp", "ell"])
def test_fused_traces_one_gather_where_standalone_trace_three(g, b):
    before = ops.gather_trace_count()
    _lower(g, MultiProgram(_programs(), max_steps=5), b)
    assert ops.gather_trace_count() - before == 1
    before = ops.gather_trace_count()
    for p in _programs():
        _lower(g, p, b)
    assert ops.gather_trace_count() - before == 3


def test_multi_kernel_direct_parity(g):
    """ops.neighbor_multi_ell == the three standalone combines, bit-exact."""
    est = jnp.asarray(g.deg, jnp.int32)
    lab = jnp.arange(g.N, dtype=jnp.int32)
    contrib = jnp.where(g.deg > 0, 1.0 / jnp.maximum(g.deg, 1),
                        0.0).astype(jnp.float32)
    fused = ops.neighbor_multi_ell(
        g.nbr, (est, lab, contrib), ("hindex", "min", "sum"),
        interpret=True)
    np.testing.assert_array_equal(
        np.asarray(fused[0]), np.asarray(ops.hindex_ell(g.nbr, est)))
    np.testing.assert_array_equal(
        np.asarray(fused[1]), np.asarray(ops.neighbor_min_ell(g.nbr, lab)))
    np.testing.assert_array_equal(
        np.asarray(fused[2]), np.asarray(ops.neighbor_sum_ell(g.nbr, contrib)))


def test_count_common_not_fusable():
    with pytest.raises(ValueError, match="not fusable"):
        MultiProgram((ConnectedComponentsProgram(), TriangleCountProgram()))


def test_empty_multi_rejected():
    with pytest.raises(ValueError, match="at least one"):
        MultiProgram(())


def test_unknown_combine_rejected(g):
    class Bad(CorenessBlockProgram):
        combine = "nonsense"

    with pytest.raises(ValueError, match="unknown combine"):
        ops.run_block_program(g, Bad(), backend="jnp")


# ---------------------------------------------------------------------------
# auto backend crossover (measured table, TPU only)
# ---------------------------------------------------------------------------


def test_auto_crossover_table(monkeypatch):
    # off-TPU (this container): always jnp — Pallas would run interpreted
    assert ops.resolve_backend("auto", 256) == "jnp"
    assert ops.resolve_backend("auto", 1 << 20) == "jnp"
    # on TPU: the measured N crossovers of AUTO_CROSSOVER
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    assert ops.resolve_backend("auto", 1) == "jnp"
    assert ops.resolve_backend("auto", ops.JNP_AUTO_MAX) == "jnp"
    assert ops.resolve_backend("auto", ops.JNP_AUTO_MAX + 1) == "dense"
    assert ops.resolve_backend("auto", ops.DENSE_AUTO_MAX) == "dense"
    assert ops.resolve_backend("auto", ops.DENSE_AUTO_MAX + 1) == "ell"
    # explicit names pass through untouched on every platform
    for b in ("jnp", "dense", "ell", "ell_spmd"):
        assert ops.resolve_backend(b, 17) == b
