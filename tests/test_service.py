"""Query service correctness: snapshot parity, batching, the serving loop.

Three contracts under test:

  1. **Snapshot parity** — every batched query answer over an epoch
     snapshot is bit-identical to a from-scratch recompute on that
     epoch's graph (`coreness` / `connected_components` /
     `pagerank(tol=None, max_steps=pr_steps)`), on the jnp and ell_spmd
     backends (CI runs this file at 1 AND 8 forced host devices).
  2. **Transfer discipline** — steady-state serving performs exactly ONE
     `jax.device_get` per answered batch, and zero recompiles after
     warmup (gather/query/mesh-step trace counters all hold still).
  3. **The serving loop** — admission control sheds at the bound,
     buckets batch by kind, and the end-to-end interleave (>= 100 mixed
     queries during a multi-window stream) answers everything exactly
     with zero executor rebuilds.
"""
import dataclasses
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.core import build_blocks, coreness
from repro.core.algorithms import connected_components, pagerank
from repro.core.partition import node_random_partition
from repro.core.updates import sample_deletions, sample_insertions
from repro.graphgen import barabasi_albert
from repro.kernels import ops
from repro.runtime import StreamSession
from repro.runtime import spmd as spmd_mod
from repro.runtime.stream import _iter_windows
from repro.service import (
    AnalyticsState,
    QueryServer,
    ServiceConfig,
    core_of,
    degree_of,
    nbr_max_core_of,
    query_trace_count,
    same_component,
    topk_pagerank,
)
from repro.service.queries import run_batch, topk_bucket

P = 4
PR_STEPS = 10
ALPHA = 0.85


@pytest.fixture
def count_device_get(monkeypatch):
    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    return calls


def _graph(n=140, seed=7):
    edges = barabasi_albert(n, 3, seed=seed)
    nn = int(edges.max()) + 1
    assign = node_random_partition(nn, P, seed=2)
    return build_blocks(edges, nn, assign, P=P, deg_slack=48)


def _mixed_updates(g, count=16, seed=11):
    per = max(1, count // 4)
    return (sample_insertions(g, per, "inter", seed=seed)
            + sample_insertions(g, per, "intra", seed=seed + 1)
            + sample_deletions(g, per, "inter", seed=seed + 2)
            + sample_deletions(g, per, "intra", seed=seed + 3))


def _open_session(g, backend, R=4):
    core = coreness(g, backend="jnp")
    labels = connected_components(g, backend="jnp")
    return StreamSession(g, core, R=R, backend=backend, cc_labels=labels)


def _epoch_graph(g0, snap):
    """The snapshot's topology as a GraphBlocks — the recompute target."""
    return dataclasses.replace(
        g0, nbr=snap.nbr, deg=snap.deg, node_mask=snap.node_mask,
        orig_id=snap.orig_id)


def _epoch_oracle(g0, snap, backend):
    """From-scratch recompute of every queryable field on snap's graph.

    Same backend as the serving session: int fields are cross-backend
    bit-identical anyway, float32 PageRank only within its own backend.
    """
    eg = _epoch_graph(g0, snap)
    return {
        "core": np.asarray(coreness(eg, backend=backend)),
        "labels": np.asarray(connected_components(eg, backend=backend)),
        "rank": np.asarray(pagerank(eg, alpha=ALPHA, tol=None,
                                    max_steps=PR_STEPS, backend=backend)),
        "deg": np.asarray(eg.deg),
        "nbr": np.asarray(eg.nbr),
        "N": eg.N,
    }


@functools.lru_cache(maxsize=None)
def _serving_trace(backend):
    """One serving run per backend: [(EpochSnapshot, oracle), ...].

    Epoch 0 is the pre-stream graph; every later epoch follows one more
    applied window (refresh_every=1), including delete windows (CC
    recompute path) and insert windows (merge path).
    """
    g = _graph()
    sess = _open_session(g, backend)
    state = AnalyticsState(sess, alpha=ALPHA, pr_steps=PR_STEPS)
    g0 = _graph()  # fresh arrays: sess donated g's buffers
    trace = [(state.snapshot, _epoch_oracle(g0, state.snapshot, backend))]
    for window in _iter_windows(_mixed_updates(g0), 4):
        sess.apply_window(window)
        snap = state.refresh()
        trace.append((snap, _epoch_oracle(g0, snap, backend)))
    return trace


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["jnp", "ell_spmd"]), st.integers(0, 10_000))
def test_batched_queries_bit_identical_to_epoch_recompute(backend, seed):
    """Hypothesis parity: random mixed batches against every epoch of a
    mixed-update serving run answer bit-identically to recomputation."""
    rng = np.random.default_rng(seed)
    for snap, ora in _serving_trace(backend):
        real = np.flatnonzero(np.asarray(snap.node_mask))
        n_q = int(rng.integers(1, 24))
        us = rng.choice(real, n_q)
        vs = rng.choice(real, n_q)

        got = run_batch(snap, "core", [core_of(u) for u in us])
        assert got == [int(x) for x in ora["core"][us]]
        got = run_batch(snap, "degree", [degree_of(u) for u in us])
        assert got == [int(x) for x in ora["deg"][us]]

        got = run_batch(snap, "nbr_max_core",
                        [nbr_max_core_of(u) for u in us])
        for u, ans in zip(us, got):
            row = ora["nbr"][u]
            nbrs = row[row >= 0]
            ref = int(ora["core"][nbrs].max()) if nbrs.size else -1
            assert ans == ref

        got = run_batch(snap, "same_component",
                        [same_component(u, v) for u, v in zip(us, vs)])
        assert got == [bool(ora["labels"][u] == ora["labels"][v])
                       for u, v in zip(us, vs)]

        k = int(rng.integers(1, 12))
        kk = topk_bucket(k, ora["N"])
        [(ids, ranks)] = run_batch(snap, "topk_pagerank",
                                   [topk_pagerank(k)], k=kk)
        ref_vals, ref_ids = jax.device_get(
            jax.lax.top_k(jnp.asarray(ora["rank"]), kk))
        assert ids == ref_ids[:k].tolist()
        assert ranks == ref_vals[:k].tolist()  # float bit-equality


def test_snapshot_survives_buffer_donation():
    """Applying more windows donates the live graph's buffers; an already
    published snapshot must stay readable (copies, not references)."""
    g = _graph()
    sess = _open_session(g, "jnp")
    state = AnalyticsState(sess, alpha=ALPHA, pr_steps=PR_STEPS)
    snap0 = state.snapshot
    for window in _iter_windows(_mixed_updates(_graph()), 4):
        sess.apply_window(window)
    # the epoch-0 snapshot still answers without touching donated buffers
    real = np.flatnonzero(np.asarray(snap0.node_mask))
    got = run_batch(snap0, "core", [core_of(int(real[0]))])
    assert got == [int(np.asarray(snap0.core)[real[0]])]


def test_one_device_get_per_answered_batch(count_device_get):
    """Steady-state serving: each answered batch costs exactly ONE
    transfer, regardless of how many queries it carries."""
    g = _graph()
    sess = _open_session(g, "jnp")
    srv = QueryServer(sess, config=ServiceConfig(
        refresh_every=1, pr_steps=PR_STEPS, alpha=ALPHA, max_batch=32))
    real = np.flatnonzero(np.asarray(srv.state.snapshot.node_mask))
    # warm the compiled caches with one batch per kind
    for u in real[:8]:
        srv.submit(core_of(u))
        srv.submit(degree_of(u))
        srv.submit(same_component(u, real[0]))
    srv.pump()

    for u in real[:16]:
        srv.submit(core_of(u))        # 16 queries -> 1 batch
        srv.submit(degree_of(u))      # 16 queries -> 1 batch
    for u in real[:4]:
        srv.submit(same_component(u, real[1]))  # 4 queries -> 1 batch
    count_device_get["n"] = 0
    answered = srv.pump()
    assert answered == 36
    assert count_device_get["n"] == 3, count_device_get["n"]


def test_admission_control_sheds_at_the_bound():
    g = _graph()
    sess = _open_session(g, "jnp")
    srv = QueryServer(sess, config=ServiceConfig(
        max_queue=8, pr_steps=PR_STEPS))
    real = np.flatnonzero(np.asarray(srv.state.snapshot.node_mask))
    results = [srv.submit(core_of(real[i % len(real)])) for i in range(12)]
    assert sum(r is not None for r in results) == 8
    assert srv.metrics.total_shed == 4
    assert srv.queued == 8
    assert srv.pump() == 8
    assert all(r.done for r in results if r is not None)
    s = srv.metrics.summary()
    assert s["answered"] == 8 and s["shed"] == 4
    assert np.isfinite(s["p50_ms"]) and np.isfinite(s["p99_ms"])


def test_submit_validates_ids_and_kinds():
    from repro.service import Query

    g = _graph()
    sess = _open_session(g, "jnp")
    srv = QueryServer(sess, config=ServiceConfig(pr_steps=PR_STEPS))
    with pytest.raises(ValueError, match="kind"):
        srv.submit(Query("bogus"))
    with pytest.raises(ValueError, match="outside"):
        srv.submit(core_of(g.N + 5))
    with pytest.raises(ValueError, match="outside"):
        srv.submit(topk_pagerank(g.N + 1))


def test_e2e_serving_mid_stream_exact_zero_rebuilds_zero_recompiles():
    """The acceptance run: a StreamSession and the query server
    interleaved on the worker mesh — >= 100 mixed queries answered
    during a multi-window update stream, every answer bit-identical to
    recompute on its epoch's graph, zero executor rebuilds, and zero
    recompiles after warmup (gather/query/mesh-step counters)."""
    g = _graph(n=160, seed=9)
    g0 = _graph(n=160, seed=9)
    sess = _open_session(g, "ell_spmd")
    srv = QueryServer(sess, config=ServiceConfig(
        refresh_every=1, pr_steps=PR_STEPS, alpha=ALPHA, max_batch=64))
    ups = _mixed_updates(g0, count=32, seed=21)
    # interleave inserts and deletes so EVERY window carries both ops:
    # warmup (the first half) then traces the merge path AND the
    # delete-triggered CC recompute path
    ins, dels = ups[:16], ups[16:]
    ups = [u for pair in zip(ins, dels) for u in pair]
    windows = list(_iter_windows(ups, 4))
    assert len(windows) == 8

    rng = np.random.default_rng(3)
    real = np.flatnonzero(np.asarray(srv.state.snapshot.node_mask))
    requests = []

    def feed():
        out = []
        for _ in range(4):
            u, v = (int(x) for x in rng.choice(real, 2))
            out += [core_of(u), degree_of(u), nbr_max_core_of(u),
                    same_component(u, v), topk_pagerank(5)]
        return out

    def play(ws):
        for w in ws:
            for query in feed():
                req = srv.submit(query)
                assert req is not None
                requests.append(req)
            srv.step(w)

    play(windows[:4])  # warmup: insert AND delete windows, all kinds
    traces0 = (ops.gather_trace_count(), query_trace_count(),
               spmd_mod.step_build_count())
    play(windows[4:])
    assert (ops.gather_trace_count(), query_trace_count(),
            spmd_mod.step_build_count()) == traces0  # ZERO recompiles
    assert sess.executor.full_rebuilds == 0          # ZERO rebuilds
    srv.pump()

    assert len(requests) >= 100 and all(r.done for r in requests)
    assert srv.metrics.total_answered == len(requests)
    assert srv.metrics.qps() > 0

    # every answer == recompute on its epoch's graph, bit-identical:
    # replay the same stream to rebuild each epoch's graph + oracle
    epochs = sorted({r.epoch for r in requests})
    assert epochs == list(range(min(epochs), max(epochs) + 1))
    sess2 = _open_session(_graph(n=160, seed=9), "ell_spmd")
    state2 = AnalyticsState(sess2, alpha=ALPHA, pr_steps=PR_STEPS)
    oracles = {0: _epoch_oracle(g0, state2.snapshot, "ell_spmd")}
    for w in windows:
        sess2.apply_window(w)
        snap = state2.refresh()
        oracles[snap.epoch] = _epoch_oracle(g0, snap, "ell_spmd")
    for r in requests:
        ora = oracles[r.epoch]
        q = r.query
        if q.kind == "core":
            assert r.answer == int(ora["core"][q.u])
        elif q.kind == "degree":
            assert r.answer == int(ora["deg"][q.u])
        elif q.kind == "nbr_max_core":
            row = ora["nbr"][q.u]
            nbrs = row[row >= 0]
            ref = int(ora["core"][nbrs].max()) if nbrs.size else -1
            assert r.answer == ref
        elif q.kind == "same_component":
            assert r.answer == bool(ora["labels"][q.u]
                                    == ora["labels"][q.v])
        else:
            kk = topk_bucket(q.k, ora["N"])
            vals, ids = jax.device_get(
                jax.lax.top_k(jnp.asarray(ora["rank"]), kk))
            assert r.answer == (ids[:q.k].tolist(), vals[:q.k].tolist())

    # the final session state is exact too
    res = sess.result()
    assert (np.asarray(res.core)
            == np.asarray(coreness(res.g, backend="jnp"))).all()
    assert (np.asarray(res.labels)
            == np.asarray(connected_components(res.g,
                                               backend="jnp"))).all()


def test_serve_drains_and_reports_staleness():
    """`serve` with a cadenced refresh: staleness stays <= refresh_every
    and the final drain leaves nothing queued."""
    g = _graph()
    sess = _open_session(g, "jnp")
    srv = QueryServer(sess, config=ServiceConfig(
        refresh_every=2, pr_steps=PR_STEPS))
    real = np.flatnonzero(np.asarray(srv.state.snapshot.node_mask))

    def feed(i):
        return [core_of(int(real[i % len(real)])), topk_pagerank(3)]

    res = srv.serve(list(_mixed_updates(_graph())), feed)
    assert srv.queued == 0
    assert srv.metrics.total_answered == 8
    assert srv.metrics.staleness_max() <= 2
    assert res.stats.batches == 4
