"""Hub splitting / mirroring: split == unsplit, exactly, everywhere.

The tentpole contract of the skew-aware vertex cut
(`core.hub_split.split_hubs`): a hub's adjacency row is sliced across
primary + replica rows (bounding Cd by the split threshold), mirrors
compute PARTIAL aggregates through the existing combines, and the
combine-then-broadcast merge makes every workload land on the value the
unsplit graph produces —

  * coreness (min/hindex), CC labels, triangle counts: BIT-exact;
  * PageRank: allclose (float slice partials re-associate);

on jnp / dense / ell / ell_spmd alike.  This file runs on whatever
devices exist (W=1 covers the full shard_map path); the multi-device CI
job re-runs it under `XLA_FLAGS=--xla_force_host_platform_device_count=8`
so the mirror merge's pmin/psum crosses real device boundaries.

Also under test: the ONLINE path (threshold-crossing inserts allocating
a fresh replica mid-stream, mirrored deletes splicing the one serving
pair) against freshly built oracle graphs, the allocation / halo-payload
counters the PR's acceptance gates ride on, and the query service
resolving replica-row ids through the primary map.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.core import build_blocks, coreness
from repro.core.algorithms import (
    CorenessBlockProgram, connected_components, fused_analytics, pagerank,
    triangle_counts,
)
from repro.core.hub_split import (
    apply_mirrored_edits, groups_of, mirror_report, split_hubs,
)
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert
from repro.kernels import ops
from repro.runtime.halo import mirror_merge_payload
from repro.runtime.stream import MirrorStream
from repro.service import AnalyticsState, core_of, degree_of, same_component
from repro.service.queries import batch_bucket, nbr_max_core_of, run_batch

BACKENDS = ("jnp", "dense", "ell", "ell_spmd")
PR_STEPS = 12


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _skewed_graph(n, seed, P=8, threshold=8, extra=0):
    """A split-worthy graph: BA skew + two planted hubs, random cut."""
    rng = np.random.default_rng(seed)
    edges = {(0, v) for v in range(1, 1 + threshold * 4)}
    edges |= {(1, v) for v in range(2 + threshold * 4,
                                    2 + threshold * 5)}
    for u, v in barabasi_albert(n, 3, seed=seed):
        if u != v:
            edges.add((min(u, v), max(u, v)))
    edges = np.array(sorted(edges))
    assign = rng.integers(0, P, n)
    g = build_blocks(edges, n, assign, P=P, node_slack=32 + extra)
    return g, edges, assign


def _bymap(oid, vals):
    return dict(zip(oid.tolist(), vals.tolist()))


def _parts(oid, labels):
    """CC partition structure keyed by original ids (label values are
    row ids of each graph's own space — compare the grouping)."""
    groups = {}
    for o, l in zip(oid.tolist(), labels.tolist()):
        groups.setdefault(l, set()).add(o)
    return frozenset(frozenset(s) for s in groups.values())


def _oracles(g, mask, oid):
    return dict(
        core=_bymap(oid, np.asarray(coreness(g, backend="jnp"))[mask]),
        tri=_bymap(oid, np.asarray(triangle_counts(g, backend="jnp"))[mask]),
        cc=_parts(oid, np.asarray(connected_components(g,
                                                       backend="jnp"))[mask]),
        pr=_bymap(oid, np.asarray(pagerank(
            g, backend="jnp", tol=None, max_steps=PR_STEPS))[mask]),
    )


def _check_split(g2, plan, want, backend):
    pm = np.asarray(plan.primary_mask)
    oid = np.asarray(g2.orig_id)[pm]
    core = _bymap(oid, np.asarray(coreness(
        g2, backend=backend, mirror=plan))[pm])
    assert core == want["core"], f"coreness diverged on {backend}"
    tri = _bymap(oid, np.asarray(triangle_counts(
        g2, backend=backend, mirror=plan))[pm])
    assert tri == want["tri"], f"triangles diverged on {backend}"
    cc = _parts(oid, np.asarray(connected_components(
        g2, backend=backend, mirror=plan))[pm])
    assert cc == want["cc"], f"CC partition diverged on {backend}"
    pr = _bymap(oid, np.asarray(pagerank(
        g2, backend=backend, tol=None, max_steps=PR_STEPS,
        mirror=plan))[pm])
    keys = sorted(want["pr"])
    np.testing.assert_allclose([pr[k] for k in keys],
                               [want["pr"][k] for k in keys], atol=1e-5)


# ---------------------------------------------------------------------------
# split == unsplit parity, every backend
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from((8, 12, 16)))
def test_split_parity_all_backends(seed, threshold):
    g, _, _ = _skewed_graph(110, seed, threshold=threshold)
    g2, plan = split_hubs(g, threshold=threshold)
    assert plan.n_groups >= 1 and g2.Cd < g.Cd
    mask = np.asarray(g.node_mask)
    want = _oracles(g, mask, np.asarray(g.orig_id)[mask])
    for b in BACKENDS:
        _check_split(g2, plan, want, b)


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_split_parity_fused(seed):
    """fused_analytics under mirror == the three standalone runs."""
    g, _, _ = _skewed_graph(100, seed, threshold=10)
    g2, plan = split_hubs(g, threshold=10)
    pm = np.asarray(plan.primary_mask)
    oid = np.asarray(g2.orig_id)[pm]
    for b in ("jnp", "ell_spmd"):
        core, labels, rank = fused_analytics(
            g2, steps=PR_STEPS, backend=b, mirror=plan)
        mask = np.asarray(g.node_mask)
        want = _oracles(g, mask, np.asarray(g.orig_id)[mask])
        assert _bymap(oid, np.asarray(core)[pm]) == want["core"]
        assert _parts(oid, np.asarray(labels)[pm]) == want["cc"]
        pr = _bymap(oid, np.asarray(rank)[pm])
        keys = sorted(want["pr"])
        np.testing.assert_allclose([pr[k] for k in keys],
                                   [want["pr"][k] for k in keys],
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# online split / mirrored delete
# ---------------------------------------------------------------------------


def _fresh_oracle(edges_set, n, assign, P=8):
    gr = build_blocks(np.array(sorted(edges_set)), n, assign, P=P)
    mask = np.asarray(gr.node_mask)
    return _oracles(gr, mask, np.asarray(gr.orig_id)[mask])


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_online_split_and_mirrored_delete(seed):
    threshold = 8
    n = 100
    g, edges, assign = _skewed_graph(n, seed, threshold=threshold, extra=32)
    g2, plan = split_hubs(g, threshold=threshold)
    pm = np.asarray(plan.primary_mask)
    row_of = {int(o): i for i, o in enumerate(np.asarray(g2.orig_id))
              if pm[i]}
    cur = set(map(tuple, edges.tolist()))
    deg = np.zeros(n, np.int64)
    for u, v in cur:
        deg[u] += 1
        deg[v] += 1
    # push a sub-threshold vertex across the threshold -> online split
    tgt = int(np.argmax(np.where(deg < threshold, deg, -1)))
    edits = []
    for v in np.argsort(deg)[::-1]:
        v = int(v)
        e = (min(tgt, v), max(tgt, v))
        if v != tgt and e not in cur:
            edits.append((tgt, v, +1))
            cur.add(e)
        if len(edits) == threshold + 4:
            break
    # ... and a MIRRORED delete: drop one of hub 0's sliced edges
    hub_e = next(e for e in sorted(cur) if e[0] == 0)
    edits.append((hub_e[0], hub_e[1], -1))
    cur.discard(hub_e)

    g3, plan3 = apply_mirrored_edits(
        g2, plan, [(row_of[u], row_of[v], op) for u, v, op in edits])
    assert plan3.n_groups > plan.n_groups, "insert burst must split tgt"
    assert len(groups_of(plan3).get(row_of[tgt], [])) >= 2
    assert plan3.uid != plan.uid  # fresh plan -> fresh SPMD cache entry

    want = _fresh_oracle(cur, n, assign)
    for b in ("jnp", "ell_spmd"):
        _check_split(g3, plan3, want, b)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 10_000))
def test_mirror_stream_windows(seed):
    """MirrorStream ingestion: maintained core/labels stay exact."""
    threshold = 8
    n = 90
    g, edges, assign = _skewed_graph(n, seed, threshold=threshold, extra=32)
    g2, plan = split_hubs(g, threshold=threshold)
    sess = MirrorStream(g2, plan, backend="jnp", cc_labels=True)
    pm = np.asarray(plan.primary_mask)
    row_of = {int(o): i for i, o in enumerate(np.asarray(g2.orig_id))
              if pm[i]}
    cur = set(map(tuple, edges.tolist()))
    rng = np.random.default_rng(seed + 1)
    for _ in range(2):
        window, tried = [], set()
        while len(window) < 6:
            u, v = (int(x) for x in rng.integers(0, n, 2))
            e = (min(u, v), max(u, v))
            if u == v or e in tried:
                continue
            tried.add(e)
            if e in cur:
                window.append((e[0], e[1], -1))
                cur.discard(e)
            else:
                window.append((e[0], e[1], +1))
                cur.add(e)
        sess.apply_window(
            [(row_of[u], row_of[v], op) for u, v, op in window])
    assert sess.windows_applied == 2
    want = _fresh_oracle(cur, n, assign)
    pm2 = np.asarray(sess.mirror.primary_mask)
    oid = np.asarray(sess.g.orig_id)[pm2]
    assert _bymap(oid, np.asarray(sess.core)[pm2]) == want["core"]
    assert _parts(oid, np.asarray(sess.labels)[pm2]) == want["cc"]


# ---------------------------------------------------------------------------
# the acceptance counters (allocation / halo payload)
# ---------------------------------------------------------------------------


def test_allocation_and_halo_counters():
    """On a BA graph with max degree >= 8x mean, splitting shrinks the
    N*Cd allocation >= 4x and the W2W inter-block halo payload."""
    edges = barabasi_albert(600, 3, seed=7)
    n = int(edges.max()) + 1
    deg = np.bincount(edges.ravel(), minlength=n)
    assert deg.max() >= 8 * deg.mean(), "generator lost its skew"
    assign = node_random_partition(n, 8, seed=7)
    g = build_blocks(edges, n, assign, P=8, node_slack=64)
    g2, plan = split_hubs(g, threshold=16)
    rep = mirror_report(g, g2, plan)
    assert rep["alloc_ratio"] >= 4.0, rep
    assert rep["slots_split"] == g2.N * g2.Cd
    assert rep["inter_split"] < rep["inter_unsplit"], rep
    # the merge's per-superstep W2W payload is O(hubs), not O(edges)
    assert rep["merge_payload"] == mirror_merge_payload(plan)
    assert rep["merge_payload"] == int(plan.Gmax) + 1
    assert rep["merge_payload"] < rep["inter_unsplit"] - rep["inter_split"]
    # and the counters describe a graph whose answers are still exact
    mask = np.asarray(g.node_mask)
    want = _bymap(np.asarray(g.orig_id)[mask],
                  np.asarray(coreness(g, backend="jnp"))[mask])
    pm = np.asarray(plan.primary_mask)
    got = _bymap(np.asarray(g2.orig_id)[pm],
                 np.asarray(coreness(g2, backend="jnp", mirror=plan))[pm])
    assert got == want


# ---------------------------------------------------------------------------
# service resolution through the primary map
# ---------------------------------------------------------------------------


def test_service_resolves_replica_rows():
    g, _, _ = _skewed_graph(100, seed=3, threshold=8)
    g2, plan = split_hubs(g, threshold=8)
    sess = MirrorStream(g2, plan, backend="jnp", cc_labels=True)
    state = AnalyticsState(sess, pr_steps=PR_STEPS)
    snap = state.snapshot
    assert snap.primary is not None and snap.nbr_max is not None

    groups = groups_of(plan)
    hub, rows = next((h, r) for h, r in groups.items() if len(r) >= 2)
    replica = next(r for r in rows if r != hub)

    # primary-row and replica-row ids answer with the hub's values
    for q, field in ((core_of, sess.core),
                     (degree_of, jnp.asarray(plan.ldeg))):
        a_hub = run_batch(snap, q(hub).kind, [q(hub)])
        a_rep = run_batch(snap, q(replica).kind, [q(replica)])
        assert a_hub == a_rep == [int(field[hub])]

    # nbr_max_core sees the WHOLE sharded neighborhood of the hub
    nbr = np.asarray(g2.nbr)
    nbrs = sorted({int(x) for r in rows for x in nbr[r] if x >= 0})
    want = max(int(sess.core[np.asarray(plan.primary_row)[x]])
               for x in nbrs)
    got = run_batch(snap, "nbr_max_core", [nbr_max_core_of(replica)])
    assert got == [want]

    # same_component accepts replica ids on either side
    q = same_component(replica, nbrs[0])
    assert run_batch(snap, q.kind, [q]) == [True]

    # replica rows never rank in top-k (rank masked to primaries)
    assert float(snap.rank[replica]) == 0.0
