"""Incremental halo-plan maintenance: `HaloPlan.apply_updates` parity.

The tentpole contract: a plan maintained incrementally over an arbitrary
insert/delete stream is **field-for-field identical** to a from-scratch
`build_halo_plan` on the post-update graph (with the maintained plan's
capacity floors, since capacities never shrink in place), including the
H/K capacity-doubling path.  Runs at whatever device count the host has
(W = 1 folds everything onto one worker but still exercises the
local-frame maintenance); the multi-device CI job re-runs this file
under `XLA_FLAGS=--xla_force_host_platform_device_count=8` so dirty-
worker recomputation happens across real worker boundaries.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax

from repro.core import build_blocks
from repro.core.partition import node_random_partition
from repro.core.updates import (
    apply_updates_host, sample_deletions, sample_insertions)
from repro.graphgen import barabasi_albert
from repro.runtime import build_halo_plan, make_worker_mesh
from repro.runtime.halo import _pow2_ceil

SCALAR_FIELDS = ("K", "H", "slot_intra", "slot_inter")
ARRAY_FIELDS = ("send_idx", "recv_pos", "halo_len", "halo_ids",
                "nbr_local", "pair_elems")


def assert_plans_equal(a, b, ctx=""):
    for f in SCALAR_FIELDS:
        assert getattr(a, f) == getattr(b, f), (ctx, f)
    for f in ARRAY_FIELDS:
        assert (getattr(a, f) == getattr(b, f)).all(), (ctx, f)


def _worker_counts(P):
    ndev = len(jax.devices())
    return sorted({w for w in (1, 2, P) if w <= ndev and P % w == 0})


def _graph(P, seed):
    edges = barabasi_albert(100 + 10 * P, 3, seed=seed)
    n = int(edges.max()) + 1
    assign = node_random_partition(n, P, seed=seed + 1)
    return build_blocks(edges, n, assign, P=P, deg_slack=48)


def _stream(g, seed, windows=4, per=4):
    """`windows` windows of mixed valid insert/delete updates."""
    out = []
    for w in range(windows):
        s = seed * 1000 + w
        ups = (sample_insertions(g, 2, "inter", seed=s)
               + sample_insertions(g, 1, "intra", seed=s + 500)
               + sample_deletions(g, 2, "inter", seed=s)
               + sample_deletions(g, 1, "intra", seed=s + 500))
        window = ups[:per]
        out.append(window)
        g = apply_updates_host(g, window)
    return out


def test_pow2_ceil_policy():
    assert [_pow2_ceil(x) for x in (0, 1, 2, 3, 4, 5, 8, 9)] == \
        [1, 1, 2, 4, 4, 8, 8, 16]


@pytest.mark.parametrize("P", (2, 4))
def test_incremental_parity_random_streams(P):
    """The acceptance criterion, deterministically seeded per P/W."""
    for W in _worker_counts(P):
        g = _graph(P, seed=3)
        wm = make_worker_mesh(g, W=W)
        plan = build_halo_plan(g, wm)
        for i, window in enumerate(_stream(g, seed=7, windows=5)):
            g = apply_updates_host(g, window)
            inc = plan.apply_updates(g, window)
            fresh = build_halo_plan(g, wm, H_min=plan.H, K_min=plan.K)
            assert_plans_equal(inc, fresh, ctx=(P, W, i))
            plan = inc


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=10_000))
def test_incremental_parity_hypothesis(seed):
    """Property form: any sampled stream keeps incremental == from-scratch."""
    P = 2 + 2 * (seed % 2)  # P in {2, 4}
    g = _graph(P, seed=seed % 50)
    wm = make_worker_mesh(g)
    plan = build_halo_plan(g, wm)
    for window in _stream(g, seed=seed, windows=3):
        g = apply_updates_host(g, window)
        plan2 = plan.apply_updates(g, window)
        assert_plans_equal(
            plan2, build_halo_plan(g, wm, H_min=plan.H, K_min=plan.K),
            ctx=seed)
        plan = plan2


def test_capacity_growth_path():
    """Flooding cross-block edges overflows H and K; the doubling policy
    must land the incremental plan exactly on the from-scratch value."""
    n = 16
    edges = ([(i, i + 1) for i in range(7)]
             + [(8 + i, 9 + i) for i in range(7)] + [(0, 8)])
    assign = np.array([0] * 8 + [1] * 8)
    g = build_blocks(np.array(edges), n, assign, P=2, Cd=14)
    wm = make_worker_mesh(g)
    plan = build_halo_plan(g, wm)
    H0, K0 = plan.H, plan.K

    orig = np.asarray(g.orig_id)
    pad_of = {int(orig[i]): i for i in range(g.N) if orig[i] >= 0}
    ups = [(pad_of[a], pad_of[b], +1)
           for a in range(8) for b in range(8, 16)
           if not (np.asarray(g.nbr)[pad_of[a]] == pad_of[b]).any()]
    grew = False
    for i in range(0, len(ups), 3):
        window = ups[i:i + 3]
        try:
            g2 = apply_updates_host(g, window)
        except ValueError:  # degree capacity reached; enough flooding
            break
        inc = plan.apply_updates(g2, window)
        assert_plans_equal(
            inc, build_halo_plan(g2, wm, H_min=plan.H, K_min=plan.K), ctx=i)
        grew = grew or (inc.H > plan.H) or (inc.K > plan.K)
        g, plan = g2, inc
    if wm.W > 1:  # W = 1 has no halo at all; growth needs real workers
        assert grew and plan.H > H0
    assert plan.H == _pow2_ceil(int(plan.halo_len.max())) or \
        plan.H >= int(plan.halo_len.max())


def test_apply_updates_skips_padding_ops_and_empty():
    g = _graph(2, seed=5)
    wm = make_worker_mesh(g)
    plan = build_halo_plan(g, wm)
    assert plan.apply_updates(g, []) is plan
    u, v, _ = sample_insertions(g, 1, "inter", seed=0)[0]
    assert_plans_equal(
        plan.apply_updates(g, [(u, v, 0)]), plan, ctx="noop")


def test_executor_apply_updates_counters_and_parity():
    """SpmdExecutor.apply_updates keeps mesh results bit-identical and
    counts incremental maintenance vs full rebuilds."""
    from repro.core import coreness
    from repro.runtime import SpmdExecutor

    g = _graph(4, seed=9)
    ex = SpmdExecutor(g)
    assert (ex.full_rebuilds, ex.plan_updates) == (0, 0)
    for window in _stream(g, seed=11, windows=3):
        g = apply_updates_host(g, window)
        ex.apply_updates(g, window)
        got = np.asarray(ex.coreness()[0])
        want = np.asarray(coreness(g, backend="jnp"))
        assert (got == want).all()
    assert ex.plan_updates == 3 and ex.full_rebuilds == 0
    ex.rebuild(g)
    assert ex.full_rebuilds == 1
    assert (np.asarray(ex.coreness()[0])
            == np.asarray(coreness(g, backend="jnp"))).all()
