"""Smoke-run every graph example so the example scripts cannot rot.

Each example is executed as a subprocess (its own jax process: examples
assert their own invariants and exit nonzero on failure) at a tiny scale.
The LM examples (serve_lm/train_lm) are exercised by the arch smoke tests
and are out of scope here.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
EXAMPLES = REPO / "examples"

GRAPH_EXAMPLES = [
    ("quickstart.py", []),
    ("kcore_dynamic.py", ["--nodes", "300", "--updates", "8", "--blocks", "2"]),
    ("kcore_dynamic.py", ["--nodes", "250", "--updates", "4", "--blocks", "2",
                          "--backend", "ell_spmd", "--stream"]),
    ("partition_dynamic.py", ["--method", "hash", "--scale", "0.05"]),
]


@pytest.mark.parametrize("script,args", GRAPH_EXAMPLES,
                         ids=lambda p: p if isinstance(p, str) else "")
def test_graph_example_runs(script, args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / script), *args],
        capture_output=True, text=True, timeout=900, env=env, cwd=str(REPO),
    )
    assert proc.returncode == 0, (
        f"{script} {' '.join(args)} failed\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
    )
