"""Static distributed k-core vs networkx + h-index properties."""
import numpy as np
import jax.numpy as jnp
import networkx as nx
import pytest
from _hyp import given, settings, st

from repro.core import build_blocks, coreness, coreness_with_stats, hindex_rows
from repro.core.partition import (
    node_random_partition, node_hash_partition, node_bfs_partition)
from repro.graphgen import erdos_renyi, barabasi_albert, nearest_neighbor_graph

from conftest import nx_graph


def _check_coreness(edges, n, P, partition_fn, seed=0):
    if partition_fn is node_bfs_partition:
        assign = partition_fn(edges, n, P, seed)
    else:
        assign = partition_fn(n, P, seed)
    g = build_blocks(edges, n, assign, P=P)
    core = np.asarray(coreness(g))
    ref = nx.core_number(nx_graph(edges, n))
    orig = np.asarray(g.orig_id)
    for i in range(g.N):
        if orig[i] >= 0:
            assert core[i] == ref[orig[i]], (i, core[i], ref[orig[i]])
        else:
            assert core[i] == 0


@pytest.mark.parametrize("gen,args", [
    (erdos_renyi, (120, 360)),
    (barabasi_albert, (150, 5)),
    (nearest_neighbor_graph, (150, 0.85)),
])
@pytest.mark.parametrize("pfn", [node_random_partition, node_hash_partition,
                                 node_bfs_partition])
def test_coreness_matches_networkx(gen, args, pfn):
    edges = gen(*args, seed=13)
    n = int(edges.max()) + 1
    _check_coreness(edges, n, 4, pfn)


def test_coreness_partition_invariance(er_graph):
    """Coreness must not depend on the partitioning (BLADYG invariant)."""
    edges, n = er_graph
    results = []
    for P in (1, 2, 8):
        assign = node_random_partition(n, P, seed=P)
        g = build_blocks(edges, n, assign, P=P)
        core = np.asarray(coreness(g))
        orig = np.asarray(g.orig_id)
        by_orig = {orig[i]: core[i] for i in range(g.N) if orig[i] >= 0}
        results.append(by_orig)
    assert results[0] == results[1] == results[2]


def test_superstep_count_reported(blocks_ba):
    core, steps = coreness_with_stats(blocks_ba)
    assert steps >= 1
    assert (np.asarray(core) == np.asarray(coreness(blocks_ba))).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-1, 12), min_size=1, max_size=12))
def test_hindex_rows_matches_bruteforce(vals):
    arr = jnp.asarray([vals], jnp.int32)
    h = int(hindex_rows(arr)[0])
    brute = 0
    for k in range(1, len(vals) + 1):
        if sum(v >= k for v in vals) >= k:
            brute = k
    assert h == brute


def test_empty_and_isolated_nodes():
    edges = np.array([[0, 1]])
    g = build_blocks(edges, 5, np.zeros(5, int), P=1)
    core = np.asarray(coreness(g))
    orig = np.asarray(g.orig_id)
    ref = {0: 1, 1: 1, 2: 0, 3: 0, 4: 0}
    for i in range(g.N):
        if orig[i] >= 0:
            assert core[i] == ref[orig[i]]
