"""Sharding rules + abstract cell construction (the dry-run plumbing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.distributed import sharding as SH
from repro.launch.mesh import make_test_mesh
from repro.launch import specs as SP


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(dp=1, tp=1)


def test_param_spec_rules(mesh):
    assert SH.param_spec("embed/w", (512, 64), mesh) == P(None, None)  # tp=1
    m = make_test_mesh(dp=1, tp=jax.device_count())
    tp = jax.device_count()
    if tp > 1:
        assert SH.param_spec("embed/w", (512 * tp, 64), m)[0] == "model"
    # fallback replication for non-divisible dims
    assert SH.param_spec("wk/w", (64, 7), m) == P(None, None)
    # stacked leading dims padded with None
    s = SH.param_spec("blocks/0/attn/wq/w", (24, 64, 128), mesh)
    assert len(s) == 3 and s[0] is None


def test_zero_spec_adds_data_axis():
    m = make_test_mesh(dp=jax.device_count(), tp=1)
    dp = jax.device_count()
    base = P(None, None)
    out = SH.zero_spec(base, (dp * 4, 8), m)
    if dp > 1:
        assert out[0] == "data"
    out2 = SH.zero_spec(P("model", None), (dp * 4, 8), m)
    assert out2[0] == "model"  # never overrides existing axes


def test_batch_spec_divisibility(mesh):
    assert SH.batch_spec(mesh, 8, 1) == P(("data",), None)
    m = make_test_mesh(dp=jax.device_count(), tp=1)
    if jax.device_count() > 1:
        assert SH.batch_spec(m, 3, 1) == P(None, None)  # non-divisible


@pytest.mark.parametrize("arch,shape", [
    ("internlm2-1.8b", "train_4k"),
    ("gemma3-1b", "decode_32k"),
    ("mamba2-370m", "long_500k"),
    ("seamless-m4t-large-v2", "prefill_32k"),
])
def test_abstract_cell_builds(arch, shape, mesh):
    """Abstract inputs materialize with shapes/dtypes and no allocation."""
    cfg = ARCHS[arch]
    sc = SHAPES_BY_NAME[shape]
    step, kwargs, donate = SP.abstract_cell(cfg, sc, mesh,
                                            optim.AdamWConfig())
    leaves = jax.tree_util.tree_leaves(kwargs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert callable(step)


def test_cache_shardings_classify(mesh):
    shapes = {
        "k": jax.ShapeDtypeStruct((4, 8, 32, 2, 16), jnp.bfloat16),
        "state": jax.ShapeDtypeStruct((4, 8, 4, 16, 8), jnp.float32),
        "conv": jax.ShapeDtypeStruct((4, 8, 3, 64), jnp.float32),
    }
    sh = SH.cache_shardings(shapes, mesh)
    assert set(sh.keys()) == set(shapes.keys())


def test_reduced_cell_lowers_on_test_mesh(mesh):
    """End-to-end: a reduced arch train cell lowers+compiles on the CPU mesh
    (the real dry-run covers the production meshes)."""
    import dataclasses
    cfg = ARCHS["internlm2-1.8b"].reduced()
    sc = dataclasses.replace(SHAPES_BY_NAME["train_4k"], seq_len=64,
                             global_batch=4)
    with mesh:
        step, kwargs, donate = SP.abstract_cell(cfg, sc, mesh,
                                                optim.AdamWConfig())
        compiled = jax.jit(step, donate_argnums=donate).lower(**kwargs).compile()
    assert compiled.cost_analysis() is not None
