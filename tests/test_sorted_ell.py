"""Property tests: the sorted-ELL invariant and the merge intersection.

The canonical row form every kernel optimization of this PR leans on:
valid slots ascending, pads (-1) on the right.  Every construction and
mutation path of `core.graph` must preserve it — `build_blocks`,
`build_ell_random`, the jitted `insert_edge`/`delete_edge`, the host
`apply_updates_host`, and `migrate_vertices` — and the host and jitted
update paths must produce bit-identical canonical rows.

The sorted-merge triangle kernel (`ell_triangles` variant "merge") must
match the all-pairs oracle bit-for-bit on ragged inputs: Cd not a lane
multiple (e.g. 130), all-pad rows, duplicate ids in raw fields.
"""
import numpy as np
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.core import (
    build_blocks, build_ell_random, insert_edge, delete_edge,
    migrate_vertices,
)
from repro.core.partition import node_random_partition
from repro.core.updates import (
    apply_updates_host, sample_deletions, sample_insertions,
)
from repro.graphgen import barabasi_albert
from repro.kernels import ops, ref


def assert_sorted_ell(nbr, deg=None):
    """Every row: valid slots first, strictly ascending, pads right."""
    nbr = np.asarray(nbr)
    for i, row in enumerate(nbr):
        vals = row[row >= 0]
        # left-filled: the valid slots are exactly the row's prefix
        np.testing.assert_array_equal(
            row[: len(vals)], vals, err_msg=f"row {i} not left-filled")
        assert np.all(row[len(vals):] == -1), f"row {i} pads not -1"
        assert np.all(np.diff(vals) > 0), f"row {i} not ascending: {vals}"
        if deg is not None:
            assert len(vals) == int(np.asarray(deg)[i]), f"row {i} deg"


def _random_graph(n, seed, P=4, m=3):
    edges = barabasi_albert(n, m, seed=seed)
    nn = int(edges.max()) + 1
    return build_blocks(edges, nn, node_random_partition(nn, P, seed=seed),
                        P=P, deg_slack=16)


@settings(max_examples=10)
@given(st.integers(10, 60), st.integers(0, 10_000))
def test_build_blocks_sorted(n, seed):
    g = _random_graph(n, seed)
    assert_sorted_ell(g.nbr, g.deg)


@settings(max_examples=6)
@given(st.integers(32, 200), st.integers(0, 10_000))
def test_build_ell_random_sorted(N, seed):
    g = build_ell_random(N, Cd=16, seed=seed)
    assert_sorted_ell(g.nbr, g.deg)


@settings(max_examples=8)
@given(st.integers(16, 50), st.integers(0, 10_000),
       st.sampled_from(["intra", "inter"]))
def test_mutations_preserve_invariant_and_host_jit_parity(n, seed, scen):
    """Jitted insert/delete keep rows canonical, bit-equal to the host path."""
    g = _random_graph(n, seed)
    ups = (sample_insertions(g, 3, scen, seed=seed)
           + sample_deletions(g, 3, scen, seed=seed + 1))
    g_host = apply_updates_host(g, ups)
    g_jit = g
    for u, v, op in ups:
        g_jit = (insert_edge if op > 0 else delete_edge)(
            g_jit, jnp.int32(u), jnp.int32(v))
    assert_sorted_ell(g_jit.nbr, g_jit.deg)
    # canonical form == the two update paths agree bit-for-bit
    np.testing.assert_array_equal(np.asarray(g_jit.nbr),
                                  np.asarray(g_host.nbr))
    np.testing.assert_array_equal(np.asarray(g_jit.deg),
                                  np.asarray(g_host.deg))


@settings(max_examples=6)
@given(st.integers(20, 60), st.integers(0, 10_000))
def test_migration_preserves_invariant(n, seed):
    g = _random_graph(n, seed)
    rng = np.random.default_rng(seed)
    mask = np.asarray(g.node_mask)
    pad_free = np.array([int(np.sum(~mask[b * g.Cn:(b + 1) * g.Cn]))
                         for b in range(g.P)])
    reals = np.flatnonzero(mask)
    moves = []
    for u in rng.permutation(reals)[:3]:
        dests = [b for b in range(g.P) if b != u // g.Cn and pad_free[b] > 0]
        if not dests:
            continue
        b = int(rng.choice(dests))
        pad_free[b] -= 1
        moves.append((int(u), b))
    if not moves:
        return
    g2, _perm = migrate_vertices(g, moves)
    assert_sorted_ell(g2.nbr, g2.deg)


# ---------------------------------------------------------------------------
# merge-intersection parity on ragged inputs
# ---------------------------------------------------------------------------


def _ragged_rows(n, cd, seed):
    """Raw (n, cd) int32 field: duplicates legal, ~25% all-pad rows."""
    rng = np.random.default_rng(seed)
    deg = rng.integers(0, cd + 1, size=n)
    deg[rng.random(n) < 0.25] = 0
    nbr = np.full((n, cd), -1, np.int32)
    for i in range(n):
        nbr[i, : deg[i]] = rng.integers(0, n, size=deg[i])  # with replacement
    return jnp.asarray(nbr)


@settings(max_examples=10)
@given(st.integers(2, 50), st.integers(1, 12), st.integers(0, 10_000))
def test_merge_matches_oracle_ragged(n, cd, seed):
    nbr = _ragged_rows(n, cd, seed)
    want = np.asarray(ref.ell_common_ref(nbr, nbr))
    for variant in ("merge", "allpairs"):
        got = ops.neighbor_common_ell(nbr, nbr, interpret=True,
                                      variant=variant)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"variant={variant}")


def test_merge_matches_oracle_cd130():
    """Cd=130: column padding crosses a lane boundary (130 % 128 != 0)."""
    nbr = _ragged_rows(40, 130, seed=7)
    want = np.asarray(ref.ell_common_ref(nbr, nbr))
    got = ops.neighbor_common_ell(nbr, nbr, interpret=True, variant="merge")
    np.testing.assert_array_equal(np.asarray(got), want)


def test_merge_all_pad_rows():
    """An entirely empty adjacency reduces to zeros (early exit at 0 trips)."""
    nbr = jnp.full((12, 8), -1, jnp.int32)
    got = ops.neighbor_common_ell(nbr, nbr, interpret=True, variant="merge")
    np.testing.assert_array_equal(np.asarray(got), np.zeros(12, np.int32))


def test_merge_on_real_graph_matches_allpairs():
    g = build_ell_random(320, Cd=24, seed=3)
    want = np.asarray(ref.ell_common_ref(g.nbr, g.nbr))
    for variant in ("merge", "allpairs"):
        got = ops.neighbor_common_ell(g.nbr, g.nbr, interpret=True,
                                      variant=variant)
        np.testing.assert_array_equal(np.asarray(got), want)
