"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles (ref.py).

All kernels run in interpret mode on CPU (TPU is the compile target).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.kcore_hindex import hindex_counts as hindex_pallas
from repro.kernels.frontier import frontier_step as frontier_pallas
from repro.graphgen import erdos_renyi, barabasi_albert


def _dense_adj(edges, n, dtype=np.float32):
    a = np.zeros((n, n), dtype)
    a[edges[:, 0], edges[:, 1]] = 1
    a[edges[:, 1], edges[:, 0]] = 1
    return jnp.asarray(a)


# ---------------------------------------------------------------- hindex ----

@pytest.mark.parametrize("n,m", [(64, 200), (128, 500), (200, 800), (384, 1500)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_hindex_kernel_sweep(n, m, dtype):
    edges = erdos_renyi(n, m, seed=n + m)
    adj = _dense_adj(edges, n).astype(dtype)
    deg = jnp.sum(adj > 0, axis=1).astype(jnp.int32)
    K = int(deg.max()) + 1
    got = ops.hindex(adj, deg, K=K)
    want = ref.hindex_counts_ref(adj.astype(jnp.float32), deg, K)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("T", [128, 256])
def test_hindex_tile_sizes(T):
    n = 512
    edges = barabasi_albert(n, 6, seed=1)
    adj = _dense_adj(edges, n, np.float32)
    est = jnp.asarray(np.random.default_rng(0).integers(0, 20, n), jnp.int32)
    got = hindex_pallas(adj.astype(jnp.bfloat16), est, K=128, T=T, interpret=True)
    want = ref.hindex_counts_ref(adj, est, 128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_coreness_dense_kernel_path():
    edges = barabasi_albert(300, 5, seed=9)
    n = int(edges.max()) + 1
    adj = _dense_adj(edges, n)
    got = ops.coreness_dense(adj)
    want = ref.coreness_dense_ref(adj)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # cross-check vs the ELL/system path
    import networkx as nx
    G = nx.Graph()
    G.add_nodes_from(range(n))
    G.add_edges_from(map(tuple, edges))
    ref_core = nx.core_number(G)
    for i in range(n):
        assert int(got[i]) == ref_core[i]


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 60), st.integers(0, 10_000))
def test_hindex_property_random(n, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < 0.2).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    est = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    adj = jnp.asarray(a)
    got = ops.hindex(adj, est)
    want = ref.hindex_counts_ref(adj, est, int(est.max()) + 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------- frontier ----

@pytest.mark.parametrize("n,R", [(64, 1), (130, 4), (256, 8), (300, 16)])
def test_frontier_kernel_sweep(n, R):
    rng = np.random.default_rng(n * R)
    edges = erdos_renyi(n, 3 * n, seed=n)
    adj = _dense_adj(edges, n)
    f = jnp.asarray((rng.random((n, R)) < 0.05).astype(np.float32))
    elig = jnp.asarray(rng.random(n) < 0.6)
    vis = jnp.asarray(rng.random((n, R)) < 0.1)
    got = ops.frontier_step(adj, f, elig, vis)
    want = ref.frontier_step_ref(adj, f, elig, vis)
    np.testing.assert_array_equal(np.asarray(got).astype(bool), np.asarray(want))


def test_frontier_full_bfs_equals_ell_bfs():
    """Kernelized BFS (A@f) reaches the same k-level set as the ELL path."""
    from repro.core import build_blocks, coreness, k_reachable
    from repro.core.partition import node_random_partition
    edges = barabasi_albert(200, 4, seed=7)
    n = int(edges.max()) + 1
    g = build_blocks(edges, n, node_random_partition(n, 4, 0), P=4)
    core = coreness(g)
    src = int(np.argmax(np.asarray(g.node_mask)))
    k = int(np.asarray(core)[src])
    roots = jnp.zeros(g.N, bool).at[src].set(True)
    want = np.asarray(k_reachable(g, core, roots, jnp.int32(k))[0])

    adj = np.zeros((g.N, g.N), np.float32)
    nbr = np.asarray(g.nbr)
    for u in range(g.N):
        for v in nbr[u]:
            if v >= 0:
                adj[u, v] = 1
    eligible = jnp.asarray(np.asarray(core) == k) & g.node_mask
    f = np.zeros((g.N, 1), np.float32)
    vis = np.zeros((g.N, 1), np.int8)
    if bool(eligible[src]):
        f[src, 0] = 1
        vis[src, 0] = 1
    f = jnp.asarray(f)
    vis = jnp.asarray(vis)
    adjj = jnp.asarray(adj)
    for _ in range(g.N):
        nxt = ops.frontier_step(adjj, f, eligible, vis)
        if not bool(jnp.any(nxt > 0)):
            break
        vis = jnp.maximum(vis, nxt)
        f = nxt.astype(jnp.float32)
    got = np.asarray(vis[:, 0]).astype(bool)
    np.testing.assert_array_equal(got, want)
