"""Partitioners + dynamic partitioning maintenance (paper §4.2, Tables 3-5)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import partition as P_
from repro.core.partition_dynamic import (
    initial_partition, incremental_part, naive_part, delete_edges)
from repro.graphgen import erdos_renyi, barabasi_albert


@pytest.fixture(scope="module")
def graph():
    e = barabasi_albert(300, 4, seed=3)
    return e, int(e.max()) + 1


def test_node_partitions_cover_and_balance(graph):
    edges, n = graph
    for fn in (P_.node_hash_partition, P_.node_random_partition):
        a = fn(n, 8, seed=1)
        assert a.shape == (n,) and a.min() >= 0 and a.max() < 8
        sizes = np.bincount(a, minlength=8)
        assert sizes.max() <= 2.0 * sizes.mean() + 2
    a = P_.node_bfs_partition(edges, n, 8, seed=1)
    assert a.min() >= 0 and a.max() < 8
    assert np.bincount(a, minlength=8).max() <= np.ceil(n / 8) + 1


def test_bfs_partition_cuts_fewer_edges_than_random(graph):
    edges, n = graph
    rnd = P_.node_random_partition(n, 8, seed=0)
    bfs = P_.node_bfs_partition(edges, n, 8, seed=0)

    def cut(a):
        return int(sum(a[u] != a[v] for u, v in edges))

    assert cut(bfs) < cut(rnd)


@pytest.mark.parametrize("method", ["hash", "random", "dfep", "vertex_cut"])
def test_edge_partition_covers_all_edges(graph, method):
    edges, n = graph
    st_, pt = initial_partition(edges, n, 8, method)
    assert len(st_.owner) == len(edges)
    assert st_.owner.min() >= 0 and st_.owner.max() < 8
    assert pt >= 0.0
    assert P_.edge_balance(st_.owner, 8) < 4.0


def test_vertex_cut_replication_reasonable(graph):
    """Greedy vertex-cut should replicate vertices less than random."""
    edges, n = graph

    def replication(owner):
        parts = [set() for _ in range(n)]
        for (u, v), p in zip(edges, owner):
            parts[u].add(p)
            parts[v].add(p)
        return np.mean([len(s) for s in parts if s])

    vc = P_.vertex_cut_greedy(edges, n, 8)
    rnd = P_.edge_random_partition(edges, 8, seed=0)
    assert replication(vc) < replication(rnd)


def test_dfep_grows_connected_regions(graph):
    edges, n = graph
    owner = P_.dfep(edges, n, 4, seed=0)
    assert (owner >= 0).all()
    # funding growth should beat random on edge locality: endpoints of an
    # edge tend to have other edges in the same partition
    sizes = np.bincount(owner, minlength=4)
    assert sizes.max() / sizes.mean() < 3.0


@pytest.mark.parametrize("method", ["hash", "random", "dfep"])
def test_incremental_vs_naive_consistency(graph, method):
    """IncrementalPart keeps old assignments; NaivePart recomputes all —
    both must remain complete/valid partitionings (paper §5.2.2 setup)."""
    edges, n = graph
    cut = int(0.9 * len(edges))
    st0, _ = initial_partition(edges[:cut], n, 8, method, seed=4)
    inc, ut_inc = incremental_part(st0, edges[cut:])
    assert (inc.owner[:cut] == st0.owner).all(), "incremental must not move old edges"
    assert len(inc.owner) == len(edges)
    nv, ut_nv = naive_part(st0, edges[cut:])
    assert len(nv.owner) == len(edges)


def test_deletion_threshold_protocol(graph):
    edges, n = graph
    st0, _ = initial_partition(edges, n, 8, "random", seed=2)
    # delete a few random edges: balanced partition stays put
    st1, repart, _ = delete_edges(st0, np.arange(10), threshold=1.5)
    assert not repart
    # delete most edges of all but one partition: forces repartition
    idx = np.flatnonzero(st1.owner != 0)
    st2, repart2, _ = delete_edges(st1, idx[: len(idx) - 5], threshold=1.5)
    assert repart2
    assert P_.edge_balance(st2.owner, 8) <= P_.edge_balance(
        np.concatenate([np.zeros(len(st2.owner) - 5, int), st1.owner[idx[-5:]]]), 8)


def test_ub_update_prefers_neighbor_partitions(graph):
    edges, n = graph
    st0, _ = initial_partition(edges, n, 4, "dfep", seed=0)
    # new edge whose endpoints' edges are mostly in one partition
    u, v = edges[0]
    p_u = st0.owner[(edges[:, 0] == u) | (edges[:, 1] == u)]
    new = np.array([[u, v]])
    got = P_.ub_update(st0.edges, st0.owner, new, n, 4)[0]
    counts = np.bincount(p_u, minlength=4)
    assert got in np.flatnonzero(counts >= counts.max() - 2)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 99999))
def test_property_hash_partition_deterministic(seed):
    e = erdos_renyi(25, 40, seed=seed)
    a1 = P_.edge_hash_partition(e, 5, seed=seed)
    a2 = P_.edge_hash_partition(e, 5, seed=seed)
    assert (a1 == a2).all()
    # permutation-invariance of per-edge hash
    perm = np.random.default_rng(seed).permutation(len(e))
    a3 = P_.edge_hash_partition(e[perm], 5, seed=seed)
    assert (a3 == a1[perm]).all()


def test_vertex_cut_update_matches_static_greedy_on_concat(graph):
    """The continuation contract: resuming from the static run's own
    prefix state reproduces the static greedy on the concatenated edge
    list bit-for-bit, at every split point."""
    edges, n = graph
    full = P_.vertex_cut_greedy(edges, n, 4)
    for m0 in (0, 1, 50, len(edges) // 2, len(edges) - 3):
        cont = P_.vertex_cut_update(edges[:m0], full[:m0], edges[m0:], n, 4)
        assert (cont == full[m0:]).all(), m0


def test_incremental_part_vertex_cut_is_true_greedy(graph):
    """incremental_part(method='vertex_cut') must run the greedy
    continuation (not DFEP's ub_update): old owners untouched, and the
    new assignment equals vertex_cut_update on the same state."""
    from repro.core.partition_dynamic import PartitionState
    edges, n = graph
    m0 = 120
    st0, _ = initial_partition(edges[:m0], n, 4, "vertex_cut")
    st1, _ = incremental_part(st0, edges[m0:])
    assert (st1.owner[:m0] == st0.owner).all()
    want = P_.vertex_cut_update(edges[:m0], st0.owner, edges[m0:], n, 4)
    assert (st1.owner[m0:] == want).all()
    ub = P_.ub_update(edges[:m0], st0.owner, edges[m0:], n, 4)
    assert not (want == ub).all()  # the two heuristics genuinely differ
