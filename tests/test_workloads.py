"""Cross-backend parity of the BlockProgram workloads (tentpole acceptance).

Connected components, PageRank, and triangle counting must agree on the
dense, ell, and ell_spmd registry backends AND with a host reference
(networkx for CC/triangles, a straight numpy power iteration for
PageRank) — on hypothesis-random ragged graphs, with Cd not a multiple
of 128, and on single-block (P = 1) meshes.  The fused fixpoints must
also keep the PR-4 contract: zero per-superstep `jax.device_get`s.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
import networkx as nx

from _hyp import given, settings, st

from repro.core import (
    build_blocks, connected_components, coreness, merge_labels, pagerank,
    triangle_counts, triangle_total,
)
from repro.core.algorithms import (
    ConnectedComponentsProgram, CorenessBlockProgram, PageRankProgram,
    TriangleCountProgram,
)
from repro.core.updates import sample_deletions, sample_insertions
from repro.graphgen import barabasi_albert
from repro.kernels import ops, ref
from repro.runtime import run_stream

ALL_BACKENDS = ("jnp", "dense", "ell", "ell_spmd")


# ---------------------------------------------------------------------------
# construction + host oracles
# ---------------------------------------------------------------------------


def _rand_graph(n, m, P, seed):
    """Random graph with a random block assignment (ragged Cd, never a
    multiple of 128 at these sizes)."""
    rng = np.random.default_rng(seed)
    uv = rng.integers(0, n, (max(1, m), 2))
    uv = uv[uv[:, 0] != uv[:, 1]]
    if not len(uv):
        uv = np.array([[0, 1]]) if n >= 2 else np.zeros((0, 2), np.int64)
    assign = rng.integers(0, P, n)
    return build_blocks(uv, n, assign, P=P,
                        deg_slack=int(rng.integers(3, 11)))


def _nx_graph(g):
    """Rebuild the graph in padded-id space for the networkx oracles."""
    G = nx.Graph()
    G.add_nodes_from(np.flatnonzero(np.asarray(g.node_mask)).tolist())
    nbr = np.asarray(g.nbr)
    us, vs = np.nonzero(nbr >= 0)
    G.add_edges_from(zip(us.tolist(), nbr[us, vs].tolist()))
    return G


def _cc_ref(g):
    """Canonical labels (min member padded id), -1 on padding rows."""
    want = np.full(g.N, -1, np.int64)
    for comp in nx.connected_components(_nx_graph(g)):
        want[list(comp)] = min(comp)
    return want


def _tri_ref(g):
    want = np.zeros(g.N, np.int64)
    for u, t in nx.triangles(_nx_graph(g)).items():
        want[u] = t
    return want


def _pagerank_ref(g, alpha=0.85, tol=1e-8, max_steps=500):
    """The documented semantics in plain numpy: teleport over real nodes,
    push contributions rank/deg, dangling mass NOT redistributed."""
    mask = np.asarray(g.node_mask)
    deg = np.asarray(g.deg)
    nbr = np.asarray(g.nbr)
    n_real = max(1, int(mask.sum()))
    r = np.where(mask, 1.0 / n_real, 0.0).astype(np.float32)
    for _ in range(max_steps):
        contrib = np.where(deg > 0, r / np.maximum(deg, 1), 0).astype(
            np.float32)
        red = np.where(nbr >= 0, contrib[np.clip(nbr, 0, None)], 0).sum(1)
        r2 = np.where(mask, (1 - alpha) / n_real + alpha * red, 0).astype(
            np.float32)
        done = np.abs(r2 - r).max(initial=0) <= tol
        r = r2
        if done:
            break
    return r


# ---------------------------------------------------------------------------
# kernel-level parity of the new combines at ragged shapes
# ---------------------------------------------------------------------------


def _ragged_ell(n, cd, seed):
    rng = np.random.default_rng(seed)
    nbr = np.full((n, cd), -1, np.int32)
    degs = rng.integers(0, cd + 1, n)
    degs[rng.random(n) < 0.2] = 0  # force all-padding rows
    for i in range(n):
        nbr[i, : degs[i]] = rng.integers(0, n, degs[i])
    return jnp.asarray(nbr)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 150), st.integers(1, 170), st.integers(0, 10_000))
def test_min_sum_kernels_match_oracles_ragged(n, cd, seed):
    """Cd deliberately spans non-multiples of 128 (wrapper pads)."""
    nbr = _ragged_ell(n, cd, seed)
    rng = np.random.default_rng(seed + 1)
    fi = jnp.asarray(rng.integers(-5, n + 5, n).astype(np.int32))
    got = np.asarray(ops.neighbor_min_ell(nbr, fi, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.ell_min_ref(nbr, fi)))
    ff = jnp.asarray(rng.random(n).astype(np.float32))
    got = np.asarray(ops.neighbor_sum_ell(nbr, ff, interpret=True))
    np.testing.assert_allclose(
        got, np.asarray(ref.ell_sum_ref(nbr, ff)), rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 60), st.integers(1, 12), st.integers(0, 10_000))
def test_common_kernel_matches_oracle_ragged(n, cd, seed):
    nbr = _ragged_ell(n, cd, seed)
    got = np.asarray(ops.neighbor_common_ell(nbr, nbr, interpret=True))
    np.testing.assert_array_equal(
        got, np.asarray(ref.ell_common_ref(nbr, nbr)))


def test_combine_dispatch_rejects_unknowns():
    g = _rand_graph(10, 20, 2, 0)
    with pytest.raises(ValueError, match="combine"):
        ops.neighbor_combine_blocks(g, jnp.zeros(g.N, jnp.int32), "bogus",
                                    backend="jnp")
    with pytest.raises(ValueError, match="ell_spmd"):
        ops.neighbor_combine_blocks(g, jnp.zeros(g.N, jnp.int32), "min",
                                    backend="ell_spmd")


# ---------------------------------------------------------------------------
# workload parity: dense == ell == ell_spmd == host reference
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 90), st.integers(1, 200), st.sampled_from([1, 2, 4]),
       st.integers(0, 10_000))
def test_connected_components_parity_all_backends(n, m, P, seed):
    g = _rand_graph(n, m, P, seed)
    want = _cc_ref(g)
    for b in ALL_BACKENDS:
        got = np.asarray(connected_components(g, backend=b))
        np.testing.assert_array_equal(got, want, err_msg=f"backend={b}")


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 70), st.integers(1, 160), st.sampled_from([1, 2, 4]),
       st.integers(0, 10_000))
def test_triangle_counts_parity_all_backends(n, m, P, seed):
    g = _rand_graph(n, m, P, seed)
    want = _tri_ref(g)
    total = int(want.sum()) // 3
    for b in ALL_BACKENDS:
        got = np.asarray(triangle_counts(g, backend=b))
        np.testing.assert_array_equal(got, want, err_msg=f"backend={b}")
        assert int(triangle_total(jnp.asarray(got))) == total


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 90), st.integers(1, 200), st.sampled_from([1, 2, 4]),
       st.integers(0, 10_000))
def test_pagerank_parity_all_backends(n, m, P, seed):
    g = _rand_graph(n, m, P, seed)
    want = _pagerank_ref(g)
    for b in ALL_BACKENDS:
        got = np.asarray(pagerank(g, tol=1e-8, max_steps=500, backend=b))
        np.testing.assert_allclose(got, want, atol=2e-6,
                                   err_msg=f"backend={b}")


def test_pagerank_fixed_iteration_variant_runs_exactly_max_steps():
    g = _rand_graph(40, 90, 2, 3)
    for b in ALL_BACKENDS:
        r, steps = pagerank(g, tol=None, max_steps=7, backend=b,
                            with_steps=True)
        assert int(steps) == 7, (b, int(steps))
    # the tolerance-halt variant stops early on the same graph
    _, steps = pagerank(g, tol=1e-3, max_steps=500, backend="jnp",
                        with_steps=True)
    assert int(steps) < 500


def test_coreness_block_program_matches_dedicated_fixpoint():
    """The contract subsumes coreness: CorenessBlockProgram == kcore path."""
    g = _rand_graph(60, 150, 4, 7)
    want = np.asarray(coreness(g, backend="jnp"))
    for b in ALL_BACKENDS:
        est = ops.run_block_program(g, CorenessBlockProgram(), backend=b)
        np.testing.assert_array_equal(np.asarray(est), want,
                                      err_msg=f"backend={b}")


def test_cd_over_128_and_unaligned():
    """An explicit Cd = 130 (> lane width, % 128 != 0) graph."""
    edges = barabasi_albert(90, 5, seed=2)
    n = int(edges.max()) + 1
    g = build_blocks(edges, n, np.zeros(n, np.int64), P=1, Cd=130)
    np.testing.assert_array_equal(
        np.asarray(connected_components(g, backend="ell")), _cc_ref(g))
    np.testing.assert_array_equal(
        np.asarray(triangle_counts(g, backend="ell")), _tri_ref(g))
    np.testing.assert_allclose(
        np.asarray(pagerank(g, tol=1e-8, max_steps=500, backend="ell")),
        _pagerank_ref(g), atol=2e-6)


def test_single_block_mesh_spmd():
    """P = 1: the whole graph folds onto one worker; the mesh path must
    still serve every workload (halo plan with no cross-worker edges)."""
    edges = barabasi_albert(50, 3, seed=5)
    n = int(edges.max()) + 1
    g = build_blocks(edges, n, np.zeros(n, np.int64), P=1, deg_slack=9)
    np.testing.assert_array_equal(
        np.asarray(connected_components(g, backend="ell_spmd")), _cc_ref(g))
    np.testing.assert_array_equal(
        np.asarray(triangle_counts(g, backend="ell_spmd")), _tri_ref(g))
    np.testing.assert_allclose(
        np.asarray(pagerank(g, tol=1e-8, max_steps=500,
                            backend="ell_spmd")),
        _pagerank_ref(g), atol=2e-6)


def test_spmd_executor_threading_reuses_one_executor(monkeypatch):
    from repro.runtime import SpmdExecutor
    from repro.runtime import spmd as spmd_mod

    g = _rand_graph(60, 140, 4, 11)
    ex = SpmdExecutor(g)
    built = {"n": 0}
    orig_init = spmd_mod.SpmdExecutor.__init__

    def counting_init(self, *a, **kw):
        built["n"] += 1
        return orig_init(self, *a, **kw)

    monkeypatch.setattr(spmd_mod.SpmdExecutor, "__init__", counting_init)
    connected_components(g, backend="ell_spmd", executor=ex)
    pagerank(g, tol=1e-6, backend="ell_spmd", executor=ex)
    triangle_counts(g, backend="ell_spmd", executor=ex)
    assert built["n"] == 0, "run_block_program built a fresh SpmdExecutor"


# ---------------------------------------------------------------------------
# zero per-superstep host transfers (PR-4 contract, counter-asserted)
# ---------------------------------------------------------------------------


def _path_graph(n=96, P=1):
    """A path: min-label propagation walks it end to end, so the CC
    fixpoint takes O(n) supersteps."""
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    return build_blocks(edges, n, np.zeros(n, np.int64) if P == 1 else
                        (np.arange(n) * P) // n, P=P, deg_slack=6)


@pytest.fixture
def count_device_get(monkeypatch):
    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    return calls


def test_workload_fixpoints_transfer_count_is_o1(count_device_get):
    g = _path_graph()
    for b in ("jnp", "dense", "ell"):
        count_device_get["n"] = 0
        labels, steps = connected_components(g, backend=b, with_steps=True)
        assert count_device_get["n"] == 0, (b, count_device_get["n"])
        assert hasattr(steps, "dtype")  # device scalar, not a host int
        assert int(steps) > 20, (b, int(steps))
        count_device_get["n"] = 0
        pagerank(g, tol=1e-8, max_steps=300, backend=b)
        assert count_device_get["n"] == 0, (b, count_device_get["n"])
        count_device_get["n"] = 0
        triangle_counts(g, backend=b)
        assert count_device_get["n"] == 0, (b, count_device_get["n"])


def test_workload_fixpoint_spmd_one_transfer_per_run(count_device_get):
    g = _path_graph(64, P=2)
    count_device_get["n"] = 0
    _, steps = connected_components(g, backend="ell_spmd", with_steps=True)
    assert int(steps) > 20
    # ONE device_get per run (the fused loop's superstep count), never
    # one per superstep
    assert count_device_get["n"] <= 2, (count_device_get["n"], int(steps))


# ---------------------------------------------------------------------------
# dynamic CC in the stream loop
# ---------------------------------------------------------------------------


def test_merge_labels_insert_only_is_exact():
    g = _rand_graph(70, 60, 2, 21)
    labels = connected_components(g, backend="jnp")
    ups = sample_insertions(g, 8, "inter", seed=22)
    us = jnp.asarray([u for u, _, _ in ups], jnp.int32)
    vs = jnp.asarray([v for _, v, _ in ups], jnp.int32)
    from repro.core.updates import apply_updates_host

    g2 = apply_updates_host(g, ups)
    merged = merge_labels(labels, us, vs, jnp.ones(len(ups), bool))
    np.testing.assert_array_equal(
        np.asarray(merged), np.asarray(connected_components(g2,
                                                            backend="jnp")))


@pytest.mark.parametrize("backend", ["jnp", "ell_spmd"])
def test_run_stream_maintains_cc_labels(backend):
    edges = barabasi_albert(120, 3, seed=31)
    n = int(edges.max()) + 1
    rng = np.random.default_rng(32)
    g = build_blocks(edges, n, rng.integers(0, 4, n), P=4, deg_slack=24)
    core = coreness(g, backend="jnp")
    labels0 = connected_components(g, backend="jnp")
    ups = (sample_insertions(g, 6, "inter", seed=33)
           + sample_deletions(g, 3, "intra", seed=34)
           + sample_insertions(g, 5, "intra", seed=35))
    res = run_stream(
        g, core, list(ups), R=4, backend=backend, cc_labels=labels0)
    g2, core2, stats, labels = res.g, res.core, res.stats, res.labels
    np.testing.assert_array_equal(
        np.asarray(labels),
        np.asarray(connected_components(g2, backend="jnp")))
    assert stats.cc_merges + stats.cc_recomputes > 0
    # exactness of the coreness path is untouched
    np.testing.assert_array_equal(
        np.asarray(coreness(g2, backend="jnp")), np.asarray(core2))


def test_run_stream_insert_only_cc_never_recomputes():
    edges = barabasi_albert(100, 3, seed=41)
    n = int(edges.max()) + 1
    rng = np.random.default_rng(42)
    g = build_blocks(edges, n, rng.integers(0, 4, n), P=4, deg_slack=24)
    core = coreness(g, backend="jnp")
    labels0 = connected_components(g, backend="jnp")
    ups = sample_insertions(g, 8, "inter", seed=43)
    res = run_stream(g, core, list(ups), R=4, cc_labels=labels0)
    g2, stats, labels = res.g, res.stats, res.labels
    assert stats.cc_recomputes == 0
    assert stats.cc_merges == len(ups)
    np.testing.assert_array_equal(
        np.asarray(labels),
        np.asarray(connected_components(g2, backend="jnp")))
