"""GraphBlocks representation: build/update round-trips + properties."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import build_blocks, insert_edge, delete_edge, to_networkx_edges
from repro.core.graph import has_edge
from repro.core.partition import node_hash_partition, node_random_partition
from repro.core.updates import (
    sample_insertions, sample_deletions, apply_updates_host, classify)
from repro.graphgen import erdos_renyi


def test_build_roundtrip(ba_graph):
    edges, n = ba_graph
    assign = node_hash_partition(n, 4)
    g = build_blocks(edges, n, assign, P=4)
    canon = np.unique(np.sort(np.asarray(edges), axis=1), axis=0)
    assert set(map(tuple, to_networkx_edges(g))) == set(map(tuple, canon))
    assert g.n_real == n
    assert g.m_real == len(canon)
    deg = np.zeros(n, int)
    np.add.at(deg, canon[:, 0], 1)
    np.add.at(deg, canon[:, 1], 1)
    orig = np.asarray(g.orig_id)
    gdeg = np.asarray(g.deg)
    for i in range(g.N):
        if orig[i] >= 0:
            assert gdeg[i] == deg[orig[i]]


def test_insert_then_delete_is_identity(blocks_ba):
    g = blocks_ba
    ins = sample_insertions(g, 5, "inter", seed=7)
    before = np.asarray(g.nbr).copy(), np.asarray(g.deg).copy()
    g2 = g
    for u, v, _ in ins:
        g2 = insert_edge(g2, jnp.int32(u), jnp.int32(v))
    for u, v, _ in ins:
        assert bool(has_edge(g2, u, v))
        g2 = delete_edge(g2, jnp.int32(u), jnp.int32(v))
    assert (np.asarray(g2.deg) == before[1]).all()
    # neighbor sets equal (order may differ after swap-with-last)
    a = np.sort(np.asarray(g2.nbr), axis=1)
    b = np.sort(before[0], axis=1)
    assert (a == b).all()


def test_block_capacity_overflow_raises():
    edges = np.array([[0, 1], [1, 2]])
    with pytest.raises(ValueError, match="overflow"):
        build_blocks(edges, 3, np.zeros(3, int), P=2, Cn=1)


def test_degree_capacity_raises():
    edges = np.array([[0, 1], [0, 2], [0, 3]])
    with pytest.raises(ValueError, match="max degree"):
        build_blocks(edges, 4, np.zeros(4, int), P=1, Cd=2)


def test_updates_host_validation(blocks_ba):
    g = blocks_ba
    dels = sample_deletions(g, 3, "intra", seed=1)
    g2 = apply_updates_host(g, dels)
    with pytest.raises(ValueError, match="not present"):
        apply_updates_host(g2, [dels[0]])
    u, v, _ = dels[0]
    g3 = apply_updates_host(g2, [(u, v, +1)])
    with pytest.raises(ValueError, match="already present"):
        apply_updates_host(g3, [(u, v, +1)])


def test_scenario_classification(blocks_ba):
    g = blocks_ba
    for u, v, _ in sample_insertions(g, 10, "intra", seed=3):
        assert classify(g, u, v) == "intra"
    for u, v, _ in sample_insertions(g, 10, "inter", seed=4):
        assert classify(g, u, v) == "inter"
    for u, v, _ in sample_deletions(g, 10, "inter", seed=5):
        assert classify(g, u, v) == "inter"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_edge_cut_matches_numpy(seed):
    edges = erdos_renyi(40, 80, seed=seed)
    n = 40
    assign = node_random_partition(n, 3, seed=seed)
    g = build_blocks(edges, n, assign, P=3)
    canon = np.unique(np.sort(edges, axis=1), axis=0)
    expect = sum(assign[a] != assign[b] for a, b in canon)
    assert int(g.edge_cut()) == expect
    boundary = np.asarray(g.is_boundary())
    orig = np.asarray(g.orig_id)
    for i in range(g.N):
        if orig[i] < 0:
            assert not boundary[i]
