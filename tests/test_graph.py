"""GraphBlocks representation: build/update round-trips + properties."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import build_blocks, insert_edge, delete_edge, to_networkx_edges
from repro.core.graph import has_edge
from repro.core.partition import node_hash_partition, node_random_partition
from repro.core.updates import (
    sample_insertions, sample_deletions, apply_updates_host, classify)
from repro.graphgen import erdos_renyi


def test_build_roundtrip(ba_graph):
    edges, n = ba_graph
    assign = node_hash_partition(n, 4)
    g = build_blocks(edges, n, assign, P=4)
    canon = np.unique(np.sort(np.asarray(edges), axis=1), axis=0)
    assert set(map(tuple, to_networkx_edges(g))) == set(map(tuple, canon))
    assert g.n_real == n
    assert g.m_real == len(canon)
    deg = np.zeros(n, int)
    np.add.at(deg, canon[:, 0], 1)
    np.add.at(deg, canon[:, 1], 1)
    orig = np.asarray(g.orig_id)
    gdeg = np.asarray(g.deg)
    for i in range(g.N):
        if orig[i] >= 0:
            assert gdeg[i] == deg[orig[i]]


def test_insert_then_delete_is_identity(blocks_ba):
    g = blocks_ba
    ins = sample_insertions(g, 5, "inter", seed=7)
    before = np.asarray(g.nbr).copy(), np.asarray(g.deg).copy()
    g2 = g
    for u, v, _ in ins:
        g2 = insert_edge(g2, jnp.int32(u), jnp.int32(v))
    for u, v, _ in ins:
        assert bool(has_edge(g2, u, v))
        g2 = delete_edge(g2, jnp.int32(u), jnp.int32(v))
    assert (np.asarray(g2.deg) == before[1]).all()
    # neighbor sets equal (order may differ after swap-with-last)
    a = np.sort(np.asarray(g2.nbr), axis=1)
    b = np.sort(before[0], axis=1)
    assert (a == b).all()


def test_block_capacity_overflow_raises():
    edges = np.array([[0, 1], [1, 2]])
    with pytest.raises(ValueError, match="overflow"):
        build_blocks(edges, 3, np.zeros(3, int), P=2, Cn=1)


def test_degree_capacity_raises():
    edges = np.array([[0, 1], [0, 2], [0, 3]])
    with pytest.raises(ValueError, match="max degree"):
        build_blocks(edges, 4, np.zeros(4, int), P=1, Cd=2)


def test_updates_host_validation(blocks_ba):
    g = blocks_ba
    dels = sample_deletions(g, 3, "intra", seed=1)
    g2 = apply_updates_host(g, dels)
    with pytest.raises(ValueError, match="not present"):
        apply_updates_host(g2, [dels[0]])
    u, v, _ = dels[0]
    g3 = apply_updates_host(g2, [(u, v, +1)])
    with pytest.raises(ValueError, match="already present"):
        apply_updates_host(g3, [(u, v, +1)])


def test_scenario_classification(blocks_ba):
    g = blocks_ba
    for u, v, _ in sample_insertions(g, 10, "intra", seed=3):
        assert classify(g, u, v) == "intra"
    for u, v, _ in sample_insertions(g, 10, "inter", seed=4):
        assert classify(g, u, v) == "inter"
    for u, v, _ in sample_deletions(g, 10, "inter", seed=5):
        assert classify(g, u, v) == "inter"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_edge_cut_matches_numpy(seed):
    edges = erdos_renyi(40, 80, seed=seed)
    n = 40
    assign = node_random_partition(n, 3, seed=seed)
    g = build_blocks(edges, n, assign, P=3)
    canon = np.unique(np.sort(edges, axis=1), axis=0)
    expect = sum(assign[a] != assign[b] for a, b in canon)
    assert int(g.edge_cut()) == expect
    boundary = np.asarray(g.is_boundary())
    orig = np.asarray(g.orig_id)
    for i in range(g.N):
        if orig[i] < 0:
            assert not boundary[i]


# ------------------------------------------------------------------ migration

def _migration_fixture():
    from repro.core import coreness
    edges = erdos_renyi(60, 150, seed=3)
    n = 60
    assign = np.where(np.arange(n) < 30, 0, 1 + np.arange(n) % 2)
    g = build_blocks(edges, n, assign, P=3, Cn=40, deg_slack=32)
    return g, coreness(g, backend="jnp")


def test_migrate_vertices_is_a_permutation():
    from repro.core import migrate_vertices
    g, core = _migration_fixture()
    mask = np.asarray(g.node_mask)
    movers = np.flatnonzero(mask & (np.arange(g.N) // g.Cn == 0))[:4]
    g2, perm, core2 = migrate_vertices(g, [(int(u), 2) for u in movers], core)
    assert sorted(perm) == list(range(g.N))          # bijection
    assert (perm[np.asarray(movers)] // g.Cn == 2).all()  # landed in block 2
    assert (g2.P, g2.Cn, g2.Cd) == (g.P, g.Cn, g.Cd)  # static shape held
    # edge set in original ids is untouched
    assert (to_networkx_edges(g2) == to_networkx_edges(g)).all()
    # per-node metadata rode the permutation
    assert (np.asarray(g2.deg)[perm] == np.asarray(g.deg)).all()
    assert (np.asarray(g2.orig_id)[perm] == np.asarray(g.orig_id)).all()
    assert (np.asarray(core2)[perm] == np.asarray(core)).all()


def test_migrate_vertices_coreness_invariant():
    from repro.core import coreness, migrate_vertices
    g, core = _migration_fixture()
    mask = np.asarray(g.node_mask)
    movers = np.flatnonzero(mask & (np.arange(g.N) // g.Cn == 0))[:6]
    g2, perm, core2 = migrate_vertices(g, [(int(u), 1) for u in movers], core)
    # recomputing from scratch on the migrated layout equals the carried
    # (permuted) coreness bit-for-bit
    assert (np.asarray(coreness(g2, backend="jnp"))
            == np.asarray(core2)).all()


def test_migrate_vertices_validation():
    from repro.core import migrate_vertices
    g, core = _migration_fixture()
    mask = np.asarray(g.node_mask)
    pad = int(np.flatnonzero(~mask)[0])
    real0 = int(np.flatnonzero(mask & (np.arange(g.N) // g.Cn == 0))[0])
    with pytest.raises(ValueError, match="non-real"):
        migrate_vertices(g, [(pad, 1)])
    with pytest.raises(ValueError, match="no-op"):
        migrate_vertices(g, [(real0, 0)])
    with pytest.raises(ValueError, match="outside"):
        migrate_vertices(g, [(real0, 99)])
    with pytest.raises(ValueError, match="duplicate"):
        migrate_vertices(g, [(real0, 1), (real0, 2)])
    # fill block 1 to capacity, then one more move must raise
    free1 = int((~mask[g.Cn:2 * g.Cn]).sum())
    movers = np.flatnonzero(mask & (np.arange(g.N) // g.Cn == 0))
    too_many = [(int(u), 1) for u in movers[:free1 + 1]]
    with pytest.raises(ValueError, match="free node capacity"):
        migrate_vertices(g, too_many)


# ------------------------------------------------------- build_ell_random ---

def test_build_ell_random_invariants_and_determinism():
    from repro.core import build_ell_random
    g = build_ell_random(600, Cd=6, seed=4)
    nbr = np.asarray(g.nbr)
    deg = np.asarray(g.deg)
    valid = nbr >= 0
    assert deg.max() <= 6 and (valid.sum(1) == deg).all()
    src = np.repeat(np.arange(g.N), g.Cd)[valid.ravel()]
    dst = nbr.ravel()[valid.ravel()]
    assert not (src == dst).any()                       # no self-loops
    e = np.stack([np.minimum(src, dst), np.maximum(src, dst)], 1)
    _, cnt = np.unique(e, axis=0, return_counts=True)
    assert (cnt == 2).all()       # undirected, stored once per endpoint
    g2 = build_ell_random(600, Cd=6, seed=4)
    assert (np.asarray(g2.nbr) == nbr).all()            # per-seed determinism
    g3 = build_ell_random(600, Cd=6, seed=5)
    assert not (np.asarray(g3.nbr) == nbr).all()        # seed actually used
    # capacity pressure: most rows should be well filled at m_factor=2.2
    assert deg.mean() > 2.0
