"""Distributed block runtime: mesh fold, halo plan, shard_map execution.

Runs on whatever devices exist: with one CPU device every test still
exercises the full shard_map/all-to-all path at W = 1 (all blocks folded
onto one worker); the multi-device CI job re-runs this file under
`XLA_FLAGS=--xla_force_host_platform_device_count=8` so the halo exchange
carries real cross-device traffic and the blocks-per-device fold is
covered with W > 1 as well.

The two headline contracts (ISSUE acceptance):
  * `run_spmd` / `coreness(backend="ell_spmd")` is bit-identical to the
    single-device path on ≥ 2 generated graphs with P ∈ {2, 4, 8};
  * executed W2W inter/intra counts equal `halo_slot_counts` metering.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    BladygEngine, CorenessProgram, build_blocks, coreness,
    coreness_via_engine, coreness_via_spmd, halo_pair_counts,
    halo_slot_counts, maintain_batch,
)
from repro.core.partition import node_bfs_partition, node_random_partition
from repro.graphgen import barabasi_albert, erdos_renyi
from repro.kernels import ops, ref
from repro.runtime import (
    SpmdCorenessProgram, SpmdEngine, SpmdExecutor, best_worker_count,
    build_halo_plan, make_worker_mesh,
)

PS = (2, 4, 8)


def _graphs():
    """Two generated graphs (the acceptance floor) with distinct structure."""
    ba = barabasi_albert(180, 4, seed=11)
    er = erdos_renyi(150, 450, seed=5)
    return [("ba", ba, int(ba.max()) + 1), ("er", er, 150)]


def _blocks(edges, n, P, seed=2):
    assign = node_random_partition(n, P, seed=seed)
    return build_blocks(edges, n, assign, P=P, deg_slack=48)


def _worker_counts(P):
    """W options available on this host: always 1; plus any divisor of P
    that fits the device count (covers fold B > 1 whenever possible)."""
    ndev = len(jax.devices())
    return sorted({w for w in (1, 2, P) if w <= ndev and P % w == 0})


def _clone(g):
    return jax.tree.map(
        lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, g)


# ---------------------------------------------------------------------------
# mesh geometry
# ---------------------------------------------------------------------------


def test_best_worker_count_divisor_rule():
    assert best_worker_count(8, 8) == 8
    assert best_worker_count(8, 5) == 4   # largest divisor that fits
    assert best_worker_count(6, 4) == 3
    assert best_worker_count(4, 1) == 1
    assert best_worker_count(1, 16) == 1
    with pytest.raises(ValueError):
        best_worker_count(0, 4)


def test_worker_mesh_fold_geometry():
    g = _blocks(*_graphs()[0][1:], P=4)
    wm = make_worker_mesh(g, W=1)
    assert (wm.W, wm.B, wm.S) == (1, 4, 4 * g.Cn)
    assert wm.N == g.N and wm.worker_of(g.N - 1) == 0
    with pytest.raises(ValueError):
        make_worker_mesh(g, W=3)  # 3 does not divide P=4


# ---------------------------------------------------------------------------
# halo plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", PS)
def test_plan_slot_counts_match_metering(P):
    for _, edges, n in _graphs():
        g = _blocks(edges, n, P)
        for W in _worker_counts(P):
            plan = build_halo_plan(g, W=W)
            assert plan.slot_counts() == halo_slot_counts(g)


def test_plan_pair_matrix_consistent_with_graph_matrix():
    _, edges, n = _graphs()[0]
    g = _blocks(edges, n, 4)
    pm = halo_pair_counts(g)
    intra, inter = halo_slot_counts(g)
    assert int(np.trace(pm)) == intra
    assert int(pm.sum() - np.trace(pm)) == inter
    # executed pair matrix: deduplicated, so bounded by the slot matrix
    plan = build_halo_plan(g, W=len(jax.devices()) > 1 and 2 or 1)
    assert plan.device_elems <= inter
    # every off-diagonal device element corresponds to a boundary vertex
    assert (plan.pair_elems >= 0).all()


def test_plan_local_frame_covers_every_slot():
    _, edges, n = _graphs()[1]
    g = _blocks(edges, n, 4)
    plan = build_halo_plan(g, W=1)
    nbrl = plan.nbr_local
    valid = np.asarray(g.nbr) >= 0
    S = plan.wm.S
    # valid slots index local rows or halo entries, PAD slots the sentinel
    assert (nbrl[valid] < S + plan.H).all()
    assert (nbrl[~valid] == plan.pad_slot).all()


def test_plan_build_under_jit_raises():
    _, edges, n = _graphs()[0]
    g = _blocks(edges, n, 2)

    @jax.jit
    def bad(g):
        return ops.hindex_blocks(
            g, jnp.zeros(g.N, jnp.int32), backend="ell_spmd")

    with pytest.raises(TypeError, match="concrete"):
        bad(g)


# ---------------------------------------------------------------------------
# bit-identical execution (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", PS)
def test_coreness_spmd_bit_identical(P):
    for name, edges, n in _graphs():
        g = _blocks(edges, n, P)
        ref_core = np.asarray(ops.coreness_blocks(g, backend="jnp"))
        for W in _worker_counts(P):
            got = np.asarray(coreness(g, backend="ell_spmd")) if W == 1 \
                else np.asarray(
                    SpmdExecutor(g, W=W).coreness()[0])
            assert (ref_core == got).all(), (name, P, W)


def test_hindex_and_frontier_dispatch_parity():
    _, edges, n = _graphs()[0]
    g = _blocks(edges, n, 4)
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    h_ref = np.asarray(ref.ell_hindex_ref(g.nbr, est))
    h_spmd = np.asarray(ops.hindex_blocks(g, est, backend="ell_spmd"))
    assert (h_ref == h_spmd).all()

    rng = np.random.default_rng(0)
    R = 3
    f = jnp.asarray(rng.random((g.N, R)) < 0.05)
    elig = jnp.asarray(rng.random((g.N, R)) < 0.8)
    vis = jnp.zeros((g.N, R), bool)
    hop_ref = np.asarray(ref.ell_frontier_hop_ref(g.nbr, f, elig, vis))
    hop_spmd = np.asarray(
        ops.frontier_blocks(g, f, elig, vis, backend="ell_spmd"))
    assert (hop_ref == hop_spmd).all()
    # shared (N,) eligibility broadcast path
    elig1 = jnp.asarray(rng.random(g.N) < 0.8)
    hop_ref1 = np.asarray(ref.ell_frontier_hop_ref(
        g.nbr, f, jnp.broadcast_to(elig1[:, None], f.shape), vis))
    hop_spmd1 = np.asarray(
        ops.frontier_blocks(g, f, elig1, vis, backend="ell_spmd"))
    assert (hop_ref1 == hop_spmd1).all()


# ---------------------------------------------------------------------------
# engine traces: executed vs metered accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", (2, 4))
def test_run_spmd_traces_match_metered_w2w(P):
    _, edges, n = _graphs()[0]
    g = _blocks(edges, n, P)
    core_m, eng_m = coreness_via_engine(g)
    core_x, eng_x = coreness_via_spmd(g)
    assert (np.asarray(core_m) == np.asarray(core_x)).all()
    assert len(eng_m.traces) == len(eng_x.traces)
    tm, tx = eng_m.message_totals(), eng_x.message_totals()
    # the acceptance contract: executed == metered, both splits
    assert (tm.w2w_intra, tm.w2w_inter) == (tx.w2w_intra, tx.w2w_inter)
    # per-superstep too, since the plan is static across the run
    for a, b in zip(eng_m.traces, eng_x.traces):
        assert (a.stats.w2w_intra, a.stats.w2w_inter) == \
               (b.stats.w2w_intra, b.stats.w2w_inter)
    # the SPMD engine's W2M carries per-*block* flags (P per superstep)
    assert tx.w2m == P * len(eng_x.traces)


def test_engine_w2w_override_stamps_executed_counts():
    _, edges, n = _graphs()[0]
    g = _blocks(edges, n, 2)
    plan = build_halo_plan(g, W=1)
    est0 = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    eng = BladygEngine(g)
    eng.run(CorenessProgram(), est0, None, w2w_override=plan.slot_counts())
    t = eng.message_totals()
    intra, inter = plan.slot_counts()
    assert t.w2w_intra == intra * len(eng.traces)
    assert t.w2w_inter == inter * len(eng.traces)


# ---------------------------------------------------------------------------
# maintenance routed through the mesh
# ---------------------------------------------------------------------------


def test_maintain_batch_ell_spmd_bit_identical():
    from repro.core.updates import sample_deletions, sample_insertions

    _, edges, n = _graphs()[0]
    g = _blocks(edges, n, 4)
    core0 = coreness(g, backend="jnp")
    ups = (sample_insertions(g, 2, "inter", seed=2)
           + sample_insertions(g, 2, "intra", seed=3)
           + sample_deletions(g, 2, "intra", seed=4))
    g_a, core_a, st_a = maintain_batch(
        _clone(g), jnp.asarray(core0), ups, R=3, backend="jnp")
    g_b, core_b, st_b = maintain_batch(
        _clone(g), jnp.asarray(core0), ups, R=3, backend="ell_spmd")
    assert (np.asarray(core_a) == np.asarray(core_b)).all()
    assert (np.asarray(g_a.nbr) == np.asarray(g_b.nbr)).all()
    assert st_b.updates == len(ups)
    # and the maintained result equals recompute-from-scratch on the mesh
    assert (np.asarray(coreness(g_b, backend="ell_spmd"))
            == np.asarray(core_b)).all()
