"""Incremental k-core maintenance: exactness vs networkx on update streams.

This is the paper's central claim — maintained coreness equals
recompute-from-scratch after every insertion/deletion.
"""
import numpy as np
import jax
import jax.numpy as jnp
import networkx as nx
import pytest
from _hyp import given, settings, st

from repro.core import (
    build_blocks, coreness, insert_edge_maintain, delete_edge_maintain,
    k_reachable, maintain_batch_host)
from repro.core.partition import node_random_partition
from repro.core.updates import sample_insertions, sample_deletions
from repro.graphgen import barabasi_albert, erdos_renyi

from conftest import nx_graph


def _assert_core_equal(g, core, G):
    ref = nx.core_number(G)
    c = np.asarray(core)
    orig = np.asarray(g.orig_id)
    for i in range(g.N):
        if orig[i] >= 0:
            assert c[i] == ref[orig[i]], (orig[i], c[i], ref[orig[i]])


@pytest.mark.parametrize("scenario", ["inter", "intra"])
def test_insert_maintenance_exact(scenario, ba_graph):
    edges, n = ba_graph
    assign = node_random_partition(n, 4, seed=3)
    g = build_blocks(edges, n, assign, P=4, deg_slack=40)
    core = coreness(g)
    G = nx_graph(edges, n)
    orig = np.asarray(g.orig_id)
    for u, v, _ in sample_insertions(g, 15, scenario, seed=8):
        g, core, stats = insert_edge_maintain(g, core, jnp.int32(u), jnp.int32(v))
        G.add_edge(orig[u], orig[v])
        assert int(stats.candidates) >= 1
    _assert_core_equal(g, core, G)


@pytest.mark.parametrize("scenario", ["inter", "intra"])
def test_delete_maintenance_exact(scenario, ba_graph):
    edges, n = ba_graph
    assign = node_random_partition(n, 4, seed=3)
    g = build_blocks(edges, n, assign, P=4, deg_slack=40)
    core = coreness(g)
    G = nx_graph(edges, n)
    orig = np.asarray(g.orig_id)
    for u, v, _ in sample_deletions(g, 15, scenario, seed=9):
        g, core, stats = delete_edge_maintain(g, core, jnp.int32(u), jnp.int32(v))
        G.remove_edge(orig[u], orig[v])
    _assert_core_equal(g, core, G)


def test_mixed_stream_exact(er_graph):
    edges, n = er_graph
    assign = node_random_partition(n, 4, seed=1)
    g = build_blocks(edges, n, assign, P=4, deg_slack=40)
    core = coreness(g)
    G = nx_graph(edges, n)
    orig = np.asarray(g.orig_id)
    ups = (sample_insertions(g, 8, "inter", seed=2)
           + sample_deletions(g, 8, "intra", seed=3))
    g, core, stats = maintain_batch_host(g, core, ups)
    for (u, v, op) in ups:
        if op > 0:
            G.add_edge(orig[u], orig[v])
        else:
            G.remove_edge(orig[u], orig[v])
    _assert_core_equal(g, core, G)
    assert len(stats) == len(ups)


def test_candidate_set_is_local_for_intra_updates(ba_graph):
    """The paper's efficiency claim: intra-partition updates usually touch
    fewer blocks than inter-partition ones (Table 2 rationale)."""
    edges, n = ba_graph
    assign = node_random_partition(n, 8, seed=5)
    g = build_blocks(edges, n, assign, P=8, deg_slack=40)
    core = coreness(g)

    def avg_blocks(scenario, seed):
        # donating maintain fns consume their input: hand them a copy
        gg = jax.tree.map(lambda x: x.copy(), g)
        cc = core.copy()
        tot = 0
        ups = sample_insertions(g, 10, scenario, seed=seed)
        for u, v, _ in ups:
            gg, cc, st_ = insert_edge_maintain(gg, cc, jnp.int32(u), jnp.int32(v))
            tot += int(st_.blocks_touched)
        return tot / len(ups)

    # candidates include both endpoints; inter updates span >= 2 blocks
    assert avg_blocks("inter", 21) >= 2.0


def test_k_reachable_matches_bfs(er_graph):
    edges, n = er_graph
    assign = node_random_partition(n, 4, seed=0)
    g = build_blocks(edges, n, assign, P=4)
    core = coreness(g)
    c = np.asarray(core)
    # pick a node, BFS through its own core level in numpy
    src = int(np.argmax(np.asarray(g.node_mask)))
    k = int(c[src])
    roots = jnp.zeros(g.N, bool).at[src].set(True)
    got = np.asarray(k_reachable(g, core, roots, jnp.int32(k))[0])
    # reference BFS
    nbr = np.asarray(g.nbr)
    seen = {src} if c[src] == k else set()
    frontier = list(seen)
    while frontier:
        nxt = []
        for u in frontier:
            for v in nbr[u]:
                if v >= 0 and v not in seen and c[v] == k:
                    seen.add(int(v))
                    nxt.append(int(v))
        frontier = nxt
    assert set(np.flatnonzero(got)) == seen


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_property_random_stream(seed):
    """Random small graph + random update stream -> maintained == oracle."""
    rng = np.random.default_rng(seed)
    edges = erdos_renyi(30, 60, seed=seed)
    n = 30
    assign = node_random_partition(n, 3, seed=seed)
    g = build_blocks(edges, n, assign, P=3, deg_slack=30)
    core = coreness(g)
    G = nx_graph(edges, n)
    orig = np.asarray(g.orig_id)
    present = set(map(tuple, np.sort(edges, 1)))
    o2n = {orig[i]: i for i in range(g.N) if orig[i] >= 0}
    for _ in range(12):
        a, b = rng.integers(0, n, 2)
        if a == b:
            continue
        key = (min(a, b), max(a, b))
        u, v = o2n[a], o2n[b]
        if key in present:
            g, core, _ = delete_edge_maintain(g, core, jnp.int32(u), jnp.int32(v))
            G.remove_edge(a, b)
            present.discard(key)
        else:
            g, core, _ = insert_edge_maintain(g, core, jnp.int32(u), jnp.int32(v))
            G.add_edge(a, b)
            present.add(key)
    _assert_core_equal(g, core, G)
