"""Scenario sampling + host-boundary validation in core/updates.py.

The paper's §5.2.1 experiments depend on the sampler's invariants: inter
updates cross blocks, intra updates stay inside one, insertions are
non-adjacent pairs, deletions are existing edges, and everything is
deterministic under a fixed seed.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_blocks, coreness
from repro.core.partition import node_random_partition
from repro.core.updates import (
    apply_updates_host, classify, sample_deletions, sample_insertions,
)
from repro.graphgen import erdos_renyi


@pytest.fixture(scope="module")
def g():
    edges = erdos_renyi(120, 400, seed=6)
    n = int(edges.max()) + 1
    return build_blocks(edges, n, node_random_partition(n, 4, seed=1), P=4,
                        deg_slack=24)


# ------------------------------------------------------------- sampling ----

@pytest.mark.parametrize("scenario", ["inter", "intra"])
def test_insertions_respect_scenario_and_are_nonadjacent(g, scenario):
    ups = sample_insertions(g, 20, scenario, seed=3)
    assert len(ups) == 20
    nbr = np.asarray(g.nbr)
    mask = np.asarray(g.node_mask)
    seen = set()
    for u, v, op in ups:
        assert op == +1 and u != v
        assert classify(g, u, v) == scenario
        assert mask[u] and mask[v]
        assert not (nbr[u] == v).any(), "insertion must be a non-edge"
        key = (min(u, v), max(u, v))
        assert key not in seen, "sampler must not repeat pairs"
        seen.add(key)


@pytest.mark.parametrize("scenario", ["inter", "intra"])
def test_deletions_are_existing_edges_of_scenario(g, scenario):
    ups = sample_deletions(g, 15, scenario, seed=4)
    assert len(ups) == 15
    nbr = np.asarray(g.nbr)
    for u, v, op in ups:
        assert op == -1
        assert classify(g, u, v) == scenario
        assert (nbr[u] == v).any() and (nbr[v] == u).any()


def test_sampling_is_deterministic_per_seed(g):
    a = sample_insertions(g, 10, "inter", seed=7)
    b = sample_insertions(g, 10, "inter", seed=7)
    c = sample_insertions(g, 10, "inter", seed=8)
    assert a == b
    assert a != c
    d1 = sample_deletions(g, 10, "intra", seed=7)
    d2 = sample_deletions(g, 10, "intra", seed=7)
    assert d1 == d2


# ------------------------------------------------------ host validation ----

def test_apply_updates_host_roundtrip(g):
    ins = sample_insertions(g, 5, "inter", seed=2)
    g2 = apply_updates_host(g, ins)
    assert int(np.asarray(g2.deg).sum()) == int(np.asarray(g.deg).sum()) + 10
    g3 = apply_updates_host(g2, [(u, v, -1) for u, v, _ in ins])
    np.testing.assert_array_equal(np.asarray(g3.deg), np.asarray(g.deg))
    # coreness insensitive to slot permutation introduced by delete-swap
    np.testing.assert_array_equal(
        np.asarray(coreness(g3)), np.asarray(coreness(g))
    )


def test_apply_updates_host_rejects_out_of_range(g):
    with pytest.raises(ValueError, match="out of range"):
        apply_updates_host(g, [(0, g.N + 10, +1)])
    with pytest.raises(ValueError, match="out of range"):
        apply_updates_host(g, [(-3, 0, +1)])  # would wrap silently in numpy


def test_apply_updates_host_rejects_self_loop(g):
    u = int(np.flatnonzero(np.asarray(g.node_mask))[0])
    with pytest.raises(ValueError, match="self-loop"):
        apply_updates_host(g, [(u, u, +1)])


def test_apply_updates_host_rejects_duplicate_insert(g):
    nbr = np.asarray(g.nbr)
    u = int(np.flatnonzero((nbr >= 0).any(axis=1))[0])
    v = int(nbr[u][nbr[u] >= 0][0])
    with pytest.raises(ValueError, match="already present"):
        apply_updates_host(g, [(u, v, +1)])


def test_apply_updates_host_rejects_missing_delete(g):
    ups = sample_insertions(g, 1, "inter", seed=9)  # a known non-edge
    (u, v, _), = ups
    with pytest.raises(ValueError, match="not present"):
        apply_updates_host(g, [(u, v, -1)])


def test_apply_updates_host_rejects_capacity_overflow():
    # star center at degree capacity; P=1 keeps padded ids == original ids
    edges = np.array([[0, i] for i in range(1, 5)])
    g = build_blocks(edges, 6, np.zeros(6, int), P=1, Cd=4)
    with pytest.raises(ValueError, match="capacity"):
        apply_updates_host(g, [(0, 5, +1)])
