"""BlockProgram workloads: superstep counts + wall-clock per backend.

The framework claim of ISSUE 5 measured: connected components, PageRank,
and triangle counting all run through ONE fused runner
(`ops.run_block_program`) on every registry backend.  Rows:

  workloads/<wl>/<graph>/<backend>  — us/call for a full fused run, with
      derived = "steps=<supersteps> n=<padded nodes>"; the superstep
      count rides back as a device scalar (the fused loop performs zero
      per-superstep host transfers, so us/call IS the end-to-end fixpoint
      latency, not a loop of kernel launches).

Two bench graphs bracket the superstep regimes: a Barabási–Albert graph
(small diameter — label propagation converges in a few supersteps) and a
grid (huge diameter — CC walks it, the stress case for fused-loop
overhead).  PageRank runs the tolerance-halt variant; triangle counting
is always exactly one superstep, so its row isolates the per-superstep
combine cost.  The jnp rows are the honest CPU numbers; on the CI
container the Pallas backends run in interpret mode (feasibility, not
speed — same caveat as §Backends).

Parity is asserted across backends on every run — this bench doubles as
a smoke gate, like bench_runtime.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import jax

from repro.core import build_blocks, connected_components, pagerank, \
    triangle_counts, triangle_total
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert, grid_like

from .common import row, timeit_us


def _graphs(smoke: bool, seed: int):
    nb = 300 if smoke else 3000
    ng = 256 if smoke else 2500
    out = []
    for name, edges in (("ba", barabasi_albert(nb, 4, seed=seed)),
                        ("grid", grid_like(ng, seed=seed))):
        n = int(edges.max()) + 1
        P = 4
        g = build_blocks(edges, n, node_random_partition(n, P, seed=seed),
                         P=P, deg_slack=24)
        out.append((name, g))
    return out


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    reps = 2 if smoke else 5
    backends = ("jnp", "ell") if smoke else ("jnp", "dense", "ell")

    for gname, g in _graphs(smoke, seed):
        want_cc = want_tri = want_pr = None
        for b in backends:
            # connected components (min-label fixpoint)
            labels, steps = connected_components(g, backend=b,
                                                 with_steps=True)
            labels = np.asarray(labels)
            if want_cc is None:
                want_cc = labels
            assert (labels == want_cc).all(), f"cc parity {gname}/{b}"
            us = timeit_us(lambda: jax.block_until_ready(
                connected_components(g, backend=b)), n=reps)
            rows.append(row(f"workloads/cc/{gname}/{b}", us,
                            f"steps={int(steps)} n={g.N}"))

            # PageRank (tolerance halt)
            pr, steps = pagerank(g, tol=1e-6, max_steps=500, backend=b,
                                 with_steps=True)
            pr = np.asarray(pr)
            if want_pr is None:
                want_pr = pr
            assert np.allclose(pr, want_pr, atol=1e-5), \
                f"pagerank parity {gname}/{b}"
            us = timeit_us(lambda: jax.block_until_ready(
                pagerank(g, tol=1e-6, max_steps=500, backend=b)), n=reps)
            rows.append(row(f"workloads/pagerank/{gname}/{b}", us,
                            f"steps={int(steps)} n={g.N}"))

            # triangle counting (single combine superstep)
            tri, steps = triangle_counts(g, backend=b, with_steps=True)
            tri = np.asarray(tri)
            if want_tri is None:
                want_tri = tri
            assert (tri == want_tri).all(), f"triangles parity {gname}/{b}"
            us = timeit_us(lambda: jax.block_until_ready(
                triangle_counts(g, backend=b)), n=reps)
            rows.append(row(
                f"workloads/triangles/{gname}/{b}", us,
                f"steps={int(steps)} total={int(triangle_total(tri))}"))
    return rows
