"""Soft perf-regression check over the BENCH_*.json trajectory files.

Compares a fresh benchmark run (``--fresh`` dir, written by
``benchmarks.run --out-dir``) against the committed baselines
(``--baseline`` dir, normally the repo root) row-by-row and WARNS — never
fails — when a row's ``us_per_call`` grew by more than ``--threshold``
(default 2x).  Smoke timings on shared CI runners are noisy; the check is
a tripwire for order-of-magnitude regressions (a fixpoint falling back to
per-superstep host syncs, a kernel silently hitting a slow path), not a
gate.  Rows faster than ``--floor-us`` in the baseline are skipped (pure
noise), as are rows missing on either side (sweeps legitimately change).

Exit code is always 0; under GitHub Actions warnings surface as
``::warning`` annotations.

Usage: python -m benchmarks.check_regression [--baseline .] [--fresh .]
       [--threshold 2.0] [--floor-us 200]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys


def _load_rows(path: pathlib.Path):
    data = json.loads(path.read_text())
    return {
        r["name"]: r["us_per_call"]
        for r in data.get("rows", [])
        if r.get("us_per_call") is not None
    }


def _warn(msg: str) -> None:
    if os.environ.get("GITHUB_ACTIONS"):
        print(f"::warning title=bench regression::{msg}")
    else:
        print(f"WARNING: {msg}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=".",
                    help="dir holding the committed BENCH_*.json baselines")
    ap.add_argument("--fresh", default=".",
                    help="dir holding the freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="warn when fresh/baseline exceeds this ratio")
    ap.add_argument("--floor-us", type=float, default=200.0,
                    help="ignore rows whose baseline is below this (noise)")
    args = ap.parse_args(argv)

    base_dir = pathlib.Path(args.baseline)
    fresh_dir = pathlib.Path(args.fresh)
    fresh_files = sorted(fresh_dir.glob("BENCH_*.json"))
    if not fresh_files:
        _warn(f"no BENCH_*.json found under {fresh_dir} — nothing to check")
        return 0

    compared = regressed = 0
    for fresh_path in fresh_files:
        base_path = base_dir / fresh_path.name
        if not base_path.exists() or base_path.resolve() == fresh_path.resolve():
            print(f"{fresh_path.name}: no distinct baseline, skipped")
            continue
        base = _load_rows(base_path)
        fresh = _load_rows(fresh_path)
        for name in sorted(set(base) & set(fresh)):
            if base[name] < args.floor_us:
                continue
            compared += 1
            ratio = fresh[name] / base[name]
            if ratio > args.threshold:
                regressed += 1
                _warn(
                    f"{name}: {base[name]:.0f}us -> {fresh[name]:.0f}us "
                    f"({ratio:.1f}x > {args.threshold:.1f}x baseline)")
    print(f"check_regression: {compared} rows compared, "
          f"{regressed} above {args.threshold:.1f}x (soft check, exit 0)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
