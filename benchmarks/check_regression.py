"""Perf-regression check over the BENCH_*.json trajectory files.

Compares a fresh benchmark run (``--fresh`` dir, written by
``benchmarks.run --out-dir``) against the committed baselines
(``--baseline`` dir, normally the repo root) row-by-row.  Rows are only
compared when the two files were produced on the SAME ``jax_backend``
(platform field of the JSON payload): a CPU-interpret fresh point against
a committed TPU baseline (or vice versa) measures the platform, not the
code, so the file is skipped with a notice.

Two severity tiers:

  * soft (``--threshold``, default 2x): WARN on any row whose
    ``us_per_call`` grew past the ratio — smoke timings on shared CI
    runners are noisy, so this tier never fails the job;
  * hard (``--hard-threshold``, default 3x, ``kernels/*`` rows only):
    FAIL (exit 1).  The kernel sweep rows time single jitted dispatches
    of this repo's own Pallas/jnp kernels — far less scheduler noise than
    the end-to-end workload rows — so a >3x growth there is a real
    regression (a kernel silently falling off its fast path), not jitter.

Rows faster than ``--floor-us`` in the baseline are skipped (pure noise),
as are rows missing on either side (sweeps legitimately change).

``service/*`` rows (BENCH_service.json, p50 submit->answer latency under
concurrent update load) ride the soft tier: end-to-end serving latency
folds in window apply + snapshot refresh, which is noisier than a single
kernel dispatch, so growth warns rather than fails.

Usage: python -m benchmarks.check_regression [--baseline .] [--fresh .]
       [--threshold 2.0] [--hard-threshold 3.0] [--floor-us 200]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

#: row-name prefix subject to the hard-fail tier
HARD_PREFIX = "kernels/"


def _load(path: pathlib.Path):
    data = json.loads(path.read_text())
    rows = {
        r["name"]: r["us_per_call"]
        for r in data.get("rows", [])
        if r.get("us_per_call") is not None
    }
    backend = data.get("platform", {}).get("jax_backend")
    return rows, backend


def _warn(msg: str) -> None:
    if os.environ.get("GITHUB_ACTIONS"):
        print(f"::warning title=bench regression::{msg}")
    else:
        print(f"WARNING: {msg}")


def _error(msg: str) -> None:
    if os.environ.get("GITHUB_ACTIONS"):
        print(f"::error title=bench regression::{msg}")
    else:
        print(f"ERROR: {msg}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=".",
                    help="dir holding the committed BENCH_*.json baselines")
    ap.add_argument("--fresh", default=".",
                    help="dir holding the freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="warn when fresh/baseline exceeds this ratio")
    ap.add_argument("--hard-threshold", type=float, default=3.0,
                    help=f"fail (exit 1) when a {HARD_PREFIX}* row exceeds "
                         "this ratio")
    ap.add_argument("--floor-us", type=float, default=200.0,
                    help="ignore rows whose baseline is below this (noise)")
    args = ap.parse_args(argv)

    base_dir = pathlib.Path(args.baseline)
    fresh_dir = pathlib.Path(args.fresh)
    fresh_files = sorted(fresh_dir.glob("BENCH_*.json"))
    if not fresh_files:
        _warn(f"no BENCH_*.json found under {fresh_dir} — nothing to check")
        return 0

    compared = regressed = failed = 0
    for fresh_path in fresh_files:
        base_path = base_dir / fresh_path.name
        if not base_path.exists() or base_path.resolve() == fresh_path.resolve():
            print(f"{fresh_path.name}: no distinct baseline, skipped")
            continue
        base, base_backend = _load(base_path)
        fresh, fresh_backend = _load(fresh_path)
        if base_backend != fresh_backend:
            print(f"{fresh_path.name}: platform mismatch "
                  f"(baseline={base_backend}, fresh={fresh_backend}) — "
                  "timings not comparable, skipped")
            continue
        for name in sorted(set(base) & set(fresh)):
            if base[name] < args.floor_us:
                continue
            compared += 1
            ratio = fresh[name] / base[name]
            msg = (f"{name}: {base[name]:.0f}us -> {fresh[name]:.0f}us "
                   f"({ratio:.1f}x baseline)")
            if name.startswith(HARD_PREFIX) and ratio > args.hard_threshold:
                failed += 1
                _error(f"{msg} > {args.hard_threshold:.1f}x hard limit")
            elif ratio > args.threshold:
                regressed += 1
                _warn(f"{msg} > {args.threshold:.1f}x soft limit")
    print(f"check_regression: {compared} rows compared, {regressed} above "
          f"{args.threshold:.1f}x (warn), {failed} {HARD_PREFIX}* rows above "
          f"{args.hard_threshold:.1f}x (fail)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
