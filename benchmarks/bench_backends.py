"""Kernel backend comparison: jnp vs dense-tile vs ELL block-sparse.

Times full coreness through each registry backend at increasing N and emits
the table EXPERIMENTS.md §Backends is built from.  The headline row is the
large-N one: the dense path's (N, N) bf16 adjacency would exceed 4 GiB, so
it is reported as INFEASIBLE while the O(N*Cd) ELL path (and the jnp
fallback) still run.

Off-TPU the Pallas backends execute in interpret mode — their absolute
times are NOT hardware numbers (see EXPERIMENTS.md); the point of this
table on CPU is memory feasibility + exact parity, which is asserted here
for every size where two backends both run.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np
import jax

from repro.core import build_blocks, build_ell_random, coreness
from repro.core.partition import node_random_partition
from repro.graphgen import erdos_renyi
from repro.kernels import ops

from .common import row

#: large-N where the padded dense bf16 adjacency crosses 4 GiB
BIG_N = 46848


def _time_coreness(g, backend: str) -> Tuple[float, jax.Array]:
    core = coreness(g, backend=backend)  # warmup/compile
    jax.block_until_ready(core)
    t0 = time.perf_counter()
    core = coreness(g, backend=backend)
    jax.block_until_ready(core)
    return time.perf_counter() - t0, core


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    """No `full` knob: this table measures the feasibility boundary (fixed
    sizes incl. BIG_N), not dataset scale — paper-scale runs live in the
    dataset benches."""
    rows = []
    sizes = [512] if smoke else [512, 2048]
    for n in sizes:
        edges = erdos_renyi(n, 3 * n, seed=seed)
        nn = int(edges.max()) + 1
        g = build_blocks(edges, nn, node_random_partition(nn, 8, seed=seed),
                         P=8, deg_slack=24)
        ref_core = None
        for b in ("jnp", "dense", "ell"):
            dt, core = _time_coreness(g, b)
            if ref_core is None:
                ref_core = np.asarray(core)
            else:
                np.testing.assert_array_equal(ref_core, np.asarray(core))
            rows.append(row(f"backends/N{g.N}/{b}", dt * 1e6,
                            f"s={dt:.3f};parity=ok;dense_gib="
                            f"{ops.dense_bytes(g.N)/2**30:.3f}"))
    if smoke:
        return rows

    # headline: N where the dense adjacency alone would exceed 4 GiB
    g = build_ell_random(BIG_N, seed=seed)
    gib = ops.dense_bytes(g.N) / 2**30
    assert gib > 4.0, gib
    ref_core = None
    for b in ("jnp", "ell"):
        dt, core = _time_coreness(g, b)
        if ref_core is None:
            ref_core = np.asarray(core)
        else:
            np.testing.assert_array_equal(ref_core, np.asarray(core))
        rows.append(row(f"backends/N{g.N}/{b}", dt * 1e6,
                        f"s={dt:.3f};parity=ok;dense_gib={gib:.2f}"))
    rows.append(row(f"backends/N{g.N}/dense", float("nan"),
                    f"INFEASIBLE;dense_gib={gib:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
