"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table2  — AIT/ADT inter- vs intra-partition k-core maintenance (Table 2)
  fig7    — incremental maintenance vs naive full recompute    (Figure 7)
  table3/4/5 — dynamic partitioning PT/UT hash/random/DFEP     (Tables 3-5)
  kcore_static — static decomposition time + supersteps        (§4.1 step 1)
  roofline — three-term roofline per (arch × shape) from the dry-run JSONs

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--updates N]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (slow; CI default is scaled)")
    ap.add_argument("--updates", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    help="comma list: table2,fig7,partitioning,static,roofline")
    args = ap.parse_args()

    from . import (bench_kcore_maintenance, bench_vs_naive_kcore,
                   bench_partitioning, bench_static_kcore, roofline)

    benches = {
        "table2": lambda: bench_kcore_maintenance.run(
            updates=args.updates, full=args.full, seed=args.seed),
        "fig7": lambda: bench_vs_naive_kcore.run(
            updates=max(5, args.updates // 4), full=args.full, seed=args.seed),
        "partitioning": lambda: bench_partitioning.run(
            full=args.full, seed=args.seed),
        "static": lambda: bench_static_kcore.run(full=args.full,
                                                 seed=args.seed),
        "roofline": lambda: roofline.run(full=args.full, seed=args.seed),
    }
    only = set(args.only.split(",")) if args.only else set(benches)

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches.items():
        if name not in only:
            continue
        try:
            for r in fn():
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
            sys.stdout.flush()
        except Exception:
            failed += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
