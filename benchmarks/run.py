"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table2  — AIT/ADT inter- vs intra-partition k-core maintenance (Table 2)
            plus batched-maintenance rows when --batch-sizes is given
  fig7    — incremental maintenance vs naive full recompute    (Figure 7)
  table3/4/5 — dynamic partitioning PT/UT hash/random/DFEP     (Tables 3-5)
  kcore_static — static decomposition time + supersteps        (§4.1 step 1)
  backends — jnp vs dense vs ELL registry sweep incl. the >4 GiB dense-
             infeasible N (EXPERIMENTS.md §Backends)
  kernels  — h-index kernel variants (sort vs count) + fused-vs-host-loop
             fixpoint latency (EXPERIMENTS.md §Kernels)
  runtime  — mesh (ell_spmd) coreness parity/time + metered vs executed
             W2W accounting (EXPERIMENTS.md §Runtime)
  stream   — incremental vs full halo-plan maintenance, executor-reuse
             stream pass, §4.2 live rebalancing (EXPERIMENTS.md §Stream)
  workloads — BlockProgram workload sweep: CC / PageRank / triangles per
             backend, superstep counts + parity (EXPERIMENTS.md §Workloads)
  service  — query service qps + p50/p99 under concurrent update load,
             sweeping query mix × window width R (EXPERIMENTS.md §Service)
  roofline — three-term roofline per (arch × shape) from the dry-run JSONs

The `kernels`, `stream`, `workloads`, and `service` rows are additionally
written to ``BENCH_kernels.json`` / ``BENCH_stream.json`` /
``BENCH_workloads.json`` / ``BENCH_service.json``
under --out-dir: the machine-readable perf trajectory (committed
baselines at the repo root, fresh points uploaded as CI artifacts and
soft-checked by ``benchmarks.check_regression``).

``--profile`` additionally measures the per-kernel roofline points
(analytic FLOPs/bytes + achieved fraction, `benchmarks.profile_kernels`)
and writes them to ``PROFILE_kernels.json`` under --out-dir — a distinct
prefix, so the BENCH_* regression glob never compares profile payloads.

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--updates N]
       [--backends jnp,dense,ell] [--batch-sizes 1,4,8] [--smoke]
       [--profile] [--out-dir DIR]

--smoke is the CI gate: tiny graphs, every backend, a few updates — fails
fast on kernel parity regressions without the full table runtime.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import platform
import sys
import traceback

#: benches whose rows feed the machine-readable perf trajectory
JSON_BENCHES = ("kernels", "stream", "workloads", "service", "skew",
                "elastic")


def write_bench_json(out_dir: str, bench: str, rows) -> pathlib.Path:
    """Write one bench's rows as BENCH_<name>.json (NaN -> null)."""
    import jax

    payload = {
        "bench": bench,
        "schema": ["name", "us_per_call", "derived"],
        "platform": {
            "jax_backend": jax.devices()[0].platform,
            "device_count": len(jax.devices()),
            "python": platform.python_version(),
        },
        "rows": [
            {
                "name": name,
                "us_per_call": round(us, 1) if math.isfinite(us) else None,
                "derived": derived,
            }
            for name, us, derived in rows
        ],
    }
    path = pathlib.Path(out_dir) / f"BENCH_{bench}.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=2, allow_nan=False) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (slow; CI default is scaled)")
    ap.add_argument("--updates", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", default="jnp",
                    help="comma list for the static sweep: jnp,dense,ell")
    ap.add_argument("--batch-sizes", default="",
                    help="comma list of maintain_batch R values for table2")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI pass: backend parity + a few updates")
    ap.add_argument("--only", default=None,
                    help="comma list: table2,fig7,partitioning,static,"
                         "backends,kernels,runtime,stream,workloads,"
                         "service,skew,roofline")
    ap.add_argument("--profile", action="store_true",
                    help="also dump per-kernel roofline points "
                         "(PROFILE_kernels.json under --out-dir)")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the BENCH_*.json trajectory files")
    args = ap.parse_args()

    from . import (bench_backends, bench_elastic, bench_kcore_maintenance,
                   bench_kernels, bench_vs_naive_kcore, bench_partitioning,
                   bench_runtime, bench_service, bench_skew,
                   bench_static_kcore, bench_stream, bench_workloads,
                   roofline)

    backends = tuple(b for b in args.backends.split(",") if b)
    batch_sizes = tuple(int(r) for r in args.batch_sizes.split(",") if r)

    if args.smoke:
        # shrink the Table-1 stand-ins to a fast sanity scale and force the
        # full backend sweep + a batched-maintenance pass
        from . import common
        small = {"DS1": 0.02, "ego-Facebook": 0.10}
        common.CI_SCALES.clear()
        common.CI_SCALES.update(small)
        args.updates = min(args.updates, 6)
        backends = ("jnp", "dense", "ell")
        batch_sizes = batch_sizes or (4,)

    benches = {
        "table2": lambda: bench_kcore_maintenance.run(
            updates=args.updates, full=args.full, seed=args.seed,
            batch_sizes=batch_sizes),
        "fig7": lambda: bench_vs_naive_kcore.run(
            updates=max(5, args.updates // 4), full=args.full, seed=args.seed),
        "partitioning": lambda: bench_partitioning.run(
            full=args.full, seed=args.seed),
        "static": lambda: bench_static_kcore.run(
            full=args.full, seed=args.seed, backends=backends),
        "backends": lambda: bench_backends.run(
            seed=args.seed, smoke=args.smoke),
        "kernels": lambda: bench_kernels.run(
            seed=args.seed, smoke=args.smoke),
        "runtime": lambda: bench_runtime.run(
            seed=args.seed, smoke=args.smoke),
        "stream": lambda: bench_stream.run(
            seed=args.seed, smoke=args.smoke),
        "workloads": lambda: bench_workloads.run(
            seed=args.seed, smoke=args.smoke),
        "service": lambda: bench_service.run(
            seed=args.seed, smoke=args.smoke),
        "skew": lambda: bench_skew.run(
            seed=args.seed, smoke=args.smoke),
        "elastic": lambda: bench_elastic.run(
            seed=args.seed, smoke=args.smoke),
        "roofline": lambda: roofline.run(full=args.full, seed=args.seed),
    }
    if args.smoke:
        for excluded in ("roofline", "fig7"):
            benches.pop(excluded)  # roofline needs dry-run JSONs; fig7
            # adds minutes without touching the kernel/backend surface
            # (partitioning stays: it is pure numpy and fast at CI scale,
            # and gates the §4.2 IncrementalPart/NaivePart protocol)
    only = set(args.only.split(",")) if args.only else set(benches)
    unknown = only - set(benches)
    if unknown:
        raise SystemExit(
            f"--only {','.join(sorted(unknown))}: not available"
            + (" under --smoke" if args.smoke else "")
            + f"; choose from {','.join(sorted(benches))}"
        )

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches.items():
        if name not in only:
            continue
        try:
            rows = list(fn())
            for r in rows:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
            sys.stdout.flush()
            if name in JSON_BENCHES:
                path = write_bench_json(args.out_dir, name, rows)
                print(f"# wrote {path}", file=sys.stderr)
        except Exception:
            failed += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.profile:
        from . import profile_kernels

        payload = profile_kernels.profile_points(seed=args.seed)
        path = pathlib.Path(args.out_dir) / "PROFILE_kernels.json"
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {path}", file=sys.stderr)
        for p in payload["points"]:
            print(f"profile/{p['name']},{p['us_per_call']:.1f},"
                  f"achieved={p['achieved_fraction']};"
                  f"intensity={p['intensity_flops_per_byte']}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
