"""Serving path: queries/sec + tail latency under a live update stream.

Measures the Layer-5 query service (`repro.service`) the way it runs in
production shape: one `QueryServer` interleaving admitted query batches
with `StreamSession` update windows on the same device program.  Sweeps
the query mix x the window width R:

  * `service/serve/<mix>/R<r>` — us_per_call is the p50 submit->answer
    latency (a query waits for the in-flight window + snapshot refresh,
    so this is the honest interleaved-serving number, not just the
    gather).  The derived field carries queries/sec of batch busy time
    (`qps`), p99, answered/shed counts, and the max snapshot staleness
    observed (0 at refresh_every=1 — every answer reads the newest
    epoch).

Mixes: `gather` (core/degree point reads), `mixed` (all five kinds),
`topk` (top-k PageRank, bucketed k).  Each sweep point runs the same
update+query replay twice on fresh graph clones and reports the second
pass only, so every compile — the query kernels, the stream step, and
the escalation/CC-recompute paths some windows trigger (inserts are
interleaved with deletes so every window carries both ops) — lands in
the process-global jit caches before the measured pass.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import build_blocks, connected_components, coreness
from repro.core.partition import node_bfs_partition
from repro.core.updates import sample_deletions, sample_insertions
from repro.graphgen import barabasi_albert
from repro.runtime import StreamSession
from repro.service import (
    QueryServer, ServiceConfig, core_of, degree_of, nbr_max_core_of,
    same_component, topk_pagerank)

from .common import row


def _mixed_updates(g, count: int, seed: int):
    per = max(1, count // 4)
    ups = (sample_insertions(g, per, "inter", seed=seed)
           + sample_insertions(g, per, "intra", seed=seed + 1)
           + sample_deletions(g, per, "inter", seed=seed + 2)
           + sample_deletions(g, per, "intra", seed=seed + 3))
    # interleave inserts with deletes so every window carries both ops
    # (and the warmup windows compile both maintenance paths)
    half = len(ups) // 2
    return [u for pair in zip(ups[:half], ups[half:]) for u in pair]


def _mix_gather(rng, n: int, count: int):
    return [core_of(int(rng.integers(n))) if rng.random() < 0.5
            else degree_of(int(rng.integers(n))) for _ in range(count)]


def _mix_mixed(rng, n: int, count: int):
    out = []
    for _ in range(count):
        r = int(rng.integers(5))
        u, v = int(rng.integers(n)), int(rng.integers(n))
        out.append([core_of(u), degree_of(u), nbr_max_core_of(u),
                    same_component(u, v), topk_pagerank(8)][r])
    return out


def _mix_topk(rng, n: int, count: int):
    return [topk_pagerank(int(rng.integers(1, 17))) for _ in range(count)]


MIXES: List[Tuple[str, Callable]] = [
    ("gather", _mix_gather), ("mixed", _mix_mixed), ("topk", _mix_topk)]


def _clone(gg):
    import jax
    return jax.tree.map(
        lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, gg)


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    n = 240 if smoke else 1600
    updates = 32 if smoke else 128
    qpw = 12 if smoke else 48          # queries submitted per window
    Rs = (4,) if smoke else (4, 8)

    edges = barabasi_albert(n, 4, seed=seed)
    nn = int(edges.max()) + 1
    assign = node_bfs_partition(edges, nn, 4, seed=seed)
    g0 = build_blocks(edges, nn, assign, P=4, deg_slack=48)
    core0 = coreness(g0, backend="jnp")
    labels0 = connected_components(g0, backend="jnp")
    ups = _mixed_updates(g0, updates, seed + 1)
    cfg = ServiceConfig(max_queue=4096, max_batch=64, refresh_every=1,
                        pr_steps=10)

    for mix_name, mix in MIXES:
        for R in Rs:
            # two identical passes, each on a fresh clone (the session
            # donates its graph buffers window-over-window): pass 0 lands
            # every compile — query kernels, the stream step, and the
            # escalation/CC-recompute paths some windows trigger — into
            # the process-global jit caches; pass 1 is what we report.
            for measured in (False, True):
                sess = StreamSession(_clone(g0), jnp.copy(core0), R=R,
                                     backend="jnp",
                                     cc_labels=jnp.copy(labels0))
                srv = QueryServer(sess, config=cfg)
                rng = np.random.default_rng(seed + 2)

                def feed(i: int):
                    return mix(rng, nn, qpw)

                t0 = time.perf_counter()
                srv.serve(list(ups), feed)
                wall = time.perf_counter() - t0
            s = srv.metrics.summary()
            rows.append(row(
                f"service/serve/{mix_name}/R{R}", s["p50_ms"] * 1e3,
                f"qps={s['qps']:.0f};p99_ms={s['p99_ms']:.2f};"
                f"answered={s['answered']};shed={s['shed']};"
                f"batches={s['batches']};stale_max={s['staleness_max']};"
                f"wall_s={wall:.2f}"))
            assert s["shed"] == 0, \
                f"bench feed overran admission control ({s['shed']} shed)"
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
