"""Kernel-variant and fixpoint-latency sweeps (the BENCH_kernels.json source).

Four measurement surfaces for the kernel pass:

  * `kernels/hindex/*` — the h-index kernel variants at a (N, Cd) grid:
    the O(Cd log Cd) in-tile sort sweep vs the legacy O(Cd*K) count-matrix
    kernel (K = Cd), plus the single-superstep latency of each registry
    backend.  Off-TPU the Pallas rows run in interpret mode — relative
    variant cost, not hardware speed; parity vs `ref.ell_hindex_ref` is
    asserted on every row (this file is part of the --smoke gate).
  * `kernels/coreness/*` — the full min-H fixpoint as ONE fused
    `lax.while_loop` (`ops.coreness_blocks`) vs a host-driven replica of
    the pre-refactor loop (one `device_get` convergence check per
    superstep).  The derived field carries the superstep count so
    us/superstep is recoverable from the JSON trajectory.
  * `kernels/triangles/*` — the sorted-merge binary-probe intersection
    vs the legacy all-pairs cube on the same adjacency, bit-parity
    asserted against `ref.ell_common_ref` on both.
  * `kernels/multi/*` — the fused multi-field superstep
    (`ops.neighbor_multi_ell`: coreness + CC + PageRank reduces off ONE
    adjacency read) vs the three standalone kernel launches, per-field
    bit-parity asserted.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_blocks, build_ell_random
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert
from repro.kernels import ops, ref

from .common import row, timeit_us


def _timed(fn, reps: int) -> float:
    out = fn()            # warmup / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / max(1, reps) * 1e6


def _hostloop_coreness(g, backend: str):
    """Pre-refactor fixpoint: one kernel launch + one host sync/superstep."""
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    adj = ops.dense_adj(g, backend)
    steps = 0
    while True:
        h = ops.hindex_blocks(g, est, backend=backend, adj=adj)
        new = jnp.where(g.node_mask, jnp.minimum(est, h), est)
        steps += 1
        if bool(jax.device_get(jnp.all(new == est))):
            break
        est = new
    return est, steps


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    reps = 3 if smoke else 10

    # ---- kernel-variant sweep: sort vs count h-index ------------------
    shapes = [(512, 256)] if smoke else [(512, 256), (2048, 256), (2048, 512)]
    for N, Cd in shapes:
        g = build_ell_random(N, Cd=Cd, seed=seed, m_factor=Cd / 3)
        est = jnp.asarray(g.deg, jnp.int32)
        want = np.asarray(ref.ell_hindex_ref(g.nbr, est))
        K = ops.degree_bound(g)
        us_by = {}
        for variant in ("sort", "count"):
            got = ops.hindex_ell(g.nbr, est, variant=variant)
            np.testing.assert_array_equal(np.asarray(got), want)
            us_by[variant] = _timed(
                lambda v=variant: ops.hindex_ell(g.nbr, est, variant=v), reps)
        for variant, us in us_by.items():
            rows.append(row(
                f"kernels/hindex/N{g.N}/Cd{Cd}/{variant}", us,
                f"K={K};sort_speedup={us_by['count'] / max(us_by['sort'], 1e-9):.1f}x"))
        # degree-bucketed K: same kernel, fewer columns swept
        got = ops.hindex_ell(g.nbr, est, K=K)
        np.testing.assert_array_equal(np.asarray(got), want)
        rows.append(row(
            f"kernels/hindex/N{g.N}/Cd{Cd}/sort_degK",
            _timed(lambda: ops.hindex_ell(g.nbr, est, K=K), reps),
            f"K={K}"))

    # ---- single-superstep latency per backend -------------------------
    n = 240 if smoke else 1000
    edges = barabasi_albert(n, 4, seed=seed)
    nn = int(edges.max()) + 1
    g = build_blocks(edges, nn, node_random_partition(nn, 8, seed=seed),
                     P=8, deg_slack=24)
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    want = np.asarray(ref.ell_hindex_ref(g.nbr, est))
    for b in ("jnp", "dense", "ell"):
        got = ops.hindex_blocks(g, est, backend=b)
        np.testing.assert_array_equal(np.asarray(got).astype(want.dtype), want)
        us = _timed(lambda bb=b: ops.hindex_blocks(g, est, backend=bb), reps)
        rows.append(row(f"kernels/superstep/N{g.N}/{b}", us, "parity=ok"))

    # ---- triangles: sorted-merge vs all-pairs intersection ------------
    tri_shapes = [(320, 24)] if smoke else [(320, 24), (320, 128), (1024, 64)]
    for N, Cd in tri_shapes:
        gt = build_ell_random(N, Cd=Cd, seed=seed, m_factor=Cd / 3)
        want = np.asarray(ref.ell_common_ref(gt.nbr, gt.nbr))
        us_by = {}
        for variant in ("merge", "allpairs"):
            got = ops.neighbor_common_ell(gt.nbr, gt.nbr, variant=variant)
            np.testing.assert_array_equal(np.asarray(got), want)
            us_by[variant] = _timed(
                lambda v=variant: ops.neighbor_common_ell(
                    gt.nbr, gt.nbr, variant=v), reps)
        speedup = us_by["allpairs"] / max(us_by["merge"], 1e-9)
        for variant, us in us_by.items():
            rows.append(row(
                f"kernels/triangles/N{gt.N}/Cd{Cd}/{variant}", us,
                f"merge_speedup={speedup:.1f}x;parity=ok"))

    # ---- fused multi-field superstep vs three standalone launches -----
    for N, Cd in ([(512, 32)] if smoke else [(512, 32), (2048, 64)]):
        gm = build_ell_random(N, Cd=Cd, seed=seed, m_factor=Cd / 3)
        est = jnp.asarray(gm.deg, jnp.int32)
        lab = jnp.arange(gm.N, dtype=jnp.int32)
        contrib = jnp.where(gm.deg > 0, 1.0 / jnp.maximum(gm.deg, 1),
                            0.0).astype(jnp.float32)
        combines = ("hindex", "min", "sum")

        def fused():
            return ops.neighbor_multi_ell(
                gm.nbr, (est, lab, contrib), combines)

        def separate():
            return (ops.hindex_ell(gm.nbr, est),
                    ops.neighbor_min_ell(gm.nbr, lab),
                    ops.neighbor_sum_ell(gm.nbr, contrib))

        for f, s in zip(fused(), separate()):
            np.testing.assert_array_equal(np.asarray(f), np.asarray(s))
        us_f = _timed(fused, reps)
        us_s = _timed(separate, reps)
        ratio = us_s / max(us_f, 1e-9)
        rows.append(row(f"kernels/multi/N{gm.N}/Cd{Cd}/fused", us_f,
                        f"fields=3;separate_speedup={ratio:.1f}x;parity=ok"))
        rows.append(row(f"kernels/multi/N{gm.N}/Cd{Cd}/separate", us_s,
                        "fields=3"))

    # ---- fused vs host-synced fixpoint --------------------------------
    for b in ("jnp", "dense", "ell"):
        core_h, steps_h = _hostloop_coreness(g, b)
        t_host = timeit_us(lambda bb=b: jax.block_until_ready(
            _hostloop_coreness(g, bb)[0]), n=reps)
        def fused(bb=b):
            return ops.coreness_blocks(g, backend=bb, with_steps=True)

        core_f, steps_f = fused()
        np.testing.assert_array_equal(np.asarray(core_h), np.asarray(core_f))
        assert int(steps_f) == steps_h, (b, int(steps_f), steps_h)
        t_fused = _timed(lambda: fused()[0], reps)
        rows.append(row(
            f"kernels/coreness/N{g.N}/{b}/fused", t_fused,
            f"steps={int(steps_f)};"
            f"hostloop_speedup={t_host / max(t_fused, 1e-9):.1f}x"))
        rows.append(row(
            f"kernels/coreness/N{g.N}/{b}/hostloop", t_host,
            f"steps={steps_h}"))

    # ---- skew sweep: hub-mirrored vs unsplit fixpoint -----------------
    from . import bench_skew
    rows += bench_skew.kernel_rows(seed=seed, smoke=smoke,
                                   prefix="kernels/skew")
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
